"""Fault tolerance: deadline gossip, straggler robustness, elastic rescale,
and message compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.core import (CompressionState, complete_graph, ef_compress,
                        ef_init, mix_dense, ratio_bytes, ring_graph)
from repro.core.graphs import random_regular_expander
from repro.runtime.elastic import plan_rescale, rescale_state
from repro.runtime.fault_tolerance import StragglerModel, degraded_matrix


@given(seed=st.integers(0, 20))
def test_degraded_matrix_row_stochastic(seed):
    g = random_regular_expander(10, k=4, seed=0)
    rng = np.random.default_rng(seed)
    arrived = rng.random(10) > 0.3
    P = degraded_matrix(g, arrived)
    assert np.allclose(P.sum(axis=1), 1.0, atol=1e-9)
    assert (P >= -1e-12).all()
    # columns of missing nodes are zeroed except self
    for j in range(10):
        if not arrived[j]:
            col = P[:, j].copy()
            col[j] = 0.0
            assert np.allclose(col, 0.0)


def test_consensus_with_random_drops_still_converges():
    """Gossip with 30% dropped messages per round still mixes to (near)
    consensus -- the paper's robustness claim, empirically."""
    g = random_regular_expander(12, k=4, seed=1)
    rng = np.random.default_rng(0)
    z = rng.normal(size=(12, 5)).astype(np.float64)
    for _ in range(300):
        arrived = rng.random(12) > 0.3
        P = degraded_matrix(g, arrived)
        z = P @ z
    assert np.max(np.std(z, axis=0)) < 1e-3


def test_straggler_model_deadline():
    m = StragglerModel(p_slow=0.5, m_slow=8.0, deadline=2.0, seed=0)
    times = m.sample_round(1000)
    assert set(np.unique(times)) <= {1.0, 8.0}
    mask = m.arrival_mask(1000)
    # slow nodes (8.0 > 2.0) miss the deadline
    assert 0.3 < mask.mean() < 0.7


def test_elastic_rescale_shrink_and_grow():
    state = {"w": jnp.arange(8.0).reshape(4, 2)}
    # 4 -> 3 nodes, node 1 failed
    plan = plan_rescale("complete", 4, 3, m_rows=120, failed=[1])
    out = rescale_state(state, plan)
    assert out["w"].shape == (3, 2)
    np.testing.assert_allclose(np.asarray(out["w"][0]), [0, 1])  # node 0
    np.testing.assert_allclose(np.asarray(out["w"][1]), [4, 5])  # node 2
    # grow 3 -> 5: new rows = survivors' mean
    plan2 = plan_rescale("complete", 3, 5, m_rows=120)
    out2 = rescale_state({"w": out["w"]}, plan2)
    assert out2["w"].shape == (5, 2)
    np.testing.assert_allclose(np.asarray(out2["w"][3]),
                               np.asarray(out["w"]).mean(0), rtol=1e-6)
    # data slices cover the whole dataset
    assert sum(s.stop - s.start for s in plan2.data_slices) == 120


def test_straggler_arrival_mask_seeded_determinism():
    """Two models with the same seed draw identical mask sequences (the
    netsim/benchmarks reproducibility contract); a different seed diverges."""
    a = StragglerModel(p_slow=0.3, m_slow=4.0, deadline=2.0, seed=11)
    b = StragglerModel(p_slow=0.3, m_slow=4.0, deadline=2.0, seed=11)
    c = StragglerModel(p_slow=0.3, m_slow=4.0, deadline=2.0, seed=12)
    seq_a = [a.arrival_mask(64) for _ in range(5)]
    seq_b = [b.arrival_mask(64) for _ in range(5)]
    seq_c = [c.arrival_mask(64) for _ in range(5)]
    for ma, mb in zip(seq_a, seq_b):
        np.testing.assert_array_equal(ma, mb)
    assert any(not np.array_equal(ma, mc)
               for ma, mc in zip(seq_a, seq_c))
    # consecutive draws from ONE model advance its stream (not frozen)
    assert any(not np.array_equal(seq_a[0], m) for m in seq_a[1:])


def test_plan_rescale_rejects_out_of_range_failed_ids():
    with pytest.raises(ValueError, match=r"failed ids \[4\] out of range"):
        plan_rescale("complete", 4, 3, m_rows=100, failed=[4])
    with pytest.raises(ValueError, match="out of range"):
        plan_rescale("complete", 4, 3, m_rows=100, failed=[-1])


def test_plan_rescale_rejects_all_failed():
    with pytest.raises(ValueError, match="no survivors"):
        plan_rescale("complete", 3, 3, m_rows=100, failed=[0, 1, 2])


def test_elastic_rescale_grow_from_single_survivor():
    """Degenerate shrink-to-one then grow: every new row must equal the
    lone survivor (its mean is itself)."""
    state = {"w": jnp.arange(6.0).reshape(3, 2)}
    plan = plan_rescale("complete", 3, 4, m_rows=40, failed=[0, 2])
    out = rescale_state(state, plan)
    assert out["w"].shape == (4, 2)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out["w"][i]), [2, 3])


def test_error_feedback_accumulates_everything():
    """Over T rounds, sum(sent) + residual == sum(messages): EF loses
    nothing permanently."""
    rng = np.random.default_rng(0)
    msgs = [jnp.asarray(rng.normal(size=(32,)), jnp.float32)
            for _ in range(10)]
    state = ef_init(msgs[0])
    total_sent = jnp.zeros(32)
    for m in msgs:
        sent, state = ef_compress(m, state, keep_fraction=0.1)
        total_sent = total_sent + sent
    total_msgs = sum(msgs)
    np.testing.assert_allclose(np.asarray(total_sent + state.residual),
                               np.asarray(total_msgs), atol=1e-5)


def test_ratio_bytes():
    assert np.isclose(ratio_bytes(0.01, 4, 4), 0.02)
    assert np.isclose(ratio_bytes(0.05, 8, 4), 0.075)
