"""The differential serving tier: `repro.serve` must be invisible.

A result served from a warm compile cache, or packed into a cross-request
`run_batch` lane, must be BIT-IDENTICAL (exact JSON equality under
`comparable_result_dict`, which strips only wall-clock and serve
bookkeeping) to a cold solo `repro.run()` of the same spec -- the same
equivalence-gate-before-timing discipline the PR 2/5 fast paths shipped
under. Plus: hermetic client->server->result TCP e2e (`-m serve`),
property tests for the cache key and the packer's admission relation,
and the packer/cache units.
"""

import threading

import pytest

import repro
from repro.experiments import ExperimentSpec
from repro.serve import (Client, CompileCache, ExperimentServer, LanePacker,
                         ServeError, cache_signature, comparable_result_dict,
                         lane_key)

from _hyp import HAVE_HYPOTHESIS, given, settings, st


def _spec(**kw):
    base = dict(
        name="serve",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 6, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        T=60, eval_every=20, seed=0, r=0.01, eps_frac=0.05)
    base.update(kw)
    return ExperimentSpec(**base)


def _assert_identical(served, solo, what):
    a, b = comparable_result_dict(served), comparable_result_dict(solo)
    assert a == b, f"{what}: served result differs from solo repro.run()"


# ---------------------------------------------------------------------------
# differential gates (the headline tests)
# ---------------------------------------------------------------------------


def test_warm_cache_run_bit_identical_to_cold_solo():
    """Gate (a): a warm-cache served run round-trips to EXACTLY the cold
    solo result -- and says so in its counters."""
    spec = _spec(name="warm_gate")
    solo = repro.run(spec, backend="dense")
    with ExperimentServer(workers=1, max_wait_s=0.01) as srv:
        cold = srv.submit(spec).result()
        warm = srv.submit(spec).result()
    # exact compare happens on the JSON ROUND-TRIPPED dict: what a client
    # reads from an artifact, not just the in-memory object
    _assert_identical(repro.RunResult.from_json(cold.to_json()), solo,
                      "cold served")
    _assert_identical(repro.RunResult.from_json(warm.to_json()), solo,
                      "warm served")
    assert cold.metrics.counters["cache_miss"] == 1.0
    assert warm.metrics.counters["cache_hit"] == 1.0
    assert warm.metrics.counters["queue_wait_s"] >= 0.0


def test_cross_request_packed_lane_bit_identical_to_solo():
    """Gate (b): specs packed into ONE vmap lane from different requests
    each return results bit-identical to their solo runs."""
    variants = [_spec(name=f"lane{s}", seed=s, r=0.01 * (s + 1))
                for s in range(3)]
    solos = [repro.run(v, backend="dense") for v in variants]
    with ExperimentServer(workers=1, max_width=3, max_wait_s=5.0) as srv:
        futs = [srv.submit(v) for v in variants]  # width 3 == max: flushes
        packed = [f.result(timeout=120) for f in futs]
    for served, solo in zip(packed, solos):
        _assert_identical(repro.RunResult.from_json(served.to_json()),
                          solo, "packed lane")
        assert served.metrics.counters["lane_width"] == 3.0
        assert served.extras["lane_width"] == 3
    st_ = srv.stats()
    assert st_["packer"]["packed_requests"] == 3
    assert st_["packer"]["occupancy"] == 1.0


def test_all_comm_lane_keeps_solo_program_variant():
    """An all-comm spec ("every") must pack only with all-comm peers:
    `run_batch` picks the cond-free program variant from `masks.all()`,
    and mixing variants would break bit-identity with solo runs."""
    every = _spec(name="ac", schedule={"kind": "every"})
    sparse = _spec(name="sp", schedule={"kind": "periodic",
                                        "params": {"h": 2}})
    key_every, _ = lane_key(every, None)
    key_sparse, _ = lane_key(sparse, None)
    assert key_every is not None and key_sparse is not None
    assert key_every != key_sparse  # same shapes, different ac bit
    # and the differential holds end-to-end when both arrive together
    solos = [repro.run(s, backend="dense") for s in (every, sparse)]
    with ExperimentServer(workers=1, max_width=4, max_wait_s=0.2) as srv:
        futs = [srv.submit(s) for s in (every, sparse)]
        served = [f.result(timeout=120) for f in futs]
    for got, solo in zip(served, solos):
        _assert_identical(got, solo, "mixed ac traffic")


def test_compression_splits_lanes_and_cache_entries():
    """`spec.compression` participates in BOTH serving keys: compressed
    and uncompressed specs never share a compile-cache entry or a vmap
    lane (the compressor realizes inside the scanned program), while
    same-compression traffic still packs -- and the served compressed
    result is bit-identical to solo repro.run()."""
    plain = _spec(name="plain")
    topk = _spec(name="topk",
                 compression={"kind": "topk", "params": {"keep": 0.25}})
    topk2 = _spec(name="topk2", seed=1,
                  compression={"kind": "topk", "params": {"keep": 0.25}})
    backend = plain.backends[0]
    assert cache_signature(plain, backend) != cache_signature(topk, backend)
    assert cache_signature(topk, backend) == cache_signature(topk2, backend)
    key_plain, _ = lane_key(plain, None)
    key_topk, _ = lane_key(topk, None)
    key_topk2, _ = lane_key(topk2, None)
    assert key_plain is not None and key_topk is not None
    assert key_plain != key_topk
    assert key_topk == key_topk2  # same compressor still packs
    solo = repro.run(topk, backend="dense")
    with ExperimentServer(workers=1, max_width=4, max_wait_s=0.2) as srv:
        futs = [srv.submit(s) for s in (topk, topk2, plain)]
        served = [f.result(timeout=120) for f in futs]
    _assert_identical(served[0], solo, "compressed spec via server")
    assert served[0].metrics.compression["kind"] == "topk"


def test_adaptive_spec_rides_warm_cache_solo():
    """Satellite: a dense_adaptive (controller) spec is not packable --
    with the stated reason -- but STILL leases the warm simulator, so
    repeat adaptive traffic skips compile too (the run_batch-aware
    DenseController path dispatches AOT executables from the shared
    cache)."""
    spec = _spec(
        name="adaptive",
        schedule={"kind": "adaptive", "params": {"h0": 2}},
        controller={"kind": "dense_adaptive",
                    "params": {"retune_every": 20}})
    key, reason = lane_key(spec, None)
    assert key is None and "controller" in reason
    with ExperimentServer(workers=1, max_wait_s=0.01) as srv:
        cold = srv.submit(spec).result(timeout=180)
        warm = srv.submit(spec).result(timeout=180)
    assert cold.metrics.counters["cache_miss"] == 1.0
    assert warm.metrics.counters["cache_hit"] == 1.0
    assert "controller" in warm.metrics.notes["solo_reason"]
    # adaptive runs are wall-clock-driven (their retune points depend on
    # measured timings), so no bit-identity gate -- but the warm run rides
    # the shared AOT cache: any chunk length the cold run compiled is free
    # (warm may still compile a NEW chunk length if its faster timings
    # retune differently, so assert strictly-less, not zero)
    assert warm.metrics.compile_s < cold.metrics.compile_s


def test_netsim_spec_served_solo_with_reason():
    """Non-dense backends run through the ordinary path, annotated."""
    spec = ExperimentSpec(
        name="net", problem={"kind": "quadratic_consensus",
                             "params": {"n": 8, "d": 4, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "every"},
        backends=[{"kind": "netsim", "params": {"scenario": "homogeneous",
                                                "engine": "vectorized"}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": 0.5}},
        T=30, eval_every=10, seed=0, r=0.01)
    solo = repro.run(spec)
    with ExperimentServer(workers=1, max_wait_s=0.01) as srv:
        served = srv.submit(spec).result(timeout=120)
    _assert_identical(served, solo, "netsim via serve")
    assert "not dense" in served.metrics.notes["solo_reason"]
    assert served.metrics.counters["lane_width"] == 1.0


def test_submit_surfaces_run_errors():
    bad = _spec(name="bad", backends=[{"kind": "dense",
                                       "params": {"bogus": 1}}])
    with ExperimentServer(workers=1, max_wait_s=0.01) as srv:
        fut = srv.submit(bad)
        with pytest.raises(ValueError, match="unknown params"):
            fut.result(timeout=60)
        assert srv.stats()["server"]["errors"] == 1


# ---------------------------------------------------------------------------
# hermetic TCP e2e (tier-1: spawned server, port 0, teardown in finally)
# ---------------------------------------------------------------------------


@pytest.mark.serve
def test_client_server_e2e_localhost():
    """Gate (c): client -> TCP server -> streamed result, hermetically."""
    spec = _spec(name="e2e", T=40, eval_every=5)  # 8 rows: multi-op smoke
    solo = repro.run(spec, backend="dense")
    srv = ExperimentServer(port=0, workers=1, max_wait_s=0.01)
    try:
        host, port = srv.start()
        assert port != 0
        with Client(host, port, timeout=120.0) as client:
            assert client.ping()
            events = []
            served = client.run(spec, backend="dense",
                                on_event=lambda e: events.append(e["event"]))
            assert events[0] == "accepted"
            assert "trace" in events and events[-1] == "result"
            _assert_identical(served, solo, "tcp e2e")
            # the streamed trace reassembled EXACTLY
            assert served.to_dict()["trace"] == solo.to_dict()["trace"]
            warm = client.run(spec, backend="dense")
            assert warm.metrics.counters["cache_hit"] == 1.0
            stats = client.stats()
            assert stats["cache"]["hits"] == 1
            bad = spec.to_dict()
            bad["problem"] = {"kind": "no_such_problem", "params": {}}
            with pytest.raises(ServeError, match="no_such_problem"):
                client.run(bad)
            assert client.ping()  # connection survives a failed run
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# cache + packer units
# ---------------------------------------------------------------------------


def test_compile_cache_lease_lru_and_concurrency():
    cache = CompileCache(max_entries=2)
    built = []

    def factory(tag):
        def make():
            built.append(tag)
            return {"sim": tag}
        return make

    s1, s2, s3 = (_spec(T=t) for t in (10, 20, 30))  # distinct signatures
    b = s1.backends[0]
    out = {}

    def contend():
        with cache.lease(s1, b, factory("a2")) as (sim2, hit2):
            out["sim"], out["hit"] = sim2, hit2

    with cache.lease(s1, b, factory("a")) as (sim, hit):
        assert sim == {"sim": "a"} and not hit
        # same signature, concurrent: blocks on the entry lock (leases
        # are exclusive), then hits the already-built simulator
        thread = threading.Thread(target=contend)
        thread.start()
        thread.join(timeout=0.2)
        assert out == {}  # still waiting: the lease is exclusive
    thread.join(timeout=30)
    assert out == {"sim": {"sim": "a"}, "hit": True}  # built once, shared
    assert built == ["a"]
    with cache.lease(s2, b, factory("b")) as _:
        pass
    with cache.lease(s3, b, factory("c")) as _:  # capacity 2: evicts LRU
        pass
    assert cache.stats()["entries"] == 2
    assert cache.stats()["evictions"] == 1
    with cache.lease(s1, b, factory("a3")) as (sim, hit):
        assert not hit and sim == {"sim": "a3"}  # s1 was the LRU victim


def test_lane_packer_admission_policy():
    now = [0.0]
    packer = LanePacker(max_width=2, max_wait_s=1.0, clock=lambda: now[0])
    packer.admit("k1", "a")
    assert packer.pop_ready() == []  # neither full nor expired
    packer.admit("k1", "b")  # hits max_width
    lanes = packer.pop_ready()
    assert [lane.items for lane in lanes] == [["a", "b"]]
    packer.admit("k2", "c")
    assert packer.next_deadline() == 1.0
    now[0] = 2.0
    lanes = packer.pop_ready()  # expired at width 1
    assert [lane.items for lane in lanes] == [["c"]]
    packer.admit("k3", "d")
    assert [lane.items for lane in packer.flush()] == [["d"]]
    stats = packer.stats()
    assert stats["lanes_flushed"] == 3
    assert stats["packed_requests"] == 2
    assert stats["occupancy"] == pytest.approx(4 / 6)


# ---------------------------------------------------------------------------
# property tests: cache key + admission relation
# ---------------------------------------------------------------------------

_IRRELEVANT = st.fixed_dictionaries({
    "seed": st.integers(0, 2**31 - 1),
    "r": st.floats(0.0, 10.0, allow_nan=False),
    "eps_frac": st.one_of(st.none(), st.floats(0.001, 0.5)),
    "name": st.text(
        st.characters(whitelist_categories=("Lu", "Ll", "Nd")),
        min_size=1, max_size=12),
})

#: shape-relevant axes and values: every pair of DISTINCT values within a
#: field must produce distinct signatures (problem seed included -- the
#: problem's data arrays are baked into the XLA program as constants)
_RELEVANT_VALUES = {
    "problem.params.n": [4, 8, 12],
    "problem.params.d": [2, 6, 10],
    "problem.params.seed": [0, 1, 2],
    "problem.kind": ["quadratic_consensus", "nonsmooth"],
    "topology.params.k": [2, 4],
    "schedule.kind": ["every", "periodic", "sparse"],
    "stepsize.params.A": [0.25, 0.5, 1.0],
    "T": [20, 40, 60],
    "eval_every": [10, 20],
    # compression realizes inside the compiled program (support masks,
    # quantization) and scales the time axis: never share a lane across it
    "compression": [None,
                    {"kind": "topk", "params": {"keep": 0.25}},
                    {"kind": "topk", "params": {"keep": 0.5}},
                    {"kind": "randk", "params": {"keep": 0.25}},
                    {"kind": "int8", "params": {}}],
}
_RELEVANT_AXES = {axis: st.sampled_from(vals)
                  for axis, vals in _RELEVANT_VALUES.items()}


@settings(max_examples=50, deadline=None)
@given(a=_IRRELEVANT, b=_IRRELEVANT)
def test_cache_key_ignores_cache_irrelevant_fields(a, b):
    base = _spec()
    backend = base.backends[0]
    specs = []
    for fields in (a, b):
        s = base
        for axis, v in fields.items():
            s = s.with_value(axis, v)
        specs.append(s)
    assert cache_signature(specs[0], backend) == \
        cache_signature(specs[1], backend)


@settings(max_examples=50, deadline=None)
@given(axis=st.sampled_from(sorted(_RELEVANT_AXES)), data=st.data())
def test_cache_key_separates_shape_relevant_fields(axis, data):
    strat = _RELEVANT_AXES[axis]
    v1 = data.draw(strat)
    v2 = data.draw(strat.filter(lambda v: v != v1))
    base = _spec()
    backend = base.backends[0]
    s1, s2 = base.with_value(axis, v1), base.with_value(axis, v2)
    assert cache_signature(s1, backend) != cache_signature(s2, backend)


@settings(max_examples=25, deadline=None)
@given(pool=st.lists(
    st.fixed_dictionaries({
        "seed": st.integers(0, 3),
        "r": st.sampled_from([0.0, 0.01]),
        "T": st.sampled_from([20, 40]),
        "schedule": st.sampled_from([
            {"kind": "every"},
            {"kind": "periodic", "params": {"h": 2}},
            {"kind": "periodic", "params": {"h": 4}},
        ]),
    }), min_size=2, max_size=6))
def test_packer_admission_is_symmetric_and_transitive(pool):
    """The admission predicate (equal non-None lane keys) is an
    equivalence relation over any generated spec pool, so lanes are
    well-defined partitions -- no ordering effects in what packs."""
    specs = [_spec(name=f"p{i}", **fields) for i, fields in enumerate(pool)]
    keys = [lane_key(s, None)[0] for s in specs]

    def compat(i, j):
        return (keys[i] is not None and keys[j] is not None
                and keys[i] == keys[j])

    idx = range(len(specs))
    for i in idx:
        assert compat(i, i) or keys[i] is None  # reflexive when packable
        for j in idx:
            assert compat(i, j) == compat(j, i)  # symmetric
            for k in idx:
                if compat(i, j) and compat(j, k):
                    assert compat(i, k)  # transitive


@pytest.mark.parametrize("axis", sorted(_RELEVANT_VALUES))
def test_cache_key_axis_inventory(axis):
    """Non-hypothesis floor under the property tests: for every declared
    shape-relevant axis, pairwise-distinct values give pairwise-distinct
    signatures (so the strategies above cannot silently test nothing)."""
    base = _spec()
    backend = base.backends[0]
    sigs = [cache_signature(base.with_value(axis, v), backend)
            for v in _RELEVANT_VALUES[axis]]
    assert len(set(sigs)) == len(sigs), axis
