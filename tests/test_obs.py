"""repro.obs: tracer, metrics registry, exporters, and their integration
with every backend `repro.run()` dispatches to.

The load-bearing contracts:

  * every backend returns a populated `RunResult.metrics` whose
    compile_s + execute_s equals wall_s exactly (the JSON back-compat
    invariant: wall_s stays the lump sum);
  * `RunMetrics` round-trips exactly through the strict-RFC JSON path;
  * detail tracing is observational -- traced runs are bit-identical to
    untraced ones (the engines' single-branch hook contract);
  * the Chrome-trace exporter never mixes the host and sim clocks in one
    Perfetto process;
  * checked-in BENCH_*.json files carry the full warm-run sample arrays
    and schema-valid metrics blocks.
"""

import json
import math
import pathlib

import numpy as np
import pytest

from repro.experiments import ExperimentSpec, run
from repro.experiments.result import RunResult
from repro.obs import (METRICS_VERSION, RunMetrics, Tracer,
                       chrome_trace_events, profile_ctx, render_summary,
                       sample_quantiles, write_chrome_trace,
                       write_json_artifact, write_jsonl)

REPO = pathlib.Path(__file__).resolve().parents[1]


def _tiny_spec(**overrides):
    """Small, fast quadratic-consensus spec shared by the backend tests."""
    base = dict(
        name="obs_tiny",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 6, "d": 4, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "every"},
        backends=[{"kind": "dense"}],
        T=40, eval_every=10, seed=0, r=0.05)
    base.update(overrides)
    return ExperimentSpec(**base)


# -- tracer ------------------------------------------------------------------


def test_tracer_spans_counters_series_and_phase_totals():
    tr = Tracer()
    with tr.span("build"):
        pass
    tr.add_host_span("execute", 1.0, 2.0)
    tr.add_host_span("execute", 3.0, 0.5)
    tr.add_span("step", 0.0, 0.125, track="node0")        # sim clock
    tr.add_instant("retune", 5.0, track="controller")
    tr.count("msgs", 10)
    tr.count("msgs", 5)
    tr.record_series("r_hat", 1.0, 0.05)
    totals = tr.phase_totals()
    assert totals["execute"] == {"total_s": 2.5, "count": 2}
    assert "step" not in totals          # sim-clock events are not phases
    assert "retune" not in totals        # instants are not phases
    assert tr.counters["msgs"] == 15
    assert tr.series["r_hat"] == [(1.0, 0.05)]


def test_tracer_caps_events_and_counts_drops():
    tr = Tracer(max_events=3)
    for i in range(10):
        tr.add_span("step", float(i), 1.0)
    assert len(tr.events) == 3
    assert tr.events_dropped == 7
    tr.count("c", 1.0)  # counters are never dropped
    assert tr.counters["c"] == 1.0


def test_tracer_batch_spans_match_singles():
    a, b = Tracer(), Tracer()
    t0s, durs = [0.0, 1.0, 2.5], [0.5, 0.25, 1.0]
    a.add_spans("step", t0s, durs, tracks=["n0", "n1", "n2"])
    for t0, dur, trk in zip(t0s, durs, ["n0", "n1", "n2"]):
        b.add_span("step", t0, dur, track=trk)
    assert [(e.name, e.t0, e.dur, e.track) for e in a.events] \
        == [(e.name, e.t0, e.dur, e.track) for e in b.events]


# -- metrics -----------------------------------------------------------------


def test_sample_quantiles_shape_and_empty():
    q = sample_quantiles([1.0, 2.0, 3.0, 4.0], "host")
    assert q["n"] == 4 and q["unit"] == "host"
    assert q["p50"] == pytest.approx(2.5)
    assert q["max"] == 4.0
    assert sample_quantiles([], "sim") is None


def test_runmetrics_round_trips_exactly():
    m = RunMetrics(
        compile_s=0.5, execute_s=1.25, eval_s=0.01, msgs=120,
        bytes_on_wire=3e4, drops=7, gossip_rounds=40, retunes=1,
        retune_history=[(3.0, 2, 1.362, 0.05, 0.4)], r_hat=0.05,
        r_hat_trajectory=[(1.0, 0.04), (2.0, 0.05)],
        step_time_quantiles={"p50": 0.1, "p90": 0.2, "p99": 0.3,
                             "max": 0.4, "n": 10, "unit": "sim"},
        phases={"execute": {"total_s": 1.25, "count": 1}},
        counters={"msgs": 120.0})
    d = m.to_dict()
    assert d["metrics_version"] == METRICS_VERSION
    # the dict must be strict-RFC serializable and loadable
    m2 = RunMetrics.from_dict(json.loads(json.dumps(d, allow_nan=False)))
    assert m2 == m


def test_runmetrics_rejects_bad_version_and_unknown_fields():
    d = RunMetrics().to_dict()
    bad = dict(d, metrics_version=99)
    with pytest.raises(ValueError, match="metrics_version"):
        RunMetrics.from_dict(bad)
    bad = dict(d, not_a_field=1)
    with pytest.raises(ValueError, match="unknown"):
        RunMetrics.from_dict(bad)


def test_runmetrics_from_tracer_inherits_aggregates():
    tr = Tracer()
    with tr.span("build"):
        pass
    tr.count("msgs", 3)
    tr.record_series("r_hat", 2.0, 0.1)
    m = RunMetrics.from_tracer(tr, execute_s=1.0)
    assert "build" in m.phases
    assert m.counters["msgs"] == 3
    assert m.r_hat_trajectory == ((2.0, 0.1),)


# -- exporters ---------------------------------------------------------------


def _traced_tracer():
    tr = Tracer(detail=True)
    with tr.span("execute"):
        pass
    tr.add_span("flight", 1.0, 0.05, track="net", src=0, dst=1)
    tr.add_instant("drop", 2.0, track="net")
    tr.count("msgs", 4)
    tr.record_series("r_hat", 1.0, 0.05)
    return tr


def test_chrome_trace_keeps_clocks_in_separate_pids(tmp_path):
    tr = _traced_tracer()
    events = chrome_trace_events(tr, run_name="t")
    host = [e for e in events if e.get("ph") == "X" and e["pid"] == 1]
    sim = [e for e in events if e.get("ph") in "Xi" and e["pid"] == 2]
    assert host and sim
    assert {e["name"] for e in host} == {"execute"}
    assert {e["name"] for e in sim} == {"flight", "drop"}
    # counters land as terminal "C" samples
    assert any(e["ph"] == "C" and e["name"] == "msgs" for e in events)
    path = write_chrome_trace(tr, tmp_path / "t.trace.json", run_name="t")
    payload = json.loads(pathlib.Path(path).read_text())
    assert payload["traceEvents"] == json.loads(json.dumps(events))
    assert payload["otherData"]["series"]["r_hat"] == [[1.0, 0.05]]


def test_jsonl_export_round_trips_the_event_stream(tmp_path):
    tr = _traced_tracer()
    path = write_jsonl(tr, tmp_path / "t.trace.jsonl")
    recs = [json.loads(line)
            for line in pathlib.Path(path).read_text().splitlines()]
    kinds = [r["kind"] for r in recs]
    assert kinds.count("span") == 2 and kinds.count("instant") == 1
    assert {"counter", "series"} <= set(kinds)
    flight = next(r for r in recs if r["name"] == "flight")
    assert flight["clock"] == "sim" and flight["args"] == {"src": 0, "dst": 1}


def test_write_json_artifact_sanitizes_nonfinite(tmp_path):
    path = write_json_artifact(tmp_path / "sub" / "a.json",
                               {"x": math.inf, "y": np.float64(2.0)})
    loaded = json.loads(pathlib.Path(path).read_text())
    assert loaded == {"x": None, "y": 2.0}


# -- backend integration -----------------------------------------------------


def test_dense_run_populates_metrics_and_wall_split():
    res = run(_tiny_spec())
    m = res.metrics
    assert m is not None
    assert m.compile_s + m.execute_s == pytest.approx(res.wall_s, abs=1e-12)
    assert m.gossip_rounds == 40           # every-iteration schedule
    assert m.msgs == 40 * 6 * 4            # rounds * n * k
    assert m.bytes_on_wire == m.msgs * 4 * 4.0
    assert {"build"} <= set(m.phases)
    assert "device_execute_s" in m.counters


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_netsim_run_populates_metrics(engine):
    spec = _tiny_spec(backends=[{"kind": "netsim",
                                 "params": {"scenario": "lossy",
                                            "loss": 0.2,
                                            "engine": engine}}])
    res = run(spec)
    m = res.metrics
    assert m.compile_s == 0.0
    assert m.execute_s == pytest.approx(res.wall_s, abs=1e-12)
    assert m.msgs == res.extras["sent"] > 0
    assert m.drops == res.extras["drops"] > 0
    assert m.bytes_on_wire > 0
    assert m.step_time_quantiles["unit"] == "sim"
    assert m.step_time_quantiles["n"] == 6 * 40


def test_netsim_detail_tracing_is_bit_identical_and_populates_events():
    spec = _tiny_spec(backends=[{"kind": "netsim",
                                 "params": {"scenario": "lossy",
                                            "loss": 0.2}}])
    plain = run(spec)
    tr = Tracer(detail=True)
    traced = run(spec, tracer=tr)
    for field in ("iters", "sim_time", "fvals", "fvals_consensus",
                  "comms", "disagreement"):
        assert getattr(plain.trace, field) == getattr(traced.trace, field)
    names = {e.name for e in tr.events if e.clock == "sim"}
    assert {"step", "flight", "drop", "eval"} <= names


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_netsim_detail_timeline_mirrors_observability_lists(engine):
    """Each engine's emitted detail timeline must describe exactly the
    events its (bit-identity-regression-tested) observability lists
    record: every kept flight becomes one span, every local step one span,
    every drop is accounted (the vectorized engine batches drops into
    per-ship instants carrying a count)."""
    from repro.netsim import NetSimulator
    from repro.netsim.scenarios import lossy
    from repro.netsim.problems import quadratic_consensus

    n, d, T = 6, 4, 40
    _centers, grad_fn, eval_fn = quadratic_consensus(n, d, seed=0)
    tr = Tracer(detail=True)
    sim = NetSimulator(lossy(n, 0.05, loss=0.2), grad_fn, eval_fn,
                       seed=0, engine=engine, tracer=tr)
    sim.run(np.zeros((n, d)), T, eval_every=10)

    flights = sorted(e.dur for e in tr.events if e.name == "flight")
    assert flights == sorted(sim.msg_flights)
    steps = sorted(e.dur for e in tr.events if e.name == "step")
    assert steps == pytest.approx(sorted(sim.compute_times))
    drop_events = [e for e in tr.events if e.name == "drop"]
    dropped = sum(e.args.get("count", 1) for e in drop_events)
    assert dropped == sim.drops > 0


def test_adaptive_netsim_metrics_carry_retunes_and_trajectory():
    spec = ExperimentSpec.from_file(
        REPO / "benchmarks" / "manifests" / "adaptive_adversarial.json")
    res = run(spec)  # first declared backend: the adversarial netsim cell
    m = res.metrics
    assert m.retunes == len(m.retune_history) == len(res.extras["retunes"])
    assert m.r_hat == res.extras["r_hat"]
    assert len(m.r_hat_trajectory) > 0
    # trajectory times are on the sim clock, monotonically nondecreasing
    ts = [t for t, _ in m.r_hat_trajectory]
    assert ts == sorted(ts)
    assert "rtracker.messages_observed" in m.counters


def test_launch_dryrun_populates_metrics():
    spec = ExperimentSpec.from_file(
        REPO / "benchmarks" / "manifests" / "launch_dryrun.json")
    res = run(spec)
    m = res.metrics
    assert m.compile_s > 0.0               # the AOT compile walls
    assert m.compile_s + m.execute_s == pytest.approx(res.wall_s, abs=1e-9)
    assert m.msgs == 0                     # dryrun runs zero steps
    assert any(p.startswith("compile:") for p in m.phases)


def test_result_json_round_trips_metrics():
    res = run(_tiny_spec())
    d = json.loads(res.to_json())
    res2 = RunResult.from_dict(d)
    assert res2.metrics == res.metrics
    # pre-metrics artifacts stay loadable (back-compat)
    d.pop("metrics")
    assert RunResult.from_dict(d).metrics is None


def test_render_summary_shows_phases_and_counters():
    res = run(_tiny_spec())
    text = render_summary(json.loads(res.to_json()))
    assert "backend=dense" in text
    assert "compile" in text and "execute" in text
    assert "msgs" in text
    assert "r̂ vs r:" in text


def test_render_summary_premetrics_artifact():
    text = render_summary({"spec": {"name": "old"},
                           "backend": {"kind": "dense"}, "wall_s": 1.0})
    assert "predates repro.obs" in text


# -- profiling hook ----------------------------------------------------------


def test_profile_ctx_none_is_noop():
    with profile_ctx(None):
        pass


def test_profile_dir_rejected_off_dense(tmp_path):
    spec = _tiny_spec(profile_dir=str(tmp_path),
                      backends=[{"kind": "netsim"}])
    with pytest.raises(ValueError, match="profile_dir"):
        run(spec)


def test_dense_profile_dir_captures_a_device_trace(tmp_path):
    run(_tiny_spec(profile_dir=str(tmp_path)))
    files = [p for p in tmp_path.rglob("*") if p.is_file()]
    assert files, "jax.profiler produced no trace files"


# -- CLI ---------------------------------------------------------------------


def test_cli_run_writes_traces_and_trace_renders(tmp_path, capsys):
    from repro.experiments.__main__ import main

    manifest = tmp_path / "tiny.json"
    manifest.write_text(_tiny_spec().to_json())
    out = tmp_path / "out"
    assert main(["run", str(manifest), "--out", str(out)]) == 0
    result_path = out / "obs_tiny__dense.json"
    trace_path = out / "obs_tiny__dense.trace.json"
    jsonl_path = out / "obs_tiny__dense.trace.jsonl"
    assert result_path.exists() and trace_path.exists() and jsonl_path.exists()
    payload = json.loads(trace_path.read_text())
    assert payload["traceEvents"], "trace must carry events"
    assert {e["pid"] for e in payload["traceEvents"]} >= {1}
    capsys.readouterr()
    assert main(["trace", str(result_path)]) == 0
    text = capsys.readouterr().out
    assert "phases:" in text and "counters:" in text


def test_cli_trace_reports_unreadable_file(tmp_path, capsys):
    from repro.experiments.__main__ import main

    assert main(["trace", str(tmp_path / "missing.json")]) == 2
    assert "cannot read" in capsys.readouterr().out


# -- checked-in bench artifacts ----------------------------------------------


def _bench_paths():
    return sorted(REPO.glob("BENCH_*.json"))


def _assert_quantiles(q, n_samples):
    assert q["n"] == n_samples and q["unit"] == "host"
    assert q["p50"] <= q["p90"] <= q["p99"] <= q["max"]


@pytest.mark.parametrize("path", _bench_paths(), ids=lambda p: p.stem)
def test_checked_in_bench_files_are_schema_valid(path):
    """Every checked-in BENCH_*.json must be strict-RFC JSON carrying the
    full warm-run sample arrays and their quantiles: per result cell with
    a version-1 RunMetrics block (throughput benches), or per latency
    spec plus the equivalence/acceptance gates (the serve bench — schema
    in benchmarks/README.md)."""
    raw = path.read_text()
    assert "NaN" not in raw and "Infinity" not in raw
    report = json.loads(raw)
    for key in ("benchmark", "mode", "config", "host"):
        assert key in report, f"{path.name} missing {key!r}"

    if report["benchmark"] == "serve":
        assert report["equivalence"]["ok"] is True
        lat = report["latency"]
        warm_total = 0
        for cell in lat["per_spec"]:
            samples = cell["warm_samples_s"]
            assert samples and all(s >= 0 for s in samples)
            assert cell["cold_s"] >= 0
            warm_total += len(samples)
        _assert_quantiles(lat["cold_quantiles"], len(lat["per_spec"]))
        _assert_quantiles(lat["warm_quantiles"], warm_total)
        thr = report["throughput"]
        assert thr["specs_per_sec"] > 0
        assert 0.0 <= thr["lanes"]["occupancy"] <= 1.0
        assert 0.0 <= thr["cache"]["hit_rate"] <= 1.0
        acc = report["acceptance"]
        assert acc["pass"] and acc["measured"] >= 0
        return

    assert report["results"], f"{path.name} has no result cells"
    for cell in report["results"]:
        samples = cell["wall_samples_s"]
        assert samples and all(s >= 0 for s in samples)
        _assert_quantiles(cell["wall_quantiles"], len(samples))
        m = RunMetrics.from_dict(cell["metrics"])  # schema-validates
        assert m.compile_s >= 0.0 and m.execute_s >= 0.0
