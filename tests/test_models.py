"""Per-architecture smoke tests (reduced configs, CPU): forward/train step
shape + finiteness, and decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, get_config, get_shapes, transformer
from repro.models.common import cross_entropy_loss


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch, "smoke")
    params, axes = transformer.init(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["enc"] = jnp.ones((B, cfg.num_encoder_tokens, cfg.encoder_dim),
                                cfg.dtype)
    logits = transformer.forward(params, tokens, cfg, enc=batch.get("enc"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shapes_assigned(arch):
    shapes = get_shapes(arch)
    assert set(shapes) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    cfg = get_config(arch, "full")
    long_cell = shapes["long_500k"]
    if cfg.supports_long_context:
        assert long_cell.skip is None
    else:
        assert long_cell.skip  # skip documented for full-attention archs


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b",
                                  "falcon-mamba-7b", "zamba2-2.7b",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_forward(arch, key):
    """Token-by-token decode must reproduce the teacher-forced forward
    logits (same prefix) -- validates every cache implementation (GQA, MLA,
    conv+SSM states, cross-attn, shared-attn)."""
    import dataclasses
    cfg = get_config(arch, "smoke")
    if cfg.moe_experts:
        # capacity-dropping differs between batch prefill and per-token
        # decode by design; use a drop-free capacity for the equivalence
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(
            cfg.moe_experts))
    params, _ = transformer.init(key, cfg)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                cfg.vocab_size)
    enc = None
    if cfg.family == "vlm":
        enc = jax.random.normal(
            key, (B, cfg.num_encoder_tokens, cfg.encoder_dim)).astype(cfg.dtype)
    full_logits = transformer.forward(params, tokens, cfg, enc=enc)

    cache = transformer.init_cache(cfg, B, S, jnp.float32)
    if cfg.family == "vlm":
        cache = _prefill_cross_cache(params, cache, cfg, enc)
    outs = []
    for pos in range(S):
        lg, cache = transformer.decode_step(params, cache,
                                            tokens[:, pos:pos + 1],
                                            jnp.int32(pos), cfg)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=0.13, rtol=0.1)


def _prefill_cross_cache(params, cache, cfg, enc):
    """Fill cross-attention encoder K/V (normally done at prefill)."""
    from repro.models import transformer as T

    def fill(slot_params_stacked, slot_cache, kind):
        if kind != "cross_attn":
            return slot_cache
        def one(prm, c):
            k = jnp.einsum("bne,ehk->bnhk", enc, prm["attn"]["wk"])
            v = jnp.einsum("bne,ehk->bnhk", enc, prm["attn"]["wv"])
            return {"ek": k.astype(c["ek"].dtype),
                    "ev": v.astype(c["ev"].dtype)}
        return jax.vmap(one)(slot_params_stacked, slot_cache)

    new_stack = {}
    for i, kind in enumerate(cfg.superblock):
        new_stack[f"slot{i}"] = fill(params["stack"][f"slot{i}"],
                                     cache["stack"][f"slot{i}"], kind)
    cache = dict(cache)
    cache["stack"] = new_stack
    return cache


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10))
    labels = jnp.array([[1, 2, -1, -1]])
    loss = cross_entropy_loss(logits, labels)
    assert np.isclose(float(loss), np.log(10), rtol=1e-5)


def test_moe_capacity_overflow_drops_gracefully():
    """With capacity_factor << 1 most assignments drop; output stays finite
    (dropped tokens contribute zero, not NaN)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("llama4-maverick-400b-a17b",
                                         "smoke"), moe_capacity_factor=0.05)
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits = transformer.forward(params, tokens, cfg)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_param_counts_match_published():
    import math
    expected = {"llama3-8b": 8.0e9, "qwen1.5-110b": 111e9,
                "deepseek-v2-236b": 236e9,
                "llama4-maverick-400b-a17b": 400e9,
                "falcon-mamba-7b": 7.3e9}
    for arch, want in expected.items():
        cfg = get_config(arch, "full")
        box = []

        def build(k, cfg=cfg):
            p, _ = transformer.init(k, cfg)
            return p

        tree = jax.eval_shape(build, jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
        assert abs(n - want) / want < 0.06, (arch, n, want)
