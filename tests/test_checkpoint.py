"""Checkpoint manager: roundtrip (incl. bf16), keep-k rotation, atomicity,
resume."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8), jnp.bfloat16),
            "m": jax.random.normal(k, (4, 8), jnp.float32),
            "step": jnp.asarray(7, jnp.int32),
            "nested": {"b": jnp.ones((3,), jnp.float32)}}


def test_roundtrip_with_bf16(tmp_path):
    tree = _tree()
    save_tree(tmp_path / "ck", tree, extra={"note": "hi"})
    restored, extra = restore_tree(tmp_path / "ck", tree)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_commit_marker_required(tmp_path):
    tree = _tree()
    save_tree(tmp_path / "ck", tree)
    (tmp_path / "ck" / "COMMIT").unlink()
    with pytest.raises(FileNotFoundError):
        restore_tree(tmp_path / "ck", tree)


def test_manager_keep_k_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step), extra={"step": step}, blocking=True)
    steps = [s for s, _ in mgr._step_dirs()]
    assert steps == [20, 30]
    got = mgr.restore_latest(_tree())
    assert got is not None
    step, tree, extra = got
    assert step == 30 and extra["step"] == 30


def test_manager_empty_dir(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_tree()) is None


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1
