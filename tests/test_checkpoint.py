"""Checkpoint manager: roundtrip (incl. bf16), keep-k rotation, atomicity,
resume."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8), jnp.bfloat16),
            "m": jax.random.normal(k, (4, 8), jnp.float32),
            "step": jnp.asarray(7, jnp.int32),
            "nested": {"b": jnp.ones((3,), jnp.float32)}}


def test_roundtrip_with_bf16(tmp_path):
    tree = _tree()
    save_tree(tmp_path / "ck", tree, extra={"note": "hi"})
    restored, extra = restore_tree(tmp_path / "ck", tree)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_commit_marker_required(tmp_path):
    tree = _tree()
    save_tree(tmp_path / "ck", tree)
    (tmp_path / "ck" / "COMMIT").unlink()
    with pytest.raises(FileNotFoundError):
        restore_tree(tmp_path / "ck", tree)


def test_manager_keep_k_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step), extra={"step": step}, blocking=True)
    steps = [s for s, _ in mgr._step_dirs()]
    assert steps == [20, 30]
    got = mgr.restore_latest(_tree())
    assert got is not None
    step, tree, extra = got
    assert step == 30 and extra["step"] == 30


def test_manager_empty_dir(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_tree()) is None


def test_injected_partial_write_never_trusted(tmp_path, monkeypatch):
    """A writer that dies mid-save (after the data files, before COMMIT)
    must leave the previous committed checkpoint as the restore source;
    the torn directory is never trusted and a later save heals over it."""
    import repro.checkpoint.manager as M

    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(1), extra={"step": 1}, blocking=True)

    real = M._write_atomic

    def dying(path, writer):
        if path.name == "COMMIT":
            # simulate the crash window: content landed, marker did not --
            # only the .part temp exists, never the committed file
            writer(path.with_name(path.name + ".part"))
            raise RuntimeError("simulated crash mid-save")
        return real(path, writer)

    monkeypatch.setattr(M, "_write_atomic", dying)
    # save_tree directly (the manager's worker thread would swallow the
    # injected exception into a warning; the on-disk effect is identical)
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_tree(tmp_path / "step_2", _tree(2), extra={"step": 2})
    monkeypatch.setattr(M, "_write_atomic", real)

    # the torn save is invisible: latest committed is still step 1
    assert mgr.latest_step() == 1
    step, tree, extra = mgr.restore_latest(_tree())
    assert step == 1 and extra["step"] == 1
    for a, b in zip(jax.tree.leaves(_tree(1)), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # restoring the torn directory directly refuses loudly
    torn = [p for p in tmp_path.iterdir() if "2" in p.name]
    for p in torn:
        with pytest.raises(FileNotFoundError):
            restore_tree(p, _tree())
    # a healthy save heals over the wreckage
    mgr.save(2, _tree(2), extra={"step": 2}, blocking=True)
    assert mgr.latest_step() == 2


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# concurrent writers (real multiprocess contention on one directory)
# ---------------------------------------------------------------------------

_WRITER = """
import sys
import numpy as np
from repro.checkpoint import CheckpointManager

d, start, stop, stride = sys.argv[1], *map(int, sys.argv[2:5])
mgr = CheckpointManager(d, keep=3)
for step in range(start, stop, stride):
    mgr.save(step, {"w": np.full((16,), step, dtype=np.float32)},
             extra={"step": step}, blocking=True)
"""


def test_two_processes_checkpoint_same_dir_safely(tmp_path):
    """Two real processes interleave keep-3 rotating saves into ONE
    directory. Neither may crash on the other's deletions (the seed's
    rotation died with FileNotFoundError here), and both writers' newest
    snapshots must survive committed and restorable."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path("src").resolve())
    env.setdefault("JAX_PLATFORMS", "cpu")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WRITER, str(tmp_path), str(start), "20", "2"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        for start in (0, 1)]
    for p in procs:
        _, err = p.communicate(timeout=300)
        assert p.returncode == 0, err.decode()

    mgr = CheckpointManager(tmp_path, keep=3)
    assert mgr.latest_step() == 19
    # each writer's final snapshot (18 even, 19 odd) is still committed
    # and yields exactly the bytes that writer saved
    for step in (18, 19):
        like = {"w": np.zeros((16,), np.float32)}
        tree, extra = restore_tree(tmp_path / f"step_{step}", like)
        assert extra["step"] == step
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.full((16,), step, np.float32))
    # no stray staging dirs survive the contention
    assert not list(tmp_path.glob("*.tmp-*"))


@pytest.mark.filterwarnings(
    # the deliberately-failed np.savez leaves a ZipFile whose __del__
    # grumbles at GC; the failure itself is the point of the test
    "ignore::pytest.PytestUnraisableExceptionWarning")
def test_background_save_errors_surface_on_wait(tmp_path):
    """A failed async save must not die silently on the worker thread:
    wait() re-raises it (once), and the manager recovers after."""
    import gc

    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, {"w": lambda: 1})  # npz cannot pickle a lambda leaf
    with pytest.raises(Exception):
        mgr.wait()
    mgr.wait()  # error is consumed, not sticky
    mgr.save(2, _tree(2), blocking=True)
    assert mgr.latest_step() == 2
    # collect the failed save's dead ZipFile HERE, while this test's
    # warning filter is active, instead of during some later test
    gc.collect()
