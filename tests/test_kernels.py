"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret=True executes the kernel body in
Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref


KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("S,D,H,KH", [
    (128, 64, 4, 4),    # MHA
    (256, 64, 8, 2),    # GQA 4x
    (256, 128, 4, 1),   # MQA
    (512, 32, 2, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, D, H, KH, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, H, S, D), dtype)
    k = jax.random.normal(ks[1], (2, KH, S, D), dtype)
    v = jax.random.normal(ks[2], (2, KH, S, D), dtype)
    out = ops.flash_attention(q, k, v, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=atol * 10)


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("S,d,N", [(256, 128, 8), (512, 256, 16),
                                   (256, 512, 16)])
def test_selective_scan_sweep(S, d, N):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, S, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, S, d)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (d, N)) * 0.3)
    B = jax.random.normal(ks[3], (2, S, N)) * 0.5
    C = jax.random.normal(ks[4], (2, S, N)) * 0.5
    Dk = jnp.ones((d,))
    y = ops.selective_scan(x, dt, A, B, C, Dk, interpret=True)
    ye = ref.selective_scan_ref(x, dt, A, B, C, Dk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               atol=5e-4, rtol=2e-3)


@pytest.mark.parametrize("S,H,P,N,chunk", [
    (256, 4, 32, 16, 128), (512, 2, 64, 64, 128), (128, 8, 64, 32, 64)])
def test_ssd_scan_sweep(S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (2, S, N)) * 0.5
    C = jax.random.normal(ks[4], (2, S, N)) * 0.5
    from repro.kernels.ssd_scan import ssd_scan
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    ye = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               atol=5e-4, rtol=2e-3)


def test_ssd_kernel_matches_model_mixer():
    """The Pallas SSD kernel agrees with the model's chunked XLA
    implementation (repro.models.ssm._ssd_chunk path)."""
    import dataclasses
    from repro.models import get_config
    from repro.models import ssm as ssm_mod
    cfg = get_config("zamba2-2.7b", "smoke")
    d_inner, nheads = ssm_mod._m2_dims(cfg)
    S = 64
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (2, S, nheads, cfg.ssm_head_dim)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, S, nheads)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (nheads,)) * 0.3)
    B = jax.random.normal(ks[3], (2, S, cfg.ssm_state)) * 0.5
    C = jax.random.normal(ks[0], (2, S, cfg.ssm_state)) * 0.5
    from repro.kernels.ssd_scan import ssd_scan
    y_kernel = ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    h0 = jnp.zeros((2, nheads, cfg.ssm_head_dim, cfg.ssm_state))
    _, y_model = ssm_mod._ssd_chunk(h0, x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-4, rtol=2e-3)


@given(m=st.integers(1, 50000), k=st.integers(1, 6),
       sw=st.floats(0.05, 0.9))
@settings(max_examples=10)
def test_gossip_mix_hypothesis(m, k, sw):
    ks = jax.random.split(jax.random.PRNGKey(m % 97), 2)
    sb = jax.random.normal(ks[0], (m,), jnp.float32)
    nb = jax.random.normal(ks[1], (k, m), jnp.float32)
    ew = (1.0 - sw) / k
    out = ops.gossip_mix(sb, nb, sw, ew, interpret=True)
    expect = ref.gossip_mix_ref(sb, nb, sw, ew)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_gossip_mix_consensus_semantics():
    """gossip_mix(self, neighbors, 1/(k+1), 1/(k+1)) == one mixing round of
    the lazy uniform matrix restricted to received buffers."""
    from repro.core.graphs import ring_graph
    g = ring_graph(5)
    rng = np.random.default_rng(0)
    z = rng.normal(size=(5, 1000)).astype(np.float32)
    # node 0's neighbors on the ring are 1 and 4
    nbrs = jnp.asarray(z[[1, 4]])
    out = ops.gossip_mix(jnp.asarray(z[0]), nbrs, g.self_weight,
                         g.edge_weight, interpret=True)
    expect = (g.mixing_matrix() @ z)[0]
    np.testing.assert_allclose(np.asarray(out), expect, atol=1e-5)


# ---------------------------------------------------------------------------
# weighted (per-edge) gossip: kernel vs ref vs dense matmul
# ---------------------------------------------------------------------------


def _expander_S_in(g):
    return jnp.asarray(np.stack([np.asarray(p) for p in g.perms], axis=1))


@given(n8=st.integers(1, 5), m=st.integers(1, 3000), k=st.integers(1, 5))
@settings(max_examples=10)
def test_gossip_mix_weighted_kernel_vs_ref(n8, m, k):
    """The per-edge-weight Pallas kernel (interpret=True) against the jnp
    oracle, over unpadded shapes routed through the padding wrapper."""
    n = 8 * n8  # ops pads rows; vary the lane padding via m
    ks = jax.random.split(jax.random.PRNGKey(m % 89), 4)
    z = jax.random.normal(ks[0], (n, m), jnp.float32)
    S_in = jax.random.randint(ks[1], (n, k), 0, n)
    ws = jax.random.uniform(ks[2], (n,), jnp.float32, 0.05, 0.9)
    we = jax.random.uniform(ks[3], (n, k), jnp.float32, 0.0, 0.3)
    out = ops.gossip_gather_mix(z, S_in, ws, we, interpret=True,
                                use_kernel=True)
    expect = ref.gossip_gather_mix_ref(z, S_in, ws, we)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_gossip_gather_mix_uniform_matches_matmul(use_kernel):
    """Uniform lazy weights on a k-regular expander == P @ z, for both the
    kernel route and the fused-jnp (CPU fast path) route."""
    from repro.core.graphs import kregular_expander
    g = kregular_expander(12, k=4, seed=0)
    z = jax.random.normal(jax.random.PRNGKey(1), (12, 257), jnp.float32)
    out = ops.gossip_gather_mix(
        z, _expander_S_in(g), jnp.float32(g.self_weight),
        jnp.float32(g.edge_weight), interpret=True, use_kernel=use_kernel)
    expect = jnp.asarray(g.mixing_matrix(), jnp.float32) @ z
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_gossip_gather_mix_weighted_matches_matmul(use_kernel):
    """A reweighted edge-supported mixing matrix (the
    `AdaptiveController(reweight_gossip=True)` shape: arbitrary weights on
    diag + edges) folded into per-edge vectors == W @ z."""
    from repro.core.graphs import kregular_expander
    g = kregular_expander(12, k=4, seed=0)
    n = g.n
    rng = np.random.default_rng(3)
    S_in_np = np.stack([np.asarray(p) for p in g.perms], axis=1)
    W = np.diag(rng.uniform(0.2, 0.6, n))
    for i in range(n):
        for src in set(S_in_np[i]):
            W[i, src] = rng.uniform(0.05, 0.2)
    # slot weight = W[i, src] / multiplicity (engines' convention)
    mult = np.zeros_like(S_in_np)
    for j in range(S_in_np.shape[1]):
        mult[:, j] = (S_in_np == S_in_np[:, j][:, None]).sum(axis=1)
    we = (W[np.arange(n)[:, None], S_in_np] / mult).astype(np.float32)
    z = jax.random.normal(jax.random.PRNGKey(2), (n, 130), jnp.float32)
    out = ops.gossip_gather_mix(
        z, jnp.asarray(S_in_np), jnp.asarray(np.diag(W), jnp.float32),
        jnp.asarray(we), interpret=True, use_kernel=use_kernel)
    expect = jnp.asarray(W, jnp.float32) @ z
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)
