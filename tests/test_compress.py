"""repro.compress: compressor invariants (property-tested), the fused
compress-mix kernel vs the dense-matmul oracle, error-feedback
telescoping, netsim engine bit-identity under compression, and the
spec/tradeoff threading of the wire ratio c."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.compress import (COMPRESSORS, INDEX_BYTES, VALUE_BYTES, Int8,
                            NoCompression, RandK, TopK, build_compressor,
                            compressors, keep_count, topk_mask_jax,
                            topk_mask_np)
from repro.core import tradeoff
from repro.core.dda import DDASimulator, stepsize_sqrt
from repro.core.graphs import kregular_expander
from repro.core.schedules import EveryIteration
from repro.kernels import ops as kops
from repro.kernels.ref import compress_mix_ref, gossip_gather_mix_ref


def _quadratic(n, d, seed=0):
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    def subgrad_stack(x, t, key):
        return x - targets

    def objective(xbar):
        return jnp.mean(jnp.sum((xbar[None, :] - targets) ** 2, axis=-1))

    return subgrad_stack, jax.jit(objective)


# ---------------------------------------------------------------------------
# registry / spec front door
# ---------------------------------------------------------------------------


def test_registry_inventory():
    assert sorted(COMPRESSORS) == ["int8", "none", "randk", "topk"]
    assert sorted(compressors.names()) == sorted(COMPRESSORS)
    for kind in COMPRESSORS:
        comp = build_compressor(kind)
        assert comp.kind == kind
        # params_dict rebuilds the exact compressor (the spec contract)
        assert build_compressor(kind, comp.params_dict()) == comp


def test_build_compressor_rejects_typos():
    with pytest.raises(ValueError, match="unknown compression kind"):
        build_compressor("top_k")
    with pytest.raises(ValueError, match="bad params"):
        build_compressor("topk", {"kep": 0.5})
    with pytest.raises(ValueError, match="keep"):
        build_compressor("topk", {"keep": 0.0})
    with pytest.raises(ValueError, match="keep"):
        build_compressor("randk", {"keep": 1.5})


def test_wire_ratios_closed_form():
    d = 64
    assert NoCompression().wire_ratio(d) == 1.0
    k = keep_count(d, 0.25)
    assert TopK(keep=0.25).wire_ratio(d) == pytest.approx(
        k * (VALUE_BYTES + INDEX_BYTES) / (d * VALUE_BYTES))
    # rand-k's support is shared randomness: no index bytes on the wire
    assert RandK(keep=0.25).wire_ratio(d) == pytest.approx(k / d)
    assert RandK(keep=0.25).wire_ratio(d) < TopK(keep=0.25).wire_ratio(d)
    assert Int8().wire_ratio(d) == pytest.approx(
        (d + VALUE_BYTES) / (d * VALUE_BYTES))
    # a 1-entry message can never beat the uncompressed float
    assert keep_count(3, 0.01) == 1


# ---------------------------------------------------------------------------
# satellite: the exact-k tie regression (the old dense inline mask kept
# every |x| >= threshold entry, i.e. MORE than k on magnitude ties)
# ---------------------------------------------------------------------------


def test_topk_exact_k_on_magnitude_ties():
    row = np.array([1.0, -1.0, 1.0, -1.0, 0.5, 1.0], np.float32)
    for k in (1, 2, 3):
        m_np = topk_mask_np(row, k)
        m_jx = np.asarray(topk_mask_jax(jnp.asarray(row)[None, :], k))[0]
        assert int(m_np.sum()) == k, "np mask must keep exactly k on ties"
        assert int(m_jx.sum()) == k, "jax mask must keep exactly k on ties"
        # both halves break ties toward the lower index -- identically
        np.testing.assert_array_equal(m_np, m_jx)


def test_topk_jax_np_halves_agree():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(7, 33)).astype(np.float32)
    comp = TopK(keep=0.3)
    sent_jax = np.asarray(
        comp.compress_jax(jnp.asarray(x), jnp.asarray(0, jnp.int32)))
    for i in range(x.shape[0]):
        np.testing.assert_allclose(comp.compress_np(x[i], i, 0),
                                   sent_jax[i], rtol=0, atol=0)


# ---------------------------------------------------------------------------
# property tests: compressor invariants
# ---------------------------------------------------------------------------

_rows = st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                 min_size=2, max_size=48) if HAVE_HYPOTHESIS else None


@settings(max_examples=60, deadline=None)
@given(row=_rows, keep=st.floats(0.01, 1.0), kind=st.sampled_from(
    ["topk", "randk"]), node=st.integers(0, 7), stamp=st.integers(0, 99))
def test_sparsifier_support_invariants(row, keep, kind, node, stamp):
    """decompress(compress(x)) == x on the support, 0 off it, support
    size exactly keep_count(d, keep), and the sent values are VERBATIM
    coordinates of x (sparsification never rescales)."""
    x = np.asarray(row, np.float32)
    d = x.shape[0]
    comp = build_compressor(kind, {"keep": keep})
    sent = comp.compress_np(x, node, stamp)
    on = sent != 0.0
    assert int(on.sum()) <= keep_count(d, keep)
    np.testing.assert_array_equal(sent[on], x[on])
    # exactly k nonzero when x is nonzero everywhere on the support
    strict = np.abs(x) > 0
    if strict.all():
        assert int(on.sum()) == keep_count(d, keep)
    # determinism: the same (seed, node, stamp) replays the same support
    np.testing.assert_array_equal(sent, comp.compress_np(x, node, stamp))


@settings(max_examples=60, deadline=None)
@given(row=_rows, stochastic=st.booleans(), node=st.integers(0, 7),
       stamp=st.integers(0, 99))
def test_quantizer_range_bounds(row, stochastic, node, stamp):
    """Int8 absmax quantization: per-entry error <= one quantization step
    s = max|x|/127, output bounded by max|x|, zero maps to zero."""
    x = np.asarray(row, np.float32)
    comp = Int8(stochastic=stochastic, seed=3)
    sent = comp.compress_np(x, node, stamp)
    s = float(np.max(np.abs(x))) / Int8.LEVELS
    if s == 0.0:
        np.testing.assert_array_equal(sent, x)
        return
    assert np.max(np.abs(sent - x)) <= s * (1.0 + 1e-6)
    assert np.max(np.abs(sent)) <= np.max(np.abs(x)) * (1.0 + 1e-6)
    if not stochastic:
        np.testing.assert_array_equal(sent, comp.compress_np(x, node, stamp))


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(["topk", "randk", "int8"]),
       seed=st.integers(0, 99), rounds=st.integers(1, 12))
def test_error_feedback_telescopes(kind, seed, rounds):
    """sum(sent) == sum(msg) + res_0 - res_T: with error feedback the
    cumulative transmitted mass is exactly the cumulative message mass
    up to the final residual -- the unbiasedness EF buys."""
    rng = np.random.default_rng(seed)
    d = 24
    params = {"keep": 0.25} if kind in ("topk", "randk") else {}
    comp = build_compressor(kind, params)
    assert comp.error_feedback
    res = np.zeros(d, np.float32)
    total_sent = np.zeros(d, np.float64)
    total_msg = np.zeros(d, np.float64)
    for t in range(rounds):
        msg = rng.normal(size=d).astype(np.float32)
        corrected = msg + res
        sent = comp.compress_np(corrected, 0, t)
        res = corrected - sent
        total_sent += sent
        total_msg += msg
    np.testing.assert_allclose(total_sent, total_msg - res, atol=1e-3)


# ---------------------------------------------------------------------------
# the fused compress-mix pass
# ---------------------------------------------------------------------------


def _sparse_inputs(n=8, k=4, d=40, seed=0):
    rng = np.random.default_rng(seed)
    g = kregular_expander(n, k=k, seed=1)
    S_in = np.stack([np.asarray(p, np.int64) for p in g.perms], axis=1)
    z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=(n, d)), jnp.float32)
    return g, jnp.asarray(S_in), z, msg, mask


def test_compress_mix_kernel_matches_ref():
    """The Pallas kernel (interpret mode on CPU) against the pure-jnp ref,
    scalar and per-edge weights."""
    g, S_in, z, msg, mask = _sparse_inputs()
    n, k = S_in.shape
    for w_self, w_edge in [
        (jnp.float32(g.self_weight), jnp.float32(g.edge_weight)),
        (jnp.asarray(np.random.default_rng(2).uniform(0.1, 0.5, n),
                     jnp.float32),
         jnp.asarray(np.random.default_rng(3).uniform(0.01, 0.2, (n, k)),
                     jnp.float32)),
    ]:
        want = compress_mix_ref(z, msg, mask, S_in, w_self, w_edge)
        got = kops.compress_mix_impl(z, msg, mask, S_in, w_self, w_edge,
                                     interpret=True, use_kernel=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_compress_mix_matches_dense_matmul_oracle():
    """The fused sparse pass == the dense-matmul oracle
    diag(P) z + P_off (msg ⊙ mask): the acceptance gate for the sparse
    path staying available under compression."""
    g, S_in, z, msg, mask = _sparse_inputs()
    got = kops.compress_mix_impl(z, msg, mask, S_in,
                                 jnp.float32(g.self_weight),
                                 jnp.float32(g.edge_weight))
    P = np.asarray(g.mixing_matrix(), np.float64)
    sent = np.asarray(msg, np.float64) * np.asarray(mask, np.float64)
    want = (np.diag(P)[:, None] * np.asarray(z, np.float64)
            + (P - np.diag(np.diag(P))) @ sent)
    rel = (np.linalg.norm(np.asarray(got, np.float64) - want)
           / np.linalg.norm(want))
    assert rel <= 1e-5, f"fused pass vs dense oracle rel={rel:.2e}"


def test_gather_mix_msg_matches_ref():
    """The msg= variant (quantizer path: dense dequantized messages ride
    the plain gather) against its ref."""
    g, S_in, z, msg, _ = _sparse_inputs()
    want = gossip_gather_mix_ref(z, S_in, jnp.float32(g.self_weight),
                                 jnp.float32(g.edge_weight), msg=msg)
    got = kops.gossip_gather_mix_impl(z, S_in, jnp.float32(g.self_weight),
                                      jnp.float32(g.edge_weight), msg=msg,
                                      interpret=True, use_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# DDASimulator integration
# ---------------------------------------------------------------------------


def test_topk_on_kregular_graph_stays_sparse():
    """Acceptance: compression no longer disqualifies the sparse path --
    the fused compress-mix kernel is why."""
    n, d = 12, 10
    subgrad, obj = _quadratic(n, d)
    g = kregular_expander(n, k=4, seed=0)
    sim = DDASimulator(subgrad, obj, g, EveryIteration(),
                       a_fn=stepsize_sqrt(0.1),
                       compression=TopK(keep=0.25))
    assert sim.mix_mode == "sparse"
    assert sim.wire_ratio(d) == TopK(keep=0.25).wire_ratio(d)


def test_sparse_vs_dense_mix_identical_under_compression():
    """The fused sparse path and the forced dense-matmul path run the SAME
    compressed algorithm: traces agree to float tolerance."""
    n, d, T = 12, 10, 80
    subgrad, obj = _quadratic(n, d)
    g = kregular_expander(n, k=4, seed=0)
    traces = {}
    for mix in ("sparse", "dense"):
        sim = DDASimulator(subgrad, obj, g, EveryIteration(),
                           a_fn=stepsize_sqrt(0.1), mix=mix,
                           compression=TopK(keep=0.25))
        assert sim.mix_mode == mix
        traces[mix] = sim.run(jnp.zeros((n, d)), T, eval_every=20)
    a = np.asarray(traces["sparse"].fvals)
    b = np.asarray(traces["dense"].fvals)
    assert np.max(np.abs(a - b)) / np.max(np.abs(b)) < 1e-5


def test_none_compression_is_bit_identical_to_seed_path():
    """kind='none' normalizes away: the program, trace and time axis are
    byte-for-byte those of an uncompressed run."""
    n, d, T = 8, 6, 60
    subgrad, obj = _quadratic(n, d)
    g = kregular_expander(n, k=4, seed=0)
    mk = lambda comp: DDASimulator(subgrad, obj, g, EveryIteration(),
                                   a_fn=stepsize_sqrt(0.1), r=0.05,
                                   compression=comp)
    sim_none = mk(NoCompression())
    assert sim_none.compression is None
    t0 = mk(None).run(jnp.zeros((n, d)), T, eval_every=20)
    t1 = sim_none.run(jnp.zeros((n, d)), T, eval_every=20)
    assert t0.fvals == t1.fvals
    assert t0.sim_time == t1.sim_time


def test_compressed_time_axis_charges_r_times_c():
    """The dense sim_time charges the effective tradeoff r*c."""
    n, d, T = 8, 16, 40
    subgrad, obj = _quadratic(n, d)
    g = kregular_expander(n, k=4, seed=0)
    r = 0.2
    plain = DDASimulator(subgrad, obj, g, EveryIteration(),
                         a_fn=stepsize_sqrt(0.1), r=r)
    comp = DDASimulator(subgrad, obj, g, EveryIteration(),
                        a_fn=stepsize_sqrt(0.1), r=r,
                        compression=RandK(keep=0.25))
    c = RandK(keep=0.25).wire_ratio(d)
    tp = plain.run(jnp.zeros((n, d)), T, eval_every=20)
    tc = comp.run(jnp.zeros((n, d)), T, eval_every=20)
    k = g.degree
    for it, s_plain, s_comp in zip(tp.iters, tp.sim_time, tc.sim_time):
        # every iteration communicates here: s = it/n + it*k*r(*c)
        assert s_plain == pytest.approx(it * (1.0 / n + k * r))
        assert s_comp == pytest.approx(it * (1.0 / n + k * r * c))


def test_error_feedback_compressed_run_converges():
    """Top-k at keep=0.25 with EF tracks the uncompressed objective."""
    n, d, T = 12, 10, 300
    subgrad, obj = _quadratic(n, d)
    g = kregular_expander(n, k=4, seed=0)
    base = DDASimulator(subgrad, obj, g, EveryIteration(),
                        a_fn=stepsize_sqrt(0.1))
    comp = DDASimulator(subgrad, obj, g, EveryIteration(),
                        a_fn=stepsize_sqrt(0.1),
                        compression=TopK(keep=0.25))
    t0 = base.run(jnp.zeros((n, d)), T, eval_every=50)
    t1 = comp.run(jnp.zeros((n, d)), T, eval_every=50)
    assert t1.fvals[-1] < 1.2 * t0.fvals[-1] + 0.5
    # the residual-norm trajectory was recorded
    assert comp.last_res_norms is not None
    assert len(comp.last_res_norms) == len(t1.fvals)


# ---------------------------------------------------------------------------
# netsim: engine bit-identity under compression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", [
    TopK(keep=0.3), RandK(keep=0.3, seed=7), Int8(),
    Int8(stochastic=True, seed=3),
], ids=lambda c: f"{c.kind}{'-st' if getattr(c, 'stochastic', 0) else ''}")
def test_netsim_engines_bit_identical_under_compression(comp):
    """Object and vectorized engines produce EXACTLY the same trace,
    residuals and wire accounting under every compressor -- randomized
    compressors key their RNG on (seed, node, stamp), a pure function of
    what is sent, never of global event order."""
    from repro.netsim import NetSimulator
    from repro.netsim.scenarios import homogeneous

    n, d, T = 8, 12, 60
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(n, d))

    def grad_fn(i, x, t):
        return x - targets[i]

    def eval_fn(xbar):
        return float(np.mean(np.sum((xbar[None] - targets) ** 2, -1)))

    runs = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(homogeneous(n, r=0.05), grad_fn, eval_fn,
                           schedule=EveryIteration(), seed=1,
                           engine=engine, compression=comp)
        tr = sim.run(np.zeros((n, d)), T, eval_every=15)
        runs[engine] = (tr, sim.comp_res_norms, sim.net.wire_bytes)
    (ta, ra, wa), (tb, rb, wb) = runs["object"], runs["vectorized"]
    assert ta.fvals == tb.fvals
    assert ta.sim_time == tb.sim_time
    assert ta.disagreement == tb.disagreement
    assert ra == rb and len(ra) == len(ta.fvals)
    assert wa == wb == sim.net.message_bytes * comp.wire_ratio(d)


def test_netsim_compression_validation():
    from repro.netsim import NetSimulator
    from repro.netsim.scenarios import homogeneous

    grad = lambda i, x, t: x
    ev = lambda xbar: 0.0
    with pytest.raises(ValueError, match="algorithm='dda'"):
        NetSimulator(homogeneous(4, r=0.05), grad, ev,
                     schedule=EveryIteration(), algorithm="pushsum",
                     compression=TopK(keep=0.5))
    with pytest.raises(TypeError, match="Compressor"):
        NetSimulator(homogeneous(4, r=0.05), grad, ev,
                     schedule=EveryIteration(), compression="topk")


# ---------------------------------------------------------------------------
# tradeoff: the c axis
# ---------------------------------------------------------------------------


def test_tradeoff_c_axis_shifts_optima():
    n, k, r, lam2 = 16, 4, 0.1, 0.6
    c = 0.25
    assert tradeoff.iteration_cost(n, k, r, c) == pytest.approx(
        1.0 / n + k * r * c)
    # compression enlarges the optimal cluster by 1/sqrt(c) ...
    assert tradeoff.n_opt_complete(r, c) == pytest.approx(
        tradeoff.n_opt_complete(r * c))
    # ... and pulls h_opt back toward 1 by sqrt(c)
    assert tradeoff.h_opt(n, k, r, lam2, c) == pytest.approx(
        tradeoff.h_opt(n, k, r * c, lam2))
    # tau is monotone improving in compression on comm-bound regimes
    taus = [tradeoff.time_to_accuracy(0.1, n, k, r, lam2, c=ci)
            for ci in (1.0, 0.5, 0.25)]
    assert taus[0] > taus[1] > taus[2]
    assert tradeoff.time_to_accuracy(0.1, n, k, r, lam2, c=1.0) == \
        tradeoff.time_to_accuracy(0.1, n, k, r, lam2)


def test_hopt_with_rc_predicts_frontier_ordering():
    """Acceptance: h_opt evaluated at r*c orders the measured dense
    time-to-accuracy frontier across compression ratios -- cheaper wires
    favor denser communication."""
    n, k, r, lam2 = 16, 4, 0.5, 0.7
    h_plain = tradeoff.h_opt(n, k, r, lam2)
    h_comp = tradeoff.h_opt(n, k, r, lam2, c=0.1)
    assert h_comp < h_plain  # communicate more often when messages shrink
