"""Vectorized netsim fast path: engine equivalence (bit-identical traces on
seeded scenarios), calendar-queue vs heap event ordering, batch-capability
probes, and the 1024-node wall-clock smoke."""

import math
import time

import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core.dda import TRACE_FIELDS
from repro.core.schedules import (EveryIteration, IncreasinglySparse,
                                  Periodic)
from repro.netsim import (EventQueue, NetSimulator, adversarial, homogeneous,
                          lossy, pushsum_mass_audit, quadratic_consensus as
                          _problem)


def _run_engines(scenario, algorithm, n, d, T=200, seed=5, eval_every=3,
                 **kw):
    _, grad_fn, eval_fn = _problem(n, d)
    out = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(scenario, grad_fn, eval_fn, algorithm=algorithm,
                           seed=seed, engine=engine, **kw)
        trace = sim.run(np.zeros((n, d)), T=T, eval_every=eval_every)
        out[engine] = (sim, trace)
    return out


def _assert_traces_identical(a, b):
    for field in TRACE_FIELDS:
        assert getattr(a, field) == getattr(b, field), field


# -- engine equivalence ------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["dda", "pushsum"])
def test_adversarial_scenario_bit_identical(algorithm):
    """Seeded lossy + straggler + rewire scenario: both engines must produce
    BIT-IDENTICAL SimTrace and measure_r_empirical -- identical RNG
    consumption, float op order, and event interleaving, not just
    statistically matching output."""
    n, d = 12, 5
    sc = adversarial(n, 0.01, loss=0.25, slow_factor=3.0, n_slow=2,
                     rewire_every=0.7, seed=0)
    runs = _run_engines(sc, algorithm, n, d)
    (sim_o, tr_o), (sim_v, tr_v) = runs["object"], runs["vectorized"]
    _assert_traces_identical(tr_o, tr_v)
    assert sim_o.measure_r_empirical() == sim_v.measure_r_empirical()
    assert (sim_o.drops, sim_o.sent, sim_o.rewires) == \
        (sim_v.drops, sim_v.sent, sim_v.rewires)


@pytest.mark.parametrize("schedule", [Periodic(h=3), IncreasinglySparse(p=0.3)])
def test_sparse_schedules_bit_identical(schedule):
    """Jitter (per-message RNG fallback) + non-trivial schedules stay
    bit-identical across engines."""
    n, d = 8, 4
    sc = lossy(n, 0.02, loss=0.15, jitter=0.05, seed=2)
    runs = _run_engines(sc, "dda", n, d, T=150, seed=9, eval_every=4,
                        schedule=schedule)
    _assert_traces_identical(runs["object"][1], runs["vectorized"][1])


def test_vectorized_pushsum_mass_audit_via_materialized_nodes():
    """The vectorized engine's materialized node views satisfy the same
    conservation invariant as real object-engine nodes."""
    n, d = 8, 5
    rng = np.random.default_rng(3)
    y0 = rng.normal(size=(n, d))
    _, _, eval_fn = _problem(n, d)
    sim = NetSimulator(lossy(n, 0.01, loss=0.4, seed=1),
                       lambda i, x, t: np.zeros(d), eval_fn,
                       algorithm="pushsum", pushsum_y0=y0, seed=2,
                       pushsum_w_floor=1e-12, engine="vectorized")
    sim.run(np.zeros((n, d)), T=150, eval_every=50)
    assert sim.drops > 0
    y_total, w_total = pushsum_mass_audit(sim.nodes)
    np.testing.assert_allclose(y_total, y0.sum(axis=0), atol=1e-9)
    assert w_total == pytest.approx(n, abs=1e-9)


def test_exact_float_tie_msg_vs_step_bit_identical():
    """Regression for the closed float-time-tie seam: serialization-free
    links whose latency EXACTLY equals the homogeneous busy time (1/n) make
    every communication's message arrival tie the receivers' next step
    completion to the ulp. The engines' message/step insertion orders
    differ, so under the old (time, seq)-only event order the object engine
    let a later-in-batch node's message leapfrog an earlier node's step and
    the traces diverged; the (time, prio, seq) order (in-flight arrivals
    first at their strictly-future timestamp) makes them bit-identical."""
    import dataclasses

    from repro.core.graphs import complete_graph
    from repro.netsim import LinkModel, NodeSpec, Scenario

    n, d = 6, 4
    sc = Scenario(name="tie", topology=complete_graph(n),
                  link=LinkModel(latency=1.0 / n, bandwidth=math.inf),
                  node_specs=tuple(NodeSpec() for _ in range(n)),
                  message_bytes=8.0)
    for schedule in (EveryIteration(), Periodic(h=2)):
        runs = _run_engines(sc, "dda", n, d, T=60, seed=1, eval_every=4,
                            schedule=schedule)
        _assert_traces_identical(runs["object"][1], runs["vectorized"][1])
    # heterogeneous variant: a 2x straggler keeps producing exact ties
    # (tie requires latency == busy; use the straggler's busy time)
    sc2 = dataclasses.replace(
        sc, link=LinkModel(latency=2.0 / n, bandwidth=math.inf),
        node_specs=(NodeSpec(compute_scale=2.0),) + sc.node_specs[1:])
    runs = _run_engines(sc2, "dda", n, d, T=60, seed=1, eval_every=4)
    _assert_traces_identical(runs["object"][1], runs["vectorized"][1])


def test_arrival_priority_only_on_strictly_future_ties():
    """A message scheduled at exactly `now` must NOT leapfrog events
    already due at `now` (simultaneous events are causally independent);
    one scheduled for a strictly future time must beat a same-time step."""
    q = EventQueue(backend="heap")
    q.schedule(1.0, "step", node=0)
    q.schedule(1.0, "msg", src=1, dst=0)   # future tie: arrival first
    assert [q.pop().kind for _ in range(2)] == ["msg", "step"]
    q.schedule(2.0, "step", node=0)
    assert q.pop().time == 2.0             # now == 2.0
    q.schedule(3.0, "step", node=1)
    q.schedule(3.0, "step", node=2)
    assert q.pop().data["node"] == 1       # now == 3.0, node 2 still due
    q.schedule(3.0, "msg", src=0, dst=2)   # at-now delivery: stays behind
    ev1, ev2 = q.pop(), q.pop()
    assert (ev1.kind, ev2.kind) == ("step", "msg")


def test_engine_arg_validation():
    n, d = 4, 3
    _, grad_fn, eval_fn = _problem(n, d)
    with pytest.raises(ValueError):
        NetSimulator(homogeneous(n, 0.01, k=2), grad_fn, eval_fn,
                     engine="gpu")


# -- batch-capability probes -------------------------------------------------


def test_eval_probe_rejects_silently_broadcasting_eval_fn():
    """The classic trap: a per-point eval_fn that does NOT crash on a
    stacked batch but silently returns a wrong scalar. The probe must
    reject it (bitwise verification against the loop) and keep the
    per-node path, so both engines still agree."""
    n, d = 8, 5
    sc = homogeneous(n, 0.01, k=4, seed=0)
    runs = _run_engines(sc, "dda", n, d, T=80)
    _assert_traces_identical(runs["object"][1], runs["vectorized"][1])
    assert runs["vectorized"][0]._eval_batch.mode == "loop"


def test_batchable_eval_and_grad_probe_engage_and_match_loop():
    n, d = 8, 5
    _, grad_fn, eval_fn = _problem(n, d, batchable=True)
    traces = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(homogeneous(n, 0.01, k=4, seed=0), grad_fn,
                           eval_fn, seed=5, engine=engine)
        traces[engine] = sim.run(np.zeros((n, d)), T=120, eval_every=4)
        assert sim._eval_batch.mode == "batch"
    # grad probe only runs on the vectorized path
    assert sim._grad_batch.mode == "batch"
    _assert_traces_identical(traces["object"], traces["vectorized"])


def test_grad_probe_defers_on_size_one_batches():
    """A scalar-style grad_fn (`if t > 0` is valid on a 1-element array but
    ambiguous on larger ones) must NOT get locked into batch mode by a
    size-1 probe batch. One fast node makes the first due batch a
    singleton; the probe must defer until a >= 2 batch, then reject."""
    import dataclasses

    from repro.netsim import NodeSpec

    n, d = 8, 5
    centers, _, eval_fn = _problem(n, d)

    def scalar_grad(i, x, t):
        if t > 0:  # ValueError on a multi-element t array
            return 2.0 * (x - centers[i])
        return np.zeros_like(x)

    base = homogeneous(n, 0.01, k=4, seed=0)
    specs = (NodeSpec(compute_scale=0.5),) + base.node_specs[1:]
    sc = dataclasses.replace(base, node_specs=specs)
    traces = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(sc, scalar_grad, eval_fn, seed=5, engine=engine)
        traces[engine] = sim.run(np.zeros((n, d)), T=60, eval_every=5)
    assert sim._grad_batch.mode == "loop"
    _assert_traces_identical(traces["object"], traces["vectorized"])


def test_next_comm_step_batch_matches_scalar():
    ts = np.arange(0, 60, dtype=np.int64)
    for sched in [EveryIteration(), Periodic(h=1), Periodic(h=4),
                  IncreasinglySparse(p=0.3)]:
        batch = sched.next_comm_step_batch(ts)
        scalar = [sched.next_comm_step(int(t)) for t in ts]
        assert batch.tolist() == scalar


# -- 1024-node smoke ---------------------------------------------------------


def test_vectorized_1024_nodes_under_budget():
    """A 1024-node, d=32 vectorized run must finish well under a CI-safe
    wall-clock budget (the object engine takes ~2s for the same cell; the
    budget would catch a regression to per-node dispatch)."""
    n, d, T = 1024, 32, 15
    _, grad_fn, eval_fn = _problem(n, d, batchable=True)
    sim = NetSimulator(homogeneous(n, 0.01, k=4, seed=0), grad_fn, eval_fn,
                       seed=0, engine="vectorized")
    t0 = time.perf_counter()
    trace = sim.run(np.zeros((n, d)), T=T, eval_every=5)
    wall = time.perf_counter() - t0
    assert wall < 10.0
    assert trace.iters[-1] == T
    assert trace.fvals[-1] < trace.fvals[0]
    assert np.isfinite(trace.fvals).all()
    m = sim.measure_r_empirical()
    assert m.r == pytest.approx(0.01, rel=1e-6)


# -- event queue backends ----------------------------------------------------


def _drain_both(schedule_ops):
    """Apply the same schedule/pop script to both backends; the popped
    (time, seq, kind) sequences must be identical."""
    out = {}
    for backend in ("heap", "calendar"):
        q = EventQueue(backend=backend)
        popped = []
        for op in schedule_ops:
            if op[0] == "push":
                q.schedule(max(op[1], q.now), str(op[2]))
            else:
                if not q.empty():
                    ev = q.pop()
                    popped.append((ev.time, ev.seq, ev.kind))
        while not q.empty():
            ev = q.pop()
            popped.append((ev.time, ev.seq, ev.kind))
        out[backend] = popped
    assert out["heap"] == out["calendar"]
    return out["heap"]


def test_calendar_queue_matches_heap_seeded():
    """Non-hypothesis version (runs even without the optional extra):
    random interleaved push/pop scripts with heavy timestamp ties."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        ops = []
        # coarse time grid => many exact ties, like the homogeneous netsim
        for _ in range(rng.integers(5, 120)):
            if rng.random() < 0.3:
                ops.append(("pop",))
            else:
                t = float(rng.integers(0, 12)) * 0.25
                ops.append(("push", t, f"k{rng.integers(0, 3)}"))
        popped = _drain_both(ops)
        times = [p[0] for p in popped]
        assert times == sorted(times)


def test_calendar_queue_resize_and_sparse_fastforward():
    """Growth across resize thresholds and popping across large empty
    stretches of the calendar (year-rotation fast-forward)."""
    q = EventQueue(backend="calendar")
    times = [float(i) * 997.0 for i in range(200)]  # sparse, forces jumps
    for t in reversed(times):
        q.schedule(t, "a")
    assert len(q) == 200
    popped = [q.pop().time for _ in range(200)]
    assert popped == sorted(times)
    assert q.empty()


def test_calendar_queue_past_scheduling_raises():
    q = EventQueue(backend="calendar")
    q.schedule(5.0, "a")
    assert q.pop().time == 5.0
    with pytest.raises(ValueError):
        q.schedule(1.0, "too-late")


@settings(max_examples=200, deadline=None)
@given(st.lists(
    st.one_of(
        st.tuples(st.just("push"),
                  st.floats(min_value=0.0, max_value=50.0,
                            allow_nan=False, allow_infinity=False),
                  st.integers(min_value=0, max_value=4)),
        st.tuples(st.just("pop")),
    ),
    max_size=200))
def test_calendar_queue_property_total_order(ops):
    """Property: for ANY interleaved schedule/pop script (including exact
    duplicate timestamps), the calendar backend pops the exact same
    (time, seq) total order as the heap backend."""
    popped = _drain_both(ops)
    assert popped == sorted(popped)


if HAVE_HYPOTHESIS:
    # quantized-time variant: maximizes same-bucket collisions
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.one_of(
        st.tuples(st.just("push"),
                  st.sampled_from([0.0, 0.5, 1.0, 1.5, 2.0, 64.0, 1e4]),
                  st.just("k")),
        st.tuples(st.just("pop"))), max_size=120))
    def test_calendar_queue_property_tie_storm(ops):
        _drain_both(ops)
