"""run_sweep parallel executors: vmapped dense batching and the process
pool, against the serial baseline."""

import numpy as np
import pytest

from repro.experiments import ExperimentSpec, run_sweep


def _dense_spec(**kw):
    base = dict(
        name="sweep",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 12, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        T=60, eval_every=20, seed=0, r=0.01, eps_frac=0.05)
    base.update(kw)
    return ExperimentSpec(**base)


def _netsim_spec():
    return ExperimentSpec(
        name="sweep-net",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 6, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "every"},
        backends=[{"kind": "netsim",
                   "params": {"scenario": "lossy", "loss": 0.2}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": 0.5}},
        T=40, eval_every=10, seed=0, r=0.01)


def _rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-12)))


@pytest.mark.parametrize("axis,values", [
    ("seed", [0, 1, 2]),
    ("schedule.params.h", [1, 2, 5]),
    ("r", [0.0, 0.01, 0.1]),
], ids=["seed", "h", "r"])
def test_vmap_sweep_matches_serial(axis, values):
    spec = _dense_spec()
    serial = run_sweep(spec, axis, values)
    vmapped = run_sweep(spec, axis, values, parallel="vmap")
    assert all(r.extras.get("vmap_lanes") == len(values) for r in vmapped)
    for a, b in zip(serial, vmapped):
        assert a.spec == b.spec
        assert a.trace.iters == b.trace.iters
        assert a.trace.sim_time == b.trace.sim_time
        assert a.trace.comms == b.trace.comms
        assert _rel(a.trace.fvals, b.trace.fvals) < 1e-6
        assert a.predictions == b.predictions
        assert a.eps_value == pytest.approx(b.eps_value)


def test_vmap_sweep_falls_back_when_not_batchable():
    """Shape-changing axes (problem n) and non-dense backends fall back to
    the serial executor -- correctly, and LOUDLY: every fallback result
    carries the reason the pool did not pack (metrics.notes + extras), so
    "my sweep got slow" is diagnosable from the artifacts."""
    res = run_sweep(_dense_spec(), "problem.params.n", [4, 8],
                    parallel="vmap")
    assert [r.spec.problem.params["n"] for r in res] == [4, 8]
    assert all("vmap_lanes" not in r.extras for r in res)
    for r in res:
        reason = r.metrics.notes["vmap_fallback"]
        assert reason == r.extras["vmap_fallback"]
        # a shape-incompatible pool must say WHY: the cells differ
        # outside the batchable lane fields
        assert "lane fields" in reason and "2 distinct" in reason
    res = run_sweep(_netsim_spec(), "seed", [0, 1], parallel="vmap")
    assert all("vmap_lanes" not in r.extras for r in res)
    assert all("not dense" in r.metrics.notes["vmap_fallback"] for r in res)
    # the reason survives the JSON artifact round-trip
    import repro
    rt = repro.RunResult.from_json(res[0].to_json())
    assert rt.metrics.notes["vmap_fallback"] == \
        res[0].metrics.notes["vmap_fallback"]


def test_vmap_sweep_whole_schedule_axis():
    """Sweeping the schedule COMPONENT (kind change every -> sparse) still
    batches: the comm pattern is data to the scanned program."""
    spec = _dense_spec()
    values = [{"kind": "every"}, {"kind": "sparse", "params": {"p": 0.3}}]
    serial = run_sweep(spec, "schedule", values)
    vmapped = run_sweep(spec, "schedule", values, parallel="vmap")
    assert all(r.extras.get("vmap_lanes") == 2 for r in vmapped)
    for a, b in zip(serial, vmapped):
        assert a.trace.comms == b.trace.comms
        assert _rel(a.trace.fvals, b.trace.fvals) < 1e-6


def test_process_sweep_matches_serial_bitwise():
    """netsim cells across a spawn pool: pure + seeded, so the merged
    results are bit-identical to the serial executor."""
    spec = _netsim_spec()
    serial = run_sweep(spec, "seed", [0, 1])
    proc = run_sweep(spec, "seed", [0, 1], parallel="process", processes=2)
    for a, b in zip(serial, proc):
        assert a.spec == b.spec
        assert a.trace.fvals == b.trace.fvals
        assert a.trace.sim_time == b.trace.sim_time
        assert a.trace.disagreement == b.trace.disagreement
        assert a.r_measurement == b.r_measurement
        assert a.extras["sent"] == b.extras["sent"]


def test_run_sweep_rejects_unknown_parallel():
    with pytest.raises(ValueError, match="parallel"):
        run_sweep(_dense_spec(), "seed", [0], parallel="threads")
