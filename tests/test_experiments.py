"""The unified experiment API: registries, spec serialization, run().

Covers the redesign's contracts:
  * ExperimentSpec <-> JSON round-trip is EXACT (seeded + property-based);
  * a spec that went through JSON runs IDENTICALLY to the original, on the
    homogeneous and adversarial presets, on both netsim engines;
  * the checked-in manifests under benchmarks/manifests/ load, round-trip,
    and run on every backend they declare;
  * run_sweep replaces dotted-path axes correctly;
  * make_schedule routes through the schedule registry (and can now build
    PiecewisePeriodic / AdaptiveSchedule);
  * the dense_adaptive controller retunes h from (injected) wall-clock
    timings; the reweight_gossip flag applies the effective P to the actual
    stale mix and still converges.
"""

import json
import math
import pathlib

import numpy as np
import pytest

import repro
from repro.core import schedules as S
from repro.core.dda import TRACE_FIELDS
from repro.experiments import (ComponentSpec, ExperimentSpec, RunResult,
                               backends, problems, run, run_all, run_sweep,
                               schedules, stepsizes, topologies)
from tests._hyp import HAVE_HYPOTHESIS, given, st

MANIFESTS = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / "manifests"


def tiny_netsim_spec(scenario="homogeneous", engine="auto", **knobs):
    return ExperimentSpec(
        name="tiny",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 4, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "netsim",
                   "params": {"scenario": scenario, "engine": engine,
                              **knobs}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": 0.5}},
        T=120, eval_every=10, seed=0, r=0.05, eps_frac=0.1)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_registry_names_and_errors():
    assert "quadratic_consensus" in problems
    assert "quadratic" in problems  # alias
    assert "complete" in topologies
    assert {"every", "periodic", "sparse", "piecewise",
            "adaptive"} <= set(schedules.names())
    assert set(backends.names()) == {"dense", "launch", "netsim"}
    with pytest.raises(KeyError, match="unknown topology"):
        topologies.build("nope", n=4)


def test_registry_rejects_duplicate_registration():
    with pytest.raises(ValueError):
        schedules.register("periodic")(lambda: None)


def test_make_schedule_routes_through_registry():
    # the legacy kinds keep their legacy defaults...
    assert isinstance(S.make_schedule("every"), S.EveryIteration)
    assert S.make_schedule("periodic", h=4).h == 4
    assert S.make_schedule("sparse", p=0.2).p == 0.2
    # ...and the kinds the ad-hoc branching could NOT build now work
    pw = S.make_schedule("piecewise", h=3)
    assert isinstance(pw, S.PiecewisePeriodic) and pw.h_current == 3
    from repro.adaptive import AdaptiveSchedule
    ad = S.make_schedule("adaptive", h0=2, p=0.1)
    assert isinstance(ad, AdaptiveSchedule) and ad.h_current == 2
    assert ad.p == 0.1  # the named p kwarg must reach kinds that take it
    # legacy tolerance: kinds ignore the legacy knobs they never took...
    assert isinstance(S.make_schedule("every", h=5), S.EveryIteration)
    # ...but non-legacy kwargs fail loudly
    with pytest.raises(TypeError):
        S.make_schedule("periodic", hh=3)
    with pytest.raises(ValueError):
        S.make_schedule("nope")


# ---------------------------------------------------------------------------
# spec serialization
# ---------------------------------------------------------------------------


def test_spec_json_round_trip_exact():
    spec = tiny_netsim_spec("adversarial", loss=0.2, slow_factor=4.0,
                            n_slow=2)
    text = spec.to_json()
    again = ExperimentSpec.from_json(text)
    assert again == spec
    assert again.to_json() == text  # fixed point


def test_spec_normalizes_tuples_and_numpy_scalars():
    spec = ComponentSpec("expander", {"k": np.int64(4),
                                      "shifts": (1, 2)})
    assert spec.params == {"k": 4, "shifts": [1, 2]}
    assert isinstance(spec.params["k"], int)


def test_spec_rejects_non_json_params():
    with pytest.raises(TypeError, match="not JSON-serializable"):
        ComponentSpec("x", {"fn": lambda: None})
    with pytest.raises(TypeError, match="not JSON-serializable"):
        ComponentSpec("x", {"arr": np.zeros(3)})


def test_spec_rejects_unknown_keys_and_versions():
    d = tiny_netsim_spec().to_dict()
    with pytest.raises(ValueError, match="unknown keys"):
        ExperimentSpec.from_dict({**d, "spam": 1})
    with pytest.raises(ValueError, match="spec_version"):
        ExperimentSpec.from_dict({**d, "spec_version": 99})


def test_with_value_axes():
    spec = tiny_netsim_spec()
    assert spec.with_value("T", 10).T == 10
    assert spec.with_value("schedule.params.h", 7).schedule.params["h"] == 7
    assert spec.with_value("topology.kind", "ring").topology.kind == "ring"
    s2 = spec.with_value("backends.0.params.engine", "object")
    assert s2.backends[0].params["engine"] == "object"
    with pytest.raises(KeyError, match="axis"):
        spec.with_value("nonsense_field", 3)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@given(st.integers(2, 32), st.integers(1, 8), st.integers(0, 2 ** 31),
       st.floats(0.0, 10.0, allow_nan=False),
       st.sampled_from(["every", "periodic", "sparse"]))
def test_spec_round_trip_property(n, h, seed, r, kind):
    spec = ExperimentSpec(
        name="prop",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": n, "d": 3, "seed": seed}},
        topology={"kind": "expander", "params": {"k": 4, "seed": seed}},
        schedule={"kind": kind,
                  "params": ({"h": h} if kind == "periodic" else {})},
        backends=[{"kind": "netsim"}],
        T=10, seed=seed, r=r)
    assert ExperimentSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# run() determinism through serialization (the satellite gate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["object", "vectorized"])
@pytest.mark.parametrize("preset", [
    ("homogeneous", {}),
    ("adversarial", {"loss": 0.2, "slow_factor": 4.0, "n_slow": 2}),
])
def test_run_deterministic_through_json(engine, preset):
    """spec -> json -> spec -> run() must equal run(spec), bitwise, on the
    homogeneous and adversarial presets, both netsim engines."""
    scenario, knobs = preset
    spec = tiny_netsim_spec(scenario, engine=engine, **knobs)
    direct = run(spec)
    rehydrated = run(ExperimentSpec.from_json(spec.to_json()))
    for f in TRACE_FIELDS:
        assert getattr(direct.trace, f) == getattr(rehydrated.trace, f)
    assert direct.r_measurement == rehydrated.r_measurement
    assert direct.time_to_target == rehydrated.time_to_target


def test_netsim_engines_bit_identical_via_spec():
    spec = tiny_netsim_spec("adversarial", engine="object", loss=0.2,
                            slow_factor=4.0, n_slow=2)
    a = run(spec)
    b = run(spec.with_value("backends.0.params.engine", "vectorized"))
    for f in TRACE_FIELDS:
        assert getattr(a.trace, f) == getattr(b.trace, f)
    assert a.r_measurement == b.r_measurement


# ---------------------------------------------------------------------------
# RunResult
# ---------------------------------------------------------------------------


def test_run_result_round_trip():
    res = run(tiny_netsim_spec())
    again = RunResult.from_json(res.to_json())
    assert again.spec == res.spec
    assert again.backend == res.backend
    for f in TRACE_FIELDS:
        assert getattr(again.trace, f) == getattr(res.trace, f)
    assert again.r_measurement == res.r_measurement
    assert again.extras["engine"] == res.extras["engine"]
    # strict RFC: no Infinity/NaN tokens in the payload
    json.loads(res.to_json())


def test_run_result_unreached_target_is_null_not_inf():
    spec = tiny_netsim_spec()
    hard = ExperimentSpec.from_dict({**spec.to_dict(), "eps_frac": 1e-12,
                                     "T": 10})
    res = run(hard)
    assert res.time_to_target is None
    assert res.eps_value is not None
    json.loads(res.to_json())


# ---------------------------------------------------------------------------
# dispatch + sweeps
# ---------------------------------------------------------------------------


def test_run_backend_selection():
    spec = ExperimentSpec.from_dict({
        **tiny_netsim_spec(engine="object").to_dict(),
        "backends": [{"kind": "netsim", "params": {"engine": "object"}},
                     {"kind": "dense"}]})
    spec = ExperimentSpec.from_dict(
        {**spec.to_dict(), "stepsize": {"kind": "sqrt", "params": {"A": 0.5}}})
    by_default = run(spec)
    assert by_default.backend.kind == "netsim"
    by_index = run(spec, backend=1)
    assert by_index.backend.kind == "dense"
    by_kind = run(spec, backend="dense")
    assert by_kind.backend.kind == "dense"
    assert [r.backend.kind for r in run_all(spec)] == ["netsim", "dense"]
    with pytest.raises(KeyError, match="unknown backend"):
        run(spec, backend="cloud")


def test_run_sweep_h_grid():
    spec = tiny_netsim_spec()
    results = run_sweep(spec, "schedule.params.h", [1, 2, 4])
    assert [r.spec.schedule.params["h"] for r in results] == [1, 2, 4]
    # more communication rounds at smaller h, same iteration count
    comms = [r.trace.comms[-1] for r in results]
    assert comms[0] > comms[1] > comms[2]
    assert len({tuple(r.trace.iters) for r in results}) == 1


def test_backend_rejects_unknown_params_and_bad_combos():
    spec = tiny_netsim_spec()
    with pytest.raises(ValueError, match="unknown params"):
        run(spec.with_value("backends.0.params.typo_knob", 3))
    with pytest.raises(ValueError, match="host-only"):
        run(spec, backend="dense")  # inv_sqrt stepsize is netsim-only
    with pytest.raises(ValueError, match="expander_sequence"):
        run(tiny_netsim_spec("time_varying", rewire_every=1.0))
    with pytest.raises(KeyError, match="unknown scenario"):
        run(tiny_netsim_spec("marshy"))


# ---------------------------------------------------------------------------
# checked-in manifests: every declared backend runs
# ---------------------------------------------------------------------------


def _manifest_paths():
    return sorted(MANIFESTS.glob("*.json"))


def test_manifests_exist_for_every_figure_regime():
    names = {p.stem for p in _manifest_paths()}
    assert {"complete_every", "expander_periodic", "expander_sparse",
            "adaptive_adversarial", "launch_dryrun"} <= names


@pytest.mark.parametrize("path", _manifest_paths(), ids=lambda p: p.stem)
def test_manifest_round_trips_and_runs(path):
    spec = ExperimentSpec.from_file(path)
    # the checked-in file is exactly what the spec serializes back to
    assert json.loads(spec.to_json()) == json.loads(path.read_text())
    if spec.problem.kind == "lm":
        pytest.skip("launch manifest is exercised by "
                    "test_launch_dryrun_manifest (compile-heavy)")
    for result in run_all(spec):
        assert result.trace.iters, f"{path.stem}: empty trace"
        assert np.isfinite(result.trace.fvals).all()
        # declared netsim engines must agree bit-for-bit across the file
    netsims = [b for b in spec.backends if b.kind == "netsim"]
    if len(netsims) > 1:
        traces = [run(spec, backend=b).trace for b in netsims]
        for f in TRACE_FIELDS:
            vals = {tuple(getattr(t, f)) for t in traces}
            assert len(vals) == 1, f"engines disagree on {f}"


def test_launch_dryrun_manifest():
    """The launch backend's CI smoke: compile both step programs (cheap
    local + fused local+mix) for the smoke LM config on a 1-pod host mesh,
    run zero steps."""
    spec = ExperimentSpec.from_file(MANIFESTS / "launch_dryrun.json")
    res = run(spec)
    assert res.backend.kind == "launch"
    assert res.extras["dryrun"] is True
    assert res.extras["local_compile_s"] >= 0
    assert res.extras["fused_compile_s"] >= 0
    assert res.trace.iters == []  # zero steps by contract
    RunResult.from_json(res.to_json())


# ---------------------------------------------------------------------------
# dense_adaptive controller (DenseRTracker wiring)
# ---------------------------------------------------------------------------


def test_dense_adaptive_retunes_from_injected_timings(monkeypatch):
    """Drive the dense wall-clock loop with a fake timer that charges comm
    chunks heavily (r >> 0): the controller must measure that r and splice
    h upward -- deterministic, no real clock involved."""
    from repro.adaptive import AdaptiveSchedule, DenseController
    from repro.core import DDASimulator, complete_graph
    from repro.core.dda import stepsize_sqrt
    from repro.experiments.runner import _dense_adaptive_run

    prob = problems.build("quadratic_consensus", n=8, d=4, seed=0)
    sched = AdaptiveSchedule(h0=1)
    sim = DDASimulator(prob.subgrad_stack, prob.objective,
                       complete_graph(8), sched,
                       a_fn=stepsize_sqrt(0.5), r=0.5)

    class FakeClock:
        """Advances by the charge of the LAST simulated chunk: plain
        iterations cost 1/n each, comm iterations 1/n + k * r_true."""
        def __init__(self):
            self.t = 0.0
            self.comm_next = False

        def __call__(self):
            return self.t

    clock = FakeClock()
    real_segment = sim._segment

    def charged_segment(z, x, xhat, res, t, mask, keys):
        n, k, r_true = 8, 7, 0.05
        comm = bool(np.asarray(mask)[0])
        per = 1.0 / n + (k * r_true if comm else 0.0)
        clock.t += per * len(np.asarray(mask))
        return real_segment(z, x, xhat, res, t, mask, keys)

    monkeypatch.setattr(sim, "_segment", charged_segment)
    import jax.numpy as jnp
    ctrl = DenseController(sched, warmup_comm=2)
    trace = _dense_adaptive_run(sim, ctrl, jnp.zeros((8, 4)), T=200,
                                eval_every=20, seed=0, timer=clock)
    assert trace.iters[-1] == 200
    # constant injected timings -> the EW means are exact, and inverting
    # eq. 9 recovers the injected r exactly: t_msg = (t_comm - t_plain)/k
    # = r_true, t_full = n * t_plain = 1, r_hat = r_true
    assert ctrl.tracker.r_hat == pytest.approx(0.05, rel=1e-6)
    # ...for which eq. 21 says h_opt = sqrt(8*7*0.05/30) ~ 0.3 -> h stays 1
    assert sched.h_current == 1
    # and with a 100x costlier message the schedule must splice h upward
    sched2 = AdaptiveSchedule(h0=1)
    sim2 = DDASimulator(prob.subgrad_stack, prob.objective,
                        complete_graph(8), sched2,
                        a_fn=stepsize_sqrt(0.5), r=5.0)
    clock2 = FakeClock()
    real_segment2 = sim2._segment

    def charged_segment2(z, x, xhat, res, t, mask, keys):
        comm = bool(np.asarray(mask)[0])
        per = 1.0 / 8 + (7 * 5.0 if comm else 0.0)
        clock2.t += per * len(np.asarray(mask))
        return real_segment2(z, x, xhat, res, t, mask, keys)

    monkeypatch.setattr(sim2, "_segment", charged_segment2)
    ctrl2 = DenseController(sched2, warmup_comm=2)
    _dense_adaptive_run(sim2, ctrl2, jnp.zeros((8, 4)), T=200,
                        eval_every=20, seed=0, timer=clock2)
    assert sched2.h_current > 1, "expensive comm must raise h"
    assert sched2.retunes, "a retune must be recorded"


def test_dense_adaptive_through_run_api():
    spec = ExperimentSpec(
        name="dense-adaptive",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 4, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "adaptive", "params": {"h0": 1}},
        controller={"kind": "dense_adaptive",
                    "params": {"warmup_comm": 2, "warmup_plain": 1}},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        T=120, eval_every=20, seed=0, r=0.5)
    res = run(spec)
    assert res.trace.iters[-1] == 120
    assert "retunes" in res.extras and "r_hat" in res.extras
    # no phantom end-of-run splice: every recorded retune shaped at least
    # one future iteration
    assert all(t < 120 for t, _ in res.extras["retunes"])
    assert np.isfinite(res.trace.fvals).all()


# ---------------------------------------------------------------------------
# reweight_gossip (StragglerReweighter acting on the real mix)
# ---------------------------------------------------------------------------


def _reweight_spec(engine):
    return ExperimentSpec(
        name="reweight",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 4, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 8, "seed": 0}},
        schedule={"kind": "adaptive", "params": {"h0": 1}},
        controller={"kind": "adaptive",
                    "params": {"update_every": 0.5, "warmup_messages": 4,
                               "warmup_steps": 4, "reweight_gossip": True}},
        backends=[{"kind": "netsim",
                   "params": {"scenario": "straggler", "slow_factor": 4.0,
                              "n_slow": 2, "engine": engine}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": 0.5}},
        T=500, eval_every=10, seed=0, r=0.5, eps_frac=0.05,
        time_limit=3000.0)


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_reweight_gossip_converges(engine):
    """Convergence smoke: with the reweighted P driving the ACTUAL gossip
    on a straggler-heavy cluster, the run still reaches the 5% target (the
    reweighted rows stay convex combinations, so DDA's contraction
    survives), and the flag actually engaged."""
    res = run(_reweight_spec(engine))
    assert res.extras["reweight_gossip"] is True
    assert res.time_to_target is not None, "never reached the 5% target"
    assert res.extras["lam2_eff"] is not None
    prob = problems.build("quadratic_consensus", n=8, d=4, seed=0)
    gap0 = prob.f0() - prob.fstar
    assert res.trace.fvals[-1] - prob.fstar < 0.1 * gap0


def test_reweight_gossip_rejected_for_pushsum():
    spec = _reweight_spec("vectorized")
    bad = spec.with_value("backends.0.params.algorithm", "pushsum")
    with pytest.raises(ValueError, match="stale-gossip"):
        run(bad)


def test_mix_weights_off_keeps_uniform_path():
    """reweight_gossip=False (default) must leave Network.mix_weights None
    for the whole run -- the bit-identity contract's precondition."""
    from repro.netsim import NetSimulator
    spec = _reweight_spec("vectorized")
    no_rw = ExperimentSpec.from_dict({
        **spec.to_dict(),
        "controller": {"kind": "adaptive",
                       "params": {"update_every": 0.5,
                                  "warmup_messages": 4,
                                  "warmup_steps": 4}}})
    a = run(no_rw)
    assert a.extras["reweight_gossip"] is False
    b = run(ExperimentSpec.from_dict(no_rw.to_dict()))
    for f in TRACE_FIELDS:
        assert getattr(a.trace, f) == getattr(b.trace, f)
