"""The robustness tier for `repro.serve`: supervised worker pools,
deadlines, retries/dedup, load shedding, and real-process chaos.

Three layers, cheapest first:

  * `WorkerPool` units against `_toy_worker_main` -- a spawn worker that
    interprets commands (sleep/crash/echo) instead of running XLA, so
    crash re-enqueue, the re-enqueue cap, deadline kills, and drain
    semantics are exercised in real processes for milliseconds each.
  * Server-level robustness with the in-process executor: bounded
    admission (`Overloaded` + retry-after hint), deadline shedding,
    idempotency dedup (in-flight join + completed replay, never a
    second execution), graceful-drain refusal, and the satellite-(a)
    regression -- a `SystemExit` escaping a run must tear the server
    down, not masquerade as a run failure.
  * The chaos gate (`-m chaos`): a real pooled server behind a
    `ChaosProxy`, a seeded `ChaosPlan` SIGKILLing a worker mid-run and
    tearing a response line, a retrying `Client` -- every request must
    still end bit-identical to cold solo `repro.run()` with at most one
    execution per idempotency key.

Client transport units (per-op timeouts, torn-line detection, tolerant
shutdown) run against tiny hand-rolled socket servers.
"""

import json
import os
import socket
import threading
import time
from concurrent.futures import Future

import pytest

import repro
from repro.experiments import ExperimentSpec
from repro.serve import (ChaosPlan, ChaosProxy, Client, DeadlineExceeded,
                         ExperimentServer, Overloaded, PoolError,
                         ShuttingDown, WorkerCrashed, WorkerPool,
                         comparable_result_dict)
from repro.serve.pool import _toy_worker_main


def _spec(**kw):
    base = dict(
        name="robust",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 8, "d": 6, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        T=60, eval_every=20, seed=0, r=0.01, eps_frac=0.05)
    base.update(kw)
    return ExperimentSpec(**base)


def _toy_pool(**kw):
    kw.setdefault("backoff_base_s", 0.05)
    kw.setdefault("backoff_cap_s", 0.2)
    return WorkerPool(kw.pop("processes", 1), worker_main=_toy_worker_main,
                      **kw)


def _cmd(**kw):
    return json.dumps(kw)


# ---------------------------------------------------------------------------
# WorkerPool units (toy workers: real processes, no XLA)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_toy_pool_echo_roundtrip():
    with _toy_pool(processes=2) as pool:
        futs = [pool.submit([_cmd(action="echo", value=i)], [None])
                for i in range(6)]
        for i, f in enumerate(futs):
            payload, meta = f.result(timeout=60)
            assert json.loads(payload[0])["value"] == i
            assert meta["reenqueues"] == 0
        assert pool.stats()["jobs_ok"] == 6


@pytest.mark.chaos
def test_crash_is_reenqueued_transparently(tmp_path):
    """A worker crash mid-job re-enqueues the job; the retry succeeds
    (the marker file makes the crash one-shot) and the caller never sees
    the failure -- only the `reenqueues` meta records it."""
    marker = str(tmp_path / "crashed-once")
    with _toy_pool(processes=1) as pool:
        payload, meta = pool.submit(
            [_cmd(action="crash_once", marker=marker)], [None]
        ).result(timeout=60)
        assert meta["reenqueues"] == 1
        stats = pool.stats()
        assert stats["worker_restarts"] >= 1
        assert stats["reenqueues"] == 1
        assert stats["jobs_ok"] == 1
    assert os.path.exists(marker)


@pytest.mark.chaos
def test_reenqueue_cap_fails_job():
    """A job that kills every worker it touches must not loop forever:
    after max_reenqueues crashes it fails with WorkerCrashed."""
    with _toy_pool(processes=1, max_reenqueues=2) as pool:
        fut = pool.submit([_cmd(action="crash")], [None])
        with pytest.raises(WorkerCrashed):
            fut.result(timeout=60)
        assert pool.stats()["reenqueues"] == 3  # initial + 2 retries
        # the pool survives its poison pill: next job runs fine
        payload, _ = pool.submit([_cmd(action="echo", value=7)],
                                 [None]).result(timeout=60)
        assert json.loads(payload[0])["value"] == 7


@pytest.mark.chaos
def test_deadline_kills_overrunning_worker():
    with _toy_pool(processes=1) as pool:
        # wait out the spawn first, so the deadline can only expire
        # MID-RUN (a slow spawn would otherwise shed it pre-dispatch)
        pool.submit([_cmd(action="echo", value=0)], [None]).result(timeout=60)
        fut = pool.submit([_cmd(action="sleep", s=30)], [None],
                          deadline=time.monotonic() + 0.5)
        with pytest.raises(DeadlineExceeded) as ei:
            fut.result(timeout=60)
        assert not ei.value.shed  # killed mid-run, not shed
        assert pool.stats()["deadline_missed"] == 1
        # the killed worker's replacement serves the next job
        payload, _ = pool.submit([_cmd(action="echo", value=1)],
                                 [None]).result(timeout=60)
        assert json.loads(payload[0])["value"] == 1


@pytest.mark.chaos
def test_expired_job_is_shed_not_run():
    with _toy_pool(processes=1) as pool:
        # occupy the worker so the expired job sits in the queue
        slow = pool.submit([_cmd(action="sleep", s=1.0)], [None])
        fut = pool.submit([_cmd(action="echo", value=1)], [None],
                          deadline=time.monotonic() + 0.05)
        with pytest.raises(DeadlineExceeded) as ei:
            fut.result(timeout=60)
        assert ei.value.shed
        slow.result(timeout=60)


@pytest.mark.chaos
def test_worker_error_does_not_restart_worker():
    """An in-worker Exception is a job failure, not a crash: the same
    process keeps serving and the exception type round-trips."""
    with _toy_pool(processes=1) as pool:
        fut = pool.submit([_cmd(action="raise", msg="boom")], [None])
        with pytest.raises(ValueError, match="boom"):
            fut.result(timeout=60)
        payload, meta = pool.submit([_cmd(action="echo", value=2)],
                                    [None]).result(timeout=60)
        assert json.loads(payload[0])["value"] == 2
        assert pool.stats()["worker_restarts"] == 0


@pytest.mark.chaos
def test_pool_drain_then_refuse():
    pool = _toy_pool(processes=1)
    fut = pool.submit([_cmd(action="sleep", s=0.3, value=9)], [None])
    pool.close(drain=True)
    payload, _ = fut.result(timeout=60)  # drained, not dropped
    assert json.loads(payload[0])["value"] == 9
    with pytest.raises(PoolError):
        pool.submit([_cmd(action="echo")], [None])


# ---------------------------------------------------------------------------
# server-level robustness (in-process executor: no spawn cost)
# ---------------------------------------------------------------------------


def test_overloaded_admission_with_retry_after_hint():
    with ExperimentServer(workers=1, max_queue=2) as srv:
        srv._pending_n = 2  # saturate admission deterministically
        with pytest.raises(Overloaded) as ei:
            srv.submit(_spec())
        assert ei.value.retry_after_s > 0
        assert srv.stats()["robustness"]["overloaded"] == 1
        srv._pending_n = 0


def test_expired_request_is_shed_server_side():
    with ExperimentServer(workers=1, packing=False) as srv:
        fut = srv.submit(_spec(), backend="dense", deadline_s=0.0)
        with pytest.raises(DeadlineExceeded) as ei:
            fut.result(timeout=60)
        assert ei.value.shed
        assert srv.stats()["robustness"]["requests_shed"] == 1


def test_idempotency_dedup_inflight_and_replay():
    spec = _spec(name="idem")
    with ExperimentServer(workers=1, max_wait_s=0.01) as srv:
        f1 = srv.submit(spec, backend="dense", idempotency_key="k1")
        f2 = srv.submit(spec, backend="dense", idempotency_key="k1")
        assert f2 is f1  # in-flight join: same Future, one execution
        r1 = f1.result(timeout=120)
        f3 = srv.submit(spec, backend="dense", idempotency_key="k1")
        assert f3.result(timeout=5) is r1  # completed key replays
        st = srv.stats()
        assert st["robustness"]["requests_retried"] == 2
        assert st["dedup"]["max_executions_per_key"] == 1
        assert comparable_result_dict(r1) == comparable_result_dict(
            repro.run(spec, backend="dense"))


def test_closed_server_refuses_with_shutting_down():
    srv = ExperimentServer(workers=1)
    srv.close()
    with pytest.raises(ShuttingDown):
        srv.submit(_spec())


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_fatal_signal_tears_server_down_not_masked():
    """Satellite (a): SystemExit out of a run is not swallowed as a run
    failure -- the waiter is failed (no stranded client) AND the server
    records the fatal and tears down, refusing further work."""
    from repro.experiments.components import problems

    @problems.register("exploding_problem_for_test")
    def _exploding(**kw):
        raise SystemExit(3)

    try:
        spec = _spec(name="fatal",
                     problem={"kind": "exploding_problem_for_test",
                              "params": {}})
        srv = ExperimentServer(workers=1, max_wait_s=0.01)
        try:
            fut = srv.submit(spec, backend="dense")
            with pytest.raises(SystemExit):
                fut.result(timeout=60)
            deadline = time.monotonic() + 10
            while srv.fatal is None and time.monotonic() < deadline:
                time.sleep(0.01)
            assert isinstance(srv.fatal, SystemExit)
            assert srv.stats()["server"]["fatal"] is not None
            deadline = time.monotonic() + 10
            while not srv._closed and time.monotonic() < deadline:
                time.sleep(0.01)
            with pytest.raises(ShuttingDown):
                srv.submit(_spec())
        finally:
            srv.close()
    finally:
        problems._builders.pop("exploding_problem_for_test", None)


# ---------------------------------------------------------------------------
# client transport units (hand-rolled socket peers)
# ---------------------------------------------------------------------------


def _fake_server(behavior):
    """One-connection-at-a-time fake server; `behavior(conn, rfile)` is
    called per accepted connection. Returns (host, port, close)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", 0))
    srv.listen(4)

    def loop():
        while True:
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            try:
                behavior(conn, conn.makefile("rb"))
            except OSError:
                pass
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    threading.Thread(target=loop, daemon=True).start()
    host, port = srv.getsockname()[:2]
    return host, port, srv.close


def test_client_shutdown_tolerates_connection_close():
    """Satellite (b): a server that closes the connection instead of
    replying "bye" is a clean shutdown, not a ConnectionResetError."""
    def behavior(conn, rfile):
        rfile.readline()  # the shutdown op
        import struct
        conn.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                        struct.pack("ii", 1, 0))  # hard RST on close
        conn.close()

    host, port, close = _fake_server(behavior)
    try:
        with Client(host, port, timeout=5) as c:
            c.shutdown()  # must not raise
    finally:
        close()


def test_client_per_op_timeout_override():
    """Satellite (b): a per-op timeout beats the connect-time default."""
    def behavior(conn, rfile):
        rfile.readline()
        time.sleep(5)  # never answer within the op timeout

    host, port, close = _fake_server(behavior)
    try:
        with Client(host, port, timeout=60) as c:
            t0 = time.monotonic()
            with pytest.raises(OSError):
                c.ping(timeout=0.2)
            assert time.monotonic() - t0 < 2
    finally:
        close()


def test_client_detects_torn_response_line():
    """A response cut mid-line is a transport error (retryable), not a
    JSON parse crash."""
    def behavior(conn, rfile):
        rfile.readline()
        conn.sendall(b'{"event": "po')  # torn: no newline, then close

    host, port, close = _fake_server(behavior)
    try:
        with Client(host, port, timeout=5) as c:
            with pytest.raises(ConnectionError, match="torn|closed"):
                c.ping()
    finally:
        close()


# ---------------------------------------------------------------------------
# the chaos gate (real pooled server + proxy + retrying client)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.serve
def test_chaos_gate_bit_identical_and_no_double_execution():
    """The acceptance gate: a seeded ChaosPlan SIGKILLs a worker mid-run
    and tears one TCP response; every request must still succeed (via
    re-enqueue or client retry) bit-identical to cold solo repro.run(),
    with at most one execution per idempotency key."""
    specs = [_spec(name=f"chaos{i}", seed=i) for i in range(3)]
    solos = {s.seed: repro.run(s, backend="dense") for s in specs}
    plan = ChaosPlan(seed=7, kill_at_dispatch=(1,),
                     kill_delay_s=(0.05, 0.3),
                     tear_response_at=(5,))
    srv = ExperimentServer(processes=2, max_wait_s=0.02, chaos=plan,
                           pool_kwargs={"backoff_base_s": 0.05})
    try:
        host, port = srv.start()
        with ChaosProxy(host, port, plan) as proxy:
            phost, pport = proxy.address
            with Client(phost, pport, timeout=240, retries=4,
                        seed=11) as client:
                results = {s.seed: client.run(s, backend="dense")
                           for s in specs}
        for seed, res in results.items():
            rt = repro.RunResult.from_json(res.to_json())
            assert (comparable_result_dict(rt)
                    == comparable_result_dict(solos[seed])), \
                f"chaos seed {seed}: served result differs from solo"
        st = srv.stats()
        assert st["robustness"]["worker_restarts"] >= 1
        assert st["dedup"]["max_executions_per_key"] <= 1
        assert st["chaos"]["kills_delivered"] >= 1
        assert proxy.stats()["torn_responses"] == 1
    finally:
        srv.close()


@pytest.mark.chaos
@pytest.mark.serve
def test_pooled_server_inflight_survives_drain():
    """Graceful drain: in-flight pooled work finishes through close()."""
    spec = _spec(name="drain")
    solo = repro.run(spec, backend="dense")
    srv = ExperimentServer(processes=1, packing=False)
    fut = srv.submit(spec, backend="dense")
    srv.close()  # drain, not drop
    res = fut.result(timeout=10)
    assert comparable_result_dict(res) == comparable_result_dict(solo)
