"""Convergence-regression tier: the paper's guarantees as executable
assertions (run via `pytest -m convergence`; excluded from the default
tier-1 run by addopts, see pyproject.toml).

Two families:

  * envelope shapes -- seeded end-to-end `DDASimulator` runs under the
    every-iteration / periodic-h / increasingly-sparse schedules must keep
    the optimality gap inside the C_1 / C_h / C_p envelopes of eqs. (7),
    (18), (31): gap(t) <= TOL * C * t^(-power) past a burn-in, with
    checked-in TOL bounds. The measured peak envelope ratios on the seed
    are ~0.16 / 0.16 / 0.04, so the TOLs (~2x those) pin real regressions
    (broken mixing, mis-scaled stepsize, schedule bookkeeping drift) while
    staying insensitive to platform float noise.

  * closed-loop win -- on the `scenarios.adversarial` preset the adaptive
    controller must reach the accuracy target in no more simulated
    wall-clock than the best fixed Periodic(h) of a swept grid (the
    fig_adaptive acceptance, as a regression test).

On failure each test dumps its traces as JSON under
$CONVERGENCE_ARTIFACTS (default `convergence-traces/`) so the CI job can
upload them for post-mortem.
"""

import math
import os
import pathlib

import numpy as np
import pytest

pytestmark = pytest.mark.convergence

ARTIFACT_DIR = os.environ.get("CONVERGENCE_ARTIFACTS", "convergence-traces")

# checked-in tolerance bounds: measured peak envelope ratio on the seed,
# with ~2x headroom (runs are seeded and derandomized; see module docstring)
ENVELOPE_TOL = {
    "every": 0.35,        # measured 0.161
    "periodic3": 0.35,    # measured 0.161
    "sparse0.25": 0.10,   # measured 0.036
}
BURN_IN = 100  # iterations before the envelope is enforced (transient)


def _dump_artifact(name: str, payload: dict) -> str:
    from repro.obs import write_json_artifact

    # always ship the r-hat trajectory key, even when the failing run had
    # no controller: post-mortems grep one schema across all artifacts
    payload.setdefault("r_hat_trajectory", [])
    return write_json_artifact(
        pathlib.Path(ARTIFACT_DIR) / f"{name}.json", payload)


def _checked(name: str, payload: dict, ok: bool, message: str) -> None:
    """Assert, dumping the run's traces as an artifact on failure."""
    if not ok:
        where = _dump_artifact(name, payload)
        pytest.fail(f"{message} (trace dumped to {where})")


# -- envelope fixtures -------------------------------------------------------


def _paper_problem(n=8, d=4, seed=0):
    """Quadratic consensus objective with KNOWN constants: domain ball of
    radius R_dom containing the optimum, subgradient bound L on the ball,
    psi(x*) <= R^2 with psi = 0.5||x||^2 -- everything eqs. (7)/(18)/(31)
    need, with F* in closed form."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n, d)) * 2.0 + 3.0
    cbar = centers.mean(axis=0)
    fstar = float(np.mean(np.sum(centers ** 2, axis=1)) - np.sum(cbar ** 2))
    R_dom = float(np.linalg.norm(cbar)) * 2.0
    L = float(2.0 * (R_dom + np.max(np.linalg.norm(centers, axis=1))))
    R = float(np.linalg.norm(cbar)) / math.sqrt(2.0)
    cj = jnp.asarray(centers)

    def subgrad(x, t, key):
        return 2.0 * (x - cj)

    def evalf(x):
        return jnp.mean(jnp.sum((x[None] - cj) ** 2, axis=-1))

    def proj(x):
        nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
        return jnp.where(nrm > R_dom, x * (R_dom / nrm), x)

    return subgrad, evalf, proj, fstar, L, R


def _envelope_cases():
    from repro.core.schedules import (EveryIteration, IncreasinglySparse,
                                      Periodic, c1_constant, ch_constant,
                                      cp_constant)

    # (key, schedule, constant_fn(L, R, lam2), envelope power, h for eq-18 A)
    return [
        ("every", EveryIteration(),
         lambda L, R, lam2: c1_constant(L, R, lam2), 0.5, 1),
        ("periodic3", Periodic(h=3),
         lambda L, R, lam2: ch_constant(L, R, lam2, 3), 0.5, 3),
        ("sparse0.25", IncreasinglySparse(p=0.25),
         lambda L, R, lam2: cp_constant(L, R, lam2, 0.25),
         (1.0 - 2.0 * 0.25) / 2.0, 1),
    ]


@pytest.mark.parametrize("case", _envelope_cases(), ids=lambda c: c[0])
def test_error_trace_stays_inside_paper_envelope(case):
    """Seeded end-to-end run: gap(t) <= TOL * C * t^(-power) for t past the
    burn-in, with the bound-optimal stepsize of eq. (18)."""
    from repro.core.dda import DDASimulator, stepsize_sqrt
    from repro.core.graphs import kregular_expander
    from repro.core.schedules import optimal_stepsize_A

    import jax.numpy as jnp

    key, schedule, constant_fn, power, h_for_A = case
    n, d, T = 8, 4, 4000
    subgrad, evalf, proj, fstar, L, R = _paper_problem(n, d)
    graph = kregular_expander(n, k=4, seed=0)
    lam2 = graph.lambda2()
    C = constant_fn(L, R, lam2)
    A = optimal_stepsize_A(L, R, lam2, h_for_A)
    sim = DDASimulator(subgrad, evalf, graph, schedule=schedule,
                       a_fn=stepsize_sqrt(A), projection=proj)
    trace = sim.run(jnp.zeros((n, d)), T=T, eval_every=50, seed=0)

    ratios = [(fv - fstar) / (C * t ** (-power))
              for t, fv in zip(trace.iters, trace.fvals) if t >= BURN_IN]
    peak = max(ratios)
    payload = {"case": key, "C": C, "power": power, "A": A, "lam2": lam2,
               "L": L, "R": R, "fstar": fstar, "tol": ENVELOPE_TOL[key],
               "peak_ratio": peak, "iters": trace.iters,
               "fvals": trace.fvals, "ratios": ratios}
    # every TOL is < 1, so this also enforces the paper bound itself
    _checked(f"envelope_{key}", payload, peak <= ENVELOPE_TOL[key],
             f"{key}: envelope ratio {peak:.4f} exceeds checked-in "
             f"tolerance {ENVELOPE_TOL[key]} (C={C:.1f}, power={power})")


def test_envelope_constants_are_ordered():
    """Eq. (18) collapses to eq. (7)'s structure at h = 1 and grows with h
    -- the ordering the periodic tradeoff relies on."""
    from repro.core.schedules import c1_constant, ch_constant

    L, R, lam2 = 1.0, 1.0, 0.5
    assert ch_constant(L, R, lam2, 1) < ch_constant(L, R, lam2, 3) \
        < ch_constant(L, R, lam2, 9)
    assert c1_constant(L, R, lam2) > 0.0


# -- closed-loop regression --------------------------------------------------


def test_adaptive_beats_best_fixed_h_on_adversarial(capsys):
    """fig_adaptive's acceptance (closed loop strictly beats every fixed
    Periodic(h) in the swept grid on the adversarial preset, and the
    engines stay bit-identical with the controller off), run through the
    benchmark's own --smoke entry point so the regression tier and the CI
    smoke step can never drift apart."""
    import importlib
    import sys

    bench_dir = str(pathlib.Path(__file__).resolve().parents[1]
                    / "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        fig_adaptive = importlib.import_module("fig_adaptive")
        rc = fig_adaptive.main(["--smoke"])
    finally:
        sys.path.remove(bench_dir)
    out = capsys.readouterr().out
    _checked("adaptive_vs_fixed", {"smoke_output": out, "returncode": rc},
             rc == 0, f"fig_adaptive --smoke failed:\n{out}")
