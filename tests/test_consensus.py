"""Consensus mixing invariants: average preservation and contraction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.core import consensus as C
from repro.core import graphs as G


@given(name=st.sampled_from(["complete", "ring", "hypercube", "expander4"]),
       rows=st.integers(1, 6), seed=st.integers(0, 10))
def test_mean_preservation(name, rows, seed):
    n = 8
    g = G.build_graph(name, n)
    z = jnp.asarray(np.random.default_rng(seed).normal(size=(n, rows, 3)),
                    jnp.float32)
    zm = C.mix_dense(z, g.mixing_matrix())
    np.testing.assert_allclose(np.asarray(zm.mean(0)), np.asarray(z.mean(0)),
                               atol=1e-5)


@given(name=st.sampled_from(["ring", "hypercube", "expander4", "complete"]),
       seed=st.integers(0, 10))
def test_disagreement_contracts(name, seed):
    n = 8
    g = G.build_graph(name, n)
    z = jnp.asarray(np.random.default_rng(seed).normal(size=(n, 5)),
                    jnp.float32)
    d0 = float(C.disagreement(z))
    zm = C.mix_dense(z, g.mixing_matrix())
    d1 = float(C.disagreement(zm))
    assert d1 <= d0 + 1e-6


def test_complete_graph_one_round_consensus():
    g = G.complete_graph(5)
    z = jnp.asarray(np.random.default_rng(0).normal(size=(5, 7)), jnp.float32)
    zm = C.mix_dense(z, g.mixing_matrix())
    assert float(C.disagreement(zm)) < 1e-5


def test_repeated_mixing_converges_to_average():
    g = G.ring_graph(6)
    P = g.mixing_matrix()
    z = jnp.asarray(np.random.default_rng(1).normal(size=(6, 4)), jnp.float32)
    target = z.mean(0)
    for _ in range(200):
        z = C.mix_dense(z, P)
    np.testing.assert_allclose(np.asarray(z), np.tile(target, (6, 1)),
                               atol=1e-4)


def test_contraction_rate_matches_lambda2():
    """||z - zbar|| after one round shrinks by at most lambda2 (in 2-norm
    across the stacked matrix)."""
    g = G.random_regular_expander(16, k=4, seed=3)
    P = g.mixing_matrix()
    lam2 = g.lambda2()
    rng = np.random.default_rng(0)
    worst = 0.0
    for _ in range(10):
        z = rng.normal(size=(16, 8)).astype(np.float32)
        z -= z.mean(0, keepdims=True)
        zm = P @ z
        ratio = np.linalg.norm(zm) / np.linalg.norm(z)
        worst = max(worst, ratio)
    assert worst <= lam2 + 1e-5


def test_tree_mix_dense():
    g = G.complete_graph(4)
    tree = {"a": jnp.arange(8.0).reshape(4, 2),
            "b": jnp.ones((4, 3))}
    out = C.tree_mix_dense(tree, g.mixing_matrix())
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.tile(np.asarray(tree["a"]).mean(0), (4, 1)),
                               atol=1e-6)
