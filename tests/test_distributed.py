"""Multi-device distribution tests. These need >1 host device, so they run
in a SUBPROCESS with XLA_FLAGS set (the main test process keeps the default
single device per the dry-run contract)."""

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mix_collective_matches_dense_oracle():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import graphs as G, consensus as C
        from repro.launch.compat import shard_map
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("pod",))
        for name in ("complete", "ring", "hypercube", "expander4"):
            g = G.build_graph(name, 8)
            z = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                            jnp.float32)
            def mix(zl):
                return C.mix_collective(zl[0], g, "pod")[None]
            f = shard_map(mix, mesh=mesh, in_specs=P("pod"),
                          out_specs=P("pod"), axis_names={"pod"})
            got = jax.jit(f)(z)
            want = C.mix_dense(z, g.mixing_matrix())
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, err_msg=name)
        print("OK")
    """)


def test_consensus_sgd_equals_allreduce_dp():
    """Gossip parameter averaging (complete graph, h=1, plain SGD) must
    follow the EXACT same trajectory as synchronous all-reduce data
    parallelism -- the correctness anchor tying the paper's technique to
    standard DP."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import graphs as G, consensus as C

        n, d, steps, lr = 4, 6, 10, 0.1
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.normal(size=(n, 32, d)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(n, 32)), jnp.float32)

        def node_grad(w, Ai, bi):
            return jax.grad(lambda w_: jnp.mean(
                (Ai @ w_ - bi) ** 2))(w)

        # all-reduce DP: one shared w, mean gradient
        w_dp = jnp.zeros(d)
        for _ in range(steps):
            g = jnp.mean(jax.vmap(node_grad, (None, 0, 0))(w_dp, A, b), 0)
            w_dp = w_dp - lr * g

        # gossip DP: per-node w, local step then complete-graph average
        gC = G.complete_graph(n)
        w = jnp.zeros((n, d))
        for _ in range(steps):
            g = jax.vmap(node_grad)(w, A, b)
            w = w - lr * g
            w = C.mix_dense(w, gC.mixing_matrix())
        np.testing.assert_allclose(np.asarray(w[0]), np.asarray(w_dp),
                                   atol=1e-5)
        print("OK")
    """)


def test_consensus_steps_compile_and_converge():
    """make_consensus_steps on a (2,2,2) mesh: fused/local/mix all compile;
    loss decreases over 12 steps; per-pod losses stay close after mixing."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.launch.train import train_consensus_lm
        from repro.models import registry
        from repro.optim import adamw, constant_lr
        from repro.core.schedules import Periodic

        cfg = registry.get_config("llama3-8b", "smoke")
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rep = train_consensus_lm(cfg, adamw(constant_lr(2e-3)), mesh,
                                 steps=12, schedule=Periodic(h=3),
                                 topology="complete", batch_per_node=2,
                                 log_every=0)
        assert rep.losses[-1] < rep.losses[0], rep.losses
        print("OK")
    """)


def test_dryrun_single_cell_subprocess():
    """The dry-run itself (512 placeholder devices) for one small cell."""
    _run("""
        import subprocess, sys
        # run the real dryrun module (it sets its own XLA_FLAGS first)
        import os
        os.environ.pop("XLA_FLAGS", None)
        from importlib import reload
        import repro.launch.dryrun  # noqa: F401  (sets 512 devices)
        import jax
        assert jax.device_count() == 512, jax.device_count()
        from repro.configs.shapes import ShapeCell
        from repro.launch.dryrun import dryrun_cell
        cell = ShapeCell("train_4k", 4096, 256, "train")
        rec = dryrun_cell("musicgen-medium", cell, False, save=False,
                          verbose=False)
        assert rec["cost"].get("flops", 0) > 0
        assert rec["memory"]["temp_size_in_bytes"] > 0
        print("OK")
    """)
