"""repro.adaptive: streaming r estimation, schedule mutation invariants
(property-tested), straggler reweighting, and the closed loop end-to-end."""

import math

import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.adaptive import (AdaptiveController, AdaptiveSchedule,
                            DenseRTracker, RTracker, StragglerReweighter)
from repro.core.graphs import complete_graph, kregular_expander
from repro.core.schedules import Periodic, PiecewisePeriodic
from repro.core.tradeoff import ew_alpha, ew_update, h_opt_int, lambda2_fast
from repro.netsim import (NetSimulator, adversarial, quadratic_consensus as
                          _problem)
from repro.core.dda import TRACE_FIELDS
from repro.runtime.fault_tolerance import (arrival_reweighted_matrix,
                                           degraded_matrix, sinkhorn_project)


# -- RTracker ----------------------------------------------------------------


def test_rtracker_recovers_r_from_stationary_observations():
    n = 8
    tr = RTracker(n, halflife=16.0)
    assert tr.r_hat is None  # no prior, nothing observed
    for _ in range(30):
        tr.observe_steps(np.arange(n), np.full(n, 1.0 / n))
        tr.observe_messages(np.full(12, 0.05))
    assert tr.r_hat == pytest.approx(0.05, rel=1e-9)
    assert tr.t_grad_full == pytest.approx(1.0, rel=1e-9)
    assert tr.ready(min_messages=10, min_steps=10)


def test_rtracker_median_robust_to_stragglers():
    """One 4x straggler must shift the straggler quantiles, not r_hat --
    the median-of-nodes convention of measure_r_empirical."""
    n = 8
    tr = RTracker(n, halflife=8.0)
    durations = np.full(n, 1.0 / n)
    durations[0] *= 4.0
    for _ in range(20):
        tr.observe_steps(np.arange(n), durations)
        tr.observe_messages(np.array([0.02]))
    assert tr.t_grad_full == pytest.approx(1.0, rel=1e-9)
    assert tr.r_hat == pytest.approx(0.02, rel=1e-9)
    assert tr.step_means[0] == pytest.approx(4.0 / n, rel=1e-9)


def test_rtracker_ew_tracks_drift():
    tr = RTracker(2, halflife=4.0)
    tr.observe_messages(np.full(50, 1.0))
    tr.observe_messages(np.full(50, 3.0))
    assert 2.9 < tr.t_msg <= 3.0  # window forgets the old regime


def test_rtracker_prior_used_until_measured():
    tr = RTracker(4, r0=0.125)
    assert tr.r_hat == 0.125
    tr.observe_steps(np.arange(4), np.full(4, 0.25))
    assert tr.r_hat == 0.125  # still no message signal
    tr.observe_messages(np.array([0.5]))
    assert tr.r_hat == pytest.approx(0.5, rel=1e-9)


def test_ew_update_batch_fold_matches_sequential_on_constant():
    a = ew_alpha(8.0)
    m = ew_update(math.nan, 2.0, 5, a)
    assert m == 2.0
    seq = 2.0
    for _ in range(7):
        seq = ew_update(seq, 4.0, 1, a)
    batch = ew_update(2.0, 4.0, 7, a)
    assert batch == pytest.approx(seq, rel=1e-12)


def test_dense_rtracker_inverts_eq9():
    """Feed exact eq. (9) timings: plain iter = 1/n, comm iter adds k*r."""
    n, k, r = 10, 4, 0.03
    tr = DenseRTracker(n, k, halflife=8.0)
    assert tr.r_hat is None
    for _ in range(20):
        tr.observe_iteration(1.0 / n, was_comm=False)
        tr.observe_iteration(1.0 / n + k * r, was_comm=True)
    assert tr.r_hat == pytest.approx(r, rel=1e-9)


def test_rtracker_zero_length_batches_are_noops():
    """Empty observation windows (a vectorized-engine chunk with no
    completed events) must not touch the estimate or the counts."""
    tr = RTracker(4, halflife=8.0)
    tr.observe_steps(np.arange(4), np.full(4, 0.25))
    tr.observe_messages(np.array([0.5]))
    before = tr.r_hat
    tr.observe_messages(np.array([]))
    tr.observe_steps(np.array([], dtype=int), np.array([]))
    assert tr.r_hat == before
    assert (tr.n_messages, tr.n_steps) == (1, 4)


def test_rtracker_single_node_timeline():
    """n=1 degenerates cleanly: the median of one node IS that node, and
    t_grad_full carries no * n inflation."""
    tr = RTracker(1, halflife=8.0)
    assert tr.r_hat is None
    tr.observe_steps(np.array([0]), np.array([2.0]))
    tr.observe_messages(np.array([0.5]))
    assert tr.t_grad_full == pytest.approx(2.0)
    assert tr.r_hat == pytest.approx(0.25)


def test_rtracker_partial_node_coverage_uses_nanmedian():
    """Before every node has reported a step, the median runs over the
    nodes that HAVE (nanmedian), not over NaN placeholders."""
    tr = RTracker(4, halflife=8.0)
    tr.observe_steps(np.array([0, 2]), np.array([0.25, 0.25]))
    assert tr.t_grad_full == pytest.approx(1.0)
    tr.observe_messages(np.array([0.1]))
    assert tr.r_hat == pytest.approx(0.1)


def test_rtracker_ready_boundaries():
    tr = RTracker(2)
    assert not tr.ready()
    tr.observe_messages(np.array([0.1, 0.1]))
    assert not tr.ready()  # messages alone are not enough
    tr.observe_steps(np.array([0]), np.array([0.5]))
    assert tr.ready()
    assert tr.ready(min_messages=2, min_steps=1)
    assert not tr.ready(min_messages=3)
    assert not tr.ready(min_steps=2)


def test_rtracker_rejects_empty_network():
    with pytest.raises(ValueError):
        RTracker(0)
    with pytest.raises(ValueError):
        DenseRTracker(0, 1)
    with pytest.raises(ValueError):
        DenseRTracker(4, 0)


def test_dense_rtracker_rejects_negative_wall():
    tr = DenseRTracker(4, 2)
    with pytest.raises(ValueError):
        tr.observe_iteration(-1e-9, was_comm=False)


def test_dense_rtracker_clamps_when_comm_looks_cheaper():
    """Measurement noise can make a comm iteration look cheaper than a
    plain one; the eq. (9) inversion clamps t_msg at 0 instead of going
    negative."""
    tr = DenseRTracker(4, 2, halflife=4.0)
    for _ in range(10):
        tr.observe_iteration(0.25, was_comm=False)
        tr.observe_iteration(0.20, was_comm=True)
    assert tr.r_hat == 0.0


# -- schedule mutation invariants --------------------------------------------


def _assert_invariants(sched, upto=200):
    """The contract adaptive splicing must never break."""
    prev_H = 0
    for t in range(1, upto):
        Ht = sched.H(t)
        assert Ht >= prev_H, f"H decreased at {t}"
        assert Ht - prev_H == int(sched.is_comm_step(t)), \
            f"H increment vs is_comm_step mismatch at {t}"
        prev_H = Ht
    for t in range(0, upto):
        nc = sched.next_comm_step(t)
        assert nc > t
        assert sched.is_comm_step(nc), f"next_comm_step({t})={nc} not comm"
        assert all(not sched.is_comm_step(s) for s in range(t + 1, nc)), \
            f"next_comm_step({t}) skipped a comm step"
    ts = np.arange(0, upto, dtype=np.int64)
    batch = sched.next_comm_step_batch(ts)
    scalar = [sched.next_comm_step(int(t)) for t in ts]
    assert batch.tolist() == scalar


def test_piecewise_matches_periodic_unmutated():
    for h in (1, 2, 5):
        pw, p = PiecewisePeriodic(h=h), Periodic(h=h)
        for t in range(1, 120):
            assert pw.is_comm_step(t) == p.is_comm_step(t)
            assert pw.H(t) == p.H(t)
        for t in range(0, 120):
            assert pw.next_comm_step(t) == p.next_comm_step(t)


def test_piecewise_seeded_splice_sequences():
    """Non-hypothesis version: random monotone splice scripts."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        sched = PiecewisePeriodic(h=int(rng.integers(1, 6)))
        from_t = 0
        for _ in range(rng.integers(1, 8)):
            from_t += int(rng.integers(0, 30))
            sched.set_h(from_t, int(rng.integers(1, 12)))
        _assert_invariants(sched, upto=from_t + 60)


def test_piecewise_past_is_immutable():
    sched = PiecewisePeriodic(h=3)
    before = [sched.is_comm_step(t) for t in range(1, 21)]
    H20 = sched.H(20)
    sched.set_h(20, 7)
    assert [sched.is_comm_step(t) for t in range(1, 21)] == before
    assert sched.H(20) == H20
    with pytest.raises(ValueError):
        sched.set_h(10, 2)  # append-only in time
    with pytest.raises(ValueError):
        sched.set_h(25, 0)  # h >= 1


def test_piecewise_anchor_preserves_phase():
    """After h cheap steps since the last comm, the next comm lands at
    last_comm + h_new, not at an arbitrary phase reset."""
    sched = PiecewisePeriodic(h=4)  # comm at 5, 9, 13, ...
    sched.set_h(13, 6)              # anchored at 13 -> next comm 19
    assert sched.next_comm_step(13) == 19
    assert sched.H(19) == sched.H(13) + 1


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=1, max_value=8),
        st.lists(st.tuples(st.integers(min_value=0, max_value=25),
                           st.integers(min_value=1, max_value=15)),
                 max_size=6),
        st.lists(st.integers(min_value=0, max_value=160), min_size=1,
                 max_size=24))
    def test_property_splice_sequences_keep_invariants(h0, splices, queries):
        """For ANY sequence of h updates: H(t) non-decreasing (and consistent
        with is_comm_step), next_comm_step(t) > t and lands on the next true
        comm step, and the batch query agrees with the scalar path."""
        sched = PiecewisePeriodic(h=h0)
        from_t = 0
        for gap, h in splices:
            from_t += gap
            sched.set_h(from_t, h)
        prev = 0
        for t in range(1, from_t + 40):
            Ht = sched.H(t)
            assert Ht >= prev
            assert Ht - prev == int(sched.is_comm_step(t))
            prev = Ht
        qs = np.asarray(sorted(queries), dtype=np.int64)
        batch = sched.next_comm_step_batch(qs)
        for q, b in zip(qs, batch):
            nc = sched.next_comm_step(int(q))
            assert nc == int(b)
            assert nc > q and sched.is_comm_step(nc)
            assert all(not sched.is_comm_step(s) for s in range(q + 1, nc))


# -- AdaptiveSchedule policy -------------------------------------------------


def test_adaptive_schedule_retune_splices_h_opt():
    sched = AdaptiveSchedule(h0=1, p=0.0)
    n, k, r, lam2 = 16, 15, 1.3, 0.0
    changed = sched.retune(5, n, k, r, lam2)
    assert changed
    assert sched.h_current == h_opt_int(n, k, r, lam2)
    assert sched.retunes[0].from_t == 5
    # same estimates again: no pattern change, no new splice
    assert not sched.retune(9, n, k, r, lam2)
    assert len(sched.retunes) == 1


def test_adaptive_schedule_sparse_growth_increases_h():
    sched = AdaptiveSchedule(h0=1, p=0.3)
    sched.retune(4, 16, 15, 1.3, 0.0)
    h_early = sched.h_current
    sched.retune(500, 16, 15, 1.3, 0.0)  # many comms later: (1+H)^p grew
    assert sched.h_current > h_early
    _assert_invariants(sched, upto=600)


def test_adaptive_schedule_rejects_bad_params():
    with pytest.raises(ValueError):
        AdaptiveSchedule(p=0.5)  # outside the convergence guarantee
    with pytest.raises(ValueError):
        AdaptiveSchedule(h_max=0)


# -- straggler reweighting ---------------------------------------------------


def test_arrival_reweighted_matrix_is_expected_degraded_matrix():
    """Closed form == exact expectation of degraded_matrix over independent
    Bernoulli arrival masks (enumerated, n=6 -> 64 masks)."""
    g = kregular_expander(6, k=2, seed=1)
    P = g.mixing_matrix()
    rng = np.random.default_rng(2)
    a = rng.uniform(0.3, 1.0, size=6)
    expected = np.zeros_like(P)
    # enumerate masks over the 6 senders (64 terms)
    for bits in range(1 << 6):
        mask = np.array([(bits >> j) & 1 for j in range(6)], dtype=bool)
        prob = float(np.prod(np.where(mask, a, 1.0 - a)))
        expected += prob * degraded_matrix(g, mask)
    got = arrival_reweighted_matrix(P, a)
    np.testing.assert_allclose(got, expected, atol=1e-12)
    np.testing.assert_allclose(got.sum(axis=1), 1.0, atol=1e-12)


def test_arrival_reweighted_matrix_rejects_nan():
    g = complete_graph(4)
    a = np.array([1.0, np.nan, 1.0, 1.0])
    with pytest.raises(ValueError):
        arrival_reweighted_matrix(g.mixing_matrix(), a)


def test_splice_frontier_tracks_active_nodes_only():
    """Regression: the splice frontier the engines hand to maybe_retune is
    the max iteration over STILL-ACTIVE nodes. With a global-max frontier
    it jumps to T+1 the moment the fastest node reaches T -- every later
    splice then lands beyond any iteration the stragglers will ever
    execute and the controller is effectively frozen for the rest of the
    run. With 16x stragglers most of the run happens after the fast nodes
    finish, so the frontier must stay <= T throughout."""
    n, d, T = 8, 4, 60
    _, grad_fn, eval_fn = _problem(n, d)
    sc = adversarial(n, 0.6, loss=0.0, slow_factor=16.0, n_slow=2, k=n,
                     seed=0)
    for engine in ("object", "vectorized"):
        ctrl = AdaptiveController(AdaptiveSchedule(h0=1, p=0.3),
                                  update_every=0.25, warmup_messages=4,
                                  warmup_steps=4)
        frontiers = []
        real = ctrl.maybe_retune

        def spy(now, frontier, _real=real, _log=frontiers):
            _log.append(frontier)
            return _real(now, frontier)

        ctrl.maybe_retune = spy
        sim = NetSimulator(sc, grad_fn, eval_fn, seed=1, engine=engine,
                           controller=ctrl,
                           a_fn=lambda t: 0.5 / math.sqrt(max(t, 1.0)))
        sim.run(np.zeros((n, d)), T=T, eval_every=20)
        assert frontiers, f"{engine}: controller never consulted"
        # the fix's contract: an active node has t < T, so the frontier
        # can never exceed T. The global-max regression pushes it to T+1
        # as soon as the fastest node finishes -- and with 16x stragglers
        # nearly every consult happens after that point.
        assert max(frontiers) <= T, \
            f"{engine}: frontier {max(frontiers)} beyond active nodes " \
            f"(global-max regression)"


def test_maybe_retune_skips_frontier_behind_latest_splice():
    """A straggler-era frontier can sit BEHIND the latest splice point
    (issued when faster, since-finished nodes were still active); the
    controller must skip rather than rewrite pattern history those nodes
    already executed, and resume once the frontier catches up."""
    from repro.netsim import homogeneous

    n = 6
    net = homogeneous(n, 0.1, k=n).build_network()
    ctrl = AdaptiveController(AdaptiveSchedule(h0=1, p=0.0),
                              update_every=0.1, warmup_messages=1,
                              warmup_steps=1)
    ctrl.bind(net)
    ctrl.schedule.set_h(50, 2)  # splice issued at an earlier, faster era
    before = ctrl.schedule.segments.copy()
    ctrl.on_steps(np.arange(n), np.full(n, 1.0 / n))
    ctrl.on_messages(np.array([1.2]))  # big r -> h_opt > current h
    cut = ctrl.maybe_retune(now=1.0, frontier=21)  # behind the last splice
    assert cut is None and ctrl.schedule.segments == before
    # frontier caught up past the splice point: retuning resumes (the
    # measured h_opt here is 1, so the splice moves h 2 -> 1 at 55)
    cut = ctrl.maybe_retune(now=2.0, frontier=55)
    assert cut == 55
    assert ctrl.schedule.segments[-1] == (55, ctrl.schedule.h_current)
    _assert_invariants(ctrl.schedule, upto=140)


def test_sinkhorn_project_restores_double_stochasticity():
    g = complete_graph(8)
    P = arrival_reweighted_matrix(g.mixing_matrix(),
                                  np.array([0.3] * 2 + [1.0] * 6))
    assert np.abs(P.sum(axis=0) - 1.0).max() > 1e-3  # columns broken
    Pds = sinkhorn_project(P)
    np.testing.assert_allclose(Pds.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(Pds.sum(axis=1), 1.0, atol=1e-9)
    assert (Pds >= 0.0).all()


def test_sinkhorn_handles_sparse_topologies_with_extreme_stragglers():
    """Regression: the iteration budget must cover slow-balancing sparse
    support (a ring with floor-clamped stragglers needs thousands of
    Sinkhorn sweeps, not hundreds) so a live controller run cannot die
    mid-simulation on an ordinary straggler pattern."""
    from repro.core.graphs import ring_graph

    for n, n_slow in ((32, 8), (64, 16)):
        g = ring_graph(n)
        rw = StragglerReweighter(g)
        q = np.full(n, 1.0 / n)
        q[:n_slow] *= 40.0  # deep past the arrival-probability floor
        P_eff, lam2 = rw.update(q)
        assert np.abs(P_eff.sum(axis=0) - 1.0).max() < 1e-6
        assert np.abs(P_eff.sum(axis=1) - 1.0).max() < 1e-6
        assert 0.0 < lam2 <= 1.0


def test_controller_rebind_resets_schedule_for_a_fresh_run():
    """Regression: a second run() with the same controller starts from the
    cold-start pattern again instead of inheriting (and then crashing on)
    the previous run's splice history."""
    n, d = 8, 4
    _, grad_fn, eval_fn = _problem(n, d)
    sc = adversarial(n, 0.6, loss=0.1, slow_factor=2.0, n_slow=1, k=n,
                     seed=0)
    # r0 prior + no warmup: the first retune fires before any message, so
    # the splice lands EARLY (before the h0=4 pattern's first comm step)
    # and changes next_comm_step(0) -- which makes this test also catch a
    # bind-after-node-state ordering bug, where run 2's nodes would cache
    # next-comm answers from run 1's spliced pattern
    ctrl = AdaptiveController(AdaptiveSchedule(h0=4, p=0.1),
                              update_every=0.05, warmup_messages=0,
                              warmup_steps=0, r0=0.6)
    sim = NetSimulator(sc, grad_fn, eval_fn, seed=1, controller=ctrl,
                       a_fn=lambda t: 0.5 / math.sqrt(max(t, 1.0)))
    tr1 = sim.run(np.zeros((n, d)), T=200, eval_every=10)
    retunes1 = [(rt.from_t, rt.h) for rt in ctrl.schedule.retunes]
    assert len(retunes1) >= 1
    assert retunes1[0][0] < 5  # early splice, before h0=4's first comm
    tr2 = sim.run(np.zeros((n, d)), T=200, eval_every=10)  # must not raise
    # same cluster, fresh history: the second run retunes identically
    assert tr2.fvals == tr1.fvals
    assert [(rt.from_t, rt.h) for rt in ctrl.schedule.retunes] == retunes1


def test_straggler_reweighter_inflates_lambda2():
    """Stragglers weaken effective mixing: lambda2_eff must exceed the
    static lambda2, which lowers the controller's h_opt (honesty)."""
    g = complete_graph(12)
    rw = StragglerReweighter(g)
    uniform = np.full(12, 1.0 / 12)
    P_u, lam2_u = rw.update(uniform)
    np.testing.assert_allclose(P_u, g.mixing_matrix(), atol=1e-12)
    assert lam2_u == pytest.approx(g.lambda2(), abs=1e-9)
    slowed = uniform.copy()
    slowed[:3] *= 4.0
    _, lam2_s = rw.update(slowed)
    assert lam2_s > lam2_u + 0.01
    assert (rw.last_arrive_prob[:3] < 1.0).all()
    assert (rw.last_arrive_prob[3:] == 1.0).all()


def test_lambda2_fast_matches_general_path():
    g = kregular_expander(10, k=4, seed=3)
    assert lambda2_fast(g.mixing_matrix()) == pytest.approx(g.lambda2(),
                                                            abs=1e-9)


# -- the closed loop end-to-end ---------------------------------------------


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_controller_retunes_and_converges(engine):
    n, d = 12, 5
    _, grad_fn, eval_fn = _problem(n, d)
    sc = adversarial(n, 0.6, loss=0.2, slow_factor=4.0, n_slow=2, k=n,
                     seed=0)
    ctrl = AdaptiveController(AdaptiveSchedule(h0=1, p=0.1),
                              update_every=0.5, warmup_messages=4,
                              warmup_steps=4)
    sim = NetSimulator(sc, grad_fn, eval_fn, seed=3, engine=engine,
                       controller=ctrl,
                       a_fn=lambda t: 0.5 / math.sqrt(max(t, 1.0)))
    trace = sim.run(np.zeros((n, d)), T=400, eval_every=10)
    assert len(ctrl.schedule.retunes) >= 1        # the loop actually acted
    assert ctrl.schedule.h_current > 1            # and moved off cold-start
    assert ctrl.tracker.r_hat == pytest.approx(0.6, rel=1e-6)
    assert np.isfinite(trace.fvals).all()
    assert trace.fvals[-1] < trace.fvals[0]
    # mutation bookkeeping stayed consistent under live splices
    _assert_invariants(ctrl.schedule, upto=450)


def test_controller_off_engines_stay_bit_identical():
    """The hook points must be invisible when no controller is attached."""
    n, d = 10, 4
    _, grad_fn, eval_fn = _problem(n, d)
    sc = adversarial(n, 0.05, loss=0.25, slow_factor=3.0, n_slow=2,
                     rewire_every=0.7, seed=0)
    traces = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(sc, grad_fn, eval_fn, seed=5, engine=engine)
        traces[engine] = sim.run(np.zeros((n, d)), T=150, eval_every=4)
    for f in TRACE_FIELDS:
        assert getattr(traces["object"], f) == getattr(traces["vectorized"],
                                                       f), f


def test_simulator_rejects_conflicting_schedule_and_controller():
    n, d = 4, 3
    _, grad_fn, eval_fn = _problem(n, d)
    ctrl = AdaptiveController(AdaptiveSchedule())
    with pytest.raises(ValueError):
        NetSimulator(adversarial(n, 0.01, k=2, seed=0), grad_fn, eval_fn,
                     schedule=Periodic(h=2), controller=ctrl)
    # controller's schedule adopted when none is passed
    sim = NetSimulator(adversarial(n, 0.01, k=2, seed=0), grad_fn, eval_fn,
                       controller=ctrl)
    assert sim.schedule is ctrl.schedule
