"""Hypothesis import shim so the suite collects without hypothesis.

Property-based tests are the repo's preferred style, but hypothesis is an
optional `test` extra (see pyproject.toml). When it is absent, `@given`
tests skip cleanly instead of crashing the whole collection; everything
else (parametrized / plain tests) still runs. `pip install -e .[test]`
restores the property tests.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(pip install -e .[test])")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stand-in for `strategies`: every attribute is a callable that
        returns None. Only ever evaluated inside @given(...) argument
        lists, whose tests are skipped anyway."""

        def __getattr__(self, _name):
            def _strategy(*_a, **_k):
                return None
            return _strategy

    st = _AnyStrategy()
