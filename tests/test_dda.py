"""DDA correctness: the prox map, convergence on convex problems, schedule
effects, compression, and the simulated time model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DDASimulator, EveryIteration, IncreasinglySparse,
                        Periodic, complete_graph, dda_init, dda_local_step,
                        ring_graph, stepsize_sqrt)


def _quadratic_problem(n=6, d=8, seed=0):
    """f_i(x) = ||x - c_i||^2; F minimized at mean(c_i)."""
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    def subgrad(x_stack, t, key):
        return 2.0 * (x_stack - c)

    def objective(x):
        return jnp.mean(jnp.sum((x[None, :] - c) ** 2, axis=1))

    return subgrad, objective, c


def test_prox_step_solves_argmin():
    """x = argmin <z,x> + ||x||^2/(2a)  <=>  x = -a z (psi = l2/2)."""
    z = jnp.asarray(np.random.default_rng(0).normal(size=(5,)), jnp.float32)
    a = 0.37
    x = -a * z
    # numerical check: objective at x is lower than at x + perturbations
    obj = lambda y: jnp.dot(z, y) + jnp.sum(y * y) / (2 * a)
    base = obj(x)
    for _ in range(10):
        pert = 0.01 * np.random.default_rng(1).normal(size=(5,))
        assert obj(x + jnp.asarray(pert, jnp.float32)) >= base - 1e-6


@pytest.mark.parametrize("topology", ["complete", "ring"])
def test_dda_converges_quadratic(topology):
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    graph = complete_graph(n) if topology == "complete" else ring_graph(n)
    sim = DDASimulator(subgrad, jax.jit(objective), graph,
                       EveryIteration(), a_fn=stepsize_sqrt(0.05))
    trace = sim.run(jnp.zeros((n, d)), 600, eval_every=100)
    fstar = float(objective(jnp.mean(c, axis=0)))
    assert trace.fvals[-1] < fstar * 1.05 + 1e-6
    assert trace.fvals[-1] < trace.fvals[0]


def test_dda_periodic_converges_slower_but_converges():
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sims = {}
    for name, sched in (("h1", EveryIteration()), ("h5", Periodic(h=5))):
        sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                           sched, a_fn=stepsize_sqrt(0.05))
        sims[name] = sim.run(jnp.zeros((n, d)), 400, eval_every=400)
    assert sims["h1"].fvals[-1] < fstar * 1.1
    assert sims["h5"].fvals[-1] < fstar * 1.2  # still converges
    assert sims["h5"].comms[-1] < sims["h1"].comms[-1] / 4


def test_dda_sparse_schedule_converges():
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                       IncreasinglySparse(p=0.3), a_fn=stepsize_sqrt(0.05))
    tr = sim.run(jnp.zeros((n, d)), 600, eval_every=600)
    assert tr.fvals[-1] < fstar * 1.1


def test_dda_with_compression_converges():
    n, d = 6, 16
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                       EveryIteration(), a_fn=stepsize_sqrt(0.05),
                       compress_keep=0.25)
    tr = sim.run(jnp.zeros((n, d)), 800, eval_every=800)
    assert tr.fvals[-1] < fstar * 1.15


def test_time_model_accounting():
    n, d = 4, 4
    subgrad, objective, _ = _quadratic_problem(n, d)
    g = complete_graph(n)
    r = 0.01
    sim = DDASimulator(subgrad, jax.jit(objective), g, Periodic(h=3),
                       a_fn=stepsize_sqrt(0.05), r=r)
    tr = sim.run(jnp.zeros((n, d)), 90, eval_every=90)
    H = (90 - 1) // 3
    expected = 90 * (1 / n) + H * g.degree * r
    assert np.isclose(tr.sim_time[-1], expected, rtol=1e-6)
    assert tr.comms[-1] == H


def test_dda_local_step_pure():
    x0 = {"w": jnp.ones((3,))}
    state = dda_init(x0)
    grad = {"w": jnp.full((3,), 2.0)}
    a_fn = stepsize_sqrt(0.1)
    s1 = dda_local_step(state, grad, a_fn)
    np.testing.assert_allclose(np.asarray(s1.z["w"]), 2.0)
    np.testing.assert_allclose(np.asarray(s1.x["w"]), -0.1 * 2.0, rtol=1e-6)
    # running average after first step equals x(1)
    np.testing.assert_allclose(np.asarray(s1.xhat["w"]),
                               np.asarray(s1.x["w"]), rtol=1e-6)


def test_disagreement_decreases_with_communication():
    n, d = 8, 8
    subgrad, objective, _ = _quadratic_problem(n, d, seed=3)
    out = {}
    for name, sched in (("every", EveryIteration()), ("h10", Periodic(h=10))):
        sim = DDASimulator(subgrad, jax.jit(objective), ring_graph(n), sched,
                           a_fn=stepsize_sqrt(0.05))
        out[name] = sim.run(jnp.zeros((n, d)), 200, eval_every=200)
    assert out["every"].disagreement[-1] < out["h10"].disagreement[-1]


# ---------------------------------------------------------------------------
# device-resident fast path: scanned loop, sparse gossip, vmapped batch
# ---------------------------------------------------------------------------


def _expander(n, k=4, seed=0):
    from repro.core.graphs import kregular_expander
    return kregular_expander(n, k=k, seed=seed)


def _rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-12)))


@pytest.mark.parametrize("sched", [EveryIteration(), Periodic(h=3),
                                   IncreasinglySparse(p=0.3)],
                         ids=["every", "h3", "p03"])
def test_scan_loop_matches_segment_loop(sched):
    """The fully-scanned run == the legacy per-segment dispatch loop on the
    same simulator: identical time axis and comm counts, fvals equal to
    float-fusion tolerance (eval moves inside jit). Covers a partial final
    segment (T % eval_every != 0)."""
    n, d = 6, 12
    subgrad, objective, _ = _quadratic_problem(n, d)
    sim = DDASimulator(subgrad, jax.jit(objective), _expander(n, k=2),
                       sched, a_fn=stepsize_sqrt(0.05), r=0.02)
    seg = sim.run(jnp.zeros((n, d)), 103, eval_every=25, loop="segment")
    scan = sim.run(jnp.zeros((n, d)), 103, eval_every=25, loop="scan")
    assert seg.iters == scan.iters
    assert seg.sim_time == scan.sim_time
    assert seg.comms == scan.comms
    assert _rel(seg.fvals, scan.fvals) < 1e-5
    assert _rel(seg.fvals_consensus, scan.fvals_consensus) < 1e-5


def test_sparse_mix_matches_dense_on_expander():
    """The gather+fused sparse gossip path reproduces the dense-matmul mix
    on a seeded expander run to <= 1e-5 relative (the acceptance gate's
    tolerance; float accumulation order differs)."""
    n, d = 12, 24
    subgrad, objective, _ = _quadratic_problem(n, d, seed=1)
    traces = {}
    for mix in ("dense", "sparse"):
        sim = DDASimulator(subgrad, jax.jit(objective), _expander(n),
                           EveryIteration(), a_fn=stepsize_sqrt(0.05),
                           mix=mix)
        assert sim.mix_mode == mix
        traces[mix] = sim.run(jnp.zeros((n, d)), 150, eval_every=30)
    assert _rel(traces["dense"].fvals, traces["sparse"].fvals) < 1e-5
    assert traces["dense"].comms == traces["sparse"].comms


def test_sparse_mix_weights_matches_dense_weighted():
    """A reweighted edge-supported P (`mix_weights`, the
    reweight_gossip shape) runs through the sparse per-edge path and
    matches the dense matmul with the same W."""
    n, d = 10, 16
    subgrad, objective, _ = _quadratic_problem(n, d, seed=2)
    g = _expander(n)
    rng = np.random.default_rng(0)
    W = g.mixing_matrix()
    # perturb edge weights, fold the correction into the diagonal so rows
    # stay stochastic (shape-wise; exact stochasticity is not required)
    for i in range(n):
        for j in range(n):
            if i != j and W[i, j] != 0.0:
                delta = rng.uniform(-0.3, 0.3) * W[i, j]
                W[i, j] += delta
                W[i, i] -= delta
    traces = {}
    for mix in ("dense", "sparse"):
        sim = DDASimulator(subgrad, jax.jit(objective), g, Periodic(h=2),
                           a_fn=stepsize_sqrt(0.05), mix=mix,
                           mix_weights=W)
        assert sim.mix_mode == mix
        traces[mix] = sim.run(jnp.zeros((n, d)), 120, eval_every=30)
    assert _rel(traces["dense"].fvals, traces["sparse"].fvals) < 1e-5


def test_auto_mix_fallbacks():
    """auto -> dense for complete graphs and for a mix_weights with weight
    OUTSIDE the graph's edge support (non-regular P); forcing mix="sparse"
    there raises. Compression does NOT disqualify sparse: compressed
    messages ride the fused compress-mix gather."""
    n, d = 8, 8
    subgrad, objective, _ = _quadratic_problem(n, d)
    g = _expander(n)
    obj = jax.jit(objective)
    assert DDASimulator(subgrad, obj, g, EveryIteration()).mix_mode \
        == "sparse"
    assert DDASimulator(subgrad, obj, complete_graph(n),
                        EveryIteration()).mix_mode == "dense"
    assert DDASimulator(subgrad, obj, g, EveryIteration(),
                        compress_keep=0.5).mix_mode == "sparse"
    W = g.mixing_matrix()
    W[0, :] = 1.0 / n  # weight on non-edges: not gatherable along edges
    sim = DDASimulator(subgrad, obj, g, EveryIteration(), mix_weights=W)
    assert sim.mix_mode == "dense"
    with pytest.raises(ValueError, match="edge support"):
        DDASimulator(subgrad, obj, g, EveryIteration(), mix_weights=W,
                     mix="sparse")
    # the dense fallback actually APPLIES the override
    tr_w = sim.run(jnp.zeros((n, d)), 40, eval_every=40)
    tr_p = DDASimulator(subgrad, obj, g, EveryIteration()).run(
        jnp.zeros((n, d)), 40, eval_every=40)
    assert tr_w.fvals != tr_p.fvals


def test_scan_loop_empty_run():
    """T=0 returns an empty trace on every loop, as the legacy path did."""
    n, d = 4, 4
    subgrad, objective, _ = _quadratic_problem(n, d)
    sim = DDASimulator(subgrad, jax.jit(objective), _expander(n, k=2))
    for loop in ("scan", "segment"):
        tr = sim.run(jnp.zeros((n, d)), 0, eval_every=10, loop=loop)
        assert tr.iters == [] and tr.fvals == []
    batch = sim.run_batch(jnp.zeros((n, d)), 0, 10,
                          np.zeros((2, 0), bool), seeds=[0, 1])
    assert all(tr.iters == [] for tr in batch)


def test_run_batch_matches_single_runs():
    """One vmapped program over (schedule, seed, r) lanes == the per-lane
    scanned runs, bitwise (same program, batched)."""
    n, d, T = 6, 10, 77
    subgrad, objective, _ = _quadratic_problem(n, d)
    sim = DDASimulator(subgrad, jax.jit(objective), _expander(n, k=2),
                       a_fn=stepsize_sqrt(0.05))
    scheds = [EveryIteration(), Periodic(h=2), Periodic(h=5)]
    masks = np.stack([s.comm_mask(0, T) for s in scheds])
    seeds, rs = [0, 1, 2], [0.0, 0.01, 0.1]
    batch = sim.run_batch(jnp.zeros((n, d)), T, 25, masks, seeds, rs)
    for sched, seed, r, btr in zip(scheds, seeds, rs, batch):
        one = DDASimulator(subgrad, jax.jit(objective), _expander(n, k=2),
                           sched, a_fn=stepsize_sqrt(0.05), r=r)
        tr = one.run(jnp.zeros((n, d)), T, eval_every=25, seed=seed)
        assert btr.iters == tr.iters
        assert btr.sim_time == tr.sim_time
        assert btr.comms == tr.comms
        assert _rel(btr.fvals, tr.fvals) < 1e-6
        assert _rel(btr.disagreement, tr.disagreement) < 1e-5
