"""DDA correctness: the prox map, convergence on convex problems, schedule
effects, compression, and the simulated time model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DDASimulator, EveryIteration, IncreasinglySparse,
                        Periodic, complete_graph, dda_init, dda_local_step,
                        ring_graph, stepsize_sqrt)


def _quadratic_problem(n=6, d=8, seed=0):
    """f_i(x) = ||x - c_i||^2; F minimized at mean(c_i)."""
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    def subgrad(x_stack, t, key):
        return 2.0 * (x_stack - c)

    def objective(x):
        return jnp.mean(jnp.sum((x[None, :] - c) ** 2, axis=1))

    return subgrad, objective, c


def test_prox_step_solves_argmin():
    """x = argmin <z,x> + ||x||^2/(2a)  <=>  x = -a z (psi = l2/2)."""
    z = jnp.asarray(np.random.default_rng(0).normal(size=(5,)), jnp.float32)
    a = 0.37
    x = -a * z
    # numerical check: objective at x is lower than at x + perturbations
    obj = lambda y: jnp.dot(z, y) + jnp.sum(y * y) / (2 * a)
    base = obj(x)
    for _ in range(10):
        pert = 0.01 * np.random.default_rng(1).normal(size=(5,))
        assert obj(x + jnp.asarray(pert, jnp.float32)) >= base - 1e-6


@pytest.mark.parametrize("topology", ["complete", "ring"])
def test_dda_converges_quadratic(topology):
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    graph = complete_graph(n) if topology == "complete" else ring_graph(n)
    sim = DDASimulator(subgrad, jax.jit(objective), graph,
                       EveryIteration(), a_fn=stepsize_sqrt(0.05))
    trace = sim.run(jnp.zeros((n, d)), 600, eval_every=100)
    fstar = float(objective(jnp.mean(c, axis=0)))
    assert trace.fvals[-1] < fstar * 1.05 + 1e-6
    assert trace.fvals[-1] < trace.fvals[0]


def test_dda_periodic_converges_slower_but_converges():
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sims = {}
    for name, sched in (("h1", EveryIteration()), ("h5", Periodic(h=5))):
        sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                           sched, a_fn=stepsize_sqrt(0.05))
        sims[name] = sim.run(jnp.zeros((n, d)), 400, eval_every=400)
    assert sims["h1"].fvals[-1] < fstar * 1.1
    assert sims["h5"].fvals[-1] < fstar * 1.2  # still converges
    assert sims["h5"].comms[-1] < sims["h1"].comms[-1] / 4


def test_dda_sparse_schedule_converges():
    n, d = 6, 8
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                       IncreasinglySparse(p=0.3), a_fn=stepsize_sqrt(0.05))
    tr = sim.run(jnp.zeros((n, d)), 600, eval_every=600)
    assert tr.fvals[-1] < fstar * 1.1


def test_dda_with_compression_converges():
    n, d = 6, 16
    subgrad, objective, c = _quadratic_problem(n, d)
    fstar = float(objective(jnp.mean(c, axis=0)))
    sim = DDASimulator(subgrad, jax.jit(objective), complete_graph(n),
                       EveryIteration(), a_fn=stepsize_sqrt(0.05),
                       compress_keep=0.25)
    tr = sim.run(jnp.zeros((n, d)), 800, eval_every=800)
    assert tr.fvals[-1] < fstar * 1.15


def test_time_model_accounting():
    n, d = 4, 4
    subgrad, objective, _ = _quadratic_problem(n, d)
    g = complete_graph(n)
    r = 0.01
    sim = DDASimulator(subgrad, jax.jit(objective), g, Periodic(h=3),
                       a_fn=stepsize_sqrt(0.05), r=r)
    tr = sim.run(jnp.zeros((n, d)), 90, eval_every=90)
    H = (90 - 1) // 3
    expected = 90 * (1 / n) + H * g.degree * r
    assert np.isclose(tr.sim_time[-1], expected, rtol=1e-6)
    assert tr.comms[-1] == H


def test_dda_local_step_pure():
    x0 = {"w": jnp.ones((3,))}
    state = dda_init(x0)
    grad = {"w": jnp.full((3,), 2.0)}
    a_fn = stepsize_sqrt(0.1)
    s1 = dda_local_step(state, grad, a_fn)
    np.testing.assert_allclose(np.asarray(s1.z["w"]), 2.0)
    np.testing.assert_allclose(np.asarray(s1.x["w"]), -0.1 * 2.0, rtol=1e-6)
    # running average after first step equals x(1)
    np.testing.assert_allclose(np.asarray(s1.xhat["w"]),
                               np.asarray(s1.x["w"]), rtol=1e-6)


def test_disagreement_decreases_with_communication():
    n, d = 8, 8
    subgrad, objective, _ = _quadratic_problem(n, d, seed=3)
    out = {}
    for name, sched in (("every", EveryIteration()), ("h10", Periodic(h=10))):
        sim = DDASimulator(subgrad, jax.jit(objective), ring_graph(n), sched,
                           a_fn=stepsize_sqrt(0.05))
        out[name] = sim.run(jnp.zeros((n, d)), 200, eval_every=200)
    assert out["every"].disagreement[-1] < out["h10"].disagreement[-1]
