import os

# Tests run on the default single CPU device (the dry-run sets its own
# device count in its own process). Keep hypothesis deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is an optional `test` extra (pip install -e .[test]);
    # property tests skip via the tests/_hyp.py shim when it is missing.
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None,
                              derandomize=True)
    settings.load_profile("ci")
