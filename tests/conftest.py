import os

# Tests run on the default single CPU device (the dry-run sets its own
# device count in its own process). Keep hypothesis deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None,
                          derandomize=True)
settings.load_profile("ci")
