"""Convergence gate for fault injection (run via `pytest -m convergence`).

Two executable acceptance criteria for repro.faults:

  * churn envelope -- time-to-2%-gap under 20% node churn (the "churn"
    FaultPlan preset: rotating crash/restart waves) stays within a fixed
    envelope of the fault-free run, on BOTH netsim engines. The measured
    seed ratio is ~0.21 (warm restarts resume from the survivors'
    consensus average, which acts as extra mixing, so moderate churn does
    not slow the recorded trajectory); the checked-in envelope of 2.0
    leaves ~10x headroom over the seed while still pinning a real
    regression (a restart that loses state, a splice that disconnects the
    graph, or masking that records dead iterates all blow far past 2x).

  * rejoin bound -- a crashed-then-restored node's iterate is back inside
    the survivors' consensus ball within a bounded number of post-restart
    rounds (its distance to the node mean is within a small multiple of
    the median distance).

On failure the traces are dumped under $CONVERGENCE_ARTIFACTS for the CI
job to upload (same protocol as test_convergence_regression.py).
"""

import os
import pathlib

import numpy as np
import pytest

from repro.faults import FaultPlan, faultplans
from repro.netsim import NetSimulator, lossy, quadratic_consensus

pytestmark = pytest.mark.convergence

ARTIFACT_DIR = os.environ.get("CONVERGENCE_ARTIFACTS", "convergence-traces")

# checked-in envelope: measured seed ratio ~0.21 on both engines (see
# module docstring), enforced bound 2.0
CHURN_TTA_ENVELOPE = 2.0
GAP_FRAC = 0.02
REJOIN_SPREAD_MULT = 5.0

N, D = 10, 4


def _dump_artifact(name: str, payload: dict) -> str:
    from repro.obs import write_json_artifact

    payload.setdefault("r_hat_trajectory", [])
    return write_json_artifact(
        pathlib.Path(ARTIFACT_DIR) / f"{name}.json", payload)


def _checked(name: str, payload: dict, ok: bool, message: str) -> None:
    if not ok:
        where = _dump_artifact(name, payload)
        pytest.fail(f"{message} (traces dumped to {where})")


def _problem():
    centers, grad_fn, eval_fn = quadratic_consensus(N, D, 0)
    fstar = eval_fn(np.asarray(centers).mean(0))
    f0 = eval_fn(np.zeros(D))
    eps = fstar + GAP_FRAC * (f0 - fstar)
    return grad_fn, eval_fn, eps


def _time_to_eps(trace, eps):
    for t, f in zip(trace.sim_time, trace.fvals):
        if f <= eps:
            return t
    return None


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_churn_time_to_accuracy_within_envelope(engine):
    grad_fn, eval_fn, eps = _problem()
    plan = faultplans.build("churn", n=N, frac=0.2, period=2.0,
                            downtime=0.5, start=1.0, cycles=4, seed=7)

    def run(p):
        sim = NetSimulator(lossy(N, 0.02, loss=0.1, seed=3), grad_fn,
                           eval_fn, seed=5, engine=engine, faults=p)
        trace = sim.run(np.zeros((N, D)), T=1200, eval_every=10)
        return sim, trace

    sim_c, tr_churn = run(plan)
    _, tr_free = run(None)
    tta_free = _time_to_eps(tr_free, eps)
    tta_churn = _time_to_eps(tr_churn, eps)
    payload = {
        "engine": engine, "eps": eps,
        "tta_free": tta_free, "tta_churn": tta_churn,
        "fault_stats": sim_c.fault_stats,
        "churn": {"times": list(tr_churn.sim_time),
                  "fvals": list(tr_churn.fvals)},
        "fault_free": {"times": list(tr_free.sim_time),
                       "fvals": list(tr_free.fvals)},
    }
    _checked(f"churn_envelope_{engine}", payload, tta_free is not None,
             "fault-free run never reached the 2% gap target")
    _checked(f"churn_envelope_{engine}", payload, tta_churn is not None,
             "churn run never reached the 2% gap target")
    ratio = tta_churn / tta_free
    payload["ratio"] = ratio
    _checked(f"churn_envelope_{engine}", payload,
             ratio <= CHURN_TTA_ENVELOPE,
             f"churn tta ratio {ratio:.3f} outside envelope "
             f"{CHURN_TTA_ENVELOPE}")
    # 20% churn actually happened (4 waves x ceil(0.2*10) victims)
    assert sim_c.fault_stats["crashes"] == 8
    assert sim_c.fault_stats["restarts"] == 8


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_restored_node_rejoins_consensus_ball(engine):
    """Crash one node for a full simulated time unit mid-run, then give
    the run a bounded post-restart window: the victim's iterate must be
    back inside the consensus ball (distance to the node mean within
    REJOIN_SPREAD_MULT of the median node distance)."""
    grad_fn, eval_fn, _ = _problem()
    victim = 3
    plan = FaultPlan(events=(
        {"time": 2.0, "action": "crash", "node": victim},
        {"time": 3.0, "action": "restart", "node": victim}), seed=1)
    sim = NetSimulator(lossy(N, 0.02, loss=0.1, seed=3), grad_fn, eval_fn,
                       seed=5, engine=engine, faults=plan)
    # T sized so the run ends a bounded ~30 rounds/node past the restart
    trace = sim.run(np.zeros((N, D)), T=int(3.0 * N) + 30, eval_every=10)
    z = np.stack([np.asarray(nd.z) for nd in sim.nodes])
    spread = np.linalg.norm(z - z.mean(0), axis=1)
    bound = REJOIN_SPREAD_MULT * float(np.median(spread)) + 1e-12
    payload = {
        "engine": engine, "victim": victim,
        "spread": spread.tolist(), "bound": bound,
        "downtime": sim.fault_stats["downtime_sim"],
        "fvals": list(trace.fvals), "times": list(trace.sim_time),
    }
    _checked(f"rejoin_{engine}", payload,
             sim.fault_stats["downtime_sim"] == pytest.approx(1.0),
             "victim was not down for the planned window")
    _checked(f"rejoin_{engine}", payload, spread[victim] <= bound,
             f"restored node spread {spread[victim]:.3g} outside "
             f"{REJOIN_SPREAD_MULT}x median {np.median(spread):.3g}")
