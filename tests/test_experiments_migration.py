"""Migration gate: spec-driven runs == pre-redesign hand-wired runs, bitwise.

The api_redesign moved benchmarks/fig_async.py, benchmarks/fig_adaptive.py
and benchmarks/bench_netsim.py (plus the examples) onto
`ExperimentSpec -> repro.run()`. These tests reconstruct each driver's
PRE-redesign wiring -- direct NetSimulator / DDASimulator / controller
assembly, exactly as the seeded drivers built it before the migration --
and assert the new spec path reproduces the traces BIT-IDENTICALLY
(`SimTrace` field equality, plus `RMeasurement` equality where measured).
The netsim engines are deterministic numpy, so equality here is
machine-independent; the dense comparison runs both paths in-process
against the same jit cache.
"""

import math

import numpy as np
import pytest

from repro.core.dda import TRACE_FIELDS
from repro.experiments import ExperimentSpec, run


def _assert_traces_equal(a, b, what=""):
    for f in TRACE_FIELDS:
        assert getattr(a, f) == getattr(b, f), f"{what}: {f} differs"


# ---------------------------------------------------------------------------
# fig_async cells
# ---------------------------------------------------------------------------

FIG_ASYNC = dict(n=8, M=10, d=6, seed=0, T=250, r=0.01, eval_every=2, k=4)


def _legacy_async_cell(scenario, schedule, algorithm="dda"):
    """The pre-redesign fig_async.run_cell wiring, verbatim."""
    from repro.data.pipeline import nonsmooth_quadratic_problem
    from repro.netsim import NetSimulator

    g = FIG_ASYNC
    centers = nonsmooth_quadratic_problem(
        g["n"], g["M"], g["d"], g["seed"], center_scale=1.5
    ).astype(np.float64)

    def grad_fn(i, x, t):
        diff = x[None, None, :] - centers[i]
        q = np.sum(diff * diff, axis=-1)
        pick = np.argmax(q, axis=-1)
        chosen = np.take_along_axis(diff, pick[:, None, None], axis=1)[:, 0]
        return 2.0 * np.sum(chosen, axis=0)

    def eval_fn(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    a_scale = 1.0 / (4.0 * g["M"])
    a_fn = (lambda t: a_scale / math.sqrt(max(t, 1.0)))
    sim = NetSimulator(scenario, grad_fn, eval_fn, a_fn=a_fn,
                       schedule=schedule, algorithm=algorithm,
                       seed=g["seed"])
    trace = sim.run(np.zeros((g["n"], g["d"])), g["T"],
                    eval_every=g["eval_every"])
    return sim, trace


def _async_spec(scenario_kind, knobs, schedule):
    g = FIG_ASYNC
    return ExperimentSpec(
        name="mig",
        problem={"kind": "nonsmooth",
                 "params": {"n": g["n"], "M": g["M"], "d": g["d"],
                            "seed": g["seed"]}},
        topology={"kind": "expander",
                  "params": {"k": g["k"], "seed": g["seed"]}},
        schedule=schedule,
        backends=[{"kind": "netsim",
                   "params": {"scenario": scenario_kind, **knobs}}],
        stepsize={"kind": "inv_sqrt",
                  "params": {"A": 1.0 / (4.0 * g["M"])}},
        T=g["T"], eval_every=g["eval_every"], seed=g["seed"], r=g["r"])


@pytest.mark.parametrize("cell", [
    ("homogeneous", {}, {"kind": "every"}),
    ("lossy", {"loss": 0.2}, {"kind": "every"}),
    ("straggler", {"slow_factor": 4.0}, {"kind": "periodic",
                                         "params": {"h": 2}}),
    ("adversarial", {"loss": 0.1, "slow_factor": 2.0},
     {"kind": "sparse", "params": {"p": 0.3}}),
], ids=lambda c: c[0] if isinstance(c, tuple) else str(c))
def test_fig_async_cells_bit_identical(cell):
    scenario_kind, knobs, schedule_comp = cell
    from repro.core import make_schedule
    from repro.netsim import adversarial, homogeneous, lossy, straggler

    g = FIG_ASYNC
    legacy_scenario = {
        "homogeneous": lambda: homogeneous(g["n"], g["r"], k=g["k"],
                                           seed=g["seed"]),
        "lossy": lambda: lossy(g["n"], g["r"], loss=0.2, k=g["k"],
                               seed=g["seed"]),
        "straggler": lambda: straggler(g["n"], g["r"], slow_factor=4.0,
                                       k=g["k"], seed=g["seed"]),
        "adversarial": lambda: adversarial(g["n"], g["r"], loss=0.1,
                                           slow_factor=2.0, k=g["k"],
                                           seed=g["seed"]),
    }[scenario_kind]()
    sched_kind = schedule_comp["kind"]
    legacy_sched = make_schedule(
        sched_kind, **schedule_comp.get("params", {}))
    sim, legacy_trace = _legacy_async_cell(legacy_scenario, legacy_sched)

    res = run(_async_spec(scenario_kind, knobs, schedule_comp))
    _assert_traces_equal(legacy_trace, res.trace, f"fig_async {scenario_kind}")
    assert sim.measure_r_empirical() == res.r_measurement


def test_fig_async_pushsum_cell_bit_identical():
    from repro.core import make_schedule
    from repro.netsim import lossy

    g = FIG_ASYNC
    sc = lossy(g["n"], g["r"], loss=0.3, k=g["k"], seed=g["seed"])
    _, legacy_trace = _legacy_async_cell(sc, make_schedule("every"),
                                         algorithm="pushsum")
    spec = _async_spec("lossy", {"loss": 0.3, "algorithm": "pushsum"},
                       {"kind": "every"})
    res = run(spec)
    _assert_traces_equal(legacy_trace, res.trace, "fig_async pushsum")


# ---------------------------------------------------------------------------
# fig_adaptive cells (fixed grid + the closed loop)
# ---------------------------------------------------------------------------

FIG_AD = dict(n=8, d=6, seed=0, T=600, r=1.3, eval_every=10, k=8,
              loss=0.2, straggler=4.0, n_slow=2, a_scale=0.5,
              time_limit=3000.0)


def _legacy_adaptive_run(schedule=None, ctrl=None, engine="auto"):
    """The pre-redesign fig_adaptive.run_one wiring, verbatim."""
    from repro.netsim import NetSimulator, adversarial, quadratic_consensus

    g = FIG_AD
    _, grad_fn, eval_fn = quadratic_consensus(g["n"], g["d"],
                                              seed=g["seed"])
    sc = adversarial(g["n"], g["r"], loss=g["loss"],
                     slow_factor=g["straggler"], n_slow=g["n_slow"],
                     k=g["k"], seed=g["seed"])
    a_fn = (lambda t: g["a_scale"] / math.sqrt(max(t, 1.0)))
    sim = NetSimulator(sc, grad_fn, eval_fn, a_fn=a_fn, schedule=schedule,
                       controller=ctrl, seed=g["seed"], engine=engine)
    trace = sim.run(np.zeros((g["n"], g["d"])), g["T"],
                    eval_every=g["eval_every"],
                    time_limit=g["time_limit"])
    return sim, trace


def _adaptive_spec(schedule, controller=None, engine="auto"):
    g = FIG_AD
    return ExperimentSpec(
        name="mig-adaptive",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": g["n"], "d": g["d"], "seed": g["seed"]}},
        topology={"kind": "expander",
                  "params": {"k": g["k"], "seed": g["seed"]}},
        schedule=schedule,
        controller=controller,
        backends=[{"kind": "netsim",
                   "params": {"scenario": "adversarial", "loss": g["loss"],
                              "slow_factor": g["straggler"],
                              "n_slow": g["n_slow"], "engine": engine}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": g["a_scale"]}},
        T=g["T"], eval_every=g["eval_every"], seed=g["seed"], r=g["r"],
        time_limit=g["time_limit"])


@pytest.mark.parametrize("h", [1, 4])
def test_fig_adaptive_fixed_cells_bit_identical(h):
    from repro.core.schedules import Periodic

    _, legacy_trace = _legacy_adaptive_run(schedule=Periodic(h=h))
    res = run(_adaptive_spec({"kind": "periodic", "params": {"h": h}}))
    _assert_traces_equal(legacy_trace, res.trace, f"fig_adaptive h={h}")


@pytest.mark.parametrize("engine", ["object", "vectorized"])
def test_fig_adaptive_closed_loop_bit_identical(engine):
    from repro.adaptive import AdaptiveController, AdaptiveSchedule

    ctrl = AdaptiveController(AdaptiveSchedule(h0=1, p=0.1),
                              update_every=0.5, warmup_messages=4,
                              warmup_steps=4)
    _, legacy_trace = _legacy_adaptive_run(ctrl=ctrl, engine=engine)
    res = run(_adaptive_spec(
        {"kind": "adaptive", "params": {"h0": 1, "p": 0.1}},
        controller={"kind": "adaptive",
                    "params": {"update_every": 0.5, "warmup_messages": 4,
                               "warmup_steps": 4}},
        engine=engine))
    _assert_traces_equal(legacy_trace, res.trace,
                         f"fig_adaptive closed-loop {engine}")
    assert res.extras["retunes"] == [(rt.from_t, rt.h)
                                     for rt in ctrl.schedule.retunes]


# ---------------------------------------------------------------------------
# bench_netsim cells
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["object", "vectorized"])
@pytest.mark.parametrize("algorithm", ["dda", "pushsum"])
def test_bench_netsim_cells_bit_identical(engine, algorithm):
    """The pre-redesign bench_cell wiring (batchable problem, default
    stepsize) against the spec path used by the migrated bench."""
    from benchmarks.bench_netsim import cell_spec
    from repro.netsim import NetSimulator, homogeneous, quadratic_consensus

    n, d, T, r, k, seed, ev = 16, 8, 40, 0.01, 4, 0, 5
    _, grad_fn, eval_fn = quadratic_consensus(n, d, seed, batchable=True)
    sc = homogeneous(n, r, k=k, seed=seed)
    sim = NetSimulator(sc, grad_fn, eval_fn, algorithm=algorithm,
                       seed=seed, engine=engine)
    legacy_trace = sim.run(np.zeros((n, d)), T=T, eval_every=ev)

    res = run(cell_spec(n, d, T, r, k, algorithm, engine, seed, ev))
    _assert_traces_equal(legacy_trace, res.trace,
                         f"bench {algorithm}/{engine}")
    assert res.extras["sent"] == sim.sent


# ---------------------------------------------------------------------------
# dense driver (fig1/fig2-style DDASimulator wiring)
# ---------------------------------------------------------------------------


def test_dense_cell_bit_identical():
    import jax
    import jax.numpy as jnp

    from repro.core import DDASimulator, Periodic, complete_graph
    from repro.core.dda import stepsize_sqrt
    from repro.experiments.components import problems

    n, d, T, seed = 10, 8, 150, 0
    prob = problems.build("quadratic_consensus", n=n, d=d, seed=seed)
    sim = DDASimulator(prob.subgrad_stack, jax.jit(prob.objective),
                       complete_graph(n), Periodic(h=2),
                       a_fn=stepsize_sqrt(0.5), r=0.01)
    legacy_trace = sim.run(jnp.zeros((n, d)), T, eval_every=15, seed=seed)

    spec = ExperimentSpec(
        name="mig-dense",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": n, "d": d, "seed": seed}},
        topology={"kind": "complete"},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        T=T, eval_every=15, seed=seed, r=0.01)
    res = run(spec)
    _assert_traces_equal(legacy_trace, res.trace, "dense")


# ---------------------------------------------------------------------------
# fig1 (metric learning, complete graph) + fig2 (non-smooth schedules):
# the last pre-spec drivers, migrated onto manifests in this PR. The legacy
# side reconstructs the direct DDASimulator wiring the drivers used (same
# registry problem closures, same stepsize family), the spec side goes
# through the migrated drivers' cell_spec + repro.run().
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compress_keep", [None, 0.5],
                         ids=["exact", "compressed"])
def test_fig1_cells_bit_identical(compress_keep):
    import jax
    import jax.numpy as jnp

    from benchmarks.fig1_complete import cell_spec
    from repro.core import DDASimulator, EveryIteration, complete_graph
    from repro.core.dda import stepsize_sqrt
    from repro.experiments.components import problems

    n, m_pairs, d, T, seed, r, A = 4, 400, 6, 40, 0, 0.03, 3e-4
    prob = problems.build("metric_learning", n=n, m_pairs=m_pairs,
                          d_feat=d, seed=seed)
    sim = DDASimulator(prob.subgrad_stack, jax.jit(prob.objective),
                       complete_graph(n), EveryIteration(),
                       a_fn=stepsize_sqrt(A), projection=prob.projection,
                       r=r, compress_keep=compress_keep)
    legacy_trace = sim.run(jnp.zeros((n, prob.d)), T, eval_every=10,
                           seed=seed)

    res = run(cell_spec(n, m_pairs, d, T, A, r, seed,
                        compress_keep=compress_keep))
    _assert_traces_equal(legacy_trace, res.trace,
                         f"fig1 compress={compress_keep}")


def test_fig1_reduced_applies_byte_ratio():
    """fig1_reduced is fig1_complete at r scaled by the paper's PCA byte
    ratio -- the spec cell only differs in the r field."""
    from benchmarks import fig1_reduced
    from benchmarks.fig1_complete import cell_spec

    a = cell_spec(4, 400, 6, 40, 3e-4, 0.03, 0)
    b = cell_spec(4, 400, 6, 40, 3e-4,
                  0.03 * fig1_reduced.PCA_BYTE_RATIO, 0)
    assert b.r == pytest.approx(a.r * fig1_reduced.PCA_BYTE_RATIO)
    assert a.with_value("r", b.r) == b


@pytest.mark.parametrize("sched_name", ["h1", "h2", "p03"])
def test_fig2_cells_bit_identical(sched_name):
    import jax
    import jax.numpy as jnp

    from benchmarks.fig2_sparse import SCHEDULES, cell_spec
    from repro.core import DDASimulator, complete_graph
    from repro.core.dda import stepsize_sqrt
    from repro.experiments.components import problems

    n, M, d, T, seed, r, A = 6, 8, 10, 80, 0, 0.00089, 0.005
    sched_comp, sched_obj = SCHEDULES[sched_name]
    prob = problems.build("nonsmooth", n=n, M=M, d=d, seed=seed)
    sim = DDASimulator(prob.subgrad_stack, jax.jit(prob.objective),
                       complete_graph(n), sched_obj,
                       a_fn=stepsize_sqrt(A), r=r)
    legacy_trace = sim.run(jnp.zeros((n, d)), T, eval_every=20, seed=seed)

    res = run(cell_spec(n, M, d, T, sched_comp, A, r, seed))
    _assert_traces_equal(legacy_trace, res.trace, f"fig2 {sched_name}")
