"""End-to-end behaviour tests for the whole system on a single device:
training loop + schedules + checkpointing + data pipeline wired together."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedules import IncreasinglySparse
from repro.data.pipeline import TokenStream
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import registry, transformer
from repro.optim import adamw, constant_lr, dual_averaging, rsqrt_lr, sgd


def test_train_step_reduces_loss_single_device():
    cfg = registry.get_config("llama3-8b", "smoke")
    opt = adamw(constant_lr(2e-3))
    step = jax.jit(make_train_step(cfg, opt))
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for _ in range(15):
        params, state, metrics = step(params, state, next(stream))
        losses.append(float(metrics["loss"]))
    stream.close()
    assert losses[-1] < losses[0]


def test_train_step_with_microbatching_matches_full_batch():
    """Gradient accumulation must be numerically equivalent (up to fp
    reassociation) to the full-batch step."""
    cfg = registry.get_config("musicgen-medium", "smoke")
    opt = sgd(constant_lr(1e-2))
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                          cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    p1, _, m1 = jax.jit(make_train_step(cfg, opt))(params, opt.init(params),
                                                   batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, opt, microbatches=4))(
        params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)


def test_dual_averaging_optimizer_trains():
    """Faithful DDA inner update as the LM optimizer (paper's algorithm on
    the substrate model)."""
    cfg = registry.get_config("musicgen-medium", "smoke")
    opt = dual_averaging(rsqrt_lr(0.5))
    step = jax.jit(make_train_step(cfg, opt))
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=1)
    losses = []
    for _ in range(20):
        params, state, metrics = step(params, state, next(stream))
        losses.append(float(metrics["loss"]))
    stream.close()
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_serve_step_greedy_decode_runs():
    cfg = registry.get_config("llama3-8b", "smoke")
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    cache = transformer.init_cache(cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(8):
        logits, cache = serve(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_token_stream_determinism():
    a = TokenStream(512, 16, 4, node_index=0, seed=7)
    b = TokenStream(512, 16, 4, node_index=0, seed=7)
    c = TokenStream(512, 16, 4, node_index=1, seed=7)
    ba, bb, bc = next(a), next(b), next(c)
    np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                  np.asarray(bb["tokens"]))
    assert not np.array_equal(np.asarray(ba["tokens"]),
                              np.asarray(bc["tokens"]))
    for s in (a, b, c):
        s.close()


def test_sparse_schedule_in_training_loop():
    """The t^p schedule drives the launcher correctly: comm rounds ==
    H_T from the schedule."""
    sched = IncreasinglySparse(p=0.3)
    T = 40
    comm_steps = [t for t in range(1, T + 1) if sched.is_comm_step(t)]
    assert len(comm_steps) == sched.H(T)
    assert comm_steps[0] == 1  # first round communicates


def test_adamw_bf16_moments_trains():
    """opt_moments_bf16 path (400B-class memory knob): still trains."""
    import jax.numpy as jnp
    cfg = registry.get_config("musicgen-medium", "smoke")
    opt = adamw(constant_lr(2e-3), moment_dtype=jnp.bfloat16)
    step = jax.jit(make_train_step(cfg, opt))
    params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree.leaves(state.inner["m"]))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=2)
    losses = []
    for _ in range(12):
        params, state, metrics = step(params, state, next(stream))
        losses.append(float(metrics["loss"]))
    stream.close()
    assert losses[-1] < losses[0]
