"""The r tradeoff calculus: paper's headline numbers and monotonicities."""

import math

import pytest
from _hyp import given, st

from repro.core import (TPU_V5E, EveryIteration, IncreasinglySparse,
                        Periodic, derive_r_from_roofline, h_opt, h_opt_int,
                        iteration_cost, measure_r, n_opt_complete,
                        time_to_accuracy)


def test_paper_headline_numbers():
    # section V.A: r = 0.85/29 ~ 0.0293 -> n_opt = 5.8
    r = measure_r(0.85, 29.0)
    assert math.isclose(r, 0.0293, rel_tol=0.01)
    assert math.isclose(n_opt_complete(r), 5.8, rel_tol=0.01)
    # PCA-reduced: r = 0.0104/2.1 -> n_opt = 14.15
    r2 = measure_r(0.0104, 2.1)
    assert math.isclose(n_opt_complete(r2), 14.2, rel_tol=0.01)
    # fig 2: r=0.00089, n=10 complete -> h_opt = 1
    assert h_opt_int(10, 9, 0.00089, 0.0) == 1


@given(r=st.floats(1e-6, 0.5))
def test_nopt_is_tau_argmin(r):
    """n_opt = 1/sqrt(r) minimizes tau(eps) = C^2/eps^2 (1/n + (n-1) r)."""
    nopt = n_opt_complete(r)
    tau = lambda n: 1.0 / n + (n - 1) * r
    eps = 1e-3
    assert tau(nopt) <= tau(nopt * 1.2) + eps * r
    assert tau(nopt) <= tau(nopt / 1.2) + eps * r


@given(n=st.integers(2, 64), k=st.integers(1, 8), r=st.floats(1e-5, 1.0))
def test_iteration_cost_decomposition(n, k, r):
    assert math.isclose(iteration_cost(n, k, r), 1.0 / n + k * r)


def test_expander_beats_complete_at_large_n():
    """At large n and nontrivial r, the k-regular expander's fixed comm cost
    wins over the complete graph's (n-1) r."""
    r, eps = 0.01, 0.1
    n = 64
    tau_complete = time_to_accuracy(eps, n, n - 1, r, 0.0)
    tau_expander = time_to_accuracy(eps, n, 4, r, 0.36)
    assert tau_expander < tau_complete


def test_sparse_beats_every_iteration_in_time():
    """Claim C5 in the time model: when communication dominates the
    iteration cost (kr >> 1/n) and p is small (the bound's exponent penalty
    2/(1-2p) stays near 2), the p-sparse schedule reaches eps sooner."""
    # eq. (30): tau_sparse = T/n + T^{1/(p+1)} k r. The bound-level win
    # appears when kr dominates 1/n and eps is moderate (T small), so the
    # T-exponent penalty 2/(1-2p) stays bounded while the comm count drops.
    r, eps, n, k, lam2 = 0.5, 10.03, 16, 4, 0.36
    t_every = time_to_accuracy(eps, n, k, r, lam2,
                               schedule=EveryIteration())
    t_sparse = time_to_accuracy(eps, n, k, r, lam2,
                                schedule=IncreasinglySparse(p=0.3))
    assert t_sparse < t_every
    # and the crossover direction: tiny r favors every-iteration
    t_every2 = time_to_accuracy(eps, n, k, 1e-5, lam2,
                                schedule=EveryIteration())
    t_sparse2 = time_to_accuracy(eps, n, k, 1e-5, lam2,
                                 schedule=IncreasinglySparse(p=0.3))
    assert t_every2 < t_sparse2


def test_sparse_p_half_invalid():
    t = time_to_accuracy(0.1, 8, 4, 0.01, 0.2,
                         schedule=IncreasinglySparse(p=0.6))
    assert t == float("inf")


@given(r=st.floats(1e-4, 0.2))
def test_hopt_scales_sqrt_r(r):
    h1 = h_opt(16, 4, r, 0.25)
    h2 = h_opt(16, 4, 4 * r, 0.25)
    assert math.isclose(h2, 2 * h1, rel_tol=1e-9)


def test_derive_r_from_roofline():
    # 1 GiB message over DCN, 1 TFLOP local step on 1 chip
    r = derive_r_from_roofline(2**30, 1e12, 1e9, n=8, link_bw=25e9)
    t_msg = 2**30 / 25e9
    t_full = (1e12 / TPU_V5E.peak_flops) * 8
    assert math.isclose(r, t_msg / t_full, rel_tol=1e-9)


def test_measure_r_guards():
    with pytest.raises(ValueError):
        measure_r(1.0, 0.0)
