"""repro.faults: FaultPlan spec round-trips, engine bit-identity under
every fault class, crash/restart/membership semantics, bounded link
retransmission, and the experiments-layer wiring (spec -> runner ->
RunMetrics.faults -> trace summary)."""

import json
import math

import numpy as np
import pytest

from repro.core.dda import TRACE_FIELDS
from repro.core.graphs import random_regular_expander
from repro.faults import (FaultEvent, FaultPlan, embed_subgraph, faultplans)
from repro.netsim import LinkModel, NetSimulator, homogeneous, lossy
from repro.netsim import quadratic_consensus as _problem

N, D = 10, 3


def _run_engines(scenario, plan, T=80, seed=5, eval_every=4, algorithm="dda",
                 **kw):
    _, grad_fn, eval_fn = _problem(scenario.n, D)
    out = {}
    for engine in ("object", "vectorized"):
        sim = NetSimulator(scenario, grad_fn, eval_fn, algorithm=algorithm,
                           seed=seed, engine=engine, faults=plan, **kw)
        trace = sim.run(np.zeros((scenario.n, D)), T=T,
                        eval_every=eval_every)
        out[engine] = (sim, trace)
    return out


def _assert_engines_identical(runs):
    (sim_o, tr_o), (sim_v, tr_v) = runs["object"], runs["vectorized"]
    for field in TRACE_FIELDS:
        assert getattr(tr_o, field) == getattr(tr_v, field), field
    assert sim_o.fault_stats == sim_v.fault_stats
    assert (sim_o.sent, sim_o.drops, sim_o.retransmits) == \
        (sim_v.sent, sim_v.drops, sim_v.retransmits)


# -- FaultPlan spec ----------------------------------------------------------


def test_fault_plan_json_round_trip_exact():
    plan = FaultPlan(
        events=({"time": 0.5, "action": "crash", "node": 2},
                {"time": 1.0, "action": "restart", "node": 2},
                {"time": 1.5, "action": "partition", "group": [0, 1]},
                {"time": 2.0, "action": "heal"}),
        crash_mtbf=3.0, crash_mttr=0.5, max_crashes=4,
        flap_links=((0, 1),), flap_mtbf=1.0, flap_mttr=0.25,
        restore="warm", checkpoint_every=0.5, checkpoint_keep=2, seed=7)
    d = plan.to_dict()
    assert plan == FaultPlan.from_dict(d)
    # strict-RFC JSON exact: dict -> text -> dict -> plan is the same plan
    assert plan == FaultPlan.from_dict(json.loads(json.dumps(d)))


def test_fault_event_validation():
    with pytest.raises(ValueError, match="action"):
        FaultEvent(time=1.0, action="explode", node=0)
    with pytest.raises(ValueError, match="time"):
        FaultEvent(time=-1.0, action="crash", node=0)
    with pytest.raises(ValueError, match="node"):
        FaultEvent(time=1.0, action="crash")  # node actions need a node
    with pytest.raises(ValueError, match="group"):
        FaultEvent(time=1.0, action="partition")


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="restore"):
        FaultPlan(restore="magic")
    with pytest.raises(ValueError, match="flap"):
        FaultPlan(flap_links=((0, 1),), flap_mtbf=1.0)  # needs mttr too
    with pytest.raises(ValueError, match="checkpoint_every"):
        FaultPlan(restore="checkpoint")
    plan = FaultPlan(events=({"time": 1.0, "action": "crash", "node": 9},))
    plan.validate_for(10)
    with pytest.raises(ValueError, match="node"):
        plan.validate_for(5)


def test_churn_preset_builds_rotating_waves():
    plan = faultplans.build("churn", n=10, frac=0.2, period=2.0,
                            downtime=0.5, start=1.0, cycles=3)
    plan.validate_for(10)
    crashes = [e for e in plan.events if e.action == "crash"]
    restarts = [e for e in plan.events if e.action == "restart"]
    assert len(crashes) == len(restarts) == 6  # ceil(0.2*10)=2 per cycle
    for c, r in zip(crashes, restarts):
        assert r.node == c.node and r.time == c.time + 0.5
    # waves rotate through distinct victims
    assert len({e.node for e in crashes}) == 6
    with pytest.raises(ValueError):
        faultplans.build("churn", n=4, frac=1.0)  # would crash every node
    with pytest.raises(ValueError):
        faultplans.build("churn", n=10, downtime=3.0, period=2.0)


def test_embed_subgraph_lifts_members_and_self_loops():
    members = np.array([0, 2, 3, 5], dtype=np.int64)
    sub = random_regular_expander(4, k=2, seed=0)
    g = embed_subgraph(sub, 6, members)
    assert g.n == 6
    for perm in g.perms:
        perm = np.asarray(perm)
        # non-members only ever map to themselves
        for j in (1, 4):
            assert perm[j] == j
        # member slots are the sub-graph's perms lifted through `members`
        assert set(perm[members]) <= set(members.tolist())


# -- engine bit-identity under every fault class -----------------------------

_PLAN_GRID = {
    "crash_only": FaultPlan(
        events=({"time": 0.6, "action": "crash", "node": 3},), seed=1),
    "crash_restart_warm": FaultPlan(
        events=({"time": 0.5, "action": "crash", "node": 2},
                {"time": 1.1, "action": "restart", "node": 2}),
        restore="warm", seed=1),
    "crash_restart_checkpoint": FaultPlan(
        events=({"time": 0.7, "action": "crash", "node": 4},
                {"time": 1.4, "action": "restart", "node": 4}),
        restore="checkpoint", checkpoint_every=0.3, seed=1),
    "leave_join": FaultPlan(
        events=({"time": 0.5, "action": "leave", "node": 7},
                {"time": 1.3, "action": "join", "node": 7},
                {"time": 1.8, "action": "leave", "node": 0}), seed=2),
    "partition_heal": FaultPlan(
        events=({"time": 0.4, "action": "partition", "group": [0, 1, 2, 3]},
                {"time": 1.2, "action": "heal"},
                {"time": 1.6, "action": "partition", "group": [5, 6]},
                {"time": 2.1, "action": "heal"}), seed=3),
    "flapping_links": FaultPlan(
        flap_links=((0, 1), (2, 5), (3, 4)), flap_mtbf=0.5, flap_mttr=0.2,
        seed=4),
    "mtbf_process": FaultPlan(
        crash_mtbf=1.0, crash_mttr=0.3, max_crashes=5, seed=5),
    "everything": FaultPlan(
        events=({"time": 0.5, "action": "crash", "node": 1},
                {"time": 0.9, "action": "restart", "node": 1},
                {"time": 1.2, "action": "leave", "node": 8},
                {"time": 1.5, "action": "partition", "group": [0, 2, 4]},
                {"time": 2.0, "action": "heal"},
                {"time": 2.3, "action": "join", "node": 8}),
        crash_mtbf=2.5, crash_mttr=0.4, max_crashes=3,
        flap_links=((5, 6),), flap_mtbf=0.8, flap_mttr=0.3, seed=6),
}


@pytest.mark.parametrize("name", sorted(_PLAN_GRID))
def test_engines_bit_identical_under_fault_plan(name):
    """The acceptance gate: BOTH netsim engines execute every fault class
    as first-class events with identical RNG consumption, float op order,
    and event interleaving -- bit-identical traces and fault counters."""
    plan = _PLAN_GRID[name]
    runs = _run_engines(lossy(N, 0.02, loss=0.15, seed=3), plan)
    _assert_engines_identical(runs)
    stats = runs["object"][0].fault_stats
    assert stats is not None
    # every plan in the grid actually exercises its fault class
    if name == "flapping_links":
        assert stats["link_flaps"] > 0
    elif name == "mtbf_process":
        assert 0 < stats["crashes"] <= 5
    elif name == "partition_heal":
        assert stats["partition_epochs"] == 2 and stats["blocked_sends"] > 0
    elif name == "leave_join":
        assert stats["leaves"] == 2 and stats["joins"] == 1
    elif name.startswith("crash"):
        assert stats["crashes"] == 1


def test_checkpoint_restore_writes_and_restores(tmp_path):
    plan = FaultPlan(
        events=({"time": 0.8, "action": "crash", "node": 2},
                {"time": 1.5, "action": "restart", "node": 2}),
        restore="checkpoint", checkpoint_every=0.25,
        checkpoint_dir=str(tmp_path), seed=1)
    runs = _run_engines(homogeneous(N, 0.02, seed=1), plan)
    _assert_engines_identical(runs)
    stats = runs["object"][0].fault_stats
    assert stats["checkpoints"] > 0 and stats["restarts"] == 1
    # periodic in-sim checkpoints landed on disk, committed and rotated
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() is not None


def test_crashed_node_stops_and_rejoins_consensus():
    """During downtime the victim's iterate freezes and recording masks it
    out; after a warm restart it resumes from the survivors' consensus
    average and the run converges to the same basin as fault-free."""
    n = 8
    centers, grad_fn, eval_fn = _problem(n, D)
    fstar = eval_fn(np.asarray(centers).mean(0))
    plan = FaultPlan(
        events=({"time": 1.0, "action": "crash", "node": 3},
                {"time": 2.0, "action": "restart", "node": 3}), seed=1)

    def run(p):
        sim = NetSimulator(homogeneous(n, 0.02, seed=1), grad_fn, eval_fn,
                           algorithm="dda", seed=5, engine="object",
                           faults=p)
        tr = sim.run(np.zeros((n, D)), T=400, eval_every=10)
        return sim, tr

    sim_f, tr_f = run(plan)
    _, tr_0 = run(None)
    assert sim_f.fault_stats["downtime_sim"] == pytest.approx(1.0)
    # the restored node's iterate is back inside the consensus ball: its
    # distance to the survivors' mean is comparable to the others'
    x = np.stack([nd.z for nd in sim_f.nodes])
    spread = np.linalg.norm(x - x.mean(0), axis=1)
    assert spread[3] <= 5.0 * np.median(spread) + 1e-9
    # and the faulted run still reaches the fault-free basin
    assert tr_f.fvals[-1] < max(1.05 * tr_0.fvals[-1], 1.1 * fstar)


def test_downtime_messages_drop_and_blocked_sends_count():
    plan = FaultPlan(
        events=({"time": 0.5, "action": "partition", "group": [0, 1, 2, 3,
                                                               4]},),
        seed=1)
    runs = _run_engines(homogeneous(N, 0.05, seed=2), plan, T=60)
    _assert_engines_identical(runs)
    sim, _ = runs["object"]
    # a permanent partition refuses every cross-cut send from then on
    assert sim.fault_stats["blocked_sends"] > 0


def test_fault_free_plan_is_invisible():
    """An empty FaultPlan must not perturb the optimization RNG stream:
    the trace equals the no-faults run bit for bit."""
    scenario = lossy(N, 0.02, loss=0.2, seed=3)
    _, grad_fn, eval_fn = _problem(N, D)

    def run(faults):
        sim = NetSimulator(scenario, grad_fn, eval_fn, seed=5,
                           engine="vectorized", faults=faults)
        return sim.run(np.zeros((N, D)), T=100, eval_every=5)

    tr_none, tr_empty = run(None), run(FaultPlan())
    for field in TRACE_FIELDS:
        assert getattr(tr_none, field) == getattr(tr_empty, field), field


def test_pushsum_with_faults_rejected():
    _, grad_fn, eval_fn = _problem(N, D)
    with pytest.raises(ValueError, match="push"):
        NetSimulator(homogeneous(N, 0.02, seed=1), grad_fn, eval_fn,
                     algorithm="pushsum", faults=FaultPlan())


def test_adaptive_controller_survives_membership_and_heal():
    """The controller retunes against the spliced sub-cluster after a
    leave/join and pulls its next retune forward on partition heal; both
    engines complete and keep retuning."""
    from repro.adaptive import AdaptiveController
    plan = FaultPlan(
        events=({"time": 0.5, "action": "leave", "node": 7},
                {"time": 1.0, "action": "partition", "group": [0, 1, 2]},
                {"time": 1.6, "action": "heal"},
                {"time": 2.0, "action": "join", "node": 7}), seed=4)
    _, grad_fn, eval_fn = _problem(N, D)
    for engine in ("object", "vectorized"):
        ctrl = AdaptiveController(update_every=0.4, warmup_messages=4,
                                  warmup_steps=4)
        sim = NetSimulator(lossy(N, 0.02, loss=0.1, seed=3), grad_fn,
                           eval_fn, seed=5, engine=engine, faults=plan,
                           controller=ctrl)
        tr = sim.run(np.zeros((N, D)), T=80, eval_every=4)
        assert np.isfinite(tr.fvals).all()
        assert sim.fault_stats["leaves"] == 1
        assert sim.fault_stats["joins"] == 1
        # retunes continued after the membership change at t=0.5
        assert ctrl.r_hat_history and ctrl.r_hat_history[-1][0] > 0.5
        # the controller now solves the 9-node sub-cluster... and is put
        # back to 10 when node 7 rejoins
        assert ctrl._n == 10


# -- bounded retransmission --------------------------------------------------


def test_link_model_retry_validation():
    with pytest.raises(ValueError, match="retry_timeout"):
        LinkModel(loss=0.1, retries=2)
    with pytest.raises(ValueError, match="retries"):
        LinkModel(retries=-1)
    with pytest.raises(ValueError, match="retry_backoff"):
        LinkModel(retries=1, retry_timeout=0.1, retry_backoff=0.5)


def test_retries_recover_drops_bit_identically():
    scenario = lossy(N, 0.02, loss=0.4, seed=3, retries=3,
                     retry_timeout=0.05)
    runs = _run_engines(scenario, None, T=100)
    _assert_engines_identical(runs)
    sim, _ = runs["object"]
    assert sim.retransmits > 0
    assert sim.drops > 0  # drops still counted per attempt
    # retransmits also ride along with a fault plan
    runs_f = _run_engines(scenario, _PLAN_GRID["crash_restart_warm"], T=100)
    _assert_engines_identical(runs_f)
    assert runs_f["object"][0].retransmits > 0


def test_retries_improve_delivery_under_loss():
    """With bounded retry the effective delivery rate rises: same loss,
    same traffic pattern, strictly more arrivals."""
    _, grad_fn, eval_fn = _problem(N, D)

    def arrivals(retries):
        sc = lossy(N, 0.02, loss=0.5, seed=3,
                   retries=retries, retry_timeout=0.05 if retries else 0.0)
        sim = NetSimulator(sc, grad_fn, eval_fn, seed=5, engine="object")
        sim.run(np.zeros((N, D)), T=100, eval_every=10)
        return len(sim.msg_flights)

    assert arrivals(3) > arrivals(0)


# -- experiments-layer wiring ------------------------------------------------


def test_spec_with_faults_round_trips_and_runs():
    from repro.experiments import ExperimentSpec, run
    from repro.obs.metrics import RunMetrics

    spec = ExperimentSpec(
        name="faults_smoke",
        problem={"kind": "quadratic_consensus", "params": {"n": 8, "d": 3}},
        topology={"kind": "expander", "params": {"k": 4}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "netsim", "params": {"scenario": "lossy",
                                                "engine": "object",
                                                "loss": 0.1, "retries": 2,
                                                "retry_timeout": 0.05}}],
        faults={"kind": "churn", "params": {"frac": 0.25, "period": 1.0,
                                            "downtime": 0.3, "cycles": 2,
                                            "seed": 3}},
        T=80, eval_every=5, seed=3, r=0.02)
    assert spec == ExperimentSpec.from_json(spec.to_json())
    result = run(spec)
    faults = result.metrics.faults
    assert faults is not None
    assert faults["crashes"] == 4 and faults["restarts"] == 4
    assert "retransmits" in faults
    # strict-RFC JSON round-trip of the metrics block, faults included
    m2 = RunMetrics.from_dict(json.loads(json.dumps(
        result.metrics.to_dict())))
    assert m2.faults == faults
    # the trace CLI summary renders the faults block
    from repro.obs import render_summary
    text = render_summary(json.loads(result.to_json()))
    assert "faults:" in text and "crashes" in text


def test_dense_backend_rejects_faults():
    from repro.experiments import ExperimentSpec, run

    spec = ExperimentSpec(
        name="dense_faults",
        problem={"kind": "quadratic_consensus", "params": {"n": 4, "d": 2}},
        topology={"kind": "complete"},
        schedule={"kind": "every"},
        backends=[{"kind": "dense"}],
        faults={"kind": "plan"},
        T=10, seed=0)
    with pytest.raises(ValueError, match="netsim"):
        run(spec)


def test_fault_spans_land_in_tracer():
    from repro.obs import Tracer

    _, grad_fn, eval_fn = _problem(N, D)
    tracer = Tracer(detail=True)
    plan = _PLAN_GRID["crash_restart_warm"]
    sim = NetSimulator(homogeneous(N, 0.02, seed=1), grad_fn, eval_fn,
                       seed=5, engine="object", faults=plan, tracer=tracer)
    sim.run(np.zeros((N, D)), T=60, eval_every=5)
    names = {ev.name for ev in tracer.events if ev.track == "faults"}
    assert "fault_crash" in names and "fault_restart" in names
