"""Communication-graph properties (paper section III prerequisites)."""

import math

import numpy as np
import pytest
from _hyp import given, st

from repro.core import graphs as G


TOPOLOGIES = ["complete", "ring", "hypercube", "torus", "expander4",
              "rregular4"]


def _build(name, n):
    return G.build_graph(name, n)


@pytest.mark.parametrize("name,n", [
    ("complete", 2), ("complete", 8), ("complete", 14),
    ("ring", 4), ("ring", 9),
    ("hypercube", 8), ("hypercube", 16),
    ("torus", 16), ("torus", 25),
    ("expander4", 12), ("expander4", 64),
    ("rregular4", 16), ("rregular4", 100),
])
def test_doubly_stochastic(name, n):
    P = _build(name, n).mixing_matrix()
    assert np.allclose(P.sum(axis=0), 1.0, atol=1e-9)
    assert np.allclose(P.sum(axis=1), 1.0, atol=1e-9)
    assert (P >= -1e-12).all()


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_lambda2_in_unit_interval(name):
    g = _build(name, 16)
    lam2 = g.lambda2()
    assert 0.0 <= lam2 < 1.0  # connected => strictly < 1


def test_complete_graph_exact_average():
    g = G.complete_graph(6)
    assert g.lambda2() < 1e-8  # one round reaches consensus
    P = g.mixing_matrix()
    assert np.allclose(P, np.full((6, 6), 1 / 6))


def test_expander_gap_beats_ring():
    """Claim C3's prerequisite: the expander keeps a usable gap as n grows,
    the ring does not."""
    for n, factor in ((16, 3), (64, 10), (256, 100)):
        e = G.random_regular_expander(n, k=4)
        r = G.ring_graph(n)
        assert e.spectral_gap() > factor * r.spectral_gap(), n


def test_rregular_gap_roughly_constant():
    gaps = [G.random_regular_expander(n, k=4, seed=1).spectral_gap()
            for n in (64, 256, 1024)]
    assert max(gaps) / min(gaps) < 2.5, gaps


def test_ppermute_pairs_are_permutations():
    g = G.kregular_expander(12, k=4)
    for pairs in g.ppermute_pairs():
        srcs = sorted(s for s, _ in pairs)
        dsts = sorted(d for _, d in pairs)
        assert srcs == list(range(12)) and dsts == list(range(12))


@given(n=st.integers(3, 40), seed=st.integers(0, 5))
def test_expander_doubly_stochastic_hypothesis(n, seed):
    g = G.random_regular_expander(n, k=2, seed=seed)
    P = g.mixing_matrix()
    assert np.allclose(P.sum(axis=0), 1.0, atol=1e-9)
    assert np.allclose(P.sum(axis=1), 1.0, atol=1e-9)


@given(n=st.sampled_from([4, 8, 16, 32]))
def test_hypercube_degree_logn(n):
    g = G.hypercube_graph(n)
    assert g.degree == int(math.log2(n))


def test_mixing_matrix_matches_perms():
    g = G.ring_graph(5)
    P = g.mixing_matrix()
    # each node averages self + two neighbors with weight 1/3
    assert np.isclose(P[0, 0], 1 / 3) and np.isclose(P[0, 1], 1 / 3) \
        and np.isclose(P[0, 4], 1 / 3)
