"""Communication schedules: H_t / Q_t bookkeeping (paper eq. 12/19/22) and
the convergence constants (eq. 7/18/31)."""

import math

import pytest
from _hyp import given, st

from repro.core import schedules as S


def test_every_iteration():
    s = S.EveryIteration()
    assert all(s.is_comm_step(t) for t in range(1, 20))
    assert s.H(17) == 17


@given(h=st.integers(1, 10), T=st.integers(1, 200))
def test_periodic_H_matches_paper_formula(h, T):
    """Paper eq. (19): of T iterations only H_T = floor((T-1)/h) are
    expensive."""
    s = S.Periodic(h=h)
    assert s.H(T) == (T - 1) // h
    # consistency with the indicator
    assert s.H(T) == sum(1 for t in range(1, T + 1) if s.is_comm_step(t))


@given(h=st.integers(1, 10), T=st.integers(1, 100))
def test_periodic_Q_range(h, T):
    s = S.Periodic(h=h)
    q = s.Q(T)
    assert 1 <= q <= h


@given(p=st.floats(0.05, 0.45), T=st.sampled_from([50, 200, 800]))
def test_sparse_H_growth_theta(p, T):
    """Paper eq. (22): H_T = Theta(T^{1/(p+1)})."""
    s = S.IncreasinglySparse(p=p)
    H = s.H(T)
    pred = T ** (1.0 / (p + 1.0))
    assert 0.4 * pred <= H <= 2.5 * pred, (H, pred)


def test_sparse_comm_times_monotone_gaps():
    s = S.IncreasinglySparse(p=0.5)
    times = [t for t in range(1, 400) if s.is_comm_step(t)]
    gaps = [b - a for a, b in zip(times, times[1:])]
    # gaps are nondecreasing within +-1 rounding
    for a, b in zip(gaps, gaps[5:]):
        assert b >= a - 1


def test_sparse_p0_is_every_iteration():
    s = S.IncreasinglySparse(p=0.0)
    assert [t for t in range(1, 10) if s.is_comm_step(t)] == list(range(1, 10))


def test_constants_paper_values():
    # C1 = 2LR sqrt(19 + 12) for lam2=0
    assert math.isclose(S.c1_constant(1, 1, 0.0), 2 * math.sqrt(31))
    # C_h at h=1 reduces to the C1 form: 1 + 18 + 12 = 31
    assert math.isclose(S.ch_constant(1, 1, 0.0, 1), 2 * math.sqrt(31))


@given(p=st.floats(0.01, 0.49), lam2=st.floats(0.0, 0.9))
def test_cp_below_c1(p, lam2):
    """Claim C5: C_p < C_1 for 0 < p < 1/2."""
    assert S.cp_constant(1, 1, lam2, p) < S.c1_constant(1, 1, lam2)


@given(h=st.integers(2, 50), lam2=st.floats(0.0, 0.9))
def test_ch_above_c1(h, lam2):
    assert S.ch_constant(1, 1, lam2, h) > S.c1_constant(1, 1, lam2)


def test_optimal_stepsize_matches_ch():
    # A = R/L / sqrt(...) and C_h = 2RL sqrt(...) => A * C_h = 2 R^2
    for h in (1, 3, 9):
        A = S.optimal_stepsize_A(2.0, 3.0, 0.25, h)
        C = S.ch_constant(2.0, 3.0, 0.25, h)
        assert math.isclose(A * C, 2 * 3.0 * 3.0, rel_tol=1e-9)


def test_make_schedule_dispatch():
    assert isinstance(S.make_schedule("every"), S.EveryIteration)
    assert S.make_schedule("periodic", h=4).h == 4
    assert S.make_schedule("sparse", p=0.2).p == 0.2
    with pytest.raises(ValueError):
        S.make_schedule("nope")
