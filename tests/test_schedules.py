"""Communication schedules: H_t / Q_t bookkeeping (paper eq. 12/19/22) and
the convergence constants (eq. 7/18/31)."""

import math

import pytest
from _hyp import given, st

from repro.core import schedules as S


def test_every_iteration():
    s = S.EveryIteration()
    assert all(s.is_comm_step(t) for t in range(1, 20))
    assert s.H(17) == 17


@given(h=st.integers(1, 10), T=st.integers(1, 200))
def test_periodic_H_matches_paper_formula(h, T):
    """Paper eq. (19): of T iterations only H_T = floor((T-1)/h) are
    expensive."""
    s = S.Periodic(h=h)
    assert s.H(T) == (T - 1) // h
    # consistency with the indicator
    assert s.H(T) == sum(1 for t in range(1, T + 1) if s.is_comm_step(t))


@given(h=st.integers(1, 10), T=st.integers(1, 100))
def test_periodic_Q_range(h, T):
    s = S.Periodic(h=h)
    q = s.Q(T)
    assert 1 <= q <= h


@given(p=st.floats(0.05, 0.45), T=st.sampled_from([50, 200, 800]))
def test_sparse_H_growth_theta(p, T):
    """Paper eq. (22): H_T = Theta(T^{1/(p+1)})."""
    s = S.IncreasinglySparse(p=p)
    H = s.H(T)
    pred = T ** (1.0 / (p + 1.0))
    assert 0.4 * pred <= H <= 2.5 * pred, (H, pred)


def test_sparse_comm_times_monotone_gaps():
    s = S.IncreasinglySparse(p=0.5)
    times = [t for t in range(1, 400) if s.is_comm_step(t)]
    gaps = [b - a for a, b in zip(times, times[1:])]
    # gaps are nondecreasing within +-1 rounding
    for a, b in zip(gaps, gaps[5:]):
        assert b >= a - 1


def test_sparse_p0_is_every_iteration():
    s = S.IncreasinglySparse(p=0.0)
    assert [t for t in range(1, 10) if s.is_comm_step(t)] == list(range(1, 10))


def test_constants_paper_values():
    # C1 = 2LR sqrt(19 + 12) for lam2=0
    assert math.isclose(S.c1_constant(1, 1, 0.0), 2 * math.sqrt(31))
    # C_h at h=1 reduces to the C1 form: 1 + 18 + 12 = 31
    assert math.isclose(S.ch_constant(1, 1, 0.0, 1), 2 * math.sqrt(31))


@given(p=st.floats(0.01, 0.49), lam2=st.floats(0.0, 0.9))
def test_cp_below_c1(p, lam2):
    """Claim C5: C_p < C_1 for 0 < p < 1/2."""
    assert S.cp_constant(1, 1, lam2, p) < S.c1_constant(1, 1, lam2)


@given(h=st.integers(2, 50), lam2=st.floats(0.0, 0.9))
def test_ch_above_c1(h, lam2):
    assert S.ch_constant(1, 1, lam2, h) > S.c1_constant(1, 1, lam2)


def test_optimal_stepsize_matches_ch():
    # A = R/L / sqrt(...) and C_h = 2RL sqrt(...) => A * C_h = 2 R^2
    for h in (1, 3, 9):
        A = S.optimal_stepsize_A(2.0, 3.0, 0.25, h)
        C = S.ch_constant(2.0, 3.0, 0.25, h)
        assert math.isclose(A * C, 2 * 3.0 * 3.0, rel_tol=1e-9)


def test_make_schedule_dispatch():
    assert isinstance(S.make_schedule("every"), S.EveryIteration)
    assert S.make_schedule("periodic", h=4).h == 4
    assert S.make_schedule("sparse", p=0.2).p == 0.2
    with pytest.raises(ValueError):
        S.make_schedule("nope")


# ---------------------------------------------------------------------------
# batch closed forms: comm_mask + next_comm_step_batch (the scanned-loop
# mask precompute in core.dda relies on these agreeing with the scalar
# queries for every schedule kind)
# ---------------------------------------------------------------------------

import numpy as np


def _spliced_piecewise():
    s = S.PiecewisePeriodic(h=1)
    s.set_h(7, 3)
    s.set_h(20, 2)
    s.set_h(41, 5)
    return s


def _all_kinds():
    return [S.EveryIteration(), S.Periodic(h=1), S.Periodic(h=4),
            S.IncreasinglySparse(p=0.0), S.IncreasinglySparse(p=0.3),
            S.PiecewisePeriodic(h=3), _spliced_piecewise()]


@pytest.mark.parametrize("t0,length", [(0, 60), (5, 40), (37, 90), (0, 1)])
def test_comm_mask_matches_is_comm_step(t0, length):
    for sched in _all_kinds():
        mask = sched.comm_mask(t0, length)
        expect = np.array([sched.is_comm_step(t)
                           for t in range(t0 + 1, t0 + length + 1)])
        assert mask.dtype == bool and mask.shape == (length,)
        assert (mask == expect).all(), type(sched).__name__


@given(p=st.floats(0.0, 0.49), tmax=st.integers(1, 300))
def test_sparse_next_comm_step_batch_closed_form(p, tmax):
    """IncreasinglySparse's vectorized batch query == the scalar loop
    (previously the base class fell back to per-element Python)."""
    sched = S.IncreasinglySparse(p=p)
    t = np.arange(0, tmax, max(1, tmax // 37))
    batch = sched.next_comm_step_batch(t)
    scalar = np.array([sched.next_comm_step(int(s)) for s in t])
    assert (batch == scalar).all()


def test_piecewise_comm_mask_tracks_splices():
    """comm_mask over a window spanning several spliced segments equals the
    scalar queries, and stays consistent with next_comm_step_batch."""
    sched = _spliced_piecewise()
    mask = sched.comm_mask(0, 80)
    comm_ts = np.flatnonzero(mask) + 1
    assert sched.H(80) == len(comm_ts)
    nxt = sched.next_comm_step_batch(np.arange(0, 79))
    for t in range(0, 79):
        after = comm_ts[comm_ts > t]
        if len(after):
            assert nxt[t] == after[0]
