"""Event-driven async cluster simulator (repro.netsim): determinism, the
eq. (9) wall-clock contract, empirical r recovery, scenario orderings, and
push-sum mass conservation under packet loss."""

import math

import numpy as np
import pytest

from repro.core import (EveryIteration, GraphSequence, IncreasinglySparse,
                        Periodic, expander_sequence, iteration_cost,
                        kregular_expander)
from repro.netsim import (EventQueue, LinkModel, NetSimulator, NodeSpec,
                          homogeneous, lossy, pushsum_mass_audit, straggler,
                          time_varying_expander)

N, D, R = 8, 5, 0.01


def _quadratic_problem(seed=0):
    """The canonical netsim quadratic (see repro.netsim.problems)."""
    from repro.netsim import quadratic_consensus
    return quadratic_consensus(N, D, seed)


def _run(scenario, T=300, seed=0, eval_every=5, **kw):
    _, grad_fn, eval_fn = _quadratic_problem()
    sim = NetSimulator(scenario, grad_fn, eval_fn, seed=seed, **kw)
    trace = sim.run(np.zeros((N, D)), T, eval_every=eval_every)
    return sim, trace


# -- events -----------------------------------------------------------------


def test_event_queue_ordering_and_clock():
    q = EventQueue()
    q.schedule(2.0, "b")
    q.schedule(1.0, "a")
    q.schedule(2.0, "c")  # same time: insertion order breaks the tie
    kinds = [q.pop().kind for _ in range(3)]
    assert kinds == ["a", "b", "c"]
    assert q.now == 2.0
    with pytest.raises(ValueError):
        q.schedule(1.0, "past")


def test_link_model_loss_and_serialize():
    link = LinkModel(bandwidth=100.0, loss=0.5, latency=0.25)
    assert link.serialize(50.0) == 0.5
    rng = np.random.default_rng(0)
    flights = [link.sample_flight(50.0, rng) for _ in range(400)]
    dropped = sum(f is None for f in flights)
    assert 120 < dropped < 280  # ~50%
    assert all(f == pytest.approx(0.75) for f in flights if f is not None)


# -- wall-clock contract ----------------------------------------------------


def test_homogeneous_wall_clock_matches_eq9():
    """Lossless homogeneous cluster: event clock == T * (1/n + k r)."""
    sc = homogeneous(N, R, k=4, seed=0)
    sim, trace = _run(sc, T=200)
    k = sc.topology.degree
    assert trace.sim_time[-1] == pytest.approx(
        200 * iteration_cost(N, k, R), rel=1e-9)


def test_measure_r_empirical_recovers_configured_r():
    sim, _ = _run(homogeneous(N, R, k=4, seed=0), T=200)
    m = sim.measure_r_empirical()
    assert m.r == pytest.approx(R, rel=1e-6)
    assert m.drop_rate == 0.0
    pred = sim.predict(eps=0.1)
    assert pred["n_opt"] == pytest.approx(1.0 / math.sqrt(R), rel=1e-6)
    assert pred["h_opt"] >= 1


def test_deterministic_given_seed():
    _, t1 = _run(lossy(N, R, loss=0.3, seed=0), T=120, seed=7)
    _, t2 = _run(lossy(N, R, loss=0.3, seed=0), T=120, seed=7)
    assert t1.sim_time == t2.sim_time
    assert t1.fvals == t2.fvals


# -- scenario orderings -----------------------------------------------------


def _tta(sim, trace, eval_fn_value):
    return sim.time_to_reach(trace, eval_fn_value)


def test_straggler_strictly_slower_than_homogeneous():
    """One 4x straggler: its own iterations pace 4x slower AND its stale z
    drags every neighbor's mixing -- time-to-accuracy strictly increases."""
    centers, _, eval_fn = _quadratic_problem()
    fstar = eval_fn(centers.mean(0))
    f0 = eval_fn(np.zeros(D))
    eps = fstar + 0.025 * (f0 - fstar)
    sim0, tr0 = _run(homogeneous(N, R, k=4, seed=0), T=800)
    sim1, tr1 = _run(straggler(N, R, slow_factor=4.0, k=4, seed=0), T=1200)
    t0, t1 = _tta(sim0, tr0, eps), _tta(sim1, tr1, eps)
    assert math.isfinite(t0) and math.isfinite(t1)
    assert t1 > t0
    # wall clock itself is strictly longer too, per iteration completed
    assert tr1.sim_time[-1] / tr1.iters[-1] > tr0.sim_time[-1] / tr0.iters[-1]


def test_lossy_slower_than_homogeneous():
    """30% packet loss leaves the wall clock per iteration unchanged but
    degrades mixing, so time-to-accuracy strictly increases at tight eps."""
    centers, _, eval_fn = _quadratic_problem()
    fstar = eval_fn(centers.mean(0))
    f0 = eval_fn(np.zeros(D))
    eps = fstar + 0.015 * (f0 - fstar)
    sim0, tr0 = _run(homogeneous(N, R, k=4, seed=0), T=1200)
    sim1, tr1 = _run(lossy(N, R, loss=0.3, seed=0), T=1200)
    t0, t1 = _tta(sim0, tr0, eps), _tta(sim1, tr1, eps)
    assert math.isfinite(t0) and math.isfinite(t1)
    assert t1 > t0
    assert sim1.measure_r_empirical().drop_rate == pytest.approx(0.3, abs=0.08)


def test_time_varying_expander_runs_and_rewires():
    sim, trace = _run(time_varying_expander(N, R, rewire_every=1.0, seed=0),
                      T=150)
    assert sim.rewires > 3
    assert trace.fvals[-1] < trace.fvals[0]


# -- push-sum ---------------------------------------------------------------


def test_pushsum_mass_conservation_under_drops():
    """The sigma/rho counters conserve total (value, weight) mass exactly
    under 40% i.i.d. packet loss (averaging mode: zero gradients)."""
    rng = np.random.default_rng(3)
    y0 = rng.normal(size=(N, D))
    _, _, eval_fn = _quadratic_problem()
    sim = NetSimulator(lossy(N, R, loss=0.4, seed=1),
                       lambda i, x, t: np.zeros(D), eval_fn,
                       algorithm="pushsum", pushsum_y0=y0, seed=2,
                       pushsum_w_floor=1e-12)  # exact ratio, no basin clamp
    sim.run(np.zeros((N, D)), T=150, eval_every=50)
    assert sim.drops > 0
    y_total, w_total = pushsum_mass_audit(sim.nodes)
    np.testing.assert_allclose(y_total, y0.sum(axis=0), atol=1e-9)
    assert w_total == pytest.approx(N, abs=1e-9)
    # ratio estimates converge to the true average despite the drops
    est = np.stack([nd.z_est for nd in sim.nodes])
    np.testing.assert_allclose(est, np.broadcast_to(y0.mean(0), est.shape),
                               atol=1e-6)


def test_pushsum_dda_converges_under_loss():
    centers, _, eval_fn = _quadratic_problem()
    fstar = eval_fn(centers.mean(0))
    sim, trace = _run(lossy(N, R, loss=0.3, seed=0), T=1500,
                      algorithm="pushsum",
                      a_fn=lambda t: 0.5 / math.sqrt(max(t, 1.0)))
    assert trace.fvals[-1] < fstar * 1.05
    assert np.isfinite(trace.fvals).all()


def test_pushsum_w_floor_bias_is_bounded_damping():
    """Quantifies the w_floor ratio-guard bias (ROADMAP item).

    The floor clamps only the DENOMINATOR of the ratio estimate, so the
    sigma/rho mass dynamics are untouched (bitwise identical runs for any
    floor) and the floored estimate is EXACTLY the exact ratio damped
    per-node:  z_floor_i = (y_i / w_i) * min(1, w_i / w_floor).
    The relative bias is therefore bounded by max(0, 1 - w_i / w_floor),
    nonzero only while held weight dwells below the floor, and vanishes as
    push-sum mixes w_i back toward 1 -- a bounded, transient damping toward
    zero, in exchange for the divergence protection the companion test
    below measures."""
    rng = np.random.default_rng(3)
    y0 = rng.normal(size=(N, D)) * 2.0
    _, _, eval_fn = _quadratic_problem()
    floor = 0.5

    def run(w_floor):
        sim = NetSimulator(lossy(N, R, loss=0.5, seed=1),
                           lambda i, x, t: np.zeros(D), eval_fn,
                           algorithm="pushsum", pushsum_y0=y0, seed=2,
                           pushsum_w_floor=w_floor)
        sim.run(np.zeros((N, D)), T=120, eval_every=40)
        y = np.stack([nd.y for nd in sim.nodes])
        w = np.array([nd.w for nd in sim.nodes])
        return y, w

    y_f, w_f = run(floor)
    y_e, w_e = run(1e-12)
    # 1. the guard never touches the mass bookkeeping
    np.testing.assert_array_equal(y_f, y_e)
    np.testing.assert_array_equal(w_f, w_e)
    assert (w_f < floor).any()  # heavy loss actually exercised the clamp
    # 2. bias identity: floored estimate == exact ratio * damping factor
    z_exact = y_f / w_f[:, None]
    z_floor = y_f / np.maximum(w_f, floor)[:, None]
    damp = np.minimum(1.0, w_f / floor)
    np.testing.assert_allclose(z_floor, z_exact * damp[:, None], rtol=1e-9)
    # 3. documented bound: relative bias <= 1 - w/floor where binding
    rel_bias = np.linalg.norm(z_floor - z_exact, axis=1) \
        / np.maximum(np.linalg.norm(z_exact, axis=1), 1e-300)
    np.testing.assert_allclose(rel_bias, np.maximum(0.0, 1.0 - damp),
                               atol=1e-9)
    assert rel_bias.max() <= 1.0


def test_pushsum_w_floor_prevents_divergence_under_heavy_loss():
    """The other side of the tradeoff: with gradient injection under 60%
    loss, the unguarded ratio (w_floor ~ 0) amplifies fresh gradients by
    1/w and the primal feedback loop blows up by many orders of magnitude;
    the default guard keeps the whole trajectory bounded."""
    centers, grad_fn, eval_fn = _quadratic_problem()
    f0 = eval_fn(np.zeros(D))

    def run(w_floor):
        sim = NetSimulator(lossy(N, R, loss=0.6, seed=1), grad_fn, eval_fn,
                           algorithm="pushsum", seed=2,
                           pushsum_w_floor=w_floor,
                           a_fn=lambda t: 0.2 / math.sqrt(max(t, 1.0)))
        trace = sim.run(np.zeros((N, D)), T=400, eval_every=20)
        return max(abs(f) for f in trace.fvals)

    assert run(0.5) < 10.0 * f0          # guarded: stays in the basin
    assert run(1e-12) > 1e6 * f0         # unguarded: catastrophic blow-up


def test_pushsum_scaled_injection_bounded_without_floor():
    """inject="scaled" closes the divergence loop at the SOURCE: the
    gradient enters pre-scaled by the held w (y += w * grad), so the ratio
    estimate never amplifies fresh gradients by 1/w and the trajectory
    stays bounded even with the denominator guard disabled -- where plain
    injection blows up by >1e6 x under the same 60% loss (companion test
    above). The price is a w-proportional downweighting of a depleted
    node's own gradient, a bias that shrinks as push-sum remixes w toward
    1 (it does not accumulate: each step's gradient is scaled once, by
    that step's w)."""
    centers, grad_fn, eval_fn = _quadratic_problem()
    f0 = eval_fn(np.zeros(D))

    def run(inject, engine="auto"):
        sim = NetSimulator(lossy(N, R, loss=0.6, seed=1), grad_fn, eval_fn,
                           algorithm="pushsum", seed=2,
                           pushsum_w_floor=1e-12, pushsum_inject=inject,
                           engine=engine,
                           a_fn=lambda t: 0.2 / math.sqrt(max(t, 1.0)))
        return sim.run(np.zeros((N, D)), T=400, eval_every=20)

    tr = run("scaled")
    assert max(abs(f) for f in tr.fvals) < 10.0 * f0
    assert np.isfinite(tr.fvals).all()
    # both engines implement the scaled injection identically
    to, tv = run("scaled", "object"), run("scaled", "vectorized")
    from repro.core.dda import TRACE_FIELDS
    for field in TRACE_FIELDS:
        assert getattr(to, field) == getattr(tv, field), field


def test_pushsum_inject_validation():
    _, grad_fn, eval_fn = _quadratic_problem()
    with pytest.raises(ValueError, match="pushsum_inject"):
        NetSimulator(lossy(N, R, seed=0), grad_fn, eval_fn,
                     algorithm="pushsum", pushsum_inject="nope")
    with pytest.raises(ValueError, match="pushsum"):
        NetSimulator(lossy(N, R, seed=0), grad_fn, eval_fn,
                     algorithm="dda", pushsum_inject="scaled")


# -- core hooks the netsim relies on ---------------------------------------


def test_next_comm_step_consistent_with_is_comm_step():
    for sched in [EveryIteration(), Periodic(h=1), Periodic(h=4),
                  IncreasinglySparse(p=0.3)]:
        for t in range(0, 60):
            nxt = sched.next_comm_step(t)
            assert nxt > t
            assert sched.is_comm_step(nxt)
            assert not any(sched.is_comm_step(s) for s in range(t + 1, nxt))


def test_graph_sequence():
    seq = expander_sequence(N, k=4, length=3, seed=0)
    assert seq.n == N and len(seq) == 3
    assert seq.at(0).n == N
    assert seq.at(5) is seq.at(2)  # periodic
    assert 0.0 < seq.lambda2_worst() < 1.0
    with pytest.raises(ValueError):
        GraphSequence((kregular_expander(4, 2), kregular_expander(6, 2)))


def test_node_spec_hardware_scaling():
    assert NodeSpec().scale == pytest.approx(1.0)
    assert NodeSpec.slowed(4.0).scale == pytest.approx(4.0)
    assert NodeSpec(compute_scale=2.5).scale == 2.5


def test_dda_simulator_time_to_reach_flag():
    """Satellite fix: default reads Fbar (paper Fig 1/2); the flag switches
    to F at the consensus average."""
    from repro.core import DDASimulator
    from repro.core.dda import SimTrace
    trace = SimTrace(iters=[1, 2], sim_time=[0.5, 1.0],
                     fvals=[5.0, 1.0], comms=[1, 2],
                     disagreement=[0.0, 0.0],
                     fvals_consensus=[0.5, 0.1])
    sim = DDASimulator.__new__(DDASimulator)  # only time_to_reach needed
    assert sim.time_to_reach(trace, 2.0) == 1.0
    assert sim.time_to_reach(trace, 2.0, use_consensus=True) == 0.5
    assert sim.time_to_reach(trace, 0.01) == float("inf")
