"""[beyond paper] Event-driven async cluster sweep: loss-rate x straggler-
factor against the paper's predicted tau(eps).

The paper validates eq. (9)-(21) on a real cluster where r is measured and
the clock is wall time. `repro.netsim` recreates that setting in simulation:
this benchmark runs the paper's non-smooth problem (section V.B) on an
8-node expander under increasingly hostile cluster conditions and reports,
per cell of the (loss, straggler) grid:

  * empirical time-to-accuracy on the event clock,
  * r recovered from the observed timeline (measure_r_empirical),
  * the flat-time-model prediction `T_emp * iteration_cost(n, k, r_hat)`
    via core.tradeoff.time_to_accuracy (exact for a lossless homogeneous
    cluster; the grid shows where reality departs from the model).

Knobs (see --help): --n, --T, --r, --k, --loss, --straggler, --eval-every,
--seed, --schedule/--h, --pushsum, --smoke.

--smoke runs the acceptance check: on a lossless homogeneous 8-node
expander the event-driven trace's time-to-accuracy must match
core.tradeoff.time_to_accuracy within 15%, and the lossy / straggler
scenarios must produce strictly slower traces. Exits nonzero on failure.
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import sys

import numpy as np

from repro.core import (EveryIteration, iteration_cost, make_schedule,
                        time_to_accuracy)
from repro.data.pipeline import nonsmooth_quadratic_problem
from repro.netsim import NetSimulator, homogeneous, lossy, straggler


def build_problem(n: int, M: int, d: int, seed: int):
    """Paper V.B non-smooth quadratics, in pure numpy (the netsim is
    host-side; no need to round-trip each per-node subgradient through jax)."""
    centers = nonsmooth_quadratic_problem(n, M, d, seed,
                                          center_scale=1.5).astype(np.float64)

    def grad_fn(i, x, t):
        diff = x[None, None, :] - centers[i]          # (M, 2, d)
        q = np.sum(diff * diff, axis=-1)              # (M, 2)
        pick = np.argmax(q, axis=-1)                  # (M,)
        chosen = np.take_along_axis(
            diff, pick[:, None, None], axis=1)[:, 0]  # (M, d)
        return 2.0 * np.sum(chosen, axis=0)

    def eval_fn(x):
        diff = x[None, None, None, :] - centers       # (n, M, 2, d)
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    return centers, grad_fn, eval_fn


def centralized_optimum(centers: np.ndarray, iters: int = 800) -> float:
    """Reference F* via centralized subgradient descent on the mean
    objective (mirrors NonsmoothQuadratics.optimum_value)."""
    n, M, _, d = centers.shape

    def full_grad(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        pick = np.argmax(q, axis=-1)
        chosen = np.take_along_axis(diff, pick[..., None, None],
                                    axis=2)[:, :, 0]
        return 2.0 * np.sum(chosen, axis=(0, 1)) / n

    def value(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    x = np.zeros(d)
    best = value(x)
    lr0 = 1.0 / (4.0 * M)
    for t in range(1, iters + 1):
        x = x - (lr0 / math.sqrt(t)) * full_grad(x)
        if t % 50 == 0:
            best = min(best, value(x))
    return best


def run_cell(scenario, grad_fn, eval_fn, d, schedule, T, eval_every, seed,
             a_scale, algorithm="dda"):
    a_fn = (lambda t: a_scale / math.sqrt(max(t, 1.0)))
    sim = NetSimulator(scenario, grad_fn, eval_fn, a_fn=a_fn,
                       schedule=schedule, algorithm=algorithm, seed=seed)
    trace = sim.run(np.zeros((scenario.n, d)), T, eval_every=eval_every)
    return sim, trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=8, help="cluster size")
    ap.add_argument("--k", type=int, default=4, help="expander degree")
    ap.add_argument("--M", type=int, default=30, help="terms per node")
    ap.add_argument("--d", type=int, default=20, help="dimension")
    ap.add_argument("--T", type=int, default=1000, help="iterations per node")
    ap.add_argument("--r", type=float, default=0.01,
                    help="configured per-message time (full-grad units)")
    ap.add_argument("--loss", type=float, nargs="*", default=[0.0, 0.1, 0.3],
                    help="loss-rate sweep values")
    ap.add_argument("--straggler", type=float, nargs="*",
                    default=[1.0, 2.0, 4.0],
                    help="straggler slow-factor sweep values (1 = none)")
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="every",
                    choices=["every", "periodic", "sparse"])
    ap.add_argument("--h", type=int, default=2, help="h for --schedule periodic")
    ap.add_argument("--pushsum", action="store_true",
                    help="use drop-robust push-sum instead of stale gossip")
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance check and exit")
    args = ap.parse_args(argv)

    n, d = args.n, args.d
    centers, grad_fn, eval_fn = build_problem(n, args.M, d, args.seed)
    fstar = centralized_optimum(centers)
    f0 = eval_fn(np.zeros(d))
    eps_value = fstar + 0.05 * (f0 - fstar)   # 5% of the initial gap
    schedule = make_schedule(args.schedule, h=args.h)
    algorithm = "pushsum" if args.pushsum else "dda"
    # empirical stepsize: the bound-optimal A is too conservative at these
    # sizes; one global multiplier, as in fig2_sparse
    a_scale = 1.0 / (4.0 * args.M)
    common = dict(d=d, schedule=schedule, T=args.T,
                  eval_every=args.eval_every, seed=args.seed,
                  a_scale=a_scale, algorithm=algorithm)

    if args.smoke:
        return smoke(args, grad_fn, eval_fn, eps_value, common)

    print("scenario,loss,straggler,tta,final_F,r_emp,tau_model,drop_rate")
    for loss_p in args.loss:
        for factor in args.straggler:
            if factor > 1.0 and loss_p > 0.0:
                sc = dataclasses.replace(
                    lossy(n, args.r, loss=loss_p, k=args.k, seed=args.seed),
                    name=f"lossy{loss_p:g}_strag{factor:g}",
                    node_specs=straggler(n, args.r, slow_factor=factor,
                                         k=args.k, seed=args.seed).node_specs)
            elif factor > 1.0:
                sc = straggler(n, args.r, slow_factor=factor, k=args.k,
                               seed=args.seed)
            elif loss_p > 0.0:
                sc = lossy(n, args.r, loss=loss_p, k=args.k, seed=args.seed)
            else:
                sc = homogeneous(n, args.r, k=args.k, seed=args.seed)
            sim, trace = run_cell(sc, grad_fn, eval_fn, **common)
            tta = sim.time_to_reach(trace, eps_value)
            m = sim.measure_r_empirical()
            # flat-model wall clock for the empirically needed iterations
            T_eps = next((it for it, f in zip(trace.iters, trace.fvals)
                          if f <= eps_value), None)
            g = sim.net.graph
            tau_model = (T_eps * iteration_cost(n, g.degree, m.r)
                         if T_eps else float("inf"))
            print(f"{sc.name},{loss_p:g},{factor:g},{tta:.3f},"
                  f"{trace.fvals[-1]:.3f},{m.r:.5f},{tau_model:.3f},"
                  f"{m.drop_rate:.3f}")
    return 0


def smoke(args, grad_fn, eval_fn, eps_value, common) -> int:
    """Acceptance: lossless homogeneous event trace matches the flat time
    model (eq. 9/10) within 15%; lossy + straggler are strictly slower.

    The check is defined for every-iteration stale-gossip DDA only: the
    eps_eff inversion below assumes T = (C/eps)^2 (wrong for the sparse
    schedule's exponent) and the tuned T/eps targets assume communication
    every iteration, so --schedule/--pushsum are pinned here rather than
    silently producing a spurious FAIL.
    """
    if (not isinstance(common["schedule"], EveryIteration)
            or common["algorithm"] != "dda"):
        print("[smoke] note: acceptance check runs with --schedule every "
              "and stale-gossip dda; ignoring other flags")
        common = {**common, "schedule": make_schedule("every"),
                  "algorithm": "dda"}
    n = args.n
    sc0 = homogeneous(n, args.r, k=args.k, seed=args.seed)
    sim0, tr0 = run_cell(sc0, grad_fn, eval_fn, **common)
    tta0 = sim0.time_to_reach(tr0, eps_value)
    T_eps = next((it for it, f in zip(tr0.iters, tr0.fvals)
                  if f <= eps_value), None)
    ok = True
    if T_eps is None or not math.isfinite(tta0):
        print(f"[smoke] FAIL: homogeneous run never reached eps={eps_value:.3f}"
              f" (final F {tr0.fvals[-1]:.3f})")
        return 1

    # express the model's wall clock through time_to_accuracy: pick the
    # eps whose iteration count T = (C/eps)^2 equals the observed T_eps,
    # so the comparison isolates the TIME AXIS (the netsim's claim), not
    # the conservatism of the bound constants
    g = sim0.net.graph
    lam2 = g.lambda2()
    m = sim0.measure_r_empirical()
    C = common["schedule"].constant(1.0, 1.0, lam2)
    eps_eff = C / math.sqrt(T_eps)
    tau_pred = time_to_accuracy(eps_eff, n, g.degree, m.r, lam2,
                                schedule=common["schedule"])
    rel = abs(tta0 - tau_pred) / tau_pred
    line = (f"[smoke] homogeneous: tta={tta0:.3f} model tau={tau_pred:.3f} "
            f"rel_err={rel:.3%} r_emp={m.r:.5f} (configured {args.r:g})")
    if rel > 0.15:
        ok = False
        line += "  FAIL(>15%)"
    print(line)

    for name, sc in [
        ("lossy", lossy(n, args.r, loss=0.2, k=args.k, seed=args.seed)),
        ("straggler", straggler(n, args.r, slow_factor=4.0, k=args.k,
                                seed=args.seed)),
    ]:
        sim, tr = run_cell(sc, grad_fn, eval_fn, **common)
        tta = sim.time_to_reach(tr, eps_value)
        slower = tta > tta0
        print(f"[smoke] {name}: tta={tta:.3f} vs homogeneous {tta0:.3f} "
              f"{'slower OK' if slower else 'FAIL(not slower)'}")
        ok = ok and slower

    print(f"[smoke] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
