"""[beyond paper] Event-driven async cluster sweep: loss-rate x straggler-
factor against the paper's predicted tau(eps).

The paper validates eq. (9)-(21) on a real cluster where r is measured and
the clock is wall time. `repro.netsim` recreates that setting in simulation:
this benchmark runs the paper's non-smooth problem (section V.B) on an
8-node expander under increasingly hostile cluster conditions and reports,
per cell of the (loss, straggler) grid:

  * empirical time-to-accuracy on the event clock,
  * r recovered from the observed timeline (measure_r_empirical),
  * the flat-time-model prediction `T_emp * iteration_cost(n, k, r_hat)`
    via core.tradeoff.time_to_accuracy (exact for a lossless homogeneous
    cluster; the grid shows where reality departs from the model).

Every cell is one declarative `ExperimentSpec` run through `repro.run()`
(the unified experiment API); the pre-redesign hand-wired traces are
reproduced bit-identically (gated in tests/test_experiments_migration.py).

Knobs (see --help): --n, --T, --r, --k, --loss, --straggler, --eval-every,
--seed, --schedule/--h, --pushsum, --pushsum-inject/--pushsum-w-floor,
--smoke.

`--pushsum` runs additionally fold in the injection-bias table: each loss
level is re-run under both `pushsum_inject` modes and the realized
degradation is quantified against the w_floor damping identity
z_floor = (y/w) * min(1, w/w_floor) (see PushSumDDANode).

--smoke runs the acceptance check: on a lossless homogeneous 8-node
expander the event-driven trace's time-to-accuracy must match
core.tradeoff.time_to_accuracy within 15%, and the lossy / straggler
scenarios must produce strictly slower traces. Exits nonzero on failure.
"""

from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from repro.core import iteration_cost, make_schedule, time_to_accuracy
from repro.data.pipeline import nonsmooth_quadratic_problem
from repro.experiments import ExperimentSpec, run as run_spec
from repro.experiments.components import (nonsmooth_centralized_optimum,
                                          problems)


def build_problem(n: int, M: int, d: int, seed: int):
    """Deprecated shim: the paper V.B closures now live in
    `repro.experiments.components` (problem kind "nonsmooth"); kept for
    callers that still want the raw closures."""
    prob = problems.build("nonsmooth", n=n, M=M, d=d, seed=seed)
    centers = nonsmooth_quadratic_problem(n, M, d, seed,
                                          center_scale=1.5).astype(np.float64)
    return centers, prob.grad_fn, prob.eval_fn


def centralized_optimum(centers: np.ndarray, iters: int = 800) -> float:
    """Deprecated shim for
    `repro.experiments.components.nonsmooth_centralized_optimum`."""
    return nonsmooth_centralized_optimum(centers, iters)


def _schedule_component(kind: str, h: int) -> dict:
    return {"kind": kind, "params": ({"h": h} if kind == "periodic" else {})}


def cell_spec(args, *, scenario: str, knobs: dict,
              schedule_kind: str | None = None) -> ExperimentSpec:
    """One (scenario, schedule) grid cell as a declarative spec."""
    a_scale = 1.0 / (4.0 * args.M)  # empirical stepsize, as in fig2_sparse
    algorithm = "pushsum" if args.pushsum else "dda"
    backend_params = {"scenario": scenario, "algorithm": algorithm, **knobs}
    if args.pushsum:
        backend_params["pushsum_inject"] = args.pushsum_inject
        backend_params["pushsum_w_floor"] = args.pushsum_w_floor
    return ExperimentSpec(
        name=f"fig_async_{scenario}",
        problem={"kind": "nonsmooth",
                 "params": {"n": args.n, "M": args.M, "d": args.d,
                            "seed": args.seed}},
        topology={"kind": "expander",
                  "params": {"k": args.k, "seed": args.seed}},
        schedule=_schedule_component(schedule_kind or args.schedule, args.h),
        backends=[{"kind": "netsim", "params": backend_params}],
        stepsize={"kind": "inv_sqrt", "params": {"A": a_scale}},
        T=args.T, eval_every=args.eval_every, seed=args.seed, r=args.r,
        eps_frac=0.05)  # 5% of the initial gap, as the paper reads Fig. 1


def _scenario_for(loss_p: float, factor: float) -> tuple[str, dict]:
    if factor > 1.0 and loss_p > 0.0:
        return "adversarial", {"loss": loss_p, "slow_factor": factor}
    if factor > 1.0:
        return "straggler", {"slow_factor": factor}
    if loss_p > 0.0:
        return "lossy", {"loss": loss_p}
    return "homogeneous", {}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=8, help="cluster size")
    ap.add_argument("--k", type=int, default=4, help="expander degree")
    ap.add_argument("--M", type=int, default=30, help="terms per node")
    ap.add_argument("--d", type=int, default=20, help="dimension")
    ap.add_argument("--T", type=int, default=1000, help="iterations per node")
    ap.add_argument("--r", type=float, default=0.01,
                    help="configured per-message time (full-grad units)")
    ap.add_argument("--loss", type=float, nargs="*", default=[0.0, 0.1, 0.3],
                    help="loss-rate sweep values")
    ap.add_argument("--straggler", type=float, nargs="*",
                    default=[1.0, 2.0, 4.0],
                    help="straggler slow-factor sweep values (1 = none)")
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="every",
                    choices=["every", "periodic", "sparse"])
    ap.add_argument("--h", type=int, default=2, help="h for --schedule periodic")
    ap.add_argument("--pushsum", action="store_true",
                    help="use drop-robust push-sum instead of stale gossip")
    ap.add_argument("--pushsum-inject", default="plain",
                    choices=["plain", "scaled"],
                    help="push-sum gradient injection: textbook y += g, or "
                         "w-scaled y += w*g (bias hits one step's gradient "
                         "instead of the whole estimate)")
    ap.add_argument("--pushsum-w-floor", type=float, default=0.5,
                    help="denominator clamp for the push-sum ratio estimate")
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance check and exit")
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args)

    from repro.experiments.components import topologies
    # the ACTUAL degree, not args.k: kregular_expander silently returns the
    # complete graph (degree n-1) whenever n <= k
    degree = topologies.build("expander", n=args.n, k=args.k,
                              seed=args.seed).degree
    inject_col = ",inject" if args.pushsum else ""
    print(f"scenario{inject_col},loss,straggler,tta,final_F,r_emp,"
          f"tau_model,drop_rate")
    for loss_p in args.loss:
        for factor in args.straggler:
            scenario, knobs = _scenario_for(loss_p, factor)
            res = run_spec(cell_spec(args, scenario=scenario, knobs=knobs))
            tr = res.trace
            tta = (math.inf if res.time_to_target is None
                   else res.time_to_target)
            m = res.r_measurement
            # flat-model wall clock for the empirically needed iterations
            T_eps = next((it for it, f in zip(tr.iters, tr.fvals)
                          if f <= res.eps_value), None)
            tau_model = (T_eps * iteration_cost(args.n, degree, m.r)
                         if T_eps else float("inf"))
            inject_val = f",{args.pushsum_inject}" if args.pushsum else ""
            print(f"{res.extras['scenario']}{inject_val},{loss_p:g},"
                  f"{factor:g},{tta:.3f},{tr.fvals[-1]:.3f},{m.r:.5f},"
                  f"{tau_model:.3f},{m.drop_rate:.3f}")
    if args.pushsum:
        pushsum_bias_table(args)
    return 0


def pushsum_bias_table(args) -> None:
    """Quantify the injection-mode bias on the loss sweep against the
    w_floor damping identity (folded into `--pushsum` runs).

    The ratio guard is EXACTLY a per-node damping of the exact ratio,
    z_floor = (y/w) * min(1, w / w_floor), so its relative bias is bounded
    by max(0, 1 - w/w_floor) wherever held weight mass dwells below the
    floor. "plain" injection exposes the WHOLE estimate to that damping;
    "scaled" injection (y += w*g) pre-shrinks only the freshly injected
    gradient, so the same w dwell should produce a smaller realized bias.
    This table measures both on the sweep's loss grid: per (loss, inject)
    cell the final objective, its relative degradation vs the lossless run
    of the same mode, and the identity's damping factors computed from the
    final held-w snapshot (a proxy for the quasi-stationary w distribution
    under sustained loss).
    """
    from repro.experiments.components import stepsizes
    from repro.netsim import NetSimulator
    from repro.netsim.scenarios import homogeneous, lossy

    prob = problems.build("nonsmooth", n=args.n, M=args.M, d=args.d,
                          seed=args.seed)
    a_fn = stepsizes.build("inv_sqrt", A=1.0 / (4.0 * args.M))
    schedule = make_schedule(args.schedule, h=args.h)
    losses = sorted({0.0, *(p for p in args.loss)})

    def run_cell(inject: str, loss_p: float):
        scenario = (homogeneous(args.n, args.r, k=args.k, seed=args.seed)
                    if loss_p == 0.0 else
                    lossy(args.n, args.r, loss=loss_p, k=args.k,
                          seed=args.seed))
        sim = NetSimulator(scenario, prob.grad_fn, prob.eval_fn, a_fn=a_fn,
                           schedule=schedule, algorithm="pushsum",
                           seed=args.seed, pushsum_inject=inject,
                           pushsum_w_floor=args.pushsum_w_floor)
        trace = sim.run(np.zeros((args.n, args.d)), args.T,
                        eval_every=args.eval_every)
        w = np.array([nd.w for nd in sim.nodes])
        damp = np.minimum(1.0, w / args.pushsum_w_floor)
        return trace.fvals[-1], damp

    print("[pushsum-bias] loss,inject,final_F,rel_degradation,"
          "damp_min,identity_bound")
    base: dict[str, float] = {}
    rel: dict[tuple[str, float], float] = {}
    for inject in ("plain", "scaled"):
        for loss_p in losses:
            f_end, damp = run_cell(inject, loss_p)
            if loss_p == 0.0:
                base[inject] = f_end
            rel_deg = abs(f_end - base[inject]) / abs(base[inject])
            rel[(inject, loss_p)] = rel_deg
            print(f"[pushsum-bias] {loss_p:g},{inject},{f_end:.4f},"
                  f"{rel_deg:.4%},{damp.min():.4f},{1.0 - damp.min():.4%}")
    for loss_p in losses:
        if loss_p == 0.0:
            continue
        p, s = rel[("plain", loss_p)], rel[("scaled", loss_p)]
        verdict = ("scaled <= plain (per-step vs whole-estimate damping)"
                   if s <= p else
                   "scaled > plain (floor not binding, so plain is "
                   "identity-exact; scaled still pays its w-proportional "
                   "injection attenuation)")
        print(f"[pushsum-bias] loss={loss_p:g}: plain {p:.4%} vs "
              f"scaled {s:.4%} -- {verdict}")


def smoke(args) -> int:
    """Acceptance: lossless homogeneous event trace matches the flat time
    model (eq. 9/10) within 15%; lossy + straggler are strictly slower.

    The check is defined for every-iteration stale-gossip DDA only: the
    eps_eff inversion below assumes T = (C/eps)^2 (wrong for the sparse
    schedule's exponent) and the tuned T/eps targets assume communication
    every iteration, so --schedule/--pushsum are pinned here rather than
    silently producing a spurious FAIL.
    """
    if args.schedule != "every" or args.pushsum:
        print("[smoke] note: acceptance check runs with --schedule every "
              "and stale-gossip dda; ignoring other flags")
        args = argparse.Namespace(**{**vars(args), "schedule": "every",
                                     "pushsum": False})
    n = args.n
    res0 = run_spec(cell_spec(args, scenario="homogeneous", knobs={}))
    tr0 = res0.trace
    tta0 = (math.inf if res0.time_to_target is None else res0.time_to_target)
    T_eps = next((it for it, f in zip(tr0.iters, tr0.fvals)
                  if f <= res0.eps_value), None)
    ok = True
    if T_eps is None or not math.isfinite(tta0):
        print(f"[smoke] FAIL: homogeneous run never reached "
              f"eps={res0.eps_value:.3f} (final F {tr0.fvals[-1]:.3f})")
        return 1

    # express the model's wall clock through time_to_accuracy: pick the
    # eps whose iteration count T = (C/eps)^2 equals the observed T_eps,
    # so the comparison isolates the TIME AXIS (the netsim's claim), not
    # the conservatism of the bound constants
    from repro.experiments.components import topologies
    schedule = make_schedule("every")
    g = topologies.build("expander", n=n, k=args.k, seed=args.seed)
    lam2 = g.lambda2()
    m = res0.r_measurement
    C = schedule.constant(1.0, 1.0, lam2)
    eps_eff = C / math.sqrt(T_eps)
    tau_pred = time_to_accuracy(eps_eff, n, g.degree, m.r, lam2,
                                schedule=schedule)
    rel = abs(tta0 - tau_pred) / tau_pred
    line = (f"[smoke] homogeneous: tta={tta0:.3f} model tau={tau_pred:.3f} "
            f"rel_err={rel:.3%} r_emp={m.r:.5f} (configured {args.r:g})")
    if rel > 0.15:
        ok = False
        line += "  FAIL(>15%)"
    print(line)

    for scenario, knobs in [("lossy", {"loss": 0.2}),
                            ("straggler", {"slow_factor": 4.0})]:
        res = run_spec(cell_spec(args, scenario=scenario, knobs=knobs))
        tta = (math.inf if res.time_to_target is None
               else res.time_to_target)
        slower = tta > tta0
        print(f"[smoke] {scenario}: tta={tta:.3f} vs homogeneous {tta0:.3f} "
              f"{'slower OK' if slower else 'FAIL(not slower)'}")
        ok = ok and slower

    print(f"[smoke] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
