"""Compressed-gossip benchmark: bytes on wire vs time to accuracy.

Sweeps the compressor axis (`none` / `topk` / `randk` / `int8`) over the
SAME bandwidth-limited experiment on both execution modes:

  * dense -- `DDASimulator` with the fused compress-mix pass on a
    k-regular expander; the simulated time axis charges the effective
    tradeoff r*c (c = the compressor's wire ratio).
  * netsim -- the event-driven cluster on a homogeneous scenario whose
    link serialization time dominates compute (large r), with sender-side
    compression scaling `Network.wire_bytes`.

Before ANY timing it runs the equivalence gates:

  1. the fused sparse compress-mix pass must match the forced
     dense-matmul oracle on the same seeded compressed run to <= --tol
     relative, with the sparse path actually engaged (mix_mode gate);
  2. the object and vectorized netsim engines must be BIT-identical under
     every compressor in the sweep.

A fast-but-wrong wire format can never post a number.

Acceptance (enforced in both modes): on the bandwidth-limited netsim
scenario at least one compressed cell must reach the 2% accuracy gap
FASTER (event clock) than the uncompressed baseline, and the paper's
`tradeoff.time_to_accuracy` evaluated at r*c must predict the measured
dense frontier ordering across compressors.

Results land in BENCH_compress.json (schema in benchmarks/README.md); the
CI tier-1 job runs `--smoke` on every push and uploads the JSON.
"""

from __future__ import annotations

import argparse
import math
import platform
import statistics
import time

import numpy as np

from repro.core import tradeoff
from repro.experiments import ExperimentSpec, run as run_spec
from repro.obs import sample_quantiles, write_json_artifact

#: eps the predicted frontier is quoted at (matches runner.PREDICT_EPS)
PREDICT_EPS = 0.1

#: the compressor axis: one uncompressed baseline + the three wire formats
COMPRESSION_AXIS = [
    ("none", None),
    ("topk", {"kind": "topk", "params": {"keep": 0.25}}),
    ("randk", {"kind": "randk", "params": {"keep": 0.25}}),
    ("int8", {"kind": "int8", "params": {}}),
]


def cell_spec(n: int, d: int, T: int, r: float, k: int, seed: int,
              eval_every: int, backend: dict, compression,
              eps_frac: float) -> ExperimentSpec:
    """One bandwidth-limited cell: quadratic consensus on a k-regular
    expander, communicate every iteration (maximum wire pressure)."""
    return ExperimentSpec(
        name="bench_compress",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": n, "d": d, "seed": seed}},
        topology={"kind": "expander", "params": {"k": k, "seed": seed}},
        schedule={"kind": "every"},
        backends=[backend],
        stepsize={"kind": "sqrt", "params": {"A": 0.5}},
        compression=compression,
        T=T, eval_every=eval_every, seed=seed, r=r, eps_frac=eps_frac)


def _rel(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-12)))


# ---------------------------------------------------------------------------
# equivalence gates
# ---------------------------------------------------------------------------


def check_fused_vs_dense_oracle(n: int, d: int, T: int, r: float, k: int,
                                seed: int, eval_every: int,
                                tol: float) -> dict:
    """Gate 1: the fused sparse compress-mix pass vs the forced
    dense-matmul path on the same seeded top-k run."""
    comp = {"kind": "topk", "params": {"keep": 0.25}}
    sparse = run_spec(cell_spec(n, d, T, r, k, seed, eval_every,
                                {"kind": "dense", "params": {}},
                                comp, eps_frac=None))
    assert sparse.extras["mix_mode"] == "sparse", (
        "compressed run must engage the fused sparse path, got "
        f"{sparse.extras['mix_mode']}")
    oracle = run_spec(cell_spec(n, d, T, r, k, seed, eval_every,
                                {"kind": "dense",
                                 "params": {"mix": "dense"}},
                                comp, eps_frac=None))
    rel = _rel(oracle.trace.fvals, sparse.trace.fvals)
    same_axes = (sparse.trace.iters == oracle.trace.iters
                 and sparse.trace.sim_time == oracle.trace.sim_time)
    return {"n": n, "d": d, "T": T, "fvals_rel": rel, "tol": tol,
            "axes_identical": bool(same_axes),
            "ok": bool(same_axes and rel <= tol)}


def check_netsim_engine_identity(n: int, d: int, T: int, r: float, k: int,
                                 seed: int, eval_every: int) -> dict:
    """Gate 2: object vs vectorized engines, bit-identical traces under
    every compressor on the sweep axis."""
    checked = []
    ok = True
    for label, comp in COMPRESSION_AXIS:
        runs = {}
        for engine in ("object", "vectorized"):
            res = run_spec(cell_spec(
                n, d, T, r, k, seed, eval_every,
                {"kind": "netsim", "params": {"scenario": "homogeneous",
                                              "engine": engine}},
                comp, eps_frac=None))
            runs[engine] = res.trace
        same = (runs["object"].fvals == runs["vectorized"].fvals
                and runs["object"].sim_time == runs["vectorized"].sim_time)
        checked.append({"compression": label, "bit_identical": bool(same)})
        ok = ok and same
    return {"n": n, "d": d, "T": T, "cells": checked, "ok": bool(ok)}


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------


def bench_cell(backend: dict, label: str, comp, n: int, d: int, T: int,
               r: float, k: int, seed: int, eval_every: int,
               eps_frac: float, repeats: int) -> dict:
    """Time one (backend, compressor) cell: a cold run, then `repeats`
    warm repeats (median wall), reporting the tradeoff-relevant outputs:
    bytes on wire, time-to-accuracy on the simulated clock, and the
    effective-r predictions."""
    spec = cell_spec(n, d, T, r, k, seed, eval_every, backend, comp,
                     eps_frac)
    res = run_spec(spec)
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = run_spec(spec)
        walls.append(time.perf_counter() - t0)
    wall = statistics.median(walls)
    m = res.metrics
    tta = res.time_to_target
    return {"backend": backend["kind"], "compression": label,
            "n": n, "d": d, "T": T, "r": r,
            "wire_ratio": (1.0 if m.compression is None
                           else m.compression["wire_ratio"]),
            "bytes_on_wire": m.bytes_on_wire,
            "bytes_saved": (0.0 if m.compression is None
                            else m.compression["bytes_saved"]),
            "time_to_target": (None if tta is None or math.isinf(tta)
                               else tta),
            "final_f": float(res.trace.fvals[-1]),
            "wall_s": round(wall, 4),
            "wall_samples_s": [round(w, 6) for w in walls],
            "wall_quantiles": sample_quantiles(walls, "host"),
            "metrics": m.to_dict(),
            "predictions": res.predictions}


def frontier_check(cells: list[dict], n: int, k: int, r: float,
                   lam2: float) -> dict:
    """The paper's design rule at the effective tradeoff: the predicted
    tau(eps; r*c) ordering across compressors must match the measured
    time-to-accuracy ordering on the bandwidth-limited cells.  Cells
    that never reach the gap within T are excluded (and reported)."""
    measured = [(c["compression"], c["time_to_target"])
                for c in cells if c["time_to_target"] is not None]
    predicted = [(c["compression"],
                  tradeoff.time_to_accuracy(PREDICT_EPS, n, k, r, lam2,
                                            c=c["wire_ratio"]))
                 for c in cells if c["time_to_target"] is not None]
    m_order = [lab for lab, _ in sorted(measured, key=lambda kv: kv[1])]
    p_order = [lab for lab, _ in sorted(predicted, key=lambda kv: kv[1])]
    excluded = [c["compression"] for c in cells
                if c["time_to_target"] is None]
    return {"measured_order": m_order, "predicted_order": p_order,
            "predicted_tau": {lab: t for lab, t in predicted},
            "measured_tta": {lab: t for lab, t in measured},
            "excluded": excluded,
            "ok": bool(m_order == p_order and len(m_order) >= 2)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=32, help="cluster size")
    ap.add_argument("--d", type=int, default=256, help="dimension")
    ap.add_argument("--k", type=int, default=4, help="expander degree")
    ap.add_argument("--T", type=int, default=600, help="iterations (dense)")
    ap.add_argument("--r", type=float, default=0.5,
                    help="tradeoff: large = bandwidth-limited (k*r >> 1/n)")
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eps-frac", type=float, default=0.02,
                    help="accuracy gap the time-to-target clock stops at")
    ap.add_argument("--tol", type=float, default=1e-5,
                    help="relative fvals tolerance for the fused-vs-oracle "
                         "gate")
    ap.add_argument("--netsim-n", type=int, default=16)
    ap.add_argument("--netsim-d", type=int, default=64)
    ap.add_argument("--netsim-T", type=int, default=600)
    ap.add_argument("--repeats", type=int, default=2,
                    help="warm timing repeats per cell (median; 1 in "
                         "--smoke)")
    ap.add_argument("--out", default="BENCH_compress.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, single repeat: CI acceptance mode "
                         "(equivalence + tradeoff gates still enforced)")
    args = ap.parse_args(argv)

    n, d, T = args.n, args.d, args.T
    nn, nd, nT = args.netsim_n, args.netsim_d, args.netsim_T
    repeats = args.repeats
    if args.smoke:
        n, d, T = min(n, 16), min(d, 128), min(T, 300)
        nn, nd, nT = min(nn, 8), min(nd, 32), min(nT, 300)
        repeats = 1

    # correctness gates before any timing
    gate1 = check_fused_vs_dense_oracle(min(n, 16), min(d, 64), T=60,
                                        r=args.r, k=args.k, seed=args.seed,
                                        eval_every=args.eval_every,
                                        tol=args.tol)
    print(f"[equivalence] fused compress-mix vs dense oracle "
          f"rel={gate1['fvals_rel']:.2e} (tol {args.tol:g}): "
          f"{'OK' if gate1['ok'] else 'FAIL'}")
    if not gate1["ok"]:
        return 1
    gate2 = check_netsim_engine_identity(min(nn, 8), min(nd, 32), T=60,
                                         r=args.r, k=args.k,
                                         seed=args.seed,
                                         eval_every=args.eval_every)
    print(f"[equivalence] netsim object vs vectorized under compression: "
          f"{'OK' if gate2['ok'] else 'FAIL'}")
    if not gate2["ok"]:
        return 1

    results = []
    print("backend,compression,wire_ratio,bytes_on_wire,time_to_target")
    for backend, (bn, bd, bT) in (
            ({"kind": "dense", "params": {}}, (n, d, T)),
            ({"kind": "netsim", "params": {"scenario": "homogeneous",
                                           "engine": "auto"}},
             (nn, nd, nT))):
        for label, comp in COMPRESSION_AXIS:
            cell = bench_cell(backend, label, comp, bn, bd, bT, args.r,
                              args.k, args.seed, args.eval_every,
                              args.eps_frac, repeats)
            results.append(cell)
            print(f"{cell['backend']},{label},{cell['wire_ratio']:.4g},"
                  f"{cell['bytes_on_wire']:.4g},{cell['time_to_target']}")

    dense_cells = [c for c in results if c["backend"] == "dense"]
    net_cells = [c for c in results if c["backend"] == "netsim"]

    # acceptance: a compressed netsim cell beats the uncompressed baseline
    # to the eps_frac gap on the event clock
    base = next(c for c in net_cells if c["compression"] == "none")
    beat = [c["compression"] for c in net_cells
            if c["compression"] != "none"
            and c["time_to_target"] is not None
            and base["time_to_target"] is not None
            and c["time_to_target"] < base["time_to_target"]]
    bandwidth_win = {
        "baseline_tta": base["time_to_target"],
        "compressed_faster": beat,
        "ok": bool(beat),
    }
    print(f"[acceptance] compressed beats uncompressed to "
          f"{args.eps_frac:.0%} gap on netsim: {beat or 'NONE'}")

    # acceptance: tau(r*c) predicts the measured frontier ordering on the
    # bandwidth-limited netsim cells (dense cells are reported alongside;
    # at small d sparsifier bias can locally reorder them)
    from repro.experiments.components import topologies
    net_lam2 = topologies.build("expander", n=nn, k=args.k,
                                seed=args.seed).lambda2()
    frontier = frontier_check(net_cells, nn, args.k, args.r, net_lam2)
    lam2 = topologies.build("expander", n=n, k=args.k,
                            seed=args.seed).lambda2()
    frontier["dense"] = frontier_check(dense_cells, n, args.k, args.r,
                                       lam2)
    print(f"[acceptance] tau(r*c) frontier ordering "
          f"{frontier['predicted_order']} vs measured "
          f"{frontier['measured_order']}: "
          f"{'OK' if frontier['ok'] else 'FAIL'}")

    report = {
        "benchmark": "compress",
        "mode": "smoke" if args.smoke else "full",
        "config": {"n": n, "d": d, "T": T, "k": args.k, "r": args.r,
                   "netsim_n": nn, "netsim_d": nd, "netsim_T": nT,
                   "eval_every": args.eval_every, "seed": args.seed,
                   "eps_frac": args.eps_frac, "schedule": "every",
                   "repeats": repeats, "tol": args.tol,
                   "compression_axis": [label for label, _ in
                                        COMPRESSION_AXIS]},
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "equivalence": {"fused_vs_oracle": gate1,
                        "netsim_engines": gate2,
                        "ok": bool(gate1["ok"] and gate2["ok"])},
        "results": results,
        "bandwidth_win": bandwidth_win,
        "frontier": frontier,
    }
    write_json_artifact(args.out, report)
    print(f"[bench_compress] wrote {args.out}")

    if not (bandwidth_win["ok"] and frontier["ok"]):
        print("[bench_compress] FAIL: tradeoff acceptance gates")
        return 1
    print("[bench_compress] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
