"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On this CPU container the interpret-mode timings measure the Python
interpreter, NOT TPU performance -- the numbers that matter for the TPU
target are the VMEM working sets and MXU-aligned block shapes reported
here, plus the correctness sweeps in tests/test_kernels.py. Oracle timings
(jnp, jit-compiled) provide the apples-to-apples CPU reference.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(verbose: bool = True):
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 8)
    rows = []

    # flash attention: VMEM per (q,k,v,acc) block at Bq=Bk=128, D=128:
    # 4 * 128*128*4B = 256 KiB << 16 MiB VMEM.
    q = jax.random.normal(ks[0], (1, 4, 512, 128), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 1, 512, 128), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 512, 128), jnp.float32)
    t_ref = _time(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)),
                  q, kk, v)
    rows.append(("flash_attention_ref_jnp", t_ref,
                 "B1H4S512D128 causal GQA4"))

    x = jax.random.normal(ks[3], (1, 512, 512)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 512, 512)) - 1)
    A = -jnp.exp(jax.random.normal(ks[5], (512, 16)) * 0.3)
    B = jax.random.normal(ks[6], (1, 512, 16)) * 0.5
    C = jax.random.normal(ks[7], (1, 512, 16)) * 0.5
    Dk = jnp.ones((512,))
    t_ref = _time(jax.jit(lambda *a: ref.selective_scan_ref(*a)),
                  x, dt, A, B, C, Dk)
    rows.append(("selective_scan_ref_jnp", t_ref, "B1S512d512N16"))

    xs = jax.random.normal(ks[3], (1, 512, 8, 64)) * 0.5
    dts = jax.nn.softplus(jax.random.normal(ks[4], (1, 512, 8)) - 1)
    As = -jnp.exp(jax.random.normal(ks[5], (8,)) * 0.3)
    t_ref = _time(jax.jit(lambda *a: ref.ssd_scan_ref(*a)), xs, dts, As, B, C)
    rows.append(("ssd_scan_ref_jnp", t_ref, "B1S512H8P64N16"))

    sb = jax.random.normal(ks[0], (1 << 20,), jnp.float32)
    nb = jax.random.normal(ks[1], (4, 1 << 20), jnp.float32)
    t_ref = _time(jax.jit(lambda a, b: ref.gossip_mix_ref(a, b, 0.2, 0.2)),
                  sb, nb)
    rows.append(("gossip_mix_ref_jnp", t_ref, "M=1Mi k=4"))
    # HBM-traffic model for the fused kernel: one pass reads (k+1)*M*4 +
    # writes M*4 bytes vs (2k+1+1)*M*4 for k separate AXPYs.
    fused = (4 + 1 + 1) * (1 << 20) * 4
    naive = (2 * 4 + 2) * (1 << 20) * 4
    rows.append(("gossip_mix_hbm_model", fused / naive * 100,
                 "fused/naive HBM-bytes %"))

    if verbose:
        for name, us, derived in rows:
            print(f"[kernels] {name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
