"""Paper Fig. 2: sparsifying communication on the non-smooth problem
(section V.B), 10 nodes, complete graph.

Claims reproduced (EXPERIMENTS.md section 'Fig 2'):
  * h_opt = 1 for the paper's r=0.00089 (eq. 21) => h=2 converges slower
    than h=1 in time-to-accuracy;
  * increasingly-sparse p=0.3 communicates ~2/3 as often as h=2 yet reaches
    a BETTER objective than h=2 (the paper's direct comparison), and its
    time-to-accuracy crosses over h=1 as r grows (eq. 20: the kr/h term);
  * p=1 is outside the permissible range (p < 1/2) and fails to converge to
    the centralized optimum.

Stepsizes are schedule-optimized per the paper (A = 2R^2/C_sched, eq.
18/31) with a uniform empirical multiplier compensating the conservative
bound constants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_problems import NonsmoothQuadratics
from repro.core import (DDASimulator, EveryIteration, IncreasinglySparse,
                        Periodic, complete_graph, h_opt_int)

R_PAPER = 0.00089  # the paper's measured r for this problem
R_HIGH = 0.01      # a higher-r regime showing the eq. (20) crossover


def run(n_nodes: int = 10, M: int = 150, d: int = 100, T: int = 300,
        seed: int = 0, verbose: bool = True, mult: float = 4.0):
    prob = NonsmoothQuadratics.build(n_nodes, M, d, seed, center_scale=1.5)
    graph = complete_graph(n_nodes)
    fstar = prob.optimum_value(iters=1500)

    xc = np.asarray(prob.centers).mean(axis=(0, 1, 2))
    R_est = float(np.linalg.norm(xc)) + 1.0
    g0 = prob.make_subgrad()(jnp.zeros((n_nodes, d)), 0, None)
    L = float(jnp.mean(jnp.linalg.norm(g0, axis=1)))

    schedules = {
        "h1": EveryIteration(),
        "h2": Periodic(h=2),
        "p03": IncreasinglySparse(p=0.3),
        "p1": IncreasinglySparse(p=1.0),
    }
    results = {}
    summary = {"h_opt_theory": h_opt_int(n_nodes, graph.degree, R_PAPER, 0.0),
               "f_star": fstar, "regimes": {}}
    for r in (R_PAPER, R_HIGH):
        reg = {}
        for name, sched in schedules.items():
            C = sched.constant(L, R_est, 0.0)  # lam2 = 0 (complete graph)
            A_scale = mult * 2.0 * R_est * R_est / C
            sim = DDASimulator(
                prob.make_subgrad(), jax.jit(prob.full_objective), graph,
                sched, a_fn=lambda t, A=A_scale: A / jnp.sqrt(t), r=r)
            trace = sim.run(jnp.zeros((n_nodes, d)), T, eval_every=20,
                            seed=seed)
            thr = fstar + 0.01 * abs(fstar)
            tta = next((t for t, f in zip(trace.sim_time, trace.fvals)
                        if f <= thr), float("inf"))
            reg[name] = {"comms": trace.comms[-1],
                         "final_F": trace.fvals[-1],
                         "time_to_1pct": tta}
            results[(r, name)] = trace
            if verbose:
                print(f"[fig2] r={r:.5f} {name:4s} "
                      f"comms={trace.comms[-1]:4d} "
                      f"final_F={trace.fvals[-1]:10.2f} "
                      f"tta(1%)={tta:8.2f}", flush=True)
        summary["regimes"][r] = reg
    if verbose:
        print(f"[fig2] F*={fstar:.2f} h_opt={summary['h_opt_theory']}")
    return results, summary


if __name__ == "__main__":
    run()
