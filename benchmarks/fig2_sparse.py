"""Paper Fig. 2: sparsifying communication on the non-smooth problem
(section V.B), 10 nodes, complete graph.

Claims reproduced (EXPERIMENTS.md section 'Fig 2'):
  * h_opt = 1 for the paper's r=0.00089 (eq. 21) => h=2 converges slower
    than h=1 in time-to-accuracy;
  * increasingly-sparse p=0.3 communicates ~2/3 as often as h=2 yet reaches
    a BETTER objective than h=2 (the paper's direct comparison), and its
    time-to-accuracy crosses over h=1 as r grows (eq. 20: the kr/h term);
  * p=1 is outside the permissible range (p < 1/2) and fails to converge to
    the centralized optimum.

Stepsizes are schedule-optimized per the paper (A = 2R^2/C_sched, eq.
18/31) with a uniform empirical multiplier compensating the conservative
bound constants.

Every cell is a declarative `ExperimentSpec` through `repro.run()` on the
registry "nonsmooth" problem (the same `data.pipeline` centers the old
hand-wired NonsmoothQuadratics built from); the wiring equivalence is
gated bit-identically in tests/test_experiments_migration.py and
benchmarks/manifests/fig2_sparse.json checks in the p=0.3 regime.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import h_opt_int
from repro.core.schedules import (EveryIteration, IncreasinglySparse,
                                  Periodic)
from repro.experiments import ExperimentSpec, run as run_spec
from repro.experiments.components import problems

R_PAPER = 0.00089  # the paper's measured r for this problem
R_HIGH = 0.01      # a higher-r regime showing the eq. (20) crossover

SCHEDULES = {
    "h1": ({"kind": "every"}, EveryIteration()),
    "h2": ({"kind": "periodic", "params": {"h": 2}}, Periodic(h=2)),
    "p03": ({"kind": "sparse", "params": {"p": 0.3}},
            IncreasinglySparse(p=0.3)),
    "p1": ({"kind": "sparse", "params": {"p": 1.0}},
           IncreasinglySparse(p=1.0)),
}


def cell_spec(n_nodes: int, M: int, d: int, T: int, schedule: dict,
              A: float, r: float, seed: int,
              eval_every: int = 20) -> ExperimentSpec:
    """One Fig. 2 cell: complete graph, schedule-optimized stepsize."""
    return ExperimentSpec(
        name="fig2_sparse",
        problem={"kind": "nonsmooth",
                 "params": {"n": n_nodes, "M": M, "d": d, "seed": seed}},
        topology={"kind": "complete"},
        schedule=schedule,
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": A}},
        T=T, eval_every=eval_every, seed=seed, r=r)


def run(n_nodes: int = 10, M: int = 150, d: int = 100, T: int = 300,
        seed: int = 0, verbose: bool = True, mult: float = 4.0):
    prob = problems.build("nonsmooth", n=n_nodes, M=M, d=d, seed=seed)
    fstar = prob.fstar

    from repro.experiments.components import nonsmooth_centers
    centers = nonsmooth_centers(n_nodes, M, d, seed)
    xc = centers.mean(axis=(0, 1, 2))
    R_est = float(np.linalg.norm(xc)) + 1.0
    g0 = prob.subgrad_stack(jnp.zeros((n_nodes, d)), 0, None)
    L = float(jnp.mean(jnp.linalg.norm(g0, axis=1)))

    results = {}
    summary = {"h_opt_theory": h_opt_int(n_nodes, n_nodes - 1, R_PAPER, 0.0),
               "f_star": fstar, "regimes": {}}
    for r in (R_PAPER, R_HIGH):
        reg = {}
        for name, (sched_comp, sched_obj) in SCHEDULES.items():
            C = sched_obj.constant(L, R_est, 0.0)  # lam2 = 0 (complete)
            A_scale = mult * 2.0 * R_est * R_est / C
            res = run_spec(cell_spec(n_nodes, M, d, T, sched_comp, A_scale,
                                     r, seed))
            trace = res.trace
            thr = fstar + 0.01 * abs(fstar)
            tta = next((t for t, f in zip(trace.sim_time, trace.fvals)
                        if f <= thr), float("inf"))
            reg[name] = {"comms": trace.comms[-1],
                         "final_F": trace.fvals[-1],
                         "time_to_1pct": tta}
            results[(r, name)] = trace
            if verbose:
                print(f"[fig2] r={r:.5f} {name:4s} "
                      f"comms={trace.comms[-1]:4d} "
                      f"final_F={trace.fvals[-1]:10.2f} "
                      f"tta(1%)={tta:8.2f}", flush=True)
        summary["regimes"][r] = reg
    if verbose:
        print(f"[fig2] F*={fstar:.2f} h_opt={summary['h_opt_theory']}")
    return results, summary


if __name__ == "__main__":
    run()
