"""Netsim engine throughput: object vs vectorized, n in {64, 256, 1024}.

Benchmarks the two `NetSimulator` execution engines (netsim.engine) on the
homogeneous expander scenario for both algorithms (stale-gossip dda and
push-sum), reporting wall-clock and events/sec -- an "event" is one node
step or one shipped message -- and the vectorized/object speedup per cell.
Before timing anything it re-verifies the engine-equivalence contract
(bit-identical traces on a seeded adversarial scenario) so a fast-but-wrong
engine can never post a number.

Every cell is a declarative `ExperimentSpec` through `repro.run()`; the
reported wall_s is `RunResult.wall_s`, which times exactly the engine's
`run()` (construction and probe setup excluded, as the hand-wired bench
always did).

Results land in BENCH_netsim.json (see benchmarks/README.md for the schema),
seeding the repo's netsim perf trajectory: CI runs `--smoke` on every push
and uploads the JSON as an artifact.

Acceptance (full mode): the vectorized engine must beat the object engine by
`--min-speedup` (default 10x) at the largest n for dda/EveryIteration;
exits nonzero otherwise.
"""

from __future__ import annotations

import argparse
import platform
import time

import numpy as np

from repro.experiments import ExperimentSpec, run as run_spec
from repro.obs import sample_quantiles, write_json_artifact

DEFAULT_SIZES = (64, 256, 1024)


def cell_spec(n: int, d: int, T: int, r: float, k: int, algorithm: str,
              engine: str, seed: int, eval_every: int,
              *, scenario: str = "homogeneous", **knobs) -> ExperimentSpec:
    """One bench cell. The problem is the BATCH-capable quadratic (the
    canonical netsim.problems one), so the engines' bitwise-verified batch
    probes engage and per-node Python evaluation disappears from the hot
    path."""
    topology = ({"kind": "expander_sequence", "params": {"k": k, "seed": seed}}
                if knobs.get("rewire_every") else
                {"kind": "expander", "params": {"k": k, "seed": seed}})
    return ExperimentSpec(
        name=f"bench_netsim_{scenario}",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": n, "d": d, "seed": seed,
                            "batchable": True}},
        topology=topology,
        schedule={"kind": "every"},
        backends=[{"kind": "netsim",
                   "params": {"scenario": scenario, "engine": engine,
                              "algorithm": algorithm, **knobs}}],
        T=T, eval_every=eval_every, seed=seed, r=r)


def check_equivalence(n: int, d: int, T: int, r: float, seed: int) -> dict:
    """Seeded adversarial scenario (loss + straggler + rewire): both engines
    must produce bit-identical traces and r-measurements, per algorithm."""
    out = {}
    for algorithm in ("dda", "pushsum"):
        res = {}
        for engine in ("object", "vectorized"):
            spec = cell_spec(n, d, T, r, 4, algorithm, engine, seed,
                             eval_every=5, scenario="adversarial",
                             loss=0.2, slow_factor=3.0, n_slow=2,
                             rewire_every=0.8)
            res[engine] = run_spec(spec)
        a, b = res["object"].trace, res["vectorized"].trace
        out[algorithm] = bool(
            a.iters == b.iters and a.sim_time == b.sim_time
            and a.fvals == b.fvals and a.fvals_consensus == b.fvals_consensus
            and a.comms == b.comms and a.disagreement == b.disagreement
            and res["object"].r_measurement
            == res["vectorized"].r_measurement)
    return out


def bench_cell(n: int, d: int, T: int, r: float, k: int, algorithm: str,
               engine: str, seed: int, eval_every: int,
               repeats: int) -> dict:
    spec = cell_spec(n, d, T, r, k, algorithm, engine, seed, eval_every)
    best = None
    walls = []
    for _ in range(repeats):  # best-of: robust to background load spikes
        res = run_spec(spec)
        walls.append(res.wall_s)
        if best is None or res.wall_s < best.wall_s:
            best = res
    wall = best.wall_s
    events = n * T + best.extras["sent"]
    return {
        "n": n, "d": d, "T": T, "k": k, "r": r,
        "algorithm": algorithm, "engine": engine,
        "schedule": "every",
        "events": int(events),
        "wall_s": round(wall, 4),
        "events_per_s": round(events / wall, 1),
        # the FULL repeat sample array + quantiles, and the best run's
        # RunMetrics block (message/byte counters, sim-clock step times)
        "wall_samples_s": [round(w, 6) for w in walls],
        "wall_quantiles": sample_quantiles(walls, "host"),
        "metrics": best.metrics.to_dict(),
        "final_f": float(best.trace.fvals[-1]),
        "final_disagreement": float(best.trace.disagreement[-1]),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--sizes", type=int, nargs="*", default=list(DEFAULT_SIZES),
                    help="cluster sizes to sweep")
    ap.add_argument("--d", type=int, default=64, help="dimension")
    ap.add_argument("--T", type=int, default=40, help="iterations per node")
    ap.add_argument("--r", type=float, default=0.01,
                    help="configured per-message time (full-grad units)")
    ap.add_argument("--k", type=int, default=4, help="expander degree")
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--algorithms", nargs="*", default=["dda", "pushsum"],
                    choices=["dda", "pushsum"])
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="required vectorized/object speedup at max n (dda)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per cell (best-of; 1 in --smoke)")
    ap.add_argument("--out", default="BENCH_netsim.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + short T: CI acceptance mode")
    args = ap.parse_args(argv)

    sizes = sorted(args.sizes)
    T = args.T
    if args.smoke:
        sizes = [16, 64]
        T = min(T, 25)
    if not sizes:
        ap.error("--sizes needs at least one cluster size")
    if sizes[0] < 4:
        ap.error("--sizes values must be >= 4 (the adversarial equivalence "
                 "scenario needs 2 stragglers + healthy nodes)")

    # correctness gate before any timing
    equiv_n = min(16, sizes[0])
    equivalence = check_equivalence(equiv_n, min(args.d, 8), T=60, r=args.r,
                                    seed=args.seed)
    for algorithm, ok in equivalence.items():
        print(f"[equivalence] {algorithm}: "
              f"{'bit-identical OK' if ok else 'FAIL'}")
    if not all(equivalence.values()):
        return 1

    results = []
    print("n,d,T,algorithm,engine,events,wall_s,events_per_s")
    for n in sizes:
        for algorithm in args.algorithms:
            for engine in ("object", "vectorized"):
                cell = bench_cell(n, args.d, T, args.r, args.k, algorithm,
                                  engine, args.seed, args.eval_every,
                                  repeats=1 if args.smoke else args.repeats)
                results.append(cell)
                print(f"{n},{args.d},{T},{algorithm},{engine},"
                      f"{cell['events']},{cell['wall_s']},"
                      f"{cell['events_per_s']}")

    speedups = []
    for n in sizes:
        for algorithm in args.algorithms:
            cells = {c["engine"]: c for c in results
                     if c["n"] == n and c["algorithm"] == algorithm}
            s = cells["object"]["wall_s"] / cells["vectorized"]["wall_s"]
            speedups.append({"n": n, "algorithm": algorithm,
                             "speedup": round(s, 2)})
            print(f"[speedup] n={n} {algorithm}: {s:.1f}x")

    report = {
        "benchmark": "netsim_engine_throughput",
        "mode": "smoke" if args.smoke else "full",
        "config": {"sizes": sizes, "d": args.d, "T": T, "r": args.r,
                   "k": args.k, "eval_every": args.eval_every,
                   "seed": args.seed, "schedule": "every",
                   "scenario": "homogeneous",
                   "repeats": 1 if args.smoke else args.repeats},
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "equivalence": {"n": equiv_n, **equivalence},
        "results": results,
        "speedups": speedups,
    }
    write_json_artifact(args.out, report)
    print(f"[bench_netsim] wrote {args.out}")

    if not args.smoke:
        n_max = sizes[-1]
        dda = next(s["speedup"] for s in speedups
                   if s["n"] == n_max and s["algorithm"] == "dda")
        if dda < args.min_speedup:
            print(f"[bench_netsim] FAIL: vectorized speedup {dda:.1f}x < "
                  f"{args.min_speedup:g}x at n={n_max} (dda)")
            return 1
        print(f"[bench_netsim] OK: {dda:.1f}x >= {args.min_speedup:g}x "
              f"at n={n_max} (dda)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
