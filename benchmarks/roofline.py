"""Roofline analysis from dry-run artifacts (deliverable g).

For every (arch x shape) cell on the single-pod mesh we derive the three
terms (per device, TPU v5e constants):

    compute    = HLO_FLOPs / 197e12            [s]
    memory     = HLO_bytes_accessed / 819e9    [s]
    collective = collective_bytes / 50e9       [s]

XLA's cost analysis counts a while-loop body ONCE, so a scanned-layer-stack
program under-reports by the trip count. We therefore compile two PROBE
programs per cell -- the same step with n_super=1 and n_super=0 -- and scale:

    per_layer  = probe(1) - probe(0)
    total      = microbatches * (probe(0) + n_super * per_layer)

(the optimizer update inside probe(0) is double-counted by the microbatch
factor; it is O(params) work, <2% of a 6ND step -- noted in EXPERIMENTS.md).
MODEL_FLOPS = 6*N_active*tokens (train), 2*N_active*tokens (prefill/decode),
per device; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}
RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def active_params(cfg) -> float:
    """Parameters touched per token (MoE counts shared + top_k experts)."""
    import jax
    from repro.models import transformer

    def count(c):
        box = []

        def build(k):
            p, _ = transformer.init(k, c)
            box.append(None)
            return p
        tree = jax.eval_shape(build, jax.random.PRNGKey(0))
        return sum(np.prod(x.shape) for x in jax.tree.leaves(tree))

    total = count(cfg)
    if not cfg.moe_experts:
        return float(total)
    # replace expert count by (shared + top_k) "active" experts
    import dataclasses as dc
    active_cfg = dc.replace(cfg, moe_experts=max(cfg.moe_top_k, 1))
    act = count(active_cfg)
    return float(act)


def model_flops_per_device(cfg, cell, devices: int) -> float:
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    n_act = active_params(cfg)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_act * tokens / devices


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    flops: float
    bytes_accessed: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    mem_gib: float

    def as_dict(self):
        return dataclasses.asdict(self)


def combine(full: dict, probe1: dict, probe0: dict, n_super: int,
            microbatches: int) -> dict:
    """Scale probe costs to the full program (see module docstring)."""
    out = {}
    for key in ("flops", "bytes accessed"):
        p1 = probe1["cost"].get(key, 0.0)
        p0 = probe0["cost"].get(key, 0.0)
        per_layer = max(p1 - p0, 0.0)
        out[key] = microbatches * (p0 + n_super * per_layer)
    c1 = sum(probe1["collectives"].values())
    c0 = sum(probe0["collectives"].values())
    out["coll_bytes"] = microbatches * (c0 + n_super * max(c1 - c0, 0.0))
    return out


def analyze_cell(arch: str, cell, *, use_probes: bool = True,
                 save: bool = True) -> RooflineRow:
    from repro.launch import dryrun as dr
    from repro.models import registry
    import dataclasses as dc

    cfg = registry.get_config(arch, "full")
    full_path = RESULTS / "dryrun" / f"{arch}__{cell.name}__pod16x16.json"
    if full_path.exists():
        full = json.loads(full_path.read_text())
    else:
        full = dr.dryrun_cell(arch, cell, False, save=True, verbose=False)

    n_super = cfg.n_super
    micro = cfg.train_microbatches if cell.kind == "train" else 1
    if use_probes:
        probes = {}
        for ns in (1, 0):
            pcfg = dc.replace(cfg, n_super=ns, prologue=cfg.prologue,
                              train_microbatches=1)
            pcell = dc.replace(
                cell, global_batch=max(cell.global_batch // micro, 16)
                if cell.kind == "train" else cell.global_batch)
            probes[ns] = dr.dryrun_cell_with_cfg(
                arch, pcfg, pcell, False, save=False, verbose=False)
        scaled = combine(full, probes[1], probes[0], n_super, micro)
        flops = scaled["flops"]
        byts = scaled["bytes accessed"]
        coll = scaled["coll_bytes"]
    else:
        flops = full["cost"].get("flops", 0.0)
        byts = full["cost"].get("bytes accessed", 0.0)
        coll = sum(full["collectives"].values())

    t_c = flops / HW["peak_flops"]
    t_m = byts / HW["hbm_bw"]
    t_x = coll / HW["link_bw"]
    bn = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
             key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(cfg, cell, full["devices"])
    row = RooflineRow(
        arch=arch, shape=cell.name, mesh=full["mesh"], flops=flops,
        bytes_accessed=byts, coll_bytes=coll, t_compute=t_c, t_memory=t_m,
        t_collective=t_x, bottleneck=bn, model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        mem_gib=full["bytes_per_device"] / 2**30)
    if save:
        outdir = RESULTS / "roofline"
        outdir.mkdir(parents=True, exist_ok=True)
        (outdir / f"{arch}__{cell.name}.json").write_text(
            json.dumps(row.as_dict(), indent=1))
    return row


def format_table(rows) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'t_comp(ms)':>10s} {'t_mem(ms)':>10s}"
           f" {'t_coll(ms)':>10s} {'bound':>10s} {'useful':>7s} {'GiB':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:26s} {r.shape:12s} {r.t_compute*1e3:10.2f} "
            f"{r.t_memory*1e3:10.2f} {r.t_collective*1e3:10.2f} "
            f"{r.bottleneck:>10s} {r.useful_ratio:7.2f} {r.mem_gib:6.1f}")
    return "\n".join(lines)
