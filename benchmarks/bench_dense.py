"""Dense fast-path benchmark: fused+scanned DDASimulator vs the seed path.

Times the device-resident dense fast path -- sparse gossip mix
(`kernels.ops.gossip_gather_mix`: neighbor-index gather + fused weighted
accumulation, O(nkd)) driven by the fully-scanned segment loop (one
compiled program per run) -- against the SEED configuration it replaced:
the dense `P @ z` matmul mix (O(n^2 d)) under the host-side per-segment
dispatch loop (`DDASimulator.run(loop="segment", mix="dense")`). Also
times `run_sweep(parallel="vmap")` (one compile + one batched dispatch for
a seed grid) against the serial executor (a fresh trace+compile per cell).

Before ANY timing it runs the equivalence gates: the fused path's fvals
must match the seed path's on the same seeded run to <= --tol relative
(the gather+FMA mix reorders float accumulation vs the matmul, so bitwise
equality is not expected), and the vmapped sweep must match the serial
sweep cell-for-cell. A fast-but-wrong path can never post a number.

Results land in BENCH_dense.json (schema in benchmarks/README.md); the CI
tier-1 job runs `--smoke` on every push and uploads the JSON. Full mode
exits nonzero unless both speedups reach --min-speedup (default 3x) at the
acceptance shape n=256, k=4, d=4096.
"""

from __future__ import annotations

import argparse
import platform
import statistics
import time

import numpy as np

from repro.core.dda import DDASimulator, stepsize_sqrt
from repro.core.schedules import EveryIteration
from repro.experiments import ExperimentSpec, run as run_spec, run_sweep
from repro.experiments.components import problems, topologies
from repro.obs import RunMetrics, sample_quantiles, write_json_artifact

SEED_BACKEND = {"kind": "dense", "params": {"mix": "dense",
                                            "loop": "segment"}}
FUSED_BACKEND = {"kind": "dense", "params": {}}


def cell_spec(n: int, d: int, T: int, r: float, k: int, seed: int,
              eval_every: int, backend: dict) -> ExperimentSpec:
    """One dense cell: quadratic consensus on a k-regular expander,
    communicate every iteration (maximum mixing pressure)."""
    return ExperimentSpec(
        name="bench_dense",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": n, "d": d, "seed": seed}},
        topology={"kind": "expander", "params": {"k": k, "seed": seed}},
        schedule={"kind": "every"},
        backends=[backend],
        stepsize={"kind": "sqrt", "params": {"A": 0.05}},
        T=T, eval_every=eval_every, seed=seed, r=r)


def _rel(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-12)))


def check_equivalence(n: int, d: int, T: int, r: float, k: int, seed: int,
                      eval_every: int, tol: float) -> dict:
    """Seed-vs-fused fvals on one seeded run, to tol relative."""
    seed_res = run_spec(cell_spec(n, d, T, r, k, seed, eval_every,
                                  SEED_BACKEND))
    fused_res = run_spec(cell_spec(n, d, T, r, k, seed, eval_every,
                                   FUSED_BACKEND))
    assert fused_res.extras["mix_mode"] == "sparse", (
        "acceptance shape must engage the sparse fast path, got "
        f"{fused_res.extras['mix_mode']}")
    rel = _rel(seed_res.trace.fvals, fused_res.trace.fvals)
    same_axes = (seed_res.trace.iters == fused_res.trace.iters
                 and seed_res.trace.sim_time == fused_res.trace.sim_time
                 and seed_res.trace.comms == fused_res.trace.comms)
    return {"n": n, "d": d, "T": T, "fvals_rel": rel, "tol": tol,
            "axes_identical": bool(same_axes),
            "ok": bool(same_axes and rel <= tol)}


def bench_path(n: int, d: int, T: int, r: float, k: int, seed: int,
               eval_every: int, mix: str, loop: str, label: str,
               repeats: int) -> dict:
    """Steady-state wall of one path: a cold run pays trace+compile (kept
    as `cold_wall_s`), then the reported `wall_s` is the median of
    `repeats` warm runs on the same simulator -- the throughput a sweep or
    long run actually sees, robust to this-box load spikes (the matmul
    path's multithreaded BLAS timing is noisy)."""
    import jax
    import jax.numpy as jnp

    prob = problems.build("quadratic_consensus", n=n, d=d, seed=seed)
    graph = topologies.build("expander", n=n, k=k, seed=seed)
    sim = DDASimulator(prob.subgrad_stack, jax.jit(prob.objective), graph,
                       EveryIteration(), a_fn=stepsize_sqrt(0.05), r=r,
                       mix=mix)
    x0 = jnp.zeros((n, d))
    t0 = time.perf_counter()
    trace = sim.run(x0, T, eval_every=eval_every, seed=seed, loop=loop)
    cold = time.perf_counter() - t0
    compile_s = sim.last_timings["compile_s"]  # cold run pays the compile
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        trace = sim.run(x0, T, eval_every=eval_every, seed=seed, loop=loop)
        walls.append(time.perf_counter() - t0)
    wall = statistics.median(walls)
    metrics = RunMetrics(
        compile_s=compile_s, execute_s=wall,
        counters={"device_execute_s": sim.last_timings["execute_s"]})
    return {"path": label, "n": n, "d": d, "T": T, "k": k,
            "wall_s": round(wall, 4),
            "cold_wall_s": round(cold, 4),
            "iters_per_s": round(T / wall, 1),
            # the FULL warm-run sample array + its quantiles: regression
            # tooling wants the distribution, not just the median
            "wall_samples_s": [round(w, 6) for w in walls],
            "wall_quantiles": sample_quantiles(walls, "host"),
            "metrics": metrics.to_dict(),
            "final_f": float(trace.fvals[-1]),
            "mix_mode": sim.mix_mode}


def bench_sweep(n: int, d: int, T: int, r: float, k: int, seed: int,
                eval_every: int, cells: int, tol: float) -> dict:
    """Serial vs vmapped run_sweep on a seed axis, equivalence first."""
    spec = cell_spec(n, d, T, r, k, seed, eval_every, FUSED_BACKEND)
    seeds = list(range(cells))
    t0 = time.perf_counter()
    serial = run_sweep(spec, "seed", seeds)
    serial_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    vmapped = run_sweep(spec, "seed", seeds, parallel="vmap")
    vmap_wall = time.perf_counter() - t0
    assert all("vmap_lanes" in res.extras for res in vmapped), (
        "vmap executor silently fell back to serial -- the cells must be "
        "shape-compatible")
    rel = max(_rel(a.trace.fvals, b.trace.fvals)
              for a, b in zip(serial, vmapped))
    return {"cells": cells, "n": n, "d": d, "T": T,
            "serial_wall_s": round(serial_wall, 4),
            "vmap_wall_s": round(vmap_wall, 4),
            "speedup": round(serial_wall / vmap_wall, 2),
            # one lane's metrics block: the amortized compile/execute
            # split every vmapped cell reports through repro.run()
            "vmap_lane_metrics": vmapped[0].metrics.to_dict(),
            "fvals_rel": rel, "tol": tol, "ok": bool(rel <= tol)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=256, help="cluster size")
    ap.add_argument("--d", type=int, default=4096, help="dimension")
    ap.add_argument("--k", type=int, default=4, help="expander degree")
    ap.add_argument("--T", type=int, default=120, help="iterations")
    ap.add_argument("--r", type=float, default=0.01)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tol", type=float, default=1e-5,
                    help="relative fvals tolerance for the equivalence gates")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="required fused/seed AND vmap/serial speedup "
                         "(full mode)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="warm timing repeats per path (median; 1 in "
                         "--smoke)")
    ap.add_argument("--sweep-cells", type=int, default=8)
    ap.add_argument("--sweep-n", type=int, default=64)
    ap.add_argument("--sweep-d", type=int, default=512)
    ap.add_argument("--sweep-T", type=int, default=120)
    ap.add_argument("--out", default="BENCH_dense.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, single repeat, no speedup gate: "
                         "CI acceptance mode (equivalence still enforced)")
    args = ap.parse_args(argv)

    n, d, T = args.n, args.d, args.T
    sweep_cells, sweep_n, sweep_d, sweep_T = (args.sweep_cells, args.sweep_n,
                                              args.sweep_d, args.sweep_T)
    repeats = args.repeats
    if args.smoke:
        n, d, T = min(n, 32), min(d, 512), min(T, 60)
        sweep_cells, sweep_n, sweep_d, sweep_T = 4, 16, 64, 60
        repeats = 1

    # correctness gates before any timing
    equiv = check_equivalence(min(n, 64), min(d, 256), T=60, r=args.r,
                              k=args.k, seed=args.seed,
                              eval_every=args.eval_every, tol=args.tol)
    print(f"[equivalence] fused vs seed fvals rel={equiv['fvals_rel']:.2e} "
          f"(tol {args.tol:g}): {'OK' if equiv['ok'] else 'FAIL'}")
    if not equiv["ok"]:
        return 1
    sweep = bench_sweep(sweep_n, sweep_d, sweep_T, args.r, args.k,
                        args.seed, args.eval_every, sweep_cells, args.tol)
    print(f"[equivalence] vmap vs serial sweep rel={sweep['fvals_rel']:.2e}"
          f": {'OK' if sweep['ok'] else 'FAIL'}")
    if not sweep["ok"]:
        return 1

    results = []
    print("path,n,d,T,wall_s,iters_per_s")
    for mix, loop, label in (("dense", "segment", "seed_matmul_segment"),
                             ("auto", "scan", "fused_scan")):
        cell = bench_path(n, d, T, args.r, args.k, args.seed,
                          args.eval_every, mix, loop, label, repeats)
        results.append(cell)
        print(f"{label},{n},{d},{T},{cell['wall_s']},{cell['iters_per_s']}")

    run_speedup = round(results[0]["wall_s"] / results[1]["wall_s"], 2)
    print(f"[speedup] fused+scanned vs seed: {run_speedup:.1f}x")
    print(f"[speedup] vmapped vs serial sweep ({sweep['cells']} cells): "
          f"{sweep['speedup']:.1f}x")

    report = {
        "benchmark": "dense_fast_path",
        "mode": "smoke" if args.smoke else "full",
        "config": {"n": n, "d": d, "T": T, "k": args.k, "r": args.r,
                   "eval_every": args.eval_every, "seed": args.seed,
                   "schedule": "every", "repeats": repeats,
                   "tol": args.tol},
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "equivalence": equiv,
        "results": results,
        "sweep": sweep,
        "speedups": {"run": run_speedup, "sweep": sweep["speedup"]},
    }
    write_json_artifact(args.out, report)
    print(f"[bench_dense] wrote {args.out}")

    if not args.smoke:
        fails = []
        if run_speedup < args.min_speedup:
            fails.append(f"fused/seed {run_speedup:.1f}x")
        if sweep["speedup"] < args.min_speedup:
            fails.append(f"vmap/serial {sweep['speedup']:.1f}x")
        if fails:
            print(f"[bench_dense] FAIL: {', '.join(fails)} < "
                  f"{args.min_speedup:g}x")
            return 1
        print(f"[bench_dense] OK: run {run_speedup:.1f}x, sweep "
              f"{sweep['speedup']:.1f}x >= {args.min_speedup:g}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
