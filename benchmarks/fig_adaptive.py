"""[beyond paper] Closed-loop adaptive scheduling vs every fixed h.

The paper's Fig. 2 picks h offline: measure r once, solve eq. (21), run
Periodic(h_opt). `repro.adaptive` closes that loop online -- RTracker
streams r_hat from the live event timeline, StragglerReweighter folds
observed per-node step times into an effective lambda2, and
AdaptiveSchedule splices the re-solved h into the running pattern
(optionally growing it like the increasingly-sparse schedule of IV.B).

This benchmark races the closed loop against a swept grid of fixed
Periodic(h) schedules on the `scenarios.adversarial` preset (packet loss +
4x stragglers on a complete graph, the regime where offline h is least
trustworthy): every run shares the problem, stepsize, seed, and target
accuracy; the score is simulated wall-clock (event time) to target. The
adaptive trajectory starts at h0 = 1 (aggressive mixing while the
disagreement transient decays and r is still unmeasured), splices to
h_opt(n, k, r_hat, lambda2_eff) within one communication round, then grows
with (1 + H)^p -- tracking the lower envelope of the fixed-h error curves,
which no constant h can do.

Knobs (see --help): --n, --d, --T, --r, --loss, --straggler, --n-slow,
--grid, --h0, --p, --update-every, --eps-frac, --eval-every, --seed,
--out (JSON), --smoke.

--smoke runs the acceptance gate and exits nonzero on failure:
  1. closed loop wins: adaptive time-to-target strictly beats EVERY fixed
     Periodic(h) in the swept grid on the adversarial scenario;
  2. controller-off bit-identity: with no controller attached, the object
     and vectorized engines still produce bit-identical traces on a seeded
     adversarial run (the controller hooks must cost nothing when off).
"""

from __future__ import annotations

import argparse
import json
import math
import sys

import numpy as np

from repro.adaptive import AdaptiveController, AdaptiveSchedule
from repro.core.dda import TRACE_FIELDS, json_sanitize, trace_time_to_reach
from repro.core.schedules import Periodic
from repro.netsim import NetSimulator, adversarial, quadratic_consensus


def build(args):
    """(scenario, problem closures, eps target) shared by every run."""
    centers, grad_fn, eval_fn = quadratic_consensus(args.n, args.d,
                                                    seed=args.seed)
    # the optimum is the centroid; asking the objective itself keeps the
    # target honest if the problem is ever rescaled
    fstar = float(eval_fn(centers.mean(axis=0)))
    f0 = eval_fn(np.zeros(args.d))
    eps_value = fstar + args.eps_frac * (f0 - fstar)
    sc = adversarial(args.n, args.r, loss=args.loss,
                     slow_factor=args.straggler, n_slow=args.n_slow,
                     k=args.k, seed=args.seed)
    return sc, grad_fn, eval_fn, fstar, eps_value


def run_one(args, sc, grad_fn, eval_fn, schedule=None, ctrl=None,
            engine="auto"):
    a_fn = (lambda t: args.a_scale / math.sqrt(max(t, 1.0)))
    sim = NetSimulator(sc, grad_fn, eval_fn, a_fn=a_fn, schedule=schedule,
                       controller=ctrl, seed=args.seed, engine=engine)
    trace = sim.run(np.zeros((args.n, args.d)), args.T,
                    eval_every=args.eval_every, time_limit=args.time_limit)
    return sim, trace


def make_controller(args):
    return AdaptiveController(
        AdaptiveSchedule(h0=args.h0, p=args.p),
        update_every=args.update_every, warmup_messages=4, warmup_steps=4)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=16, help="cluster size")
    ap.add_argument("--k", type=int, default=16,
                    help="graph degree (k >= n gives the complete graph)")
    ap.add_argument("--d", type=int, default=10, help="dimension")
    ap.add_argument("--T", type=int, default=8000, help="iterations per node")
    ap.add_argument("--r", type=float, default=1.3,
                    help="configured per-message time (full-grad units)")
    ap.add_argument("--loss", type=float, default=0.2)
    ap.add_argument("--straggler", type=float, default=4.0,
                    help="slow factor of the stragglers")
    ap.add_argument("--n-slow", type=int, default=2)
    ap.add_argument("--grid", type=int, nargs="+", default=[1, 2, 4, 8, 16],
                    help="fixed Periodic(h) sweep values")
    ap.add_argument("--h0", type=int, default=1,
                    help="adaptive cold-start interval")
    ap.add_argument("--p", type=float, default=0.1,
                    help="adaptive sparse-growth exponent")
    ap.add_argument("--update-every", type=float, default=0.5,
                    help="controller retune cadence (sim time)")
    ap.add_argument("--eps-frac", type=float, default=0.02,
                    help="target: F* + eps_frac * (F(x0) - F*)")
    ap.add_argument("--a-scale", type=float, default=0.5)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--time-limit", type=float, default=5000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", help="write results JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance gate and exit")
    args = ap.parse_args(argv)

    sc, grad_fn, eval_fn, fstar, eps_value = build(args)
    if args.smoke:
        return smoke(args, sc, grad_fn, eval_fn, eps_value)

    results = {"benchmark": "fig_adaptive", "scenario": sc.name,
               "config": vars(args), "fstar": fstar,
               "eps_value": eps_value, "fixed": [], "adaptive": None}
    print("schedule,h,tta,final_gap,r_emp")
    for h in args.grid:
        sim, tr = run_one(args, sc, grad_fn, eval_fn,
                          schedule=Periodic(h=h))
        tta = trace_time_to_reach(tr, eps_value)
        # a run can end inside --time-limit before any message flew
        # (huge h, tiny T): report nan rather than abort the sweep
        r_emp = (sim.measure_r_empirical().r
                 if sim.msg_flights and sim.compute_times else math.nan)
        results["fixed"].append({"h": h, "tta": tta,
                                 "final_gap": tr.fvals[-1] - fstar,
                                 "r_emp": r_emp})
        print(f"periodic,{h},{tta:.1f},{tr.fvals[-1] - fstar:.3f},"
              f"{r_emp:.4f}")

    ctrl = make_controller(args)
    sim, tr = run_one(args, sc, grad_fn, eval_fn, ctrl=ctrl)
    tta = trace_time_to_reach(tr, eps_value)
    r_hat = ctrl.tracker.r_hat  # None until a message has been observed
    results["adaptive"] = {
        "tta": tta, "final_gap": tr.fvals[-1] - fstar,
        "h_final": ctrl.schedule.h_current,
        "h_opt_hat": ctrl.schedule.h_opt_hat,
        "r_hat": r_hat,
        "lam2_eff": ctrl.reweighter.last_lam2,
        "retunes": [(rt.from_t, rt.h) for rt in ctrl.schedule.retunes]}
    print(f"adaptive,{ctrl.schedule.h_current},{tta:.1f},"
          f"{tr.fvals[-1] - fstar:.3f},"
          f"{math.nan if r_hat is None else r_hat:.4f}")
    print(f"# retune path: {results['adaptive']['retunes']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(json_sanitize(results), f, indent=2, allow_nan=False)
        print(f"# wrote {args.out}")
    return 0


def smoke(args, sc, grad_fn, eval_fn, eps_value) -> int:
    ok = True

    # gate 1: the closed loop beats every fixed h in the grid
    fixed = {}
    for h in args.grid:
        _, tr = run_one(args, sc, grad_fn, eval_fn, schedule=Periodic(h=h))
        fixed[h] = trace_time_to_reach(tr, eps_value)
    ctrl = make_controller(args)
    _, tr = run_one(args, sc, grad_fn, eval_fn, ctrl=ctrl)
    tta_ad = trace_time_to_reach(tr, eps_value)
    best_h = min(fixed, key=fixed.get)
    line = (f"[smoke] adaptive tta={tta_ad:.1f} vs best fixed "
            f"h={best_h} tta={fixed[best_h]:.1f} "
            f"(grid {{h: tta}} = { {h: round(v, 1) for h, v in fixed.items()} }, "
            f"retunes {[(rt.from_t, rt.h) for rt in ctrl.schedule.retunes]})")
    if not math.isfinite(tta_ad) or any(tta_ad >= v for v in fixed.values()):
        ok = False
        line += "  FAIL(adaptive not strictly fastest)"
    print(line)

    # gate 2: with the controller off, both engines stay bit-identical
    # (short run; the hook points must be unobservable when unused)
    short = argparse.Namespace(**{**vars(args), "T": 300, "eval_every": 5,
                                  "time_limit": math.inf})
    tr_by_engine = {}
    for engine in ("object", "vectorized"):
        _, tr_e = run_one(short, sc, grad_fn, eval_fn,
                          schedule=Periodic(h=2), engine=engine)
        tr_by_engine[engine] = tr_e
    same = all(getattr(tr_by_engine["object"], f)
               == getattr(tr_by_engine["vectorized"], f)
               for f in TRACE_FIELDS)
    print(f"[smoke] controller-off engine bit-identity: "
          f"{'OK' if same else 'FAIL'}")
    ok = ok and same

    print(f"[smoke] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
