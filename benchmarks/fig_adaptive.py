"""[beyond paper] Closed-loop adaptive scheduling vs every fixed h.

The paper's Fig. 2 picks h offline: measure r once, solve eq. (21), run
Periodic(h_opt). `repro.adaptive` closes that loop online -- RTracker
streams r_hat from the live event timeline, StragglerReweighter folds
observed per-node step times into an effective lambda2, and
AdaptiveSchedule splices the re-solved h into the running pattern
(optionally growing it like the increasingly-sparse schedule of IV.B).

This benchmark races the closed loop against a swept grid of fixed
Periodic(h) schedules on the `scenarios.adversarial` preset (packet loss +
4x stragglers on a complete graph, the regime where offline h is least
trustworthy): every run shares the problem, stepsize, seed, and target
accuracy; the score is simulated wall-clock (event time) to target. The
whole race is declarative: one base `ExperimentSpec`, the fixed grid via
`run_sweep(spec, "schedule.params.h", grid)`, the adaptive run by swapping
in the adaptive schedule + controller components -- and the traces are
bit-identical to the pre-redesign hand-wired runs (gated in
tests/test_experiments_migration.py).

Knobs (see --help): --n, --d, --T, --r, --loss, --straggler, --n-slow,
--grid, --h0, --p, --update-every, --eps-frac, --eval-every, --seed,
--out (JSON), --smoke.

--smoke runs the acceptance gate and exits nonzero on failure:
  1. closed loop wins: adaptive time-to-target strictly beats EVERY fixed
     Periodic(h) in the swept grid on the adversarial scenario;
  2. controller-off bit-identity: with no controller attached, the object
     and vectorized engines still produce bit-identical traces on a seeded
     adversarial run (the controller hooks must cost nothing when off).
"""

from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core.dda import TRACE_FIELDS, json_sanitize
from repro.experiments import ExperimentSpec, run as run_spec, run_sweep
from repro.experiments.components import problems


def base_spec(args, h: int) -> ExperimentSpec:
    """One fixed-Periodic(h) run on the adversarial preset, as a spec."""
    return ExperimentSpec(
        name="fig_adaptive",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": args.n, "d": args.d, "seed": args.seed}},
        topology={"kind": "expander",
                  "params": {"k": args.k, "seed": args.seed}},
        schedule={"kind": "periodic", "params": {"h": h}},
        backends=[{"kind": "netsim",
                   "params": {"scenario": "adversarial", "loss": args.loss,
                              "slow_factor": args.straggler,
                              "n_slow": args.n_slow}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": args.a_scale}},
        T=args.T, eval_every=args.eval_every, seed=args.seed, r=args.r,
        eps_frac=args.eps_frac, time_limit=args.time_limit)


def adaptive_spec(args) -> ExperimentSpec:
    """The closed-loop run: adaptive schedule + controller components."""
    spec = base_spec(args, h=1)
    return ExperimentSpec.from_dict({
        **spec.to_dict(),
        "schedule": {"kind": "adaptive",
                     "params": {"h0": args.h0, "p": args.p}},
        "controller": {"kind": "adaptive",
                       "params": {"update_every": args.update_every,
                                  "warmup_messages": 4,
                                  "warmup_steps": 4}},
    })


def _tta(res) -> float:
    return math.inf if res.time_to_target is None else res.time_to_target


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=16, help="cluster size")
    ap.add_argument("--k", type=int, default=16,
                    help="graph degree (k >= n gives the complete graph)")
    ap.add_argument("--d", type=int, default=10, help="dimension")
    ap.add_argument("--T", type=int, default=8000, help="iterations per node")
    ap.add_argument("--r", type=float, default=1.3,
                    help="configured per-message time (full-grad units)")
    ap.add_argument("--loss", type=float, default=0.2)
    ap.add_argument("--straggler", type=float, default=4.0,
                    help="slow factor of the stragglers")
    ap.add_argument("--n-slow", type=int, default=2)
    ap.add_argument("--grid", type=int, nargs="+", default=[1, 2, 4, 8, 16],
                    help="fixed Periodic(h) sweep values")
    ap.add_argument("--h0", type=int, default=1,
                    help="adaptive cold-start interval")
    ap.add_argument("--p", type=float, default=0.1,
                    help="adaptive sparse-growth exponent")
    ap.add_argument("--update-every", type=float, default=0.5,
                    help="controller retune cadence (sim time)")
    ap.add_argument("--eps-frac", type=float, default=0.02,
                    help="target: F* + eps_frac * (F(x0) - F*)")
    ap.add_argument("--a-scale", type=float, default=0.5)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--time-limit", type=float, default=5000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", help="write results JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance gate and exit")
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args)

    prob = problems.build("quadratic_consensus", n=args.n, d=args.d,
                          seed=args.seed)
    fstar = prob.fstar
    results = {"benchmark": "fig_adaptive",
               "config": vars(args), "fstar": fstar,
               "eps_value": prob.eps_value(args.eps_frac),
               "fixed": [], "adaptive": None}
    print("schedule,h,tta,final_gap,r_emp")
    fixed = run_sweep(base_spec(args, h=args.grid[0]),
                      "schedule.params.h", args.grid)
    for h, res in zip(args.grid, fixed):
        # a run can end inside --time-limit before any message flew
        # (huge h, tiny T): report nan rather than abort the sweep
        r_emp = (res.r_measurement.r if res.r_measurement is not None
                 else math.nan)
        results["fixed"].append({"h": h, "tta": _tta(res),
                                 "final_gap": res.trace.fvals[-1] - fstar,
                                 "r_emp": r_emp})
        print(f"periodic,{h},{_tta(res):.1f},"
              f"{res.trace.fvals[-1] - fstar:.3f},{r_emp:.4f}")

    res_ad = run_spec(adaptive_spec(args))
    ex = res_ad.extras
    results["adaptive"] = {
        "tta": _tta(res_ad), "final_gap": res_ad.trace.fvals[-1] - fstar,
        "h_final": ex["h_final"], "h_opt_hat": ex["h_opt_hat"],
        "r_hat": ex["r_hat"],
        "lam2_eff": ex.get("lam2_eff"), "retunes": ex["retunes"]}
    r_hat = ex["r_hat"]  # None until a message has been observed
    print(f"adaptive,{ex['h_final']},{_tta(res_ad):.1f},"
          f"{res_ad.trace.fvals[-1] - fstar:.3f},"
          f"{math.nan if r_hat is None else r_hat:.4f}")
    print(f"# retune path: {ex['retunes']}")
    results["scenario"] = res_ad.extras["scenario"]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(json_sanitize(results), f, indent=2, allow_nan=False)
        print(f"# wrote {args.out}")
    return 0


def smoke(args) -> int:
    ok = True

    # gate 1: the closed loop beats every fixed h in the grid
    fixed = {h: _tta(res)
             for h, res in zip(args.grid,
                               run_sweep(base_spec(args, h=args.grid[0]),
                                         "schedule.params.h", args.grid))}
    res_ad = run_spec(adaptive_spec(args))
    tta_ad = _tta(res_ad)
    best_h = min(fixed, key=fixed.get)
    line = (f"[smoke] adaptive tta={tta_ad:.1f} vs best fixed "
            f"h={best_h} tta={fixed[best_h]:.1f} "
            f"(grid {{h: tta}} = { {h: round(v, 1) for h, v in fixed.items()} }, "
            f"retunes {res_ad.extras['retunes']})")
    if not math.isfinite(tta_ad) or any(tta_ad >= v for v in fixed.values()):
        ok = False
        line += "  FAIL(adaptive not strictly fastest)"
    print(line)

    # gate 2: with the controller off, both engines stay bit-identical
    # (short run; the hook points must be unobservable when unused)
    short = argparse.Namespace(**{**vars(args), "T": 300, "eval_every": 5,
                                  "time_limit": None})
    spec2 = base_spec(short, h=2)
    tr_by_engine = {
        engine: run_spec(
            spec2.with_value("backends.0.params.engine", engine)).trace
        for engine in ("object", "vectorized")}
    same = all(getattr(tr_by_engine["object"], f)
               == getattr(tr_by_engine["vectorized"], f)
               for f in TRACE_FIELDS)
    print(f"[smoke] controller-off engine bit-identity: "
          f"{'OK' if same else 'FAIL'}")
    ok = ok and same

    print(f"[smoke] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
