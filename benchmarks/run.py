"""Benchmark entry point: one function per paper table/figure, printing
``name,value,derived`` CSV rows. Reduced sizes keep the full suite a few
minutes on CPU; the module-level benchmarks (fig1_complete/fig1_reduced/
fig2_sparse) expose full-size parameters.

  fig1_complete  -- paper Fig 1 (left):  n_opt = 1/sqrt(r), complete graph
  fig1_reduced   -- paper Fig 1 (right): low-r regime via message compression
  fig2_sparse    -- paper Fig 2: h-periodic + increasingly-sparse schedules
  tradeoff_laws  -- eq. 7/11/18/21/31 closed-form table
  roofline       -- summary of results/roofline (if the dry-run sweep ran)
  kernels        -- kernel micro-benches / HBM models
"""

from __future__ import annotations

import json
import pathlib

import numpy as np


def main() -> None:
    from benchmarks import fig1_complete, fig1_reduced, fig2_sparse
    from benchmarks import kernels_bench
    from repro.core import (c1_constant, ch_constant, cp_constant, h_opt_int,
                            n_opt_complete)

    print("bench,value,derived")

    # --- tradeoff closed forms (paper eq. 7/11/18/21/31) ---
    print(f"n_opt_paper_full_mnist,{n_opt_complete(0.0293):.2f},"
          f"paper:5.8 (r=0.0293)")
    print(f"n_opt_paper_pca,{n_opt_complete(0.005):.2f},paper:14.15 (r=0.005)")
    print(f"h_opt_fig2,{h_opt_int(10, 9, 0.00089, 0.0)},paper:1")
    c1 = c1_constant(1, 1, 0.0)
    print(f"C1_over_2LR,{c1/2:.3f},sqrt(19+12)=5.568 at lam2=0")
    print(f"Cp03_lt_C1,{int(cp_constant(1,1,0.0,0.3) < c1)},claim C5: C_p<C_1")
    print(f"Ch2_over_C1,{ch_constant(1,1,0.0,2)/c1:.3f},>1 (h=2 worse const)")

    # --- Fig 1 left: n sweep on complete graph (reduced size) ---
    rows, s = fig1_complete.run(m_pairs=40_000, d=24, n_max=10, T=150,
                                verbose=False)
    print(f"fig1L_r,{s['r']:.4f},measured on this host")
    print(f"fig1L_n_opt_theory,{s['n_opt_theory']:.2f},1/sqrt(r)")
    print(f"fig1L_n_best,{s['n_best_observed']},argmin time-to-eps")
    for row in rows:
        print(f"fig1L_tta_n{row['n']},{row['time_to_eps']:.3f},"
              f"finalF={row['final_F']:.1f}")

    # --- Fig 1 right: compressed messages (low r) ---
    rows, s = fig1_reduced.run(m_pairs=40_000, d=24, n_max=10, T=150,
                               verbose=False)
    print(f"fig1R_r,{s['r']:.5f},PCA byte ratio applied (paper mechanism)")
    print(f"fig1R_n_opt_theory,{s['n_opt_theory']:.2f},1/sqrt(r)")
    print(f"fig1R_n_best,{s['n_best_observed']},argmin time-to-eps")

    # --- Fig 2: communication schedules ---
    _, s = fig2_sparse.run(n_nodes=10, M=150, d=100, T=300, verbose=False)
    print(f"fig2_h_opt,{s['h_opt_theory']},paper:1")
    for r, reg in s["regimes"].items():
        for name, row in reg.items():
            print(f"fig2_r{r}_{name},{row['time_to_1pct']:.2f},"
                  f"comms={row['comms']} finalF={row['final_F']:.1f}")

    # --- roofline summary (from dry-run results, if present) ---
    roof = pathlib.Path(__file__).resolve().parents[1] / "results" / "roofline"
    if roof.exists():
        rows = [json.loads(p.read_text()) for p in sorted(roof.glob("*.json"))]
        for r in rows:
            dom = max(("t_compute", "t_memory", "t_collective"),
                      key=lambda k: r[k])
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{r[dom]*1e3:.2f},{r['bottleneck']}-bound ms/step "
                  f"useful={r['useful_ratio']:.2f}")
    else:
        print("roofline,skipped,run repro.launch.dryrun + benchmarks.roofline")

    # --- kernels ---
    for name, us, derived in kernels_bench.run(verbose=False):
        print(f"kernel_{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
