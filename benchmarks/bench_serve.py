"""Serving benchmark: the checked-in manifests replayed through
`repro.serve.ExperimentServer` as a mixed workload.

What the server amortizes is XLA compilation and dispatch: a cold dense
`repro.run()` pays trace+lower+compile per process, the serve layer pays
it once per compile-cache signature and serves every later request from
the warm `DDASimulator`. This bench measures exactly that, on the real
manifest mix under `benchmarks/manifests/`:

  * **Equivalence gates before any timing** (the PR 2/5 discipline): for
    every dense-capable manifest, the cold-served AND warm-served result
    must be bit-identical (exact JSON compare under
    `comparable_result_dict`) to a solo `repro.run()`; a cross-request
    packed lane of seed-variants must be bit-identical lane-for-lane to
    solo runs. A fast-but-wrong server never posts a number.
  * **Cold vs warm latency**: submit->result wall per manifest against a
    fresh server (cold, pays compile) then repeated against the same
    server (warm, cache hit) -> per-spec samples + p50/p90 and the
    headline `speedup_p50`.
  * **Sustained throughput**: every dense manifest x several seeds
    submitted concurrently to a warm server with lane packing ->
    specs/sec, cache hit rate, lane occupancy.

Results land in BENCH_serve.json (schema in benchmarks/README.md); the
CI serve-smoke job runs `--smoke` and uploads the JSON. Full mode exits
nonzero unless warm p50 beats cold p50 by --min-speedup (default 3x).
Non-dense manifests (netsim/launch) are excluded from the replay -- the
compile cache is a dense-program cache -- and recorded under
`config.skipped` with reasons, never silently dropped.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import numpy as np

import repro
from repro.obs import sample_quantiles, write_json_artifact
from repro.serve import ExperimentServer, comparable_result_dict

MANIFEST_DIR = pathlib.Path(__file__).parent / "manifests"


def load_workload(smoke: bool) -> tuple[list, dict[str, str]]:
    """(dense specs to replay, {manifest name: reason skipped})."""
    specs, skipped = [], {}
    for path in sorted(MANIFEST_DIR.glob("*.json")):
        spec = repro.ExperimentSpec.from_file(path)
        kinds = [b.kind for b in spec.backends]
        if "dense" not in kinds:
            skipped[spec.name] = (f"declares {kinds}: the compile cache "
                                  f"amortizes the dense scan program only")
            continue
        if smoke:
            spec = spec.with_value("T", min(spec.T, 60))
        specs.append(spec)
    return specs, skipped


def _identical(served, solo) -> bool:
    # compare the JSON ROUND-TRIPPED artifacts -- what a client reads
    rt = repro.RunResult.from_json(served.to_json())
    return comparable_result_dict(rt) == comparable_result_dict(solo)


def check_equivalence(specs, max_width: int) -> dict:
    """Differential gates, all manifests, before any timing."""
    solos = {s.name: repro.run(s, backend="dense") for s in specs}
    per_spec = {}
    with ExperimentServer(workers=1, max_width=max_width,
                          max_wait_s=0.01) as srv:
        for s in specs:
            cold = srv.submit(s, backend="dense").result()
            warm = srv.submit(s, backend="dense").result()
            per_spec[s.name] = {
                "cold_identical": _identical(cold, solos[s.name]),
                "warm_identical": _identical(warm, solos[s.name]),
                "warm_cache_hit":
                    warm.metrics.counters.get("cache_hit") == 1.0,
            }
    # cross-request packed lane: seed-variants of the first manifest
    variants = [specs[0].with_value("seed", 100 + i)
                for i in range(max_width)]
    lane_solos = [repro.run(v, backend="dense") for v in variants]
    with ExperimentServer(workers=1, max_width=max_width,
                          max_wait_s=10.0) as srv:
        futs = [srv.submit(v, backend="dense") for v in variants]
        packed = [f.result() for f in futs]
    packed_ok = all(_identical(p, s) for p, s in zip(packed, lane_solos))
    packed_width = packed[0].metrics.counters.get("lane_width")
    ok = (packed_ok and packed_width == float(max_width)
          and all(v["cold_identical"] and v["warm_identical"]
                  and v["warm_cache_hit"] for v in per_spec.values()))
    return {"ok": bool(ok), "per_spec": per_spec,
            "packed_lane": {"identical": bool(packed_ok),
                            "width": packed_width,
                            "lane_spec": specs[0].name}}


def bench_latency(specs, repeats: int) -> dict:
    """Cold (fresh server, pays compile) vs warm submit->result walls."""
    per_spec = []
    cold_walls, warm_walls = [], []
    # one fresh server for the cold round: every spec is a distinct
    # signature, so each first submission is a true cold miss
    with ExperimentServer(workers=1, max_wait_s=0.005) as srv:
        for s in specs:
            t0 = time.perf_counter()
            res = srv.submit(s, backend="dense").result()
            cold = time.perf_counter() - t0
            assert res.metrics.counters.get("cache_miss") == 1.0
            cold_walls.append(cold)
            warms = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = srv.submit(s, backend="dense").result()
                warms.append(time.perf_counter() - t0)
                assert res.metrics.counters.get("cache_hit") == 1.0
            warm_walls.extend(warms)
            per_spec.append({
                "name": s.name, "T": s.T,
                "cold_s": round(cold, 4),
                "warm_samples_s": [round(w, 6) for w in warms],
                "warm_p50_s": float(np.percentile(warms, 50)),
                "speedup_p50": round(cold / np.percentile(warms, 50), 2),
            })
        cache = srv.cache.stats()
    return {
        "per_spec": per_spec,
        "cold_quantiles": sample_quantiles(cold_walls, "host"),
        "warm_quantiles": sample_quantiles(warm_walls, "host"),
        "speedup_p50": round(float(np.percentile(cold_walls, 50)
                                   / np.percentile(warm_walls, 50)), 2),
        "cache": cache,
    }


def bench_throughput(specs, seeds: int, workers: int,
                     max_width: int) -> dict:
    """Mixed replay: every dense manifest x `seeds` seed-variants,
    submitted concurrently to a pre-warmed packing server."""
    workload = [s.with_value("seed", 200 + i)
                for i in range(seeds) for s in specs]
    with ExperimentServer(workers=workers, max_width=max_width,
                          max_wait_s=0.05) as srv:
        for s in specs:  # pre-warm: throughput is the steady state
            srv.submit(s, backend="dense").result()
        t0 = time.perf_counter()
        futs = [srv.submit(s, backend="dense") for s in workload]
        for f in futs:
            f.result()
        wall = time.perf_counter() - t0
        stats = srv.stats()
    widths = [f.result().metrics.counters["lane_width"] for f in futs]
    return {
        "specs": len(workload), "distinct_manifests": len(specs),
        "seeds_per_manifest": seeds, "workers": workers,
        "max_width": max_width,
        "wall_s": round(wall, 4),
        "specs_per_sec": round(len(workload) / wall, 2),
        "mean_lane_width": round(float(np.mean(widths)), 3),
        "lanes": stats["packer"],
        "cache": stats["cache"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--repeats", type=int, default=9,
                    help="warm latency samples per manifest (3 in --smoke)")
    ap.add_argument("--seeds", type=int, default=4,
                    help="seed-variants per manifest in the throughput "
                         "replay")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-width", type=int, default=4,
                    help="lane packer max width")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="required warm-vs-cold p50 speedup (full mode)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="short runs, fewer repeats, no speedup gate "
                         "(equivalence still enforced): CI mode")
    args = ap.parse_args(argv)

    repeats = 3 if args.smoke else args.repeats
    seeds = 2 if args.smoke else args.seeds

    specs, skipped = load_workload(args.smoke)
    print(f"[bench_serve] replaying {len(specs)} dense manifests: "
          f"{[s.name for s in specs]}")
    for name, why in skipped.items():
        print(f"[bench_serve] skipping {name}: {why}")

    equiv = check_equivalence(specs, max_width=min(args.max_width, 3))
    print(f"[equivalence] warm-cache + packed-lane bit-identity on "
          f"{len(specs)} manifests: {'OK' if equiv['ok'] else 'FAIL'}")
    if not equiv["ok"]:
        print(json.dumps(equiv, indent=2))
        return 1

    latency = bench_latency(specs, repeats)
    for row in latency["per_spec"]:
        print(f"[latency] {row['name']}: cold={row['cold_s']:.3f}s "
              f"warm_p50={row['warm_p50_s']:.4f}s "
              f"({row['speedup_p50']:.0f}x)")
    print(f"[latency] overall cold_p50="
          f"{latency['cold_quantiles']['p50']:.3f}s warm_p50="
          f"{latency['warm_quantiles']['p50']:.4f}s -> "
          f"{latency['speedup_p50']:.1f}x")

    thr = bench_throughput(specs, seeds, args.workers, args.max_width)
    print(f"[throughput] {thr['specs']} specs in {thr['wall_s']:.2f}s = "
          f"{thr['specs_per_sec']:.1f} specs/s (lane occupancy "
          f"{thr['lanes']['occupancy']:.2f}, cache hit rate "
          f"{thr['cache']['hit_rate']:.2f})")

    measured = latency["speedup_p50"]
    gate = {"warm_speedup_p50_min": args.min_speedup,
            "measured": measured,
            "pass": bool(args.smoke or measured >= args.min_speedup)}
    report = {
        "benchmark": "serve",
        "mode": "smoke" if args.smoke else "full",
        "config": {"repeats": repeats, "seeds": seeds,
                   "workers": args.workers, "max_width": args.max_width,
                   "manifests": [s.name for s in specs],
                   "skipped": skipped},
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "equivalence": equiv,
        "latency": latency,
        "throughput": thr,
        "acceptance": gate,
    }
    write_json_artifact(args.out, report)
    print(f"[bench_serve] wrote {args.out}")

    if not args.smoke and not gate["pass"]:
        print(f"[bench_serve] FAIL: warm/cold p50 {measured:.1f}x < "
              f"{args.min_speedup:g}x")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
