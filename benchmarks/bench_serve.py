"""Serving benchmark: the checked-in manifests replayed through
`repro.serve.ExperimentServer` as a mixed workload.

What the server amortizes is XLA compilation and dispatch: a cold dense
`repro.run()` pays trace+lower+compile per process, the serve layer pays
it once per compile-cache signature and serves every later request from
the warm `DDASimulator`. This bench measures exactly that, on the real
manifest mix under `benchmarks/manifests/`:

  * **Equivalence gates before any timing** (the PR 2/5 discipline): for
    every dense-capable manifest, the cold-served AND warm-served result
    must be bit-identical (exact JSON compare under
    `comparable_result_dict`) to a solo `repro.run()`; a cross-request
    packed lane of seed-variants must be bit-identical lane-for-lane to
    solo runs. A fast-but-wrong server never posts a number.
  * **Cold vs warm latency**: submit->result wall per manifest against a
    fresh server (cold, pays compile) then repeated against the same
    server (warm, cache hit) -> per-spec samples + p50/p90 and the
    headline `speedup_p50`.
  * **Sustained throughput**: every dense manifest x several seeds
    submitted concurrently to a warm server with lane packing ->
    specs/sec, cache hit rate, lane occupancy.

Two robustness axes ride on top of the cache benchmarks:

  * **Pool throughput** (`pool`): the MIXED dense+netsim manifest replay
    against a fresh multi-process `WorkerPool` server vs the same
    workload against a fresh in-process server. netsim runs are
    host-side numpy under the GIL, so worker processes are the only way
    to overlap them; dense lanes ship through the pipe and must come
    back bit-identical (equivalence-gated before any timing). Full mode
    exits nonzero unless pool wall beats in-process wall by
    --min-pool-speedup (default 1.5x) -- enforced whenever the box has
    >= 2 usable cores; on a single-core box no process count can beat
    one process, so the measurement is recorded and the gate is marked
    hardware-skipped (loudly, never silently).
  * **Load shedding** (`shedding`): offered load ~3x capacity against a
    single-threaded server with a bounded admission queue. Overload is
    answered immediately (`Overloaded` + retry-after hint, counted),
    never by a timeout, and the p99 of ACCEPTED requests stays bounded
    by the queue depth instead of growing with the burst.

Results land in BENCH_serve.json (schema in benchmarks/README.md); the
CI serve-smoke job runs `--smoke` and uploads the JSON. Full mode exits
nonzero unless warm p50 beats cold p50 by --min-speedup (default 3x).
Non-dense manifests (netsim/launch) are excluded from the cache replay
-- the compile cache is a dense-program cache -- and recorded under
`config.skipped` with reasons, never silently dropped; the pool axis
replays dense AND netsim and skips only launch.
"""

from __future__ import annotations

import argparse
import concurrent.futures
import json
import os
import pathlib
import platform
import time

import numpy as np

import repro
from repro.obs import sample_quantiles, write_json_artifact
from repro.serve import ExperimentServer, Overloaded, comparable_result_dict

MANIFEST_DIR = pathlib.Path(__file__).parent / "manifests"


def load_workload(smoke: bool) -> tuple[list, dict[str, str]]:
    """(dense specs to replay, {manifest name: reason skipped})."""
    specs, skipped = [], {}
    for path in sorted(MANIFEST_DIR.glob("*.json")):
        spec = repro.ExperimentSpec.from_file(path)
        kinds = [b.kind for b in spec.backends]
        if "dense" not in kinds:
            skipped[spec.name] = (f"declares {kinds}: the compile cache "
                                  f"amortizes the dense scan program only")
            continue
        if smoke:
            spec = spec.with_value("T", min(spec.T, 60))
        specs.append(spec)
    return specs, skipped


def _identical(served, solo) -> bool:
    # compare the JSON ROUND-TRIPPED artifacts -- what a client reads
    rt = repro.RunResult.from_json(served.to_json())
    return comparable_result_dict(rt) == comparable_result_dict(solo)


def check_equivalence(specs, max_width: int) -> dict:
    """Differential gates, all manifests, before any timing."""
    solos = {s.name: repro.run(s, backend="dense") for s in specs}
    per_spec = {}
    with ExperimentServer(workers=1, max_width=max_width,
                          max_wait_s=0.01) as srv:
        for s in specs:
            cold = srv.submit(s, backend="dense").result()
            warm = srv.submit(s, backend="dense").result()
            per_spec[s.name] = {
                "cold_identical": _identical(cold, solos[s.name]),
                "warm_identical": _identical(warm, solos[s.name]),
                "warm_cache_hit":
                    warm.metrics.counters.get("cache_hit") == 1.0,
            }
    # cross-request packed lane: seed-variants of the first manifest
    variants = [specs[0].with_value("seed", 100 + i)
                for i in range(max_width)]
    lane_solos = [repro.run(v, backend="dense") for v in variants]
    with ExperimentServer(workers=1, max_width=max_width,
                          max_wait_s=10.0) as srv:
        futs = [srv.submit(v, backend="dense") for v in variants]
        packed = [f.result() for f in futs]
    packed_ok = all(_identical(p, s) for p, s in zip(packed, lane_solos))
    packed_width = packed[0].metrics.counters.get("lane_width")
    ok = (packed_ok and packed_width == float(max_width)
          and all(v["cold_identical"] and v["warm_identical"]
                  and v["warm_cache_hit"] for v in per_spec.values()))
    return {"ok": bool(ok), "per_spec": per_spec,
            "packed_lane": {"identical": bool(packed_ok),
                            "width": packed_width,
                            "lane_spec": specs[0].name}}


def bench_latency(specs, repeats: int) -> dict:
    """Cold (fresh server, pays compile) vs warm submit->result walls."""
    per_spec = []
    cold_walls, warm_walls = [], []
    # one fresh server for the cold round: every spec is a distinct
    # signature, so each first submission is a true cold miss
    with ExperimentServer(workers=1, max_wait_s=0.005) as srv:
        for s in specs:
            t0 = time.perf_counter()
            res = srv.submit(s, backend="dense").result()
            cold = time.perf_counter() - t0
            assert res.metrics.counters.get("cache_miss") == 1.0
            cold_walls.append(cold)
            warms = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = srv.submit(s, backend="dense").result()
                warms.append(time.perf_counter() - t0)
                assert res.metrics.counters.get("cache_hit") == 1.0
            warm_walls.extend(warms)
            per_spec.append({
                "name": s.name, "T": s.T,
                "cold_s": round(cold, 4),
                "warm_samples_s": [round(w, 6) for w in warms],
                "warm_p50_s": float(np.percentile(warms, 50)),
                "speedup_p50": round(cold / np.percentile(warms, 50), 2),
            })
        cache = srv.cache.stats()
    return {
        "per_spec": per_spec,
        "cold_quantiles": sample_quantiles(cold_walls, "host"),
        "warm_quantiles": sample_quantiles(warm_walls, "host"),
        "speedup_p50": round(float(np.percentile(cold_walls, 50)
                                   / np.percentile(warm_walls, 50)), 2),
        "cache": cache,
    }


def bench_throughput(specs, seeds: int, workers: int,
                     max_width: int) -> dict:
    """Mixed replay: every dense manifest x `seeds` seed-variants,
    submitted concurrently to a pre-warmed packing server."""
    workload = [s.with_value("seed", 200 + i)
                for i in range(seeds) for s in specs]
    with ExperimentServer(workers=workers, max_width=max_width,
                          max_wait_s=0.05) as srv:
        for s in specs:  # pre-warm: throughput is the steady state
            srv.submit(s, backend="dense").result()
        t0 = time.perf_counter()
        futs = [srv.submit(s, backend="dense") for s in workload]
        for f in futs:
            f.result()
        wall = time.perf_counter() - t0
        stats = srv.stats()
    widths = [f.result().metrics.counters["lane_width"] for f in futs]
    return {
        "specs": len(workload), "distinct_manifests": len(specs),
        "seeds_per_manifest": seeds, "workers": workers,
        "max_width": max_width,
        "wall_s": round(wall, 4),
        "specs_per_sec": round(len(workload) / wall, 2),
        "mean_lane_width": round(float(np.mean(widths)), 3),
        "lanes": stats["packer"],
        "cache": stats["cache"],
    }


def load_mixed_workload(smoke: bool) -> tuple[list, dict[str, str]]:
    """((spec, backend_kind) pairs, skipped) for the pool axis.

    Dense AND netsim manifests: dense exercises bit-identity of compiled
    lanes through the worker pipe, netsim is pure-GIL host numpy -- the
    work that only real processes can overlap."""
    pairs, skipped = [], {}
    for path in sorted(MANIFEST_DIR.glob("*.json")):
        spec = repro.ExperimentSpec.from_file(path)
        kinds = [b.kind for b in spec.backends]
        kind = ("dense" if "dense" in kinds
                else "netsim" if "netsim" in kinds else None)
        if kind is None:
            skipped[spec.name] = (f"declares {kinds}: the pool replay "
                                  f"covers dense+netsim only")
            continue
        if smoke:
            spec = spec.with_value("T", min(spec.T, 60))
        pairs.append((spec, kind))
    return pairs, skipped


def bench_pool(pairs, seeds: int, processes: int, threads: int,
               max_width: int) -> dict:
    """Multi-process pool vs in-process serving on the mixed replay.

    Equivalence gates FIRST: every pooled result must be bit-identical
    to a cold solo `repro.run()` -- a worker that computed something
    else never posts a throughput number. Then the same workload (every
    manifest x seeds) is replayed against a warmed in-process server
    and a warmed pool (steady state, the same discipline as
    `bench_throughput`: spawn + per-worker jax import + first compiles
    are startup, not throughput). Warm-up submits each distinct spec
    once per worker sequentially -- dispatch is round-robin, so that
    reaches every worker's private compile cache. What remains in the
    timed region is the pool's real tradeoff: pipe serialization per
    request vs true parallelism for the GIL-bound netsim runs."""
    solos = {s.name: repro.run(s, backend=k) for s, k in pairs}
    per_spec = {}
    with ExperimentServer(workers=threads, processes=processes,
                          max_width=max_width, max_wait_s=0.05) as srv:
        futs = [(s.name, srv.submit(s, backend=k)) for s, k in pairs]
        for name, f in futs:
            per_spec[name] = _identical(f.result(), solos[name])
        equiv_pool = srv.stats()["pool"]
    if not all(per_spec.values()):
        return {"equivalence": {"ok": False, "per_spec": per_spec}}

    workload = [(s.with_value("seed", 300 + i), k)
                for i in range(seeds) for s, k in pairs]

    def replay(procs: int) -> tuple[float, dict]:
        with ExperimentServer(workers=threads, processes=procs,
                              max_width=max_width, max_wait_s=0.05) as srv:
            for s, k in pairs:  # warm every worker's cache in turn
                for _ in range(max(procs, 1)):
                    srv.submit(s, backend=k).result()
            # one untimed workload pass: packed seed-variant lanes
            # compile a WIDER program than the solo warm-up did
            warm = [srv.submit(s, backend=k) for s, k in workload]
            for f in warm:
                f.result()
            t0 = time.perf_counter()
            futs = [srv.submit(s, backend=k) for s, k in workload]
            for f in futs:
                f.result()
            wall = time.perf_counter() - t0
            return wall, srv.stats()

    single_wall, single_stats = replay(0)
    pool_wall, pool_stats = replay(processes)
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:
        cores = os.cpu_count() or 1
    return {
        "equivalence": {"ok": True, "per_spec": per_spec,
                        "pool": equiv_pool},
        "specs": len(workload), "seeds_per_manifest": seeds,
        "threads": threads, "processes": processes, "cores": cores,
        "single_process": {
            "wall_s": round(single_wall, 4),
            "specs_per_sec": round(len(workload) / single_wall, 2)},
        "pool": {
            "wall_s": round(pool_wall, 4),
            "specs_per_sec": round(len(workload) / pool_wall, 2),
            "worker_restarts": pool_stats["pool"]["worker_restarts"],
            "reenqueues": pool_stats["pool"]["reenqueues"]},
        "speedup": round(single_wall / pool_wall, 2),
    }


def bench_shedding(pairs, burst: int, max_queue: int,
                   overdrive: float = 3.0) -> dict:
    """Offered load beyond capacity: shed fast, never time out.

    One netsim manifest (fixed per-run cost, no compile jitter) is
    offered at ~overdrive x the single-threaded server's capacity with
    an admission queue capped at `max_queue`. Requests past the cap are
    answered immediately with `Overloaded` (+ retry-after hint); the
    requests that ARE admitted wait behind at most `max_queue` peers, so
    their p99 is bounded by the queue depth -- not by the burst size,
    which is what an unbounded queue would produce."""
    spec, kind = next((s, k) for s, k in pairs if k == "netsim")
    t0 = time.perf_counter()
    repro.run(spec, backend=kind)
    unit_s = time.perf_counter() - t0

    latencies, retry_hints = [], []
    overloaded = timeouts = 0
    with ExperimentServer(workers=1, packing=False,
                          max_queue=max_queue) as srv:
        futs = []
        for i in range(burst):
            try:
                f = srv.submit(spec.with_value("seed", 400 + i),
                               backend=kind)
            except Overloaded as e:
                overloaded += 1
                retry_hints.append(e.retry_after_s)
            else:
                f.add_done_callback(
                    lambda _f, t=time.perf_counter():
                        latencies.append(time.perf_counter() - t))
                futs.append(f)
            time.sleep(unit_s / overdrive)  # sustained offered load
        deadline = (max_queue + 2) * unit_s * 5 + 5.0
        for f in futs:
            try:
                f.result(timeout=deadline)
            except concurrent.futures.TimeoutError:
                timeouts += 1
        stats = srv.stats()["robustness"]

    bound_s = 2.0 * (max_queue + 1) * unit_s
    q = sample_quantiles(latencies, "host") if latencies else {}
    return {
        "manifest": spec.name, "unit_run_s": round(unit_s, 4),
        "offered": burst, "accepted": len(futs),
        "overloaded": overloaded, "timeouts": timeouts,
        "server_counted_overloaded": stats["overloaded"],
        "retry_after_hint_s": [round(h, 3) for h in retry_hints[:4]],
        "max_queue": max_queue, "overdrive": overdrive,
        "accepted_quantiles": q,
        "p99_bound_s": round(bound_s, 4),
        "p99_bounded": bool(latencies
                            and q["p99"] <= bound_s and timeouts == 0),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--repeats", type=int, default=9,
                    help="warm latency samples per manifest (3 in --smoke)")
    ap.add_argument("--seeds", type=int, default=4,
                    help="seed-variants per manifest in the throughput "
                         "replay")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-width", type=int, default=4,
                    help="lane packer max width")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="required warm-vs-cold p50 speedup (full mode)")
    ap.add_argument("--processes", type=int, default=2,
                    help="worker processes for the pool axis")
    ap.add_argument("--min-pool-speedup", type=float, default=1.5,
                    help="required pool-vs-single-process speedup on the "
                         "mixed workload (full mode)")
    ap.add_argument("--burst", type=int, default=24,
                    help="offered requests in the shedding axis")
    ap.add_argument("--max-queue", type=int, default=4,
                    help="admission-queue cap in the shedding axis")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="short runs, fewer repeats, no speedup gate "
                         "(equivalence still enforced): CI mode")
    args = ap.parse_args(argv)

    repeats = 3 if args.smoke else args.repeats
    seeds = 2 if args.smoke else args.seeds

    specs, skipped = load_workload(args.smoke)
    print(f"[bench_serve] replaying {len(specs)} dense manifests: "
          f"{[s.name for s in specs]}")
    for name, why in skipped.items():
        print(f"[bench_serve] skipping {name}: {why}")

    equiv = check_equivalence(specs, max_width=min(args.max_width, 3))
    print(f"[equivalence] warm-cache + packed-lane bit-identity on "
          f"{len(specs)} manifests: {'OK' if equiv['ok'] else 'FAIL'}")
    if not equiv["ok"]:
        print(json.dumps(equiv, indent=2))
        return 1

    latency = bench_latency(specs, repeats)
    for row in latency["per_spec"]:
        print(f"[latency] {row['name']}: cold={row['cold_s']:.3f}s "
              f"warm_p50={row['warm_p50_s']:.4f}s "
              f"({row['speedup_p50']:.0f}x)")
    print(f"[latency] overall cold_p50="
          f"{latency['cold_quantiles']['p50']:.3f}s warm_p50="
          f"{latency['warm_quantiles']['p50']:.4f}s -> "
          f"{latency['speedup_p50']:.1f}x")

    thr = bench_throughput(specs, seeds, args.workers, args.max_width)
    print(f"[throughput] {thr['specs']} specs in {thr['wall_s']:.2f}s = "
          f"{thr['specs_per_sec']:.1f} specs/s (lane occupancy "
          f"{thr['lanes']['occupancy']:.2f}, cache hit rate "
          f"{thr['cache']['hit_rate']:.2f})")

    pairs, pool_skipped = load_mixed_workload(args.smoke)
    for name, why in pool_skipped.items():
        print(f"[pool] skipping {name}: {why}")
    pool = bench_pool(pairs, seeds, args.processes, args.workers,
                      args.max_width)
    if not pool["equivalence"]["ok"]:
        print("[pool] FAIL: pooled results not bit-identical to solo")
        print(json.dumps(pool, indent=2))
        return 1
    print(f"[pool] {pool['specs']} mixed specs: in-process "
          f"{pool['single_process']['wall_s']:.2f}s vs "
          f"{args.processes}-worker pool {pool['pool']['wall_s']:.2f}s "
          f"-> {pool['speedup']:.2f}x ({pool['cores']} usable cores)")
    pool_hw_skip = pool["cores"] < 2
    if pool_hw_skip:
        print(f"[pool] GATE HARDWARE-SKIPPED: {pool['cores']} usable "
              f"core(s) -- no process count can beat one process here; "
              f"speedup recorded, not gated")

    shed = bench_shedding(pairs, args.burst, args.max_queue)
    print(f"[shedding] offered {shed['offered']} at "
          f"{shed['overdrive']:.0f}x capacity: accepted "
          f"{shed['accepted']}, overloaded {shed['overloaded']}, "
          f"timeouts {shed['timeouts']}, accepted p99 "
          f"{shed['accepted_quantiles'].get('p99', float('nan')):.3f}s "
          f"(bound {shed['p99_bound_s']:.3f}s)")

    measured = latency["speedup_p50"]
    shed_ok = bool(shed["overloaded"] > 0 and shed["timeouts"] == 0
                   and shed["p99_bounded"])
    gate = {"warm_speedup_p50_min": args.min_speedup,
            "measured": measured,
            "pass": bool(args.smoke or measured >= args.min_speedup),
            "pool_speedup_min": args.min_pool_speedup,
            "pool_measured": pool["speedup"],
            "pool_gate_hardware_skipped": pool_hw_skip,
            "pool_pass": bool(args.smoke or pool_hw_skip
                              or pool["speedup"] >= args.min_pool_speedup),
            "shedding_pass": bool(args.smoke or shed_ok)}
    report = {
        "benchmark": "serve",
        "mode": "smoke" if args.smoke else "full",
        "config": {"repeats": repeats, "seeds": seeds,
                   "workers": args.workers, "max_width": args.max_width,
                   "processes": args.processes,
                   "manifests": [s.name for s in specs],
                   "pool_manifests": [s.name for s, _ in pairs],
                   "skipped": skipped, "pool_skipped": pool_skipped},
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "numpy": np.__version__},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "equivalence": equiv,
        "latency": latency,
        "throughput": thr,
        "pool": pool,
        "shedding": shed,
        "acceptance": gate,
    }
    write_json_artifact(args.out, report)
    print(f"[bench_serve] wrote {args.out}")

    failed = False
    if not args.smoke and not gate["pass"]:
        print(f"[bench_serve] FAIL: warm/cold p50 {measured:.1f}x < "
              f"{args.min_speedup:g}x")
        failed = True
    if not gate["pool_pass"]:
        print(f"[bench_serve] FAIL: pool speedup {pool['speedup']:.2f}x < "
              f"{args.min_pool_speedup:g}x")
        failed = True
    if not gate["shedding_pass"]:
        print(f"[bench_serve] FAIL: shedding gate (overloaded="
              f"{shed['overloaded']}, timeouts={shed['timeouts']}, "
              f"p99_bounded={shed['p99_bounded']})")
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
