"""Paper Fig. 1 (left): metric learning on a COMPLETE graph, n = 1..14.

The paper measures r = t_msg / t_grad on its cluster (r = 0.0293 for full
MNIST => n_opt = 1/sqrt(r) = 5.8; fastest observed n = 6). We measure t_grad
on THIS host, model t_msg with the paper's ethernet bandwidth (11 MB/s), and
verify the same law: the fastest n in simulated time-to-accuracy matches
1/sqrt(r) for OUR measured r.

Outputs CSV rows: n, time_to_eps, final_F; plus the r/n_opt summary.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_problems import MetricLearning
from repro.core import (DDASimulator, EveryIteration, complete_graph,
                        n_opt_complete)

PAPER_ETHERNET_BPS = 11e6  # ~11 MB/s per node (paper section V)


def measure_r(problem: MetricLearning, bandwidth_bps: float) -> tuple[float, float]:
    """t_grad measured on this host (full-data subgradient, 1 node);
    t_msg = bytes/bandwidth (transmit + receive => 2x)."""
    sub = MetricLearning(problem.u, problem.v, problem.s, 1).make_subgrad()
    x = jnp.zeros((1, problem.dim))
    g = jax.jit(lambda xx: sub(xx, 0, None))
    g(x).block_until_ready()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        g(x).block_until_ready()
    t_grad = (time.perf_counter() - t0) / reps
    t_msg = 2.0 * problem.message_bytes() / bandwidth_bps
    return t_msg / t_grad, t_grad


def run(m_pairs: int = 200_000, d: int = 24, n_max: int = 14, T: int = 300,
        eps_frac: float = 0.12, bandwidth_bps: float = PAPER_ETHERNET_BPS,
        seed: int = 0, verbose: bool = True, compress_keep: float = None,
        r_override: float = None):
    problem_full = MetricLearning.build(m_pairs, d, 1, seed)
    r, t_grad = measure_r(problem_full, bandwidth_bps)
    if compress_keep is not None:
        # [beyond paper] top-k+EF message compression cuts wire bytes
        # (values + indices), and with them r -- paper eq. 11 then predicts
        # a LARGER optimal cluster: n_opt = 1/sqrt(r * ratio).
        from repro.core import ratio_bytes
        r = r * ratio_bytes(compress_keep, 8, 4)
    if r_override is not None:
        r = r_override
    nopt = n_opt_complete(r)
    f0 = float(problem_full.full_objective(jnp.zeros(problem_full.dim)))
    eps_target = eps_frac * f0
    # paper-optimal stepsize scale (eq. 18 with h=1, lam2=0): A = R/(L*sqrt(31))
    g0 = problem_full.make_subgrad()(jnp.zeros((1, problem_full.dim)), 0, None)
    L = float(jnp.linalg.norm(g0[0]))
    A_scale = 10.0 / (L * np.sqrt(31.0))

    rows = []
    for n in range(1, n_max + 1):
        prob = MetricLearning(problem_full.u, problem_full.v,
                              problem_full.s, n)
        # paper eq. (2) normalization: node subgradients are LOCAL sums over
        # m/n pairs, so the consensus direction shrinks ~1/n vs the n=1 run;
        # scaling a(t) by n keeps the effective step n-invariant.
        sim = DDASimulator(
            prob.make_subgrad(),
            jax.jit(prob.full_objective),
            complete_graph(n),
            EveryIteration(),
            a_fn=lambda t, n=n: n * A_scale / jnp.sqrt(t),
            projection=prob.projection,
            r=r, compress_keep=compress_keep)
        x0 = jnp.zeros((n, prob.dim))
        trace = sim.run(x0, T, eval_every=10, seed=seed)
        tta = sim.time_to_reach(trace, eps_target)
        rows.append({"n": n, "time_to_eps": tta,
                     "final_F": trace.fvals[-1]})
        if verbose:
            print(f"[fig1] n={n:2d} time_to_eps={tta:9.3f} "
                  f"final_F={trace.fvals[-1]:9.3f}", flush=True)

    finite = [row for row in rows if np.isfinite(row["time_to_eps"])]
    best_n = (min(finite, key=lambda row: row["time_to_eps"])["n"]
              if finite else -1)
    summary = {"r": r, "t_grad_s": t_grad, "n_opt_theory": nopt,
               "n_best_observed": best_n, "eps_target": eps_target}
    if verbose:
        print(f"[fig1L] r={r:.4f} n_opt(theory)={nopt:.1f} "
              f"best observed n={best_n}")
    return rows, summary


if __name__ == "__main__":
    run()
