"""Paper Fig. 1 (left): metric learning on a COMPLETE graph, n = 1..14.

The paper measures r = t_msg / t_grad on its cluster (r = 0.0293 for full
MNIST => n_opt = 1/sqrt(r) = 5.8; fastest observed n = 6). We measure t_grad
on THIS host, model t_msg with the paper's ethernet bandwidth (11 MB/s), and
verify the same law: the fastest n in simulated time-to-accuracy matches
1/sqrt(r) for OUR measured r.

Every cell is a declarative `ExperimentSpec` through `repro.run()` (the
"metric_learning" problems-registry kind carries the jax objective,
subgradient and PSD projection that used to be hand-wired here); only the
host-side r measurement and the eps_frac * F(0) accuracy target stay in the
driver. The spec-vs-hand-wired equivalence is gated bit-identically in
tests/test_experiments_migration.py, and benchmarks/manifests/
fig1_complete.json checks in one smoke-sized cell.

Outputs CSV rows: n, time_to_eps, final_F; plus the r/n_opt summary.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import n_opt_complete
from repro.core.dda import trace_time_to_reach
from repro.experiments import ExperimentSpec, run as run_spec
from repro.experiments.components import problems

PAPER_ETHERNET_BPS = 11e6  # ~11 MB/s per node (paper section V)


def measure_r(m_pairs: int, d: int, seed: int,
              bandwidth_bps: float) -> tuple[float, float]:
    """t_grad measured on this host (full-data subgradient, 1 node);
    t_msg = bytes/bandwidth (transmit + receive => 2x)."""
    prob1 = problems.build("metric_learning", n=1, m_pairs=m_pairs,
                           d_feat=d, seed=seed)
    x = jnp.zeros((1, prob1.d))
    g = jax.jit(lambda xx: prob1.subgrad_stack(xx, 0, None))
    g(x).block_until_ready()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        g(x).block_until_ready()
    t_grad = (time.perf_counter() - t0) / reps
    t_msg = 2.0 * (prob1.d * 8) / bandwidth_bps  # doubles, as in the paper
    return t_msg / t_grad, t_grad


def cell_spec(n: int, m_pairs: int, d: int, T: int, A: float, r: float,
              seed: int, eval_every: int = 10,
              compress_keep: float | None = None) -> ExperimentSpec:
    """One Fig. 1 cell: n-node complete graph, communicate every iteration,
    stepsize a(t) = A / sqrt(t) with the driver's measured scale."""
    backend_params = ({}
                      if compress_keep is None
                      else {"compress_keep": compress_keep})
    return ExperimentSpec(
        name="fig1_complete",
        problem={"kind": "metric_learning",
                 "params": {"n": n, "m_pairs": m_pairs, "d_feat": d,
                            "seed": seed}},
        topology={"kind": "complete"},
        schedule={"kind": "every"},
        backends=[{"kind": "dense", "params": backend_params}],
        stepsize={"kind": "sqrt", "params": {"A": A}},
        T=T, eval_every=eval_every, seed=seed, r=r)


def run(m_pairs: int = 200_000, d: int = 24, n_max: int = 14, T: int = 300,
        eps_frac: float = 0.12, bandwidth_bps: float = PAPER_ETHERNET_BPS,
        seed: int = 0, verbose: bool = True, compress_keep: float = None,
        r_override: float = None):
    r, t_grad = measure_r(m_pairs, d, seed, bandwidth_bps)
    if compress_keep is not None:
        # [beyond paper] top-k+EF message compression cuts wire bytes
        # (values + indices), and with them r -- paper eq. 11 then predicts
        # a LARGER optimal cluster: n_opt = 1/sqrt(r * ratio).
        from repro.core import ratio_bytes
        r = r * ratio_bytes(compress_keep, 8, 4)
    if r_override is not None:
        r = r_override
    nopt = n_opt_complete(r)
    prob1 = problems.build("metric_learning", n=1, m_pairs=m_pairs,
                           d_feat=d, seed=seed)
    f0 = prob1.f0()
    eps_target = eps_frac * f0
    # paper-optimal stepsize scale (eq. 18 with h=1, lam2=0): A = R/(L*sqrt(31))
    g0 = prob1.subgrad_stack(jnp.zeros((1, prob1.d)), 0, None)
    L = float(jnp.linalg.norm(g0[0]))
    A_scale = 10.0 / (L * np.sqrt(31.0))

    rows = []
    for n in range(1, n_max + 1):
        # paper eq. (2) normalization: node subgradients are LOCAL sums over
        # m/n pairs, so the consensus direction shrinks ~1/n vs the n=1 run;
        # scaling a(t) by n keeps the effective step n-invariant.
        res = run_spec(cell_spec(n, m_pairs, d, T, n * A_scale, r, seed,
                                 compress_keep=compress_keep))
        tta = trace_time_to_reach(res.trace, eps_target)
        rows.append({"n": n, "time_to_eps": tta,
                     "final_F": res.trace.fvals[-1]})
        if verbose:
            print(f"[fig1] n={n:2d} time_to_eps={tta:9.3f} "
                  f"final_F={res.trace.fvals[-1]:9.3f}", flush=True)

    finite = [row for row in rows if np.isfinite(row["time_to_eps"])]
    best_n = (min(finite, key=lambda row: row["time_to_eps"])["n"]
              if finite else -1)
    summary = {"r": r, "t_grad_s": t_grad, "n_opt_theory": nopt,
               "n_best_observed": best_n, "eps_target": eps_target}
    if verbose:
        print(f"[fig1L] r={r:.4f} n_opt(theory)={nopt:.1f} "
              f"best observed n={best_n}")
    return rows, summary


if __name__ == "__main__":
    run()
