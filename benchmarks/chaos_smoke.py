"""Chaos smoke: the manifest mix replayed through a pooled server while
a scripted ChaosPlan SIGKILLs a worker mid-lane and the TCP proxy in
front of the server tears one response and drops one connection.

This is the CI chaos-smoke job's driver and the end-to-end robustness
acceptance check in one script:

  * server: `ExperimentServer(processes=2)` -- real spawned worker
    processes with private compile caches, supervised and restarted;
  * chaos: `ChaosPlan(kill_at_dispatch=...)` delivers a SIGKILL to the
    worker that took dispatch #2, a beat after it started computing;
    `ChaosProxy` between client and server tears response line #6 in
    half and drops the connection carrying line #3;
  * client: retrying `Client` with auto idempotency keys -- every
    retry carries the same key, so the server joins/replays instead of
    re-running.

Acceptance (exit nonzero on any failure, never a silent pass):
  every request completes AND is bit-identical to a cold solo
  `repro.run()`; `worker_restarts >= 1` (the kill landed and the pool
  healed); no request executed twice (`max_executions_per_key <= 1`).

Artifacts land under --out: every served RunResult JSON plus
chaos_stats.json (plan, server/pool/chaos stats, proxy counters,
per-request identity verdicts) for post-mortem from the CI run.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from bench_serve import load_mixed_workload  # noqa: E402

import repro  # noqa: E402
from repro.serve import (ChaosPlan, ChaosProxy, Client,  # noqa: E402
                         ExperimentServer, comparable_result_dict)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="results/chaos_smoke")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--retries", type=int, default=5)
    ap.add_argument("--plan",
                    default=str(pathlib.Path(__file__).parent
                                / "chaos_plan.json"),
                    help="ChaosPlan JSON (same schema as the server's "
                         "--chaos-plan flag)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the plan's RNG seed")
    ap.add_argument("--full", action="store_true",
                    help="replay manifests at full T (default clamps "
                         "to T=60, the smoke discipline)")
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    pairs, skipped = load_mixed_workload(smoke=not args.full)
    for name, why in skipped.items():
        print(f"[chaos_smoke] skipping {name}: {why}")
    print(f"[chaos_smoke] replaying {len(pairs)} manifests "
          f"through {args.processes} workers under chaos")

    solos = {s.name: repro.run(s, backend=k) for s, k in pairs}

    plan_dict = json.loads(pathlib.Path(args.plan).read_text())
    if args.seed is not None:
        plan_dict["seed"] = args.seed
    plan = ChaosPlan.from_dict(plan_dict)
    print(f"[chaos_smoke] plan {args.plan}: {plan_dict}")
    per_request, failures = {}, []
    t0 = time.perf_counter()
    srv = ExperimentServer(workers=2, processes=args.processes,
                           max_wait_s=0.02, chaos=plan,
                           pool_kwargs={"backoff_base_s": 0.05})
    try:
        host, port = srv.start()
        with ChaosProxy(host, port, plan) as proxy:
            phost, pport = proxy.address
            with Client(phost, pport, timeout=240,
                        retries=args.retries, seed=11) as client:
                for s, k in pairs:
                    res = client.run(s, backend=k)
                    rt = repro.RunResult.from_json(res.to_json())
                    identical = (comparable_result_dict(rt)
                                 == comparable_result_dict(solos[s.name]))
                    per_request[s.name] = {
                        "identical": identical,
                        "client_retries_so_far": client.retries_used}
                    if not identical:
                        failures.append(f"{s.name}: served result "
                                        f"diverged from solo repro.run()")
                    (outdir / f"{s.name}.json").write_text(res.to_json())
            proxy_stats = proxy.stats()
        stats = srv.stats()
    finally:
        srv.close()
    wall = time.perf_counter() - t0

    rob = stats["robustness"]
    dedup = stats["dedup"]
    chaos = stats.get("chaos", {})
    checks = {
        "all_identical": all(v["identical"] for v in per_request.values()),
        "worker_restarts_ge_1": rob["worker_restarts"] >= 1,
        "kill_delivered": chaos.get("kills_delivered", 0) >= 1,
        "no_double_execution": dedup["max_executions_per_key"] <= 1,
        "proxy_dropped_connection": proxy_stats["dropped_connections"] >= 1,
        "proxy_tore_response": proxy_stats["torn_responses"] >= 1,
    }
    for name, ok in checks.items():
        print(f"[chaos_smoke] {name}: {'OK' if ok else 'FAIL'}")
        if not ok:
            failures.append(name)
    print(f"[chaos_smoke] {len(pairs)} requests healed in {wall:.2f}s: "
          f"restarts={rob['worker_restarts']} "
          f"reenqueues={rob['reenqueues']} "
          f"client_retries={rob['requests_retried']} "
          f"proxy={proxy_stats}")

    report = {
        "benchmark": "chaos_smoke",
        "mode": "full" if args.full else "smoke",
        "wall_s": round(wall, 3),
        "plan": plan.to_dict(),
        "per_request": per_request,
        "server_stats": stats,
        "proxy_stats": proxy_stats,
        "checks": checks,
        "failures": failures,
    }
    (outdir / "chaos_stats.json").write_text(json.dumps(report, indent=2))
    print(f"[chaos_smoke] wrote {outdir}/chaos_stats.json")
    if failures:
        print(f"[chaos_smoke] FAIL: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
