"""The paper's two experimental problems as JAX objectives for DDASimulator.

Metric learning (section V.A): learn PSD A and threshold b >= 1 minimizing
hinge losses on similar/dissimilar pairs; x = vec(A)|b is d^2+1 dimensional,
so the message size is quadratic in d -- the high-r regime.

Non-smooth minimization (section V.B): f_i(x) = sum_j max(||x-c1||^2,
||x-c2||^2) with node-specific centers, so consensus is ESSENTIAL for a
correct optimizer (single-node training converges to the wrong point).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import (metric_learning_pairs,
                                 nonsmooth_quadratic_problem, partition_rows)


# ---------------------------------------------------------------------------
# Metric learning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MetricLearning:
    """State vector layout: x = [vec(A) (d*d), b (1)]."""

    u: jnp.ndarray          # (m, d)
    v: jnp.ndarray          # (m, d)
    s: jnp.ndarray          # (m,)
    n_nodes: int

    @classmethod
    def build(cls, m_pairs: int, d: int, n_nodes: int, seed: int = 0):
        u, v, s = metric_learning_pairs(m_pairs, d, seed)
        return cls(jnp.asarray(u), jnp.asarray(v), jnp.asarray(s), n_nodes)

    @property
    def d(self) -> int:
        return self.u.shape[1]

    @property
    def dim(self) -> int:
        return self.d * self.d + 1

    def message_bytes(self) -> int:
        return self.dim * 8  # doubles, as in the paper (4.7 MB for d=784)

    def _split(self, x):
        d = self.d
        return x[: d * d].reshape(d, d), x[d * d]

    def loss_batch(self, x, u, v, s):
        A, b = self._split(x)
        diff = u - v
        dist2 = jnp.einsum("md,de,me->m", diff, A, diff)
        return jnp.maximum(0.0, s * (dist2 - b) + 1.0)

    def full_objective(self, x):
        return jnp.sum(self.loss_batch(x, self.u, self.v, self.s))

    def node_slices(self):
        # equal shard sizes (paper section II assumes n | m); the remainder
        # rows are dropped so the stacked per-node arrays are rectangular
        base = self.u.shape[0] // self.n_nodes
        return [slice(i * base, (i + 1) * base)
                for i in range(self.n_nodes)]

    def make_subgrad(self):
        """(x_stack (n, dim), t, key) -> g_stack; batch subgradient of f_i
        over node i's pairs (paper eq. 8: scaled by n/m per eq. 2 -- we use
        the unnormalized sum as in eq. 32 and fold constants into a(t))."""
        slices = self.node_slices()
        us = jnp.stack([self.u[sl] for sl in slices])
        vs = jnp.stack([self.v[sl] for sl in slices])
        ss = jnp.stack([self.s[sl] for sl in slices])
        d = self.d

        def node_grad(x, u, v, s):
            A, b = self._split(x)
            diff = u - v                                     # (ml, d)
            dist2 = jnp.einsum("md,de,me->m", diff, A, diff)
            active = (s * (dist2 - b) + 1.0) > 0.0           # (ml,)
            w = jnp.where(active, s, 0.0)
            gA = jnp.einsum("m,md,me->de", w, diff, diff)
            gb = -jnp.sum(w)
            return jnp.concatenate([gA.reshape(-1), gb[None]])

        def subgrad(x_stack, t, key):
            return jax.vmap(node_grad)(x_stack, us, vs, ss)

        return subgrad

    def projection(self, x_stack):
        """Project each node's A to PSD and b to [1, inf) (paper V.A)."""
        d = self.d

        def one(x):
            A = x[: d * d].reshape(d, d)
            A = 0.5 * (A + A.T)
            evals, evecs = jnp.linalg.eigh(A)
            A = (evecs * jnp.maximum(evals, 0.0)) @ evecs.T
            b = jnp.maximum(x[d * d], 1.0)
            return jnp.concatenate([A.reshape(-1), b[None]])

        return jax.vmap(one)(x_stack)


# ---------------------------------------------------------------------------
# Non-smooth quadratics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class NonsmoothQuadratics:
    centers: jnp.ndarray    # (n, M, 2, d)

    @classmethod
    def build(cls, n_nodes: int, M: int, d: int, seed: int = 0,
              center_scale: float = 1.0):
        return cls(jnp.asarray(
            nonsmooth_quadratic_problem(n_nodes, M, d, seed, center_scale)))

    @property
    def dim(self) -> int:
        return self.centers.shape[-1]

    def message_bytes(self) -> int:
        return self.dim * 8

    def node_value(self, x, node_centers):
        diff = x[None, None, :] - node_centers        # (M, 2, d)
        q = jnp.sum(diff * diff, axis=-1)             # (M, 2)
        return jnp.sum(jnp.max(q, axis=-1))

    def full_objective(self, x):
        return jnp.mean(jax.vmap(lambda c: self.node_value(x, c))(
            self.centers))

    def make_subgrad(self):
        def node_grad(x, c):
            return jax.grad(self.node_value)(x, c)

        def subgrad(x_stack, t, key):
            return jax.vmap(node_grad)(x_stack, self.centers)

        return subgrad

    def optimum_value(self, iters: int = 3000, lr: float = None) -> float:
        """Reference F* via centralized subgradient descent."""
        x = jnp.zeros(self.dim)
        obj = lambda y: self.full_objective(y)
        g = jax.jit(jax.grad(obj))
        val = jax.jit(obj)
        best = float(val(x))
        M = self.centers.shape[1]
        lr0 = 1.0 / (4.0 * M) if lr is None else lr
        for t in range(1, iters + 1):
            x = x - (lr0 / np.sqrt(t)) * g(x)
            if t % 100 == 0:
                best = min(best, float(val(x)))
        return best
