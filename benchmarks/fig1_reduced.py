"""Paper Fig. 1 (right): the LOW-r regime -- smaller messages make larger
clusters optimal (paper: PCA 784 -> 87 dims cut r to 0.005, n_opt = 14.15,
near-linear speedup to 14 nodes).

The law being reproduced is n_opt = 1/sqrt(r) as r shrinks. The paper
shrinks r by PCA-ing the PROBLEM (messages stay exact but are (87^2+1)/
(784^2+1) ~ 1.2% the size); our synthetic data carries no usable PCA
structure, so we apply the same message-byte ratio to the measured r
directly and run DDA with exact mixing -- identical time-model semantics.
(Lossy top-k+EF message compression is the beyond-paper alternative; it is
exercised in benchmarks/fig1_complete.run(compress_keep=...) and unit
tested for convergence in tests/test_dda.py.)

Like fig1_complete, every cell is an `ExperimentSpec` through `repro.run()`
(this driver only rescales the measured r before delegating);
benchmarks/manifests/fig1_reduced.json checks in the low-r smoke cell.
"""

from __future__ import annotations

from benchmarks import fig1_complete

PCA_BYTE_RATIO = (87 * 87 + 1) / (784 * 784 + 1)  # the paper's reduction


def run(m_pairs: int = 200_000, d: int = 24, n_max: int = 14, T: int = 300,
        seed: int = 0, verbose: bool = True):
    base = fig1_complete.measure_r(m_pairs, d, seed,
                                   fig1_complete.PAPER_ETHERNET_BPS)[0]
    return fig1_complete.run(
        m_pairs=m_pairs, d=d, n_max=n_max, T=T, seed=seed, verbose=verbose,
        r_override=base * PCA_BYTE_RATIO)


if __name__ == "__main__":
    run()
