"""Paper section V.A: distributed metric learning (Fig. 1 reproduction).

    PYTHONPATH=src:. python examples/metric_learning.py [--full]

Measures the communication/computation tradeoff r on THIS machine, predicts
n_opt = 1/sqrt(r) (eq. 11), then sweeps cluster sizes on the complete graph
and reports the observed optimum. `--full` uses the larger problem
(~2 minutes); default is a quick demo.
"""

import sys

from benchmarks import fig1_complete, fig1_reduced


def main():
    full = "--full" in sys.argv
    m = 200_000 if full else 40_000
    T = 300 if full else 150
    nmax = 14 if full else 10
    print("=== complete graph, measured r (paper Fig 1 left) ===")
    rows, s = fig1_complete.run(m_pairs=m, d=24, n_max=nmax, T=T)
    print(f"r={s['r']:.4f}  n_opt={s['n_opt_theory']:.1f}  "
          f"observed best n={s['n_best_observed']}")
    print("=== compressed messages: low-r regime (paper Fig 1 right) ===")
    rows, s = fig1_reduced.run(m_pairs=m, d=24, n_max=nmax, T=T)
    print(f"r={s['r']:.5f}  n_opt={s['n_opt_theory']:.1f}  "
          f"observed best n={s['n_best_observed']}")


if __name__ == "__main__":
    main()
