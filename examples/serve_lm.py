"""Batched-decode serving example: prefill + token-by-token generation with
the KV-cache serve_step on a (data=2, model=4) mesh of host devices.

    python examples/serve_lm.py [--batch 8] [--gen 32] [--arch llama3-8b]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_mesh
from repro.launch.steps import make_serve_step
from repro.models import registry, transformer
from repro.runtime import sharding as shrules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=registry.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, "smoke")
    mesh = make_mesh((2, 4), ("data", "model"))
    max_seq = args.prompt_len + args.gen

    with shrules.use_rules(shrules.DEFAULT_RULES, mesh):
        params, _ = transformer.init(jax.random.PRNGKey(0), cfg)
        cache = transformer.init_cache(cfg, args.batch, max_seq)
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

        key = jax.random.PRNGKey(1)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        # prefill token-by-token (simple; a production prefill would batch)
        tok = prompt[:, :1]
        for pos in range(args.prompt_len):
            logits, cache = serve(params, cache,
                                  prompt[:, pos:pos + 1], jnp.int32(pos))
        # greedy generation
        out = []
        t0 = time.perf_counter()
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        for i in range(args.gen):
            logits, cache = serve(params, cache, tok,
                                  jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"[serve_lm] arch={cfg.name} generated {args.gen} tokens x "
          f"batch {args.batch} in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s on CPU)")
    print("[serve_lm] sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
