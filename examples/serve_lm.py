"""Serving example: LM dryrun + dense consensus traffic through the
`repro.serve` client against a persistent experiment server.

Boots an in-process `ExperimentServer` (TCP on a free localhost port),
connects the thin JSON-lines `Client`, and replays a small mixed
workload that exercises each serving path:

  1. the `launch_dryrun` LM manifest (llama3-8b smoke plan) -- routed
     solo, since the compile cache amortizes dense scan programs only;
     the server says why on the result's `solo_reason` metrics note;
  2. a dense consensus manifest submitted cold then warm -- the second
     request leases the already-compiled `DDASimulator` from the
     compile cache and skips trace+lower+compile entirely;
  3. a burst of seed-variants of that dense spec -- the lane packer
     holds them briefly and flushes one vmapped `run_batch` lane, so
     the burst costs a single dispatch;
  4. the same request submitted twice concurrently under ONE
     idempotency key -- the server joins the duplicate onto the
     in-flight run (`requests_retried` ticks, `max_executions_per_key`
     stays 1) and both callers get the identical result, which is what
     makes `Client(retries=N)` safe: a retried request never runs
     twice.

Every streamed protocol event (accepted, trace chunks, result) passes
through `Client.run(on_event=...)`, printed here as a progress line.

    python examples/serve_lm.py [--burst 4] [--skip-lm]
"""

from __future__ import annotations

import argparse
import pathlib
import time

import repro
from repro.serve import Client, ExperimentServer, ServeError

MANIFESTS = (pathlib.Path(__file__).resolve().parents[1]
             / "benchmarks" / "manifests")


def _progress(tag: str):
    def on_event(ev: dict) -> None:
        kind = ev.get("event")
        if kind == "accepted":
            print(f"  [{tag}] accepted: {ev.get('name')}")
        elif kind == "trace":
            print(f"  [{tag}] trace rows {ev['lo']}..{ev['hi']} "
                  f"of {ev['total']}")
    return on_event


def _report(tag: str, result: repro.RunResult, wall: float) -> None:
    c = result.metrics.counters
    hit = ("hit" if c.get("cache_hit")
           else "miss" if c.get("cache_miss") else "n/a")
    line = (f"  [{tag}] wall={wall:.3f}s cache={hit} "
            f"lane_width={c.get('lane_width', 1):.0f} "
            f"queue_wait={c.get('queue_wait_s', 0.0) * 1e3:.0f}ms")
    reason = result.metrics.notes.get("solo_reason")
    if reason:
        line += f"\n  [{tag}] solo: {reason}"
    print(line)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="mixed LM + dense workload through repro.serve")
    ap.add_argument("--burst", type=int, default=4,
                    help="seed-variants packed into one vmap lane")
    ap.add_argument("--skip-lm", action="store_true",
                    help="skip the launch_dryrun LM request")
    args = ap.parse_args(argv)

    dense = repro.ExperimentSpec.from_file(
        MANIFESTS / "expander_periodic.json")
    lm = repro.ExperimentSpec.from_file(MANIFESTS / "launch_dryrun.json")

    with ExperimentServer(workers=2, max_width=max(args.burst, 2),
                          max_wait_s=0.25) as srv:
        host, port = srv.start()
        print(f"[serve_lm] server on {host}:{port}")
        with Client(host, port) as c:
            assert c.ping()

            if not args.skip_lm:
                print(f"[serve_lm] 1. LM dryrun ({lm.name}): solo route")
                t0 = time.perf_counter()
                res = c.run(lm, on_event=_progress("lm"))
                _report("lm", res, time.perf_counter() - t0)
                print(f"  [lm] plan: arch={res.extras['arch']} "
                      f"mesh={res.extras['mesh']} "
                      f"comm_rounds={res.extras['comm_rounds']}")

            print(f"[serve_lm] 2. dense cold vs warm ({dense.name})")
            t0 = time.perf_counter()
            cold = c.run(dense, backend="dense",
                         on_event=_progress("cold"))
            cold_wall = time.perf_counter() - t0
            _report("cold", cold, cold_wall)
            t0 = time.perf_counter()
            warm = c.run(dense, backend="dense")
            warm_wall = time.perf_counter() - t0
            _report("warm", warm, warm_wall)
            same = warm.trace.fvals[-1] == cold.trace.fvals[-1]
            print(f"  [warm] final F={warm.trace.fvals[-1]:.6f} "
                  f"(== cold: {same}), "
                  f"speedup {cold_wall / warm_wall:.1f}x")

            print(f"[serve_lm] 3. burst of {args.burst} seed-variants "
                  f"-> one packed lane")
            # separate connections so the requests are concurrent: one
            # Client blocks per run, which would serialize the burst
            clients = [Client(host, port) for _ in range(args.burst)]
            try:
                for i, cc in enumerate(clients):
                    cc._send({"op": "run", "backend": "dense",
                              "spec": dense.with_value("seed", 100 + i)
                              .to_dict()})
                t0 = time.perf_counter()
                for i, cc in enumerate(clients):
                    res = _drain(cc)
                    _report(f"burst {i}", res, time.perf_counter() - t0)
            finally:
                for cc in clients:
                    cc.close()

            print("[serve_lm] 4. duplicate submit, one idempotency key "
                  "-> one execution")
            dup = [Client(host, port) for _ in range(2)]
            try:
                for cc in dup:
                    cc._send({"op": "run", "backend": "dense",
                              "spec": dense.with_value("seed", 999)
                              .to_dict(),
                              "idempotency_key": "serve-lm-demo"})
                twins = [_drain(cc) for cc in dup]
            finally:
                for cc in dup:
                    cc.close()
            same = (twins[0].trace.fvals[-1] == twins[1].trace.fvals[-1])
            print(f"  [dedup] both callers answered, identical: {same}")

            stats = c.stats()
            print(f"[serve_lm] cache: {stats['cache']['entries']} entries, "
                  f"{stats['cache']['hits']} hits / "
                  f"{stats['cache']['misses']} misses; packer: "
                  f"{stats['packer']['packed_requests']} packed into "
                  f"{stats['packer']['lanes_flushed']} lanes "
                  f"(occupancy {stats['packer']['occupancy']:.2f})")
            print(f"[serve_lm] robustness: "
                  f"{stats['robustness']['requests_retried']} dedup "
                  f"joins/replays, max executions per key "
                  f"{stats['dedup']['max_executions_per_key']}")
            c.shutdown()
    print("[serve_lm] done")
    return 0


def _drain(c: Client) -> repro.RunResult:
    """Finish one already-submitted run on a raw client connection."""
    columns: dict[str, list] = {}
    while True:
        ev = c._recv()
        kind = ev.get("event")
        if kind == "trace":
            for f, col in ev["columns"].items():
                columns.setdefault(f, []).extend(col)
        elif kind == "result":
            d = ev["result"]
            d["trace"] = columns
            return repro.RunResult.from_dict(d)
        elif kind == "error":
            raise ServeError(ev.get("error", "?"), ev.get("type", "?"))


if __name__ == "__main__":
    raise SystemExit(main())
