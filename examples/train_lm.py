"""End-to-end driver: train a ~100M-parameter LM with CONSENSUS data
parallelism (the paper's technique at the pod level) for a few hundred
steps, with checkpoint/restart.

    python examples/train_lm.py [--steps 300] [--topology complete]
                                [--schedule sparse|periodic|every]
                                [--arch llama3-8b] [--resume]

Uses 8 host CPU devices as a (pod=2, data=2, model=2) mesh: 2 consensus
nodes, each an FSDP+TP group -- the same program structure the dry-run
compiles for (2, 16, 16). The model is a depth/width-reduced llama3-style
config (~100M params).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

import jax

from repro.core.schedules import make_schedule
from repro.launch.mesh import make_mesh
from repro.launch.train import train_consensus_lm
from repro.models import registry
from repro.optim import adamw, warmup_cosine


def build_100m(arch: str):
    """Width/depth-reduced config of the chosen arch family, ~100M params."""
    full = registry.get_config(arch, "full")
    return dataclasses.replace(
        full, name=full.name + "-100m", d_model=512,
        num_heads=8, num_kv_heads=max(1, min(full.num_kv_heads, 8)),
        head_dim=64, d_ff=2048, n_super=min(full.n_super, 10),
        vocab_size=32000, moe_experts=min(full.moe_experts, 8) if
        full.moe_experts else 0, moe_top_k=min(full.moe_top_k, 2) if
        full.moe_top_k else 0, moe_d_ff=512 if full.moe_experts else 0,
        train_microbatches=1,
        num_encoder_tokens=min(full.num_encoder_tokens, 16) or 0,
        encoder_dim=min(full.encoder_dim, 512) or 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama3-8b", choices=registry.ARCH_IDS)
    ap.add_argument("--topology", default="complete")
    ap.add_argument("--schedule", default="sparse",
                    choices=("every", "periodic", "sparse"))
    ap.add_argument("--h", type=int, default=4)
    ap.add_argument("--p", type=float, default=0.3)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--batch-per-node", type=int, default=8)
    args = ap.parse_args()

    cfg = build_100m(args.arch)
    n_params_est = None
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    sched = make_schedule(args.schedule, h=args.h, p=args.p)
    print(f"[train_lm] arch={cfg.name} schedule={args.schedule} "
          f"topology={args.topology} mesh=(2,2,2)")
    report = train_consensus_lm(
        cfg, adamw(warmup_cosine(3e-3, 20, args.steps)), mesh,
        steps=args.steps, schedule=sched, topology=args.topology,
        batch_per_node=args.batch_per_node, ckpt_dir=args.ckpt,
        ckpt_every=100, log_every=20)
    print(f"[train_lm] done: loss {report.losses[0]:.3f} -> "
          f"{report.losses[-1]:.3f}; comm rounds {report.comm_rounds}/"
          f"{report.steps}; sim time {report.sim_time_units:.1f} units"
          + (f"; resumed from step {report.resumed_from}"
             if report.resumed_from else ""))


if __name__ == "__main__":
    main()
