"""Quickstart: one declarative spec, swept across topologies and schedules.

    PYTHONPATH=src python examples/quickstart.py

Solves a distributed least-squares problem with 16 consensus nodes through
the unified experiment API (`repro.ExperimentSpec` -> `repro.run()`),
printing time-to-accuracy for complete graph vs k-regular expander vs ring,
at h=1 and with the paper's increasingly-sparse t^0.3 schedule. The grid is
two `run_sweep` calls over the same base spec -- compare with the
hand-wired loops this file had before the experiments API existed.
"""

import jax.numpy as jnp
import numpy as np

import repro
from repro.core import h_opt_int, n_opt_complete
from repro.core.dda import trace_time_to_reach
from repro.experiments.components import problems, topologies


def main():
    n, d, m_per_node, seed = 16, 64, 200, 0
    r = 0.02  # assumed comm/compute tradeoff for this demo

    # build the problem once (the registry is deterministic: the specs
    # below rebuild the exact same instance per run) to derive the target
    # and the paper's stepsize scale A = R/(L sqrt(31)) from measured L
    prob = problems.build("least_squares", n=n, d=d,
                          m_per_node=m_per_node, seed=seed)
    f_star = prob.fstar
    target = 1.5 * f_star
    g0 = prob.subgrad_stack(jnp.zeros((n, d)), 0, None)
    L = float(jnp.mean(jnp.linalg.norm(g0, axis=1)))
    A_scale = 24.0 / (L * np.sqrt(31.0))
    print(f"r={r} -> n_opt(complete)={n_opt_complete(r):.1f}, "
          f"h_opt(n=16,k=4 expander)={h_opt_int(16, 4, r, 0.36)}; "
          f"F*={f_star:.2f}")

    base = repro.ExperimentSpec(
        name="quickstart",
        problem={"kind": "least_squares",
                 "params": {"n": n, "d": d, "m_per_node": m_per_node,
                            "seed": seed}},
        topology={"kind": "complete"},
        schedule={"kind": "every"},
        backends=[{"kind": "dense"}],
        stepsize={"kind": "sqrt", "params": {"A": A_scale}},
        T=800, eval_every=50, seed=seed, r=r)

    for topo in ("complete", "expander", "ring"):
        spec_t = base.with_value("topology.kind", topo)
        g = topologies.build(topo, n=n)
        for sched_name, sched in (("h1", {"kind": "every"}),
                                  ("t^0.3", {"kind": "sparse",
                                             "params": {"p": 0.3}})):
            res = repro.run(repro.ExperimentSpec.from_dict(
                {**spec_t.to_dict(), "schedule": sched}))
            tr = res.trace
            tta = trace_time_to_reach(tr, target)
            print(f"{topo:10s} {sched_name:6s} k={g.degree:2d} "
                  f"lam2={g.lambda2():.3f} comms={tr.comms[-1]:4d} "
                  f"time_to_1.5F*={tta:8.2f} final_F={tr.fvals[-1]:.4f}")


if __name__ == "__main__":
    main()
