"""Quickstart: distributed dual averaging on a convex problem, comparing
communication topologies and schedules in the paper's time model.

    PYTHONPATH=src python examples/quickstart.py

Solves a distributed least-squares problem with 16 consensus nodes and
prints time-to-accuracy for complete graph vs k-regular expander vs ring,
at h=1 and with the paper's increasingly-sparse t^0.3 schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DDASimulator, EveryIteration, IncreasinglySparse,
                        build_graph, h_opt_int, n_opt_complete)


def main():
    n, d, m_per_node = 16, 64, 200
    rng = np.random.default_rng(0)
    # node-specific least squares: f_i(x) = ||A_i x - b_i||^2, solutions
    # differ per node so consensus is required.
    A = jnp.asarray(rng.normal(size=(n, m_per_node, d)) / np.sqrt(d))
    x_true = jnp.asarray(rng.normal(size=(d,)))
    b = jnp.einsum("nmd,d->nm", A, x_true) + jnp.asarray(
        rng.normal(scale=0.1 + 0.5 * rng.random((n, 1)),
                   size=(n, m_per_node)))

    def subgrad(x_stack, t, key):
        res = jnp.einsum("nmd,nd->nm", A, x_stack) - b
        return 2.0 * jnp.einsum("nmd,nm->nd", A, res)

    def objective(x):
        res = jnp.einsum("nmd,d->nm", A, x) - b
        return jnp.mean(jnp.sum(res * res, axis=1))

    # centralized optimum for the accuracy target
    Af = np.asarray(A).reshape(n * m_per_node, d)
    bf = np.asarray(b).reshape(-1)
    x_star, *_ = np.linalg.lstsq(Af, bf, rcond=None)
    f_star = float(objective(jnp.asarray(x_star)))
    target = 1.5 * f_star
    # stepsize: the paper's A = R/(L sqrt(31)) scale with measured L
    g0 = subgrad(jnp.zeros((n, d)), 0, None)
    L = float(jnp.mean(jnp.linalg.norm(g0, axis=1)))
    A_scale = 24.0 / (L * np.sqrt(31.0))
    r = 0.02  # assumed comm/compute tradeoff for this demo
    print(f"r={r} -> n_opt(complete)={n_opt_complete(r):.1f}, "
          f"h_opt(n=16,k=4 expander)={h_opt_int(16, 4, r, 0.36)}; "
          f"F*={f_star:.2f}")

    for topo in ("complete", "expander4", "ring"):
        for sched_name, sched in (("h1", EveryIteration()),
                                  ("t^0.3", IncreasinglySparse(p=0.3))):
            g = build_graph(topo, n)
            sim = DDASimulator(subgrad, jax.jit(objective), g, sched,
                               a_fn=lambda t: A_scale / jnp.sqrt(t), r=r)
            tr = sim.run(jnp.zeros((n, d)), 800, eval_every=50)
            tta = sim.time_to_reach(tr, target)
            print(f"{topo:10s} {sched_name:6s} k={g.degree:2d} "
                  f"lam2={g.lambda2():.3f} comms={tr.comms[-1]:4d} "
                  f"time_to_1.5F*={tta:8.2f} final_F={tr.fvals[-1]:.4f}")


if __name__ == "__main__":
    main()
