"""[beyond paper] Asynchronous cluster simulation with empirical r recovery.

    PYTHONPATH=src:. python examples/async_cluster.py

Runs the paper's non-smooth problem (section V.B) on a simulated 8-node
expander cluster under four conditions -- ideal, 20% packet loss, one 4x
straggler, and a topology rewired every 2 time units -- then closes the
loop the way the paper does on its real cluster: measure r from the
observed event timeline and derive n_opt (eq. 11), h_opt (eq. 21) and
tau(eps) (eq. 10) from the measurement.
"""

import numpy as np

from benchmarks.fig_async import (build_problem, centralized_optimum,
                                  run_cell)
from repro.core import EveryIteration
from repro.netsim import (homogeneous, lossy, straggler,
                          time_varying_expander)


def main():
    n, M, d, r, T = 8, 30, 20, 0.01, 1000
    centers, grad_fn, eval_fn = build_problem(n, M, d, seed=0)
    fstar = centralized_optimum(centers)
    f0 = eval_fn(np.zeros(d))
    eps_value = fstar + 0.05 * (f0 - fstar)
    common = dict(d=d, schedule=EveryIteration(), T=T, eval_every=2,
                  seed=0, a_scale=1.0 / (4.0 * M))

    scenarios = [
        homogeneous(n, r, seed=0),
        lossy(n, r, loss=0.2, seed=0),
        straggler(n, r, slow_factor=4.0, seed=0),
        time_varying_expander(n, r, rewire_every=2.0, seed=0),
    ]
    print(f"F* = {fstar:.2f}; time-to-5%-gap target F <= {eps_value:.2f}\n")
    sims = []
    for sc in scenarios:
        sim, trace = run_cell(sc, grad_fn, eval_fn, **common)
        sims.append(sim)
        tta = sim.time_to_reach(trace, eps_value)
        print(f"{sc.name:18s} tta={tta:8.2f}  final_F={trace.fvals[-1]:8.2f} "
              f"comms={trace.comms[-1]:4d}  rewires={sim.rewires}")

    # closed loop: measured r -> the paper's design rules (the homogeneous
    # run above already holds the observed timeline)
    pred = sims[0].predict(eps=0.1)
    m = pred["measurement"]
    print(f"\nempirical r = {pred['r_empirical']:.5f} "
          f"(t_msg={m.t_msg:.4f}, t_grad_full={m.t_grad_full:.4f}, "
          f"{m.n_messages} msgs)")
    print(f"  -> n_opt (eq. 11) = {pred['n_opt']:.1f}")
    print(f"  -> h_opt (eq. 21) = {pred['h_opt']}")
    print(f"  -> tau(0.1) (eq. 10) = {pred['tau_eps']:.1f} time units")


if __name__ == "__main__":
    main()
