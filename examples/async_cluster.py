"""[beyond paper] Asynchronous cluster simulation with empirical r recovery.

    PYTHONPATH=src python examples/async_cluster.py

Runs the paper's non-smooth problem (section V.B) on a simulated 8-node
expander cluster under four conditions -- ideal, 20% packet loss, one 4x
straggler, and a topology rewired every 2 time units -- then closes the
loop the way the paper does on its real cluster: measure r from the
observed event timeline and derive n_opt (eq. 11), h_opt (eq. 21) and
tau(eps) (eq. 10) from the measurement.

Each condition is the SAME declarative spec with a different netsim
scenario component -- `repro.run()` returns the trace, the RMeasurement
and the closed-loop predictions in one `RunResult`.
"""

import math

import repro
from repro.experiments.components import problems


def main():
    n, M, d, r, T = 8, 30, 20, 0.01, 1000
    a_scale = 1.0 / (4.0 * M)
    base = repro.ExperimentSpec(
        name="async_cluster",
        problem={"kind": "nonsmooth",
                 "params": {"n": n, "M": M, "d": d, "seed": 0}},
        topology={"kind": "expander", "params": {"k": 4, "seed": 0}},
        schedule={"kind": "every"},
        backends=[{"kind": "netsim", "params": {"scenario": "homogeneous"}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": a_scale}},
        T=T, eval_every=2, seed=0, r=r, eps_frac=0.05)

    conditions = [
        {"scenario": "homogeneous"},
        {"scenario": "lossy", "loss": 0.2},
        {"scenario": "straggler", "slow_factor": 4.0},
        {"scenario": "time_varying", "rewire_every": 2.0},
    ]
    prob = problems.build("nonsmooth", n=n, M=M, d=d, seed=0)
    eps_value = prob.eps_value(0.05)
    print(f"F* = {prob.fstar:.2f}; time-to-5%-gap target "
          f"F <= {eps_value:.2f}\n")
    results = []
    for cond in conditions:
        spec = base.with_value("backends.0.params", dict(cond))
        if cond["scenario"] == "time_varying":
            spec = spec.with_value("topology.kind", "expander_sequence")
        res = repro.run(spec)
        results.append(res)
        tta = (math.inf if res.time_to_target is None
               else res.time_to_target)
        print(f"{res.extras['scenario']:18s} tta={tta:8.2f}  "
              f"final_F={res.trace.fvals[-1]:8.2f} "
              f"comms={res.trace.comms[-1]:4d}  "
              f"rewires={res.extras['rewires']}")

    # closed loop: measured r -> the paper's design rules (the homogeneous
    # run's RunResult already carries the measurement and the predictions)
    pred = results[0].predictions
    m = results[0].r_measurement
    print(f"\nempirical r = {pred['r_empirical']:.5f} "
          f"(t_msg={m.t_msg:.4f}, t_grad_full={m.t_grad_full:.4f}, "
          f"{m.n_messages} msgs)")
    print(f"  -> n_opt (eq. 11) = {pred['n_opt']:.1f}")
    print(f"  -> h_opt (eq. 21) = {pred['h_opt']}")
    print(f"  -> tau(0.1) (eq. 10) = {pred['tau_eps']:.1f} time units")


if __name__ == "__main__":
    main()
