"""Paper section V.B: non-smooth convex minimization with sparse
communication schedules (Fig. 2 reproduction).

    PYTHONPATH=src:. python examples/nonsmooth_consensus.py

Runs DDA with 10 nodes on a complete graph under four schedules
(h=1, h=2, t^0.3, t^1) and prints communication counts, final objective,
and time-to-accuracy in the paper's time model -- including the
h_opt = 1 prediction (eq. 21) and the p=1 divergence.
"""

from benchmarks import fig2_sparse


def main():
    _, summary = fig2_sparse.run()
    print("\nclaims:")
    print(f"  h_opt (eq. 21) = {summary['h_opt_theory']} (paper: 1)")
    for r, reg in summary["regimes"].items():
        ok_h2 = reg["h2"]["time_to_1pct"] >= reg["h1"]["time_to_1pct"]
        div_p1 = reg["p1"]["final_F"] > reg["h1"]["final_F"] * 1.01
        fewer = reg["p03"]["comms"] < reg["h2"]["comms"]
        print(f"  r={r}: h2 slower than h1: {ok_h2}; p=1 diverges: {div_p1}; "
              f"p=0.3 uses fewer comms than h=2: {fewer}")


if __name__ == "__main__":
    main()
