"""Regenerate the data tables in EXPERIMENTS.md from results/*.json.
Run after a dry-run sweep + roofline pass:

    PYTHONPATH=src python scripts/make_experiments_md.py
"""

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def dryrun_table() -> str:
    rows = []
    for p in sorted((ROOT / "results" / "dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        coll = sum(r["collectives"].values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['bytes_per_device']/2**30:.2f} | "
            f"{r['cost'].get('flops', 0):.3g} | {coll/2**30:.3f} | "
            f"{r['compile_s']:.0f}s |")
    hdr = ("| arch | shape | mesh | GiB/dev | HLO flops/dev* | "
           "coll GiB/dev* | compile |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    rows = []
    for p in sorted((ROOT / "results" / "roofline").glob("*.json")):
        r = json.loads(p.read_text())
        dom = {"compute": r["t_compute"], "memory": r["t_memory"],
               "collective": r["t_collective"]}[r["bottleneck"]]
        frac = r["t_compute"] / max(dom, 1e-12)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} | "
            f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
            f"{r['bottleneck']} | {frac:.2f} | {r['useful_ratio']:.2f} | "
            f"{r['mem_gib']:.1f} |")
    hdr = ("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "roofline frac | useful | GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table\n")
    print(roofline_table())
