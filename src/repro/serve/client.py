"""TCP client for `ExperimentServer`'s JSON-lines protocol.

    from repro.serve import Client

    with Client(host, port, retries=3) as c:
        result = c.run(spec)            # -> RunResult (trace reassembled
        print(c.stats()["cache"])       #    exactly from streamed chunks)

The transport never touches the payload: `run()` reassembles the
streamed trace chunks into the full `RunResult` byte-for-byte -- the
differential serving tests compare a round-tripped served result against
a local `repro.run()` with exact JSON equality.

Robustness (all opt-in; `retries=0` keeps the PR 8 dumb-client
behavior): transport failures (connection reset, torn response line,
timeout) and `Overloaded` rejections are retried with jittered
exponential backoff, honoring the server's retry-after hint. A retried
`run` auto-generates an idempotency key (unless one is supplied), so the
server dedups the retry against the original -- a request never executes
twice even when the first response was lost mid-stream. Per-op `timeout`
overrides beat the connect-time default, and `shutdown()` tolerates the
server closing the connection before the "bye" lands.
"""

from __future__ import annotations

import contextlib
import json
import random
import socket
import time
import uuid
from typing import Any, Callable

from repro.experiments.result import RunResult
from repro.experiments.spec import ExperimentSpec

__all__ = ["Client", "DeadlineExceededError", "OverloadedError",
           "ServeError", "ShuttingDownError"]


class ServeError(RuntimeError):
    """Server-reported failure (`error` event), with the remote type."""

    def __init__(self, error: str, remote_type: str = "Exception"):
        super().__init__(f"{remote_type}: {error}")
        self.remote_type = remote_type


class OverloadedError(ServeError):
    """Admission queue full; `retry_after_s` is the server's hint."""

    def __init__(self, error: str, remote_type: str = "Overloaded",
                 retry_after_s: float | None = None):
        super().__init__(error, remote_type)
        self.retry_after_s = retry_after_s


class DeadlineExceededError(ServeError):
    """The request's deadline passed server-side (shed or killed)."""


class ShuttingDownError(ServeError):
    """The server is draining and refused the request."""


_ERROR_TYPES: dict[str, type] = {
    "Overloaded": OverloadedError,
    "OverloadedError": OverloadedError,
    "DeadlineExceeded": DeadlineExceededError,
    "DeadlineExceededError": DeadlineExceededError,
    "ShuttingDown": ShuttingDownError,
    "ShuttingDownError": ShuttingDownError,
}


def _error_from_event(ev: dict) -> ServeError:
    remote = ev.get("type", "?")
    cls = _ERROR_TYPES.get(remote, ServeError)
    if cls is OverloadedError:
        return OverloadedError(ev.get("error", "?"), remote,
                               retry_after_s=ev.get("retry_after_s"))
    return cls(ev.get("error", "?"), remote)


#: transport-level failures a retrying run() treats as "response lost,
#: outcome unknown" -- safe to retry because the idempotency key dedups
_RETRYABLE = (ConnectionError, socket.timeout, OSError,
              json.JSONDecodeError)


class Client:
    """One socket, blocking calls; retries opt-in via `retries`.

    Args:
      timeout: connect-time socket timeout, the default for every op.
      retries: how many times `run()` re-submits after a transport
        failure or an `Overloaded` rejection (0 = never, PR 8 behavior).
      backoff_s / backoff_cap_s / jitter: retry delay is
        `min(cap, backoff_s * 2**attempt) * (1 + jitter * U[0,1))`,
        floored at the server's retry-after hint when one was given.
      seed: seeds the jitter RNG (deterministic chaos replays).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: float | None = 600.0, retries: int = 0,
                 backoff_s: float = 0.1, backoff_cap_s: float = 2.0,
                 jitter: float = 0.5, seed: int | None = None):
        self._host, self._port, self._timeout = host, port, timeout
        self.retries = retries
        self.backoff_s, self.backoff_cap_s = backoff_s, backoff_cap_s
        self.jitter = jitter
        self._rng = random.Random(seed)
        self.retries_used = 0
        self._sock: socket.socket | None = None
        self._rfile = None
        self._connect()

    # -- plumbing ------------------------------------------------------------

    def _connect(self) -> None:
        self._close_sock()
        self._sock = socket.create_connection((self._host, self._port),
                                              timeout=self._timeout)
        self._rfile = self._sock.makefile("rb")

    def _close_sock(self) -> None:
        if self._rfile is not None:
            with contextlib.suppress(OSError):
                self._rfile.close()
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
        self._rfile = self._sock = None

    @contextlib.contextmanager
    def _op_timeout(self, timeout: float | None):
        """Per-op socket timeout override; None keeps the default."""
        if timeout is None or self._sock is None:
            yield
            return
        old = self._sock.gettimeout()
        self._sock.settimeout(timeout)
        try:
            yield
        finally:
            if self._sock is not None:
                with contextlib.suppress(OSError):
                    self._sock.settimeout(old)

    def _send(self, obj: dict) -> None:
        self._sock.sendall((json.dumps(obj, allow_nan=False) + "\n")
                           .encode("utf-8"))

    def _recv(self) -> dict:
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        if not line.endswith(b"\n"):
            # a cut mid-line (torn response) surfaces as a partial read;
            # fail as a transport error so the retry path owns it
            raise ConnectionError("connection cut mid-response (torn line)")
        return json.loads(line)

    def close(self) -> None:
        self._close_sock()

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- ops -----------------------------------------------------------------

    def ping(self, timeout: float | None = None) -> bool:
        with self._op_timeout(timeout):
            self._send({"op": "ping"})
            ev = self._recv()
        return ev.get("event") == "pong"

    def stats(self, timeout: float | None = None) -> dict[str, Any]:
        with self._op_timeout(timeout):
            self._send({"op": "stats"})
            ev = self._recv()
        if ev.get("event") == "error":
            raise _error_from_event(ev)
        ev.pop("event", None)
        return ev

    def shutdown(self, timeout: float | None = None) -> None:
        """Ask the server to drain and exit. A server that closes the
        connection before (or instead of) the "bye" reply is a clean
        shutdown, not an error."""
        with self._op_timeout(timeout):
            try:
                self._send({"op": "shutdown"})
                self._recv()  # "bye"
            except (ConnectionError, socket.timeout, OSError):
                pass

    def run(self, spec: ExperimentSpec | dict, backend: Any = None,
            on_event: Callable[[dict], None] | None = None,
            timeout: float | None = None, deadline_s: float | None = None,
            idempotency_key: str | None = None,
            retries: int | None = None) -> RunResult:
        """Submit one spec and block for its RunResult.

        `on_event` (optional) sees every raw protocol event as it
        arrives -- accepted, each trace chunk, the final result -- for
        progress display. `timeout` overrides the socket timeout for
        this op; `deadline_s`/`idempotency_key` propagate server-side.
        `retries` overrides the client default for this call; when
        retrying without an explicit key, one is auto-generated so the
        server can dedup the retry against the original submission.
        """
        if retries is None:
            retries = self.retries
        key = idempotency_key
        if retries > 0 and key is None:
            key = uuid.uuid4().hex
        spec_dict = (spec.to_dict() if isinstance(spec, ExperimentSpec)
                     else dict(spec))
        last: Exception | None = None
        for attempt in range(retries + 1):
            if attempt > 0:
                self.retries_used += 1
                time.sleep(self._delay(attempt - 1, last))
                try:
                    self._connect()
                except OSError as e:
                    last = e
                    continue
            try:
                with self._op_timeout(timeout):
                    return self._run_once(spec_dict, backend, on_event,
                                          deadline_s, key)
            except OverloadedError as e:
                last = e
            except _RETRYABLE as e:
                last = e
            if attempt == retries:
                raise last
        raise last  # all attempts spent reconnecting

    def _delay(self, attempt: int, last: Exception | None) -> float:
        base = min(self.backoff_cap_s, self.backoff_s * 2 ** attempt)
        delay = base * (1.0 + self.jitter * self._rng.random())
        hint = getattr(last, "retry_after_s", None)
        if hint is not None:
            delay = max(delay, float(hint))
        return delay

    def _run_once(self, spec_dict: dict, backend: Any,
                  on_event: Callable[[dict], None] | None,
                  deadline_s: float | None, key: str | None) -> RunResult:
        msg: dict[str, Any] = {"op": "run", "spec": spec_dict}
        if backend is not None:
            msg["backend"] = (backend.to_dict()
                              if hasattr(backend, "to_dict") else backend)
        if deadline_s is not None:
            msg["deadline_s"] = deadline_s
        if key is not None:
            msg["idempotency_key"] = key
        self._send(msg)
        columns: dict[str, list] = {}
        while True:
            ev = self._recv()
            if on_event is not None:
                on_event(ev)
            kind = ev.get("event")
            if kind == "accepted":
                continue
            if kind == "trace":
                for f, col in ev["columns"].items():
                    columns.setdefault(f, []).extend(col)
                continue
            if kind == "result":
                d = ev["result"]
                d["trace"] = columns
                return RunResult.from_dict(d)
            if kind == "error":
                raise _error_from_event(ev)
            raise ServeError(f"unexpected event {kind!r}", "ProtocolError")
