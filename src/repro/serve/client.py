"""Thin TCP client for `ExperimentServer`'s JSON-lines protocol.

    from repro.serve import Client

    with Client(host, port) as c:
        result = c.run(spec)            # -> RunResult (trace reassembled
        print(c.stats()["cache"])       #    exactly from streamed chunks)

The client is deliberately dumb: one socket, blocking calls, no retries.
`run()` reassembles the streamed trace chunks into the full `RunResult`
byte-for-byte -- the differential serving tests compare a round-tripped
served result against a local `repro.run()` with exact JSON equality, so
the transport must not (and does not) touch the payload.
"""

from __future__ import annotations

import json
import socket
from typing import Any, Callable

from repro.experiments.result import RunResult
from repro.experiments.spec import ExperimentSpec

__all__ = ["Client", "ServeError"]


class ServeError(RuntimeError):
    """Server-reported failure (`error` event), with the remote type."""

    def __init__(self, error: str, remote_type: str = "Exception"):
        super().__init__(f"{remote_type}: {error}")
        self.remote_type = remote_type


class Client:
    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: float | None = 600.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("rb")

    # -- plumbing ------------------------------------------------------------

    def _send(self, obj: dict) -> None:
        self._sock.sendall((json.dumps(obj, allow_nan=False) + "\n")
                           .encode("utf-8"))

    def _recv(self) -> dict:
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return json.loads(line)

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- ops -----------------------------------------------------------------

    def ping(self) -> bool:
        self._send({"op": "ping"})
        ev = self._recv()
        return ev.get("event") == "pong"

    def stats(self) -> dict[str, Any]:
        self._send({"op": "stats"})
        ev = self._recv()
        if ev.get("event") == "error":
            raise ServeError(ev.get("error", "?"), ev.get("type", "?"))
        ev.pop("event", None)
        return ev

    def shutdown(self) -> None:
        self._send({"op": "shutdown"})
        self._recv()  # "bye"

    def run(self, spec: ExperimentSpec | dict, backend: str | None = None,
            on_event: Callable[[dict], None] | None = None) -> RunResult:
        """Submit one spec and block for its RunResult.

        `on_event` (optional) sees every raw protocol event as it
        arrives -- accepted, each trace chunk, the final result -- for
        progress display; return value is the reassembled RunResult.
        """
        spec_dict = (spec.to_dict() if isinstance(spec, ExperimentSpec)
                     else dict(spec))
        msg: dict[str, Any] = {"op": "run", "spec": spec_dict}
        if backend is not None:
            msg["backend"] = backend
        self._send(msg)
        columns: dict[str, list] = {}
        while True:
            ev = self._recv()
            if on_event is not None:
                on_event(ev)
            kind = ev.get("event")
            if kind == "accepted":
                continue
            if kind == "trace":
                for f, col in ev["columns"].items():
                    columns.setdefault(f, []).extend(col)
                continue
            if kind == "result":
                d = ev["result"]
                d["trace"] = columns
                return RunResult.from_dict(d)
            if kind == "error":
                raise ServeError(ev.get("error", "?"), ev.get("type", "?"))
            raise ServeError(f"unexpected event {kind!r}", "ProtocolError")
