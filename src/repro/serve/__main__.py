"""CLI for the experiment server.

    # boot a server (prints the bound address; --port 0 picks a free port)
    PYTHONPATH=src python -m repro.serve serve --port 7411 --workers 2

    # submit manifests to it (streams progress, optionally saves results)
    PYTHONPATH=src python -m repro.serve submit benchmarks/manifests/*.json \
        --port 7411 --backend dense --out results/serve --retries 3

    # observe / stop it
    PYTHONPATH=src python -m repro.serve stats --port 7411
    PYTHONPATH=src python -m repro.serve ping  --port 7411
    PYTHONPATH=src python -m repro.serve shutdown --port 7411

`serve --workers N` runs N supervised worker *processes* (crash restart,
re-enqueue, deadline kills); `--workers 0` (the default) keeps the
in-process execution path byte-for-byte. `--deadline-s` and
`--max-queue` bound per-request budget and admission; `--chaos-plan`
loads a seeded `ChaosPlan` JSON for fault drills.

`submit` writes each RunResult as `<out>/<name>__serve-<backend>.json` --
the same artifact shape as `python -m repro.experiments run --out`, so
`python -m repro.experiments trace` renders them unchanged.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.experiments.spec import ExperimentSpec
from repro.serve.client import Client, ServeError
from repro.serve.server import ExperimentServer


def _cmd_serve(args) -> int:
    chaos = None
    if args.chaos_plan:
        from repro.serve.chaos import ChaosPlan
        chaos = ChaosPlan.from_dict(
            json.loads(pathlib.Path(args.chaos_plan).read_text()))
    server = ExperimentServer(host=args.host, port=args.port,
                              workers=args.threads,
                              max_width=args.max_lane,
                              max_wait_s=args.max_wait,
                              cache_entries=args.cache_entries,
                              packing=not args.no_packing,
                              processes=args.workers,
                              deadline_s=args.deadline_s,
                              max_queue=args.max_queue,
                              chaos=chaos)
    host, port = server.start()
    mode = (f"workers={args.workers} procs" if args.workers
            else f"in-process threads={args.threads}")
    print(f"[serve] listening on {host}:{port} "
          f"({mode} max_lane={args.max_lane} max_wait={args.max_wait}s "
          f"deadline_s={args.deadline_s} max_queue={args.max_queue})",
          flush=True)
    if args.port_file:
        pathlib.Path(args.port_file).write_text(str(port))
    try:
        # serve until the TCP loop exits (a client `shutdown` op, which
        # calls server.close() and stops serve_forever)
        while server._tcp_thread is not None and \
                server._tcp_thread.is_alive():
            server._tcp_thread.join(timeout=1.0)
    except KeyboardInterrupt:
        print("[serve] interrupted; draining", flush=True)
    finally:
        server.close()
    print("[serve] stopped", flush=True)
    return 0


def _cmd_submit(args) -> int:
    out_dir = pathlib.Path(args.out) if args.out else None
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
    status = 0
    with Client(args.host, args.port, timeout=args.timeout,
                retries=args.retries) as client:
        for path in args.manifests:
            spec = ExperimentSpec.from_file(path)
            try:
                result = client.run(spec, backend=args.backend,
                                    deadline_s=args.deadline_s)
            except ServeError as e:
                print(f"[serve] {spec.name}: ERROR {e}")
                status = 1
                continue
            c = (result.metrics.counters if result.metrics else {}) or {}
            hit = ("hit" if c.get("cache_hit")
                   else "miss" if c.get("cache_miss") else "n/a")
            final = result.trace.fvals[-1] if result.trace.fvals else None
            print(f"[serve] {spec.name} on {result.backend.kind}: "
                  f"wall={result.wall_s:.3f}s cache={hit} "
                  f"lane_width={int(c.get('lane_width', 1))} "
                  f"queue_wait={c.get('queue_wait_s', 0.0):.3f}s "
                  f"final_F={'n/a' if final is None else f'{final:.4g}'}")
            if out_dir is not None:
                tag = args.backend or result.backend.kind
                p = out_dir / f"{spec.name}__serve-{tag}.json"
                p.write_text(result.to_json())
                print(f"[serve] wrote {p}")
    return status


def _cmd_stats(args) -> int:
    with Client(args.host, args.port, timeout=args.timeout) as client:
        print(json.dumps(client.stats(), indent=2, sort_keys=True))
    return 0


def _cmd_ping(args) -> int:
    with Client(args.host, args.port, timeout=args.timeout) as client:
        ok = client.ping()
    print(f"[serve] {args.host}:{args.port} "
          f"{'alive' if ok else 'NOT RESPONDING'}")
    return 0 if ok else 1


def _cmd_shutdown(args) -> int:
    with Client(args.host, args.port, timeout=args.timeout) as client:
        client.shutdown()
    print(f"[serve] asked {args.host}:{args.port} to shut down")
    return 0


def main(argv=None) -> int:
    # --host/--port/--timeout live on a parent parser attached to every
    # subcommand, so they are accepted in the natural position AFTER the
    # subcommand name (`... serve --port 0`, `... ping --port 7411`)
    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--host", default="127.0.0.1")
    common.add_argument("--port", type=int, default=7411)
    common.add_argument("--timeout", type=float, default=600.0,
                        help="client socket timeout (seconds)")

    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    servep = sub.add_parser("serve", help="boot a server (blocks)",
                            parents=[common])
    servep.add_argument("--workers", type=int, default=0,
                        help="supervised worker PROCESSES; 0 = run "
                             "in-process (byte-for-byte the classic path)")
    servep.add_argument("--threads", type=int, default=2,
                        help="in-process executor width (pool mode uses "
                             "these threads only for bookkeeping)")
    servep.add_argument("--deadline-s", type=float, default=None,
                        help="default per-request budget; expired work "
                             "is shed, not run")
    servep.add_argument("--max-queue", type=int, default=0,
                        help="bounded admission queue (0 = unbounded); "
                             "over-limit submits get a structured "
                             "overloaded error + retry-after hint")
    servep.add_argument("--chaos-plan", default=None,
                        help="path to a ChaosPlan JSON (fault drills)")
    servep.add_argument("--max-lane", type=int, default=4,
                        help="lane packer max width")
    servep.add_argument("--max-wait", type=float, default=0.05,
                        help="lane packer max wait (seconds)")
    servep.add_argument("--cache-entries", type=int, default=32)
    servep.add_argument("--no-packing", action="store_true")
    servep.add_argument("--port-file", default=None,
                        help="write the bound port here (for port 0)")
    servep.set_defaults(fn=_cmd_serve)

    submitp = sub.add_parser("submit", help="run manifests via a server",
                             parents=[common])
    submitp.add_argument("manifests", nargs="+",
                         help="ExperimentSpec JSON file(s)")
    submitp.add_argument("--backend", default=None,
                         help="backend kind override (default: the "
                              "manifest's first declared backend)")
    submitp.add_argument("--out", default=None,
                         help="directory for RunResult JSON artifacts")
    submitp.add_argument("--retries", type=int, default=0,
                         help="client retries with jittered backoff + "
                              "auto idempotency keys")
    submitp.add_argument("--deadline-s", type=float, default=None,
                         help="per-request deadline propagated "
                              "server-side")
    submitp.set_defaults(fn=_cmd_submit)

    sub.add_parser("stats", help="print server stats",
                   parents=[common]).set_defaults(fn=_cmd_stats)
    sub.add_parser("ping", help="liveness check",
                   parents=[common]).set_defaults(fn=_cmd_ping)
    sub.add_parser("shutdown", help="stop a server",
                   parents=[common]).set_defaults(fn=_cmd_shutdown)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
