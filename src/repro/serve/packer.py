"""Lane packer: batch shape-compatible specs from different requests.

PR 5 proved `DDASimulator.run_batch` lanes are bit-identical to solo
scanned runs; the sweep executor already exploits that *within* one
`run_sweep` call. The packer extends it *across requests*: dense specs
that would compile and dispatch the same vmapped program are held briefly
(`max_wait_s`) and flushed as one lane of up to `max_width`, so a burst of
shape-compatible traffic costs one dispatch instead of N.

Admission is an equivalence relation (symmetric + transitive by
construction -- it is equality of `lane_key`), so lanes are well-defined:

  * the spec must be individually batchable -- same predicate the sweep
    executor uses (`repro.experiments.runner.batch_compat_report`); when
    it is not, `lane_key` returns the human-readable reason, which the
    server surfaces as the request's `solo_reason` metrics note;
  * equal `_vmap_signature` -- identical outside the per-lane data fields
    (name, seed, r, schedule, eps_frac), i.e. one compiled program serves
    every lane;
  * equal all-comm bit: `run_batch` picks the cond-free program variant
    when EVERY lane's mask is all-True (`masks.all()`), and a solo run
    picks it per its own mask -- packing an all-comm spec with a sparse
    one would flip the variant and (while numerically fine) break the
    bit-identity contract the differential gates enforce. Keying the
    lane on the bit keeps packed and solo runs on the same program.

The packer is synchronous and clock-injectable (testable without a
server): `admit()` files a request, `pop_ready()` returns lanes that are
full or past their deadline, `flush()` drains everything.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.experiments.runner import (_build_schedule, _resolve_backend,
                                      _vmap_signature, batch_compat_report)
from repro.experiments.spec import ComponentSpec, ExperimentSpec

__all__ = ["Lane", "LanePacker", "lane_key"]


def lane_key(spec: ExperimentSpec, backend: ComponentSpec | int | str | None
             ) -> tuple[str | None, str | None]:
    """(key, None) when the spec can ride a packed lane, (None, reason)
    when it must run solo. Two specs pack together iff their keys are
    equal -- symmetric and transitive because it is string equality."""
    try:
        resolved = _resolve_backend(spec, backend)
        reason = batch_compat_report(spec, resolved)
        if reason is not None:
            return None, reason
        mask = np.asarray(_build_schedule(spec).comm_mask(0, spec.T),
                          dtype=bool)
        ac = bool(mask.all())
        return json.dumps([_vmap_signature(spec, resolved), ac]), None
    except Exception as e:  # noqa: BLE001 -- a spec that does not even
        # validate must not poison the dispatcher; route it solo, where
        # the ordinary run path raises the real error to the requester
        return None, f"spec does not validate for lane packing: {e}"


@dataclass
class Lane:
    """One flush unit: requests that will run as a single `run_batch`."""

    key: str
    items: list[Any] = field(default_factory=list)
    opened_at: float = 0.0

    @property
    def width(self) -> int:
        return len(self.items)


class LanePacker:
    """Max-wait / max-width admission over `lane_key`-keyed lanes.

    Single-consumer discipline: the server's dispatcher thread is the only
    caller, so no internal locking. `clock` is injectable for tests.
    """

    def __init__(self, max_width: int = 8, max_wait_s: float = 0.05,
                 clock: Callable[[], float] = time.monotonic):
        if max_width < 1:
            raise ValueError("max_width must be >= 1")
        if max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        self.max_width = max_width
        self.max_wait_s = max_wait_s
        self.clock = clock
        self._open: dict[str, Lane] = {}
        self.packed_requests = 0  # admitted into lanes that flushed at >1
        self.lanes_flushed = 0
        self.widths: list[int] = []  # width of every flushed lane

    def admit(self, key: str, item: Any) -> None:
        lane = self._open.get(key)
        if lane is None:
            lane = self._open[key] = Lane(key=key, opened_at=self.clock())
        lane.items.append(item)

    def pop_ready(self, now: float | None = None) -> list[Lane]:
        """Lanes that must flush: at max_width, or open past max_wait_s."""
        now = self.clock() if now is None else now
        ready = [lane for lane in self._open.values()
                 if lane.width >= self.max_width
                 or now - lane.opened_at >= self.max_wait_s]
        for lane in ready:
            del self._open[lane.key]
            self._account(lane)
        return ready

    def flush(self) -> list[Lane]:
        """Drain every open lane regardless of age (shutdown path)."""
        lanes = list(self._open.values())
        self._open.clear()
        for lane in lanes:
            self._account(lane)
        return lanes

    def next_deadline(self) -> float | None:
        """Earliest instant an open lane expires; None when all idle."""
        if not self._open:
            return None
        return min(lane.opened_at + self.max_wait_s
                   for lane in self._open.values())

    def _account(self, lane: Lane) -> None:
        self.lanes_flushed += 1
        self.widths.append(lane.width)
        if lane.width > 1:
            self.packed_requests += lane.width

    def stats(self) -> dict[str, Any]:
        widths = self.widths
        return {
            "lanes_flushed": self.lanes_flushed,
            "packed_requests": self.packed_requests,
            "mean_width": (sum(widths) / len(widths)) if widths else 0.0,
            "max_width": self.max_width,
            "occupancy": ((sum(widths) / (len(widths) * self.max_width))
                          if widths else 0.0),
            "open_lanes": len(self._open),
        }
