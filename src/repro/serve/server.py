"""`ExperimentServer`: the persistent multi-tenant run server.

Architecture (all stdlib):

    TCP clients ──> ThreadingTCPServer (JSON lines)──┐
                                                     v
    in-process submit() ────────────────> request queue
                                                     │ dispatcher thread
                                 ┌───────────────────┤
                                 v                   v
                          LanePacker (dense,   solo requests
                          shape-compatible)         │
                                 │ full / expired   │
                                 v                   v
                            worker pool (ThreadPoolExecutor)
                                 │
                  CompileCache.lease -> warm DDASimulator
                                 │
                        Future[RunResult] -> stream back

Dense requests lease simulators from a `CompileCache` (so repeat traffic
skips trace+compile entirely) and, when shape-compatible with concurrent
traffic, ride one `run_batch` vmap lane (`LanePacker`); netsim/launch
requests run solo through the ordinary `repro.run()` path. Every response
carries the serving observability on its `RunMetrics`: `cache_hit`/
`cache_miss`, `queue_wait_s`, `lane_width`, `lane_occupancy` counters and
a `solo_reason` note when a dense request could not pack.

Wire protocol (one JSON object per line, strict RFC both directions --
requests parse through the frozen `ExperimentSpec` schema, responses are
`json_sanitize`d result dicts):

    -> {"op": "run", "spec": {...}, "backend": "dense"?}
    <- {"event": "accepted", "name": ...}
    <- {"event": "trace", "lo": 0, "hi": 256, "total": N,
        "columns": {"iters": [...], "fvals": [...], ...}}   (chunked)
    <- {"event": "result", "result": {...}}     (trace omitted: streamed)
    -> {"op": "ping"} / {"op": "stats"} / {"op": "shutdown"}
    <- {"event": "pong"} / {"event": "stats", ...} / {"event": "bye"}
    <- {"event": "error", "error": "...", "type": "ValueError"}
"""

from __future__ import annotations

import dataclasses
import json
import queue
import socketserver
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import numpy as np

from repro.experiments.runner import (_build_schedule, _dense_batch_results,
                                      _dense_parts, _dense_sim,
                                      _resolve_backend, _run_dense)
from repro.experiments.runner import run as _run
from repro.experiments.spec import ExperimentSpec
from repro.serve.cache import CompileCache
from repro.serve.packer import LanePacker, lane_key

__all__ = ["ExperimentServer", "TRACE_CHUNK_ROWS"]

#: rows per streamed trace chunk (a row = one evaluation point)
TRACE_CHUNK_ROWS = 256

_STOP = object()


@dataclasses.dataclass
class _Request:
    spec: ExperimentSpec
    backend: Any
    future: Future
    submitted: float
    solo_reason: str | None = None


class ExperimentServer:
    """Persistent run server; usable in-process (`submit`) or over TCP
    (`start` + `repro.serve.Client`).

    Args:
      host/port: TCP bind address (`port=0` picks a free port; read the
        real one from `start()`'s return or `.address`).
      workers: worker-pool width (each worker drives one run or lane).
      max_width / max_wait_s: lane-packer admission policy -- a lane
        flushes when `max_width` shape-compatible requests arrived or the
        oldest has waited `max_wait_s`.
      cache_entries: compile-cache capacity (warm simulators, LRU).
      packing: disable to force every request solo (the cache still
        applies); the differential tests use both modes.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 workers: int = 2, max_width: int = 4,
                 max_wait_s: float = 0.05, cache_entries: int = 32,
                 packing: bool = True):
        self.cache = CompileCache(max_entries=cache_entries)
        self.packer = LanePacker(max_width=max_width, max_wait_s=max_wait_s)
        self.packing = packing
        self._host, self._port = host, port
        self._queue: queue.Queue = queue.Queue()
        self._pool = ThreadPoolExecutor(max_workers=max(1, workers),
                                        thread_name_prefix="repro-serve")
        self._dispatcher: threading.Thread | None = None
        self._tcp: _TCPServer | None = None
        self._tcp_thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._closed = False
        self._started_at = time.monotonic()
        self.requests = 0
        self.errors = 0

    # -- lifecycle -----------------------------------------------------------

    @property
    def address(self) -> tuple[str, int] | None:
        return None if self._tcp is None else self._tcp.server_address[:2]

    def start(self) -> tuple[str, int]:
        """Bind the TCP front door and return (host, port)."""
        self._ensure_dispatcher()
        if self._tcp is None:
            self._tcp = _TCPServer((self._host, self._port), _Handler, self)
            self._tcp_thread = threading.Thread(
                target=self._tcp.serve_forever, name="repro-serve-tcp",
                daemon=True)
            self._tcp_thread.start()
        return self.address  # type: ignore[return-value]

    def close(self) -> None:
        """Stop accepting, drain open lanes, finish in-flight runs."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if self._tcp is not None:
            self._tcp.shutdown()
            self._tcp.server_close()
        if self._dispatcher is not None:
            self._queue.put(_STOP)
            self._dispatcher.join()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ExperimentServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ----------------------------------------------------------

    def submit(self, spec: ExperimentSpec | dict,
               backend: Any = None) -> "Future":
        """Enqueue one run; returns a Future resolving to its RunResult."""
        if isinstance(spec, dict):
            spec = ExperimentSpec.from_dict(spec)
        with self._lock:
            if self._closed:
                raise RuntimeError("server is closed")
            self.requests += 1
        self._ensure_dispatcher()
        req = _Request(spec=spec, backend=backend, future=Future(),
                       submitted=time.monotonic())
        self._queue.put(req)
        return req.future

    def stats(self) -> dict[str, Any]:
        return {
            "server": {"requests": self.requests, "errors": self.errors,
                       "uptime_s": time.monotonic() - self._started_at,
                       "packing": self.packing},
            "cache": self.cache.stats(),
            "packer": self.packer.stats(),
        }

    def _ensure_dispatcher(self) -> None:
        with self._lock:
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="repro-serve-dispatch",
                    daemon=True)
                self._dispatcher.start()

    # -- dispatch ------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            deadline = self.packer.next_deadline()
            timeout = (None if deadline is None
                       else max(deadline - time.monotonic(), 0.0))
            try:
                req = self._queue.get(timeout=timeout)
            except queue.Empty:
                req = None
            if req is _STOP:
                for lane in self.packer.flush():
                    self._pool.submit(self._run_lane, lane)
                return
            if req is not None:
                try:
                    self._route(req)
                except BaseException as e:  # noqa: BLE001 -- one bad
                    self._fail(req, e)  # request must not kill dispatch
            for lane in self.packer.pop_ready():
                self._pool.submit(self._run_lane, lane)

    def _route(self, req: _Request) -> None:
        if not self.packing:
            req.solo_reason = "packing disabled on this server"
            self._pool.submit(self._run_solo, req)
            return
        key, reason = lane_key(req.spec, req.backend)
        if key is None:
            req.solo_reason = reason
            self._pool.submit(self._run_solo, req)
        else:
            self.packer.admit(key, req)

    # -- execution (worker pool) ---------------------------------------------

    def _run_solo(self, req: _Request) -> None:
        queue_wait = time.monotonic() - req.submitted
        try:
            backend = _resolve_backend(req.spec, req.backend)
            if backend.kind == "dense":
                result = _run_dense(req.spec, backend, sim_cache=self.cache)
            else:
                result = _run(req.spec, backend=backend)
        except BaseException as e:  # noqa: BLE001 -- delivered to the client
            self._fail(req, e)
            return
        self._finish(req, result, width=1, queue_wait=queue_wait)

    def _run_lane(self, lane) -> None:
        reqs = lane.items
        if len(reqs) == 1:
            req = reqs[0]
            req.solo_reason = (req.solo_reason or
                               "lane flushed at width 1 (no shape-"
                               "compatible peer arrived within max_wait_s)")
            self._run_solo(req)
            return
        waits = [time.monotonic() - r.submitted for r in reqs]
        try:
            import jax.numpy as jnp
            specs = [r.spec for r in reqs]
            resolved = [_resolve_backend(r.spec, r.backend) for r in reqs]
            parts = _dense_parts(specs[0], resolved[0])
            problem, graph = parts["problem"], parts["graph"]
            schedules = [_build_schedule(c) for c in specs]
            masks = np.stack([s.comm_mask(0, specs[0].T) for s in schedules])
            with self.cache.lease(specs[0], resolved[0],
                                  lambda: _dense_sim(specs[0], parts)
                                  ) as (sim, hit):
                sim.schedule = schedules[0]
                sim.r = specs[0].r
                x0 = jnp.zeros((problem.n, problem.d))
                t0 = time.perf_counter()
                traces = sim.run_batch(x0, specs[0].T, specs[0].eval_every,
                                       masks, seeds=[c.seed for c in specs],
                                       rs=[c.r for c in specs])
                wall = time.perf_counter() - t0
                results = _dense_batch_results(
                    specs, resolved, sim, problem, graph, schedules,
                    traces, wall, lane_counter="lane_width")
        except BaseException as e:  # noqa: BLE001
            for req in reqs:
                self._fail(req, e)
            return
        for req, result, wait in zip(reqs, results, waits):
            self._finish(req, result, width=len(reqs), queue_wait=wait,
                         cache_hit=hit)

    def _finish(self, req: _Request, result, width: int, queue_wait: float,
                cache_hit: bool | None = None) -> None:
        """Attach the serve-side observability to the result's metrics.

        Everything added here is bookkeeping the differential gates
        exclude (`comparable_result_dict` strips metrics), so annotation
        can never perturb the scientific payload."""
        m = result.metrics
        if m is not None:
            counters = dict(m.counters)
            counters["queue_wait_s"] = queue_wait
            counters["lane_width"] = float(width)
            counters["lane_occupancy"] = width / self.packer.max_width
            if cache_hit is not None:
                counters["cache_hit" if cache_hit else "cache_miss"] = \
                    counters.get(
                        "cache_hit" if cache_hit else "cache_miss", 0) + 1
            notes = dict(m.notes)
            if req.solo_reason:
                notes["solo_reason"] = req.solo_reason
            result.metrics = dataclasses.replace(m, counters=counters,
                                                 notes=notes)
        if not req.future.done():
            req.future.set_result(result)

    def _fail(self, req: _Request, exc: BaseException) -> None:
        with self._lock:
            self.errors += 1
        if not req.future.done():
            req.future.set_exception(exc)


# ---------------------------------------------------------------------------
# TCP front door
# ---------------------------------------------------------------------------


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, handler, owner: ExperimentServer):
        self.owner = owner
        super().__init__(addr, handler)


class _Handler(socketserver.StreamRequestHandler):
    """One connection; any number of newline-delimited JSON ops."""

    def _send(self, obj: dict) -> None:
        line = json.dumps(obj, allow_nan=False) + "\n"
        self.wfile.write(line.encode("utf-8"))
        self.wfile.flush()

    def handle(self) -> None:
        server: ExperimentServer = self.server.owner  # type: ignore[attr-defined]
        for raw in self.rfile:
            raw = raw.strip()
            if not raw:
                continue
            try:
                msg = json.loads(raw)
                op = msg.get("op", "run")
                if op == "ping":
                    self._send({"event": "pong", "ok": True})
                elif op == "stats":
                    self._send({"event": "stats", **server.stats()})
                elif op == "shutdown":
                    self._send({"event": "bye"})
                    # shut down from a fresh thread: shutdown() joins the
                    # serve_forever loop, and this handler must first
                    # return its socket to it
                    threading.Thread(target=server.close,
                                     daemon=True).start()
                    return
                elif op == "run":
                    self._handle_run(server, msg)
                else:
                    self._send({"event": "error", "type": "ValueError",
                                "error": f"unknown op {op!r}"})
            except BrokenPipeError:
                return
            except Exception as e:  # noqa: BLE001 -- protocol surface
                try:
                    self._send({"event": "error",
                                "type": type(e).__name__, "error": str(e)})
                except OSError:
                    return

    def _handle_run(self, server: ExperimentServer, msg: dict) -> None:
        spec = ExperimentSpec.from_dict(msg["spec"])
        future = server.submit(spec, backend=msg.get("backend"))
        self._send({"event": "accepted", "name": spec.name})
        result = future.result()
        d = result.to_dict()
        trace = d.pop("trace")
        total = len(trace["iters"])
        for lo in range(0, total, TRACE_CHUNK_ROWS):
            hi = min(lo + TRACE_CHUNK_ROWS, total)
            self._send({"event": "trace", "lo": lo, "hi": hi,
                        "total": total,
                        "columns": {f: col[lo:hi]
                                    for f, col in trace.items()}})
        self._send({"event": "result", "result": d})
