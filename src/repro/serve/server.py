"""`ExperimentServer`: the persistent multi-tenant run server.

Architecture (all stdlib):

    TCP clients ──> ThreadingTCPServer (JSON lines)──┐
                                                     v
    in-process submit() ──> admission (dedup, max_queue, deadline)
                                                     │ dispatcher thread
                                 ┌───────────────────┤
                                 v                   v
                          LanePacker (dense,   solo requests
                          shape-compatible)         │
                                 │ full / expired   │
                                 v                   v
                      _execute: shed expired, count executions
                                 │
                ┌────────────────┴────────────────┐
                v (processes == 0)                v (processes >= 1)
        ThreadPoolExecutor in-process      WorkerPool (spawn procs,
        (PR 8 path, byte-for-byte)         supervised: crash restart,
                 │                         re-enqueue, deadline kill)
                 └───────> execute_requests <─────┘
                                 │
                  CompileCache.lease -> warm DDASimulator
                                 │
                        Future[RunResult] -> stream back

Dense requests lease simulators from a `CompileCache` (so repeat traffic
skips trace+compile entirely) and, when shape-compatible with concurrent
traffic, ride one `run_batch` vmap lane (`LanePacker`); netsim/launch
requests run solo through the ordinary `repro.run()` path. With
`processes >= 1` whole jobs (solo or packed lane) ship to supervised
worker processes as canonical spec JSON and come back as exact
`RunResult` JSON -- bit-identity is gated by the same differential tier
either way. Every response carries the serving observability on its
`RunMetrics`: `cache_hit`/`cache_miss`, `queue_wait_s`, `lane_width`,
`lane_occupancy` counters, a `solo_reason` note when a dense request
could not pack, and `reenqueues` when a crashed worker's job was retried.

Robustness knobs: `deadline_s` (per-request budget; expired work is shed
pre-dispatch, an in-flight pool overrun SIGKILLs the worker), `max_queue`
(bounded admission; over-limit submits raise `Overloaded` with a
retry-after hint), idempotency keys (a retried request joins the
original's Future or replays its cached result -- never runs twice), and
graceful drain on `close()` (in-flight finishes, new submits raise
`ShuttingDown`).

Wire protocol (one JSON object per line, strict RFC both directions --
requests parse through the frozen `ExperimentSpec` schema, responses are
`json_sanitize`d result dicts):

    -> {"op": "run", "spec": {...}, "backend": "dense"?,
        "deadline_s": 30.0?, "idempotency_key": "..."?}
    <- {"event": "accepted", "name": ...}
    <- {"event": "trace", "lo": 0, "hi": 256, "total": N,
        "columns": {"iters": [...], "fvals": [...], ...}}   (chunked)
    <- {"event": "result", "result": {...}}     (trace omitted: streamed)
    -> {"op": "ping"} / {"op": "stats"} / {"op": "shutdown"}
    <- {"event": "pong"} / {"event": "stats", ...} / {"event": "bye"}
    <- {"event": "error", "error": "...", "type": "Overloaded",
        "retry_after_s": 0.8?}
"""

from __future__ import annotations

import collections
import dataclasses
import json
import queue
import socketserver
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

from repro.experiments.result import RunResult
from repro.experiments.spec import ComponentSpec, ExperimentSpec
from repro.serve.cache import CompileCache
from repro.serve.chaos import ChaosMonkey, ChaosPlan
from repro.serve.packer import LanePacker, lane_key
from repro.serve.pool import (DeadlineExceeded, WorkerPool, _ser_backend,
                              execute_requests)

__all__ = ["ExperimentServer", "Overloaded", "ShuttingDown",
           "TRACE_CHUNK_ROWS"]

#: rows per streamed trace chunk (a row = one evaluation point)
TRACE_CHUNK_ROWS = 256

_STOP = object()


class Overloaded(RuntimeError):
    """Admission queue full; retry after `retry_after_s` seconds."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class ShuttingDown(RuntimeError):
    """The server is draining and refuses new work."""


@dataclasses.dataclass
class _Request:
    spec: ExperimentSpec
    backend: Any
    future: Future
    submitted: float
    solo_reason: str | None = None
    deadline: float | None = None  # absolute time.monotonic()
    idem_key: str | None = None
    settled: bool = False


class ExperimentServer:
    """Persistent run server; usable in-process (`submit`) or over TCP
    (`start` + `repro.serve.Client`).

    Args:
      host/port: TCP bind address (`port=0` picks a free port; read the
        real one from `start()`'s return or `.address`).
      workers: in-process executor width (each thread drives one run or
        lane; in pool mode these threads only deliver pool results).
      max_width / max_wait_s: lane-packer admission policy -- a lane
        flushes when `max_width` shape-compatible requests arrived or the
        oldest has waited `max_wait_s`.
      cache_entries: compile-cache capacity (warm simulators, LRU; in
        pool mode each worker process owns its own cache of this size).
      packing: disable to force every request solo (the cache still
        applies); the differential tests use both modes.
      processes: worker-process count. 0 (default) keeps the in-process
        PR 8 path byte-for-byte; >= 1 ships jobs to a supervised
        `WorkerPool` of spawn processes (crash restart + re-enqueue,
        deadline kills, heartbeats).
      deadline_s: default per-request budget; expired requests are shed
        (failed with `DeadlineExceeded`, never run). Per-request
        `deadline_s` on submit overrides.
      max_queue: bounded admission -- more than this many unsettled
        requests and `submit` raises `Overloaded` (0 = unbounded).
      dedup_entries: completed idempotency keys remembered for replay.
      chaos: optional `ChaosPlan` (pool mode only) -- a seeded
        `ChaosMonkey` SIGKILLs workers per the plan, for the chaos tier.
      pool_kwargs: extra `WorkerPool` knobs (max_reenqueues,
        backoff_base_s, heartbeat_s, ...).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 workers: int = 2, max_width: int = 4,
                 max_wait_s: float = 0.05, cache_entries: int = 32,
                 packing: bool = True, processes: int = 0,
                 deadline_s: float | None = None, max_queue: int = 0,
                 dedup_entries: int = 128,
                 chaos: ChaosPlan | dict | None = None,
                 pool_kwargs: dict | None = None):
        self.cache = CompileCache(max_entries=cache_entries)
        self.packer = LanePacker(max_width=max_width, max_wait_s=max_wait_s)
        self.packing = packing
        self.deadline_s = deadline_s
        self.max_queue = max_queue
        self._host, self._port = host, port
        self._queue: queue.Queue = queue.Queue()
        self._tpool = ThreadPoolExecutor(max_workers=max(1, workers),
                                         thread_name_prefix="repro-serve")
        if isinstance(chaos, dict):
            chaos = ChaosPlan.from_dict(chaos)
        self.chaos: ChaosMonkey | None = (None if chaos is None
                                          else ChaosMonkey(chaos))
        self.pool: WorkerPool | None = None
        if processes > 0:
            self.pool = WorkerPool(processes, cache_entries=cache_entries,
                                   chaos=self.chaos, **(pool_kwargs or {}))
        self._dispatcher: threading.Thread | None = None
        self._tcp: _TCPServer | None = None
        self._tcp_thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._closed = False
        self._started_at = time.monotonic()
        self.fatal: BaseException | None = None
        self.requests = 0
        self.errors = 0
        # robustness bookkeeping (all under self._lock)
        self._pending_n = 0
        self.shed = 0
        self.overloaded = 0
        self.dedup_hits = 0
        self._avg_run_s = 0.5  # EWMA of result walls, for retry-after hints
        self._inflight_keys: dict[str, _Request] = {}
        self._done_keys: collections.OrderedDict[str, Any] = \
            collections.OrderedDict()
        self._dedup_entries = dedup_entries
        self._executions: collections.Counter = collections.Counter()

    # -- lifecycle -----------------------------------------------------------

    @property
    def address(self) -> tuple[str, int] | None:
        return None if self._tcp is None else self._tcp.server_address[:2]

    def start(self) -> tuple[str, int]:
        """Bind the TCP front door and return (host, port)."""
        self._ensure_dispatcher()
        if self._tcp is None:
            self._tcp = _TCPServer((self._host, self._port), _Handler, self)
            self._tcp_thread = threading.Thread(
                target=self._tcp.serve_forever, name="repro-serve-tcp",
                daemon=True)
            self._tcp_thread.start()
        return self.address  # type: ignore[return-value]

    def close(self) -> None:
        """Graceful drain: stop accepting, flush open lanes, finish
        in-flight runs (pool jobs included), then stop the workers."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if self._tcp is not None:
            self._tcp.shutdown()
            self._tcp.server_close()
        if self._dispatcher is not None:
            self._queue.put(_STOP)
            self._dispatcher.join()
        if self.pool is not None:
            self.pool.close(drain=True)
        self._tpool.shutdown(wait=True)

    def __enter__(self) -> "ExperimentServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _fatal_teardown(self, exc: BaseException) -> None:
        """A fatal signal (SystemExit/KeyboardInterrupt) escaped a run:
        record it and tear the server down from a fresh thread (close()
        joins the thread the signal may be unwinding)."""
        with self._lock:
            if self.fatal is None:
                self.fatal = exc
        threading.Thread(target=self.close, name="repro-serve-fatal-close",
                         daemon=True).start()

    # -- submission ----------------------------------------------------------

    def submit(self, spec: ExperimentSpec | dict, backend: Any = None,
               deadline_s: float | None = None,
               idempotency_key: str | None = None) -> "Future":
        """Enqueue one run; returns a Future resolving to its RunResult.

        `deadline_s` (defaults to the server-wide budget) sheds the
        request instead of running it once expired. `idempotency_key`
        makes retries safe: a key already in flight returns the
        original's Future, a completed key replays its result.
        """
        if isinstance(spec, dict):
            spec = ExperimentSpec.from_dict(spec)
        now = time.monotonic()
        with self._lock:
            if self._closed:
                raise ShuttingDown("server is shutting down")
            if idempotency_key is not None:
                if idempotency_key in self._done_keys:
                    self._done_keys.move_to_end(idempotency_key)
                    self.requests += 1
                    self.dedup_hits += 1
                    fut: Future = Future()
                    fut.set_result(self._done_keys[idempotency_key])
                    return fut
                live = self._inflight_keys.get(idempotency_key)
                if live is not None:
                    self.requests += 1
                    self.dedup_hits += 1
                    return live.future
            if self.max_queue and self._pending_n >= self.max_queue:
                self.overloaded += 1
                hint = self._retry_after_locked()
                raise Overloaded(
                    f"admission queue full ({self._pending_n} pending, "
                    f"max_queue={self.max_queue})", retry_after_s=hint)
            self.requests += 1
            self._pending_n += 1
            if deadline_s is None:
                deadline_s = self.deadline_s
            req = _Request(
                spec=spec, backend=backend, future=Future(), submitted=now,
                deadline=None if deadline_s is None else now + deadline_s,
                idem_key=idempotency_key)
            if idempotency_key is not None:
                self._inflight_keys[idempotency_key] = req
        self._ensure_dispatcher()
        self._queue.put(req)
        return req.future

    def _retry_after_locked(self) -> float:
        width = len(self.pool._slots) if self.pool is not None \
            else self._tpool._max_workers
        est = self._pending_n * self._avg_run_s / max(width, 1)
        return round(min(max(est, 0.05), 30.0), 3)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            robustness = {
                "requests_shed": self.shed,
                "requests_retried": self.dedup_hits,
                "overloaded": self.overloaded,
                "pending": self._pending_n,
                "worker_restarts": 0,
                "reenqueues": 0,
                "deadline_missed": 0,
            }
            dedup = {"inflight_keys": len(self._inflight_keys),
                     "done_keys": len(self._done_keys),
                     "max_executions_per_key":
                         max(self._executions.values(), default=0)}
        if self.pool is not None:
            ps = self.pool.stats()
            robustness["worker_restarts"] = ps["worker_restarts"]
            robustness["reenqueues"] = ps["reenqueues"]
            robustness["deadline_missed"] = ps["deadline_missed"]
        out = {
            "server": {"requests": self.requests, "errors": self.errors,
                       "uptime_s": time.monotonic() - self._started_at,
                       "packing": self.packing,
                       "processes": (0 if self.pool is None
                                     else len(self.pool._slots)),
                       "fatal": (None if self.fatal is None
                                 else repr(self.fatal))},
            "cache": self.cache.stats(),
            "packer": self.packer.stats(),
            "robustness": robustness,
            "dedup": dedup,
        }
        if self.pool is not None:
            out["pool"] = self.pool.stats()
        if self.chaos is not None:
            out["chaos"] = self.chaos.stats()
        return out

    def _ensure_dispatcher(self) -> None:
        with self._lock:
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="repro-serve-dispatch",
                    daemon=True)
                self._dispatcher.start()

    # -- dispatch ------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            deadline = self.packer.next_deadline()
            timeout = (None if deadline is None
                       else max(deadline - time.monotonic(), 0.0))
            try:
                req = self._queue.get(timeout=timeout)
            except queue.Empty:
                req = None
            if req is _STOP:
                for lane in self.packer.flush():
                    self._launch_lane(lane)
                return
            if req is not None:
                try:
                    self._route(req)
                except Exception as e:  # noqa: BLE001 -- one bad
                    self._fail(req, e)  # request must not kill dispatch
                except BaseException as e:
                    # fatal signal: don't strand the waiter, then tear
                    # the server down instead of masking it as a failure
                    self._fail(req, e)
                    self._fatal_teardown(e)
                    raise
            for lane in self.packer.pop_ready():
                self._launch_lane(lane)

    def _route(self, req: _Request) -> None:
        if req.deadline is not None and time.monotonic() > req.deadline:
            self._shed(req)
            return
        if not self.packing:
            req.solo_reason = "packing disabled on this server"
            self._execute([req])
            return
        key, reason = lane_key(req.spec, req.backend)
        if key is None:
            req.solo_reason = reason
            self._execute([req])
        else:
            self.packer.admit(key, req)

    def _launch_lane(self, lane) -> None:
        reqs = lane.items
        if len(reqs) == 1:
            req = reqs[0]
            req.solo_reason = (req.solo_reason or
                               "lane flushed at width 1 (no shape-"
                               "compatible peer arrived within max_wait_s)")
        self._execute(reqs)

    # -- execution -----------------------------------------------------------

    def _shed(self, req: _Request) -> None:
        with self._lock:
            self.shed += 1
        self._fail(req, DeadlineExceeded(
            "deadline expired before dispatch; request shed", shed=True))

    def _execute(self, reqs: list) -> None:
        """Shed expired members, record idempotent executions, and hand
        the job to the in-process executor or the worker pool."""
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                self._shed(r)
            else:
                live.append(r)
        if not live:
            return
        with self._lock:
            for r in live:
                if r.idem_key is not None:
                    self._executions[r.idem_key] += 1
        if self.pool is None:
            self._tpool.submit(self._run_inproc, live)
        else:
            self._dispatch_pool(live)

    def _run_inproc(self, reqs: list) -> None:
        waits = [time.monotonic() - r.submitted for r in reqs]
        try:
            results, meta = execute_requests(
                [r.spec for r in reqs], [r.backend for r in reqs], self.cache)
        except Exception as e:  # noqa: BLE001 -- delivered to the client
            for r in reqs:
                self._fail(r, e)
            return
        except BaseException as e:
            for r in reqs:
                self._fail(r, e)
            self._fatal_teardown(e)
            raise
        hit = meta.get("cache_hit") if len(reqs) > 1 else None
        for req, result, wait in zip(reqs, results, waits):
            self._finish(req, result, width=len(reqs), queue_wait=wait,
                         cache_hit=hit)

    def _dispatch_pool(self, reqs: list) -> None:
        deadlines = [r.deadline for r in reqs]
        job_deadline = (None if any(d is None for d in deadlines)
                        else max(deadlines))
        try:
            fut = self.pool.submit(
                [r.spec.to_json(indent=None) for r in reqs],
                [_ser_backend(r.backend) for r in reqs],
                deadline=job_deadline)
        except Exception as e:  # noqa: BLE001 -- pool closed under us
            for r in reqs:
                self._fail(r, e)
            return
        fut.add_done_callback(
            lambda f: self._deliver_pool(reqs, f))

    def _deliver_pool(self, reqs: list, fut: Future) -> None:
        """Runs on the pool supervisor thread; the payload decode is
        cheap relative to a run, so deliver inline."""
        try:
            payload, meta = fut.result()
        except Exception as e:  # noqa: BLE001 -- job-level failure
            for r in reqs:
                self._fail(r, e)
            return
        try:
            results = [RunResult.from_json(s) for s in payload]
        except Exception as e:  # noqa: BLE001 -- torn/invalid payload
            for r in reqs:
                self._fail(r, e)
            return
        dispatched = meta.get("dispatched_at")
        reen = int(meta.get("reenqueues", 0))
        hit = meta.get("cache_hit") if len(reqs) > 1 else None
        for req, result in zip(reqs, results):
            wait = ((dispatched - req.submitted) if dispatched is not None
                    else 0.0)
            self._finish(req, result, width=len(reqs), queue_wait=wait,
                         cache_hit=hit, reenqueues=reen)

    def _finish(self, req: _Request, result, width: int, queue_wait: float,
                cache_hit: bool | None = None, reenqueues: int = 0) -> None:
        """Attach the serve-side observability to the result's metrics.

        Everything added here is bookkeeping the differential gates
        exclude (`comparable_result_dict` strips metrics), so annotation
        can never perturb the scientific payload."""
        m = result.metrics
        if m is not None:
            counters = dict(m.counters)
            counters["queue_wait_s"] = queue_wait
            counters["lane_width"] = float(width)
            counters["lane_occupancy"] = width / self.packer.max_width
            if cache_hit is not None:
                counters["cache_hit" if cache_hit else "cache_miss"] = \
                    counters.get(
                        "cache_hit" if cache_hit else "cache_miss", 0) + 1
            notes = dict(m.notes)
            if req.solo_reason:
                notes["solo_reason"] = req.solo_reason
            if reenqueues:
                counters["reenqueues"] = float(reenqueues)
                notes["reenqueues"] = (f"job survived {reenqueues} worker "
                                       "crash(es) via re-enqueue")
            result.metrics = dataclasses.replace(m, counters=counters,
                                                 notes=notes)
        self._settle(req, result=result)
        if not req.future.done():
            req.future.set_result(result)

    def _fail(self, req: _Request, exc: BaseException) -> None:
        with self._lock:
            self.errors += 1
        self._settle(req)
        if not req.future.done():
            req.future.set_exception(exc)

    def _settle(self, req: _Request, result=None) -> None:
        """Once per request: release its admission slot and resolve its
        idempotency key (successful results become replayable)."""
        with self._lock:
            if req.settled:
                return
            req.settled = True
            self._pending_n -= 1
            if result is not None and result.wall_s is not None:
                self._avg_run_s = (0.8 * self._avg_run_s
                                   + 0.2 * float(result.wall_s))
            if req.idem_key is not None:
                self._inflight_keys.pop(req.idem_key, None)
                if result is not None:
                    self._done_keys[req.idem_key] = result
                    while len(self._done_keys) > self._dedup_entries:
                        self._done_keys.popitem(last=False)


# ---------------------------------------------------------------------------
# TCP front door
# ---------------------------------------------------------------------------


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, handler, owner: ExperimentServer):
        self.owner = owner
        super().__init__(addr, handler)


class _Handler(socketserver.StreamRequestHandler):
    """One connection; any number of newline-delimited JSON ops."""

    def _send(self, obj: dict) -> None:
        line = json.dumps(obj, allow_nan=False) + "\n"
        self.wfile.write(line.encode("utf-8"))
        self.wfile.flush()

    def handle(self) -> None:
        server: ExperimentServer = self.server.owner  # type: ignore[attr-defined]
        for raw in self.rfile:
            raw = raw.strip()
            if not raw:
                continue
            try:
                msg = json.loads(raw)
                op = msg.get("op", "run")
                if op == "ping":
                    self._send({"event": "pong", "ok": True})
                elif op == "stats":
                    self._send({"event": "stats", **server.stats()})
                elif op == "shutdown":
                    self._send({"event": "bye"})
                    # shut down from a fresh thread: shutdown() joins the
                    # serve_forever loop, and this handler must first
                    # return its socket to it
                    threading.Thread(target=server.close,
                                     daemon=True).start()
                    return
                elif op == "run":
                    self._handle_run(server, msg)
                else:
                    self._send({"event": "error", "type": "ValueError",
                                "error": f"unknown op {op!r}"})
            except BrokenPipeError:
                return
            except Exception as e:  # noqa: BLE001 -- protocol surface
                payload = {"event": "error", "type": type(e).__name__,
                           "error": str(e)}
                retry_after = getattr(e, "retry_after_s", None)
                if retry_after is not None:
                    payload["retry_after_s"] = retry_after
                try:
                    self._send(payload)
                except OSError:
                    return

    def _handle_run(self, server: ExperimentServer, msg: dict) -> None:
        spec = ExperimentSpec.from_dict(msg["spec"])
        backend = msg.get("backend")
        if isinstance(backend, dict):
            backend = ComponentSpec.from_dict(backend)
        future = server.submit(spec, backend=backend,
                               deadline_s=msg.get("deadline_s"),
                               idempotency_key=msg.get("idempotency_key"))
        self._send({"event": "accepted", "name": spec.name})
        result = future.result()
        d = result.to_dict()
        trace = d.pop("trace")
        total = len(trace["iters"])
        for lo in range(0, total, TRACE_CHUNK_ROWS):
            hi = min(lo + TRACE_CHUNK_ROWS, total)
            self._send({"event": "trace", "lo": lo, "hi": hi,
                        "total": total,
                        "columns": {f: col[lo:hi]
                                    for f, col in trace.items()}})
        self._send({"event": "result", "result": d})
