"""Deterministic chaos for the real-process serving stack.

`repro.faults` (PR 7) injects crashes and partitions into *simulated*
time; nothing there ever kills a real process. This module extends the
same philosophy -- seeded, replayable, plan-driven -- to the serving
tier's actual failure domain:

  * `ChaosPlan` -- a frozen, JSON-round-trippable schedule of injected
    failures. Every stochastic choice is driven from the plan's own
    seeded RNG streams (`[seed, 0]` for kills, `[seed, 1]` for the wire
    proxy), so a chaos run replays exactly given the same traffic order.
  * `ChaosMonkey` -- pool-side injector: `WorkerPool` calls
    `on_dispatch(ordinal, proc)` after every job dispatch, and the plan
    decides whether that worker gets SIGKILLed (optionally after a
    drawn delay, i.e. mid-lane).
  * `ChaosProxy` -- an in-process TCP proxy between client and server
    that tears response lines mid-byte, drops connections, and delays
    lines -- the wire-level failures a retrying `Client` must absorb.

The chaos gate (tests + CI `chaos-smoke`) runs real traffic through
both injectors and asserts every request still completes bit-identical
to cold solo `repro.run()` with no double execution -- the serving
analog of PR 7's "faults must not change the answer" discipline.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import socket
import threading
import time
from typing import Any

import numpy as np

__all__ = ["ChaosMonkey", "ChaosPlan", "ChaosProxy"]


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """Seeded, replayable schedule of serving-layer failures.

    Ordinals are 1-based and deterministic given traffic order: job
    dispatch ordinals for kills (the pool counts every dispatch), global
    response-line ordinals for wire faults (the proxy counts every
    server->client line it forwards).
    """

    seed: int = 0
    #: dispatch ordinals whose worker gets SIGKILLed
    kill_at_dispatch: tuple = ()
    #: uniform [lo, hi) seconds between dispatch and the SIGKILL --
    #: a positive window lands the kill mid-run (mid-lane)
    kill_delay_s: tuple = (0.0, 0.0)
    #: response-line ordinals forwarded only halfway, then cut
    tear_response_at: tuple = ()
    #: response-line ordinals where the connection drops before the line
    drop_connection_at: tuple = ()
    #: per-line Bernoulli delay probability (proxy RNG stream)
    delay_line_prob: float = 0.0
    #: uniform [lo, hi) seconds for a drawn delay
    delay_s: tuple = (0.0, 0.02)

    def __post_init__(self):
        object.__setattr__(self, "kill_at_dispatch",
                           tuple(int(k) for k in self.kill_at_dispatch))
        object.__setattr__(self, "tear_response_at",
                           tuple(int(k) for k in self.tear_response_at))
        object.__setattr__(self, "drop_connection_at",
                           tuple(int(k) for k in self.drop_connection_at))
        object.__setattr__(self, "kill_delay_s",
                           tuple(float(x) for x in self.kill_delay_s))
        object.__setattr__(self, "delay_s",
                           tuple(float(x) for x in self.delay_s))
        for name in ("kill_delay_s", "delay_s"):
            lo, hi = getattr(self, name)
            if lo < 0 or hi < lo:
                raise ValueError(f"{name} must be 0 <= lo <= hi, "
                                 f"got ({lo}, {hi})")
        if not 0.0 <= self.delay_line_prob <= 1.0:
            raise ValueError("delay_line_prob must be in [0, 1]")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, tuple):
                d[k] = list(v)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ChaosPlan fields: {sorted(unknown)}")
        return cls(**d)


class ChaosMonkey:
    """Pool-side kill injector; RNG stream `[seed, 0]`.

    `on_dispatch` is called by the supervisor thread after every job
    dispatch; a scheduled kill fires from a daemon timer so a drawn
    delay lands the SIGKILL mid-run without blocking dispatch."""

    def __init__(self, plan: ChaosPlan):
        self.plan = plan
        self._rng = np.random.default_rng([plan.seed, 0])
        self._lock = threading.Lock()
        self.kills_scheduled = 0
        self.kills_delivered = 0

    def on_dispatch(self, ordinal: int, proc) -> None:
        if ordinal not in self.plan.kill_at_dispatch:
            return
        with self._lock:
            lo, hi = self.plan.kill_delay_s
            delay = float(self._rng.uniform(lo, hi)) if hi > lo else lo
            self.kills_scheduled += 1
        pid = proc.pid
        if delay <= 0:
            self._kill(pid)
        else:
            t = threading.Timer(delay, self._kill, args=(pid,))
            t.daemon = True
            t.start()

    def _kill(self, pid: int) -> None:
        try:
            os.kill(pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            return
        with self._lock:
            self.kills_delivered += 1

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"kills_scheduled": self.kills_scheduled,
                    "kills_delivered": self.kills_delivered}


class ChaosProxy:
    """In-process TCP proxy injecting wire faults between client and
    server; RNG stream `[seed, 1]`.

    Client->server bytes pass through untouched (requests must arrive
    intact or the retry story conflates with request loss); the
    server->client direction is read line-by-line so faults land on
    protocol-event boundaries: `tear_response_at` forwards half the
    line's bytes then cuts the connection, `drop_connection_at` cuts
    before the line, `delay_line_prob` sleeps a drawn delay first.
    """

    def __init__(self, upstream_host: str, upstream_port: int,
                 plan: ChaosPlan | None = None):
        self.plan = plan or ChaosPlan()
        self._up = (upstream_host, upstream_port)
        self._rng = np.random.default_rng([self.plan.seed, 1])
        self._lock = threading.Lock()
        self._line = 0
        self._closing = False
        self._conns: set = set()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(16)
        self._accept_thread: threading.Thread | None = None
        self.connections = 0
        self.torn_responses = 0
        self.dropped_connections = 0
        self.delayed_lines = 0

    @property
    def address(self) -> tuple[str, int]:
        return self._srv.getsockname()[:2]

    def start(self) -> tuple[str, int]:
        if self._accept_thread is None:
            self._accept_thread = threading.Thread(
                target=self._accept_loop, name="repro-chaos-proxy",
                daemon=True)
            self._accept_thread.start()
        return self.address

    def close(self) -> None:
        self._closing = True
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass

    def __enter__(self) -> "ChaosProxy":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"connections": self.connections,
                    "torn_responses": self.torn_responses,
                    "dropped_connections": self.dropped_connections,
                    "delayed_lines": self.delayed_lines,
                    "lines_forwarded": self._line}

    # -- internals -----------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                client, _ = self._srv.accept()
            except OSError:
                return
            with self._lock:
                self.connections += 1
            threading.Thread(target=self._handle, args=(client,),
                             daemon=True).start()

    def _track(self, *socks) -> None:
        with self._lock:
            self._conns.update(socks)

    def _untrack(self, *socks) -> None:
        with self._lock:
            self._conns.difference_update(socks)

    def _handle(self, client: socket.socket) -> None:
        try:
            upstream = socket.create_connection(self._up, timeout=30)
        except OSError:
            client.close()
            return
        self._track(client, upstream)
        done = threading.Event()
        t = threading.Thread(target=self._pump_up, name="repro-chaos-c2s",
                             args=(client, upstream, done), daemon=True)
        t.start()
        try:
            self._pump_down(upstream, client)
        finally:
            done.set()
            for s in (client, upstream):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                try:
                    s.close()
                except OSError:
                    pass
            self._untrack(client, upstream)

    def _pump_up(self, client: socket.socket, upstream: socket.socket,
                 done: threading.Event) -> None:
        """client -> server: verbatim bytes."""
        try:
            while not done.is_set():
                data = client.recv(65536)
                if not data:
                    break
                upstream.sendall(data)
        except OSError:
            pass
        try:
            upstream.shutdown(socket.SHUT_WR)
        except OSError:
            pass

    def _pump_down(self, upstream: socket.socket,
                   client: socket.socket) -> None:
        """server -> client: line-framed, with plan-driven faults."""
        rfile = upstream.makefile("rb")
        try:
            while True:
                line = rfile.readline()
                if not line:
                    return
                with self._lock:
                    self._line += 1
                    n = self._line
                    tear = n in self.plan.tear_response_at
                    drop = n in self.plan.drop_connection_at
                    delay = 0.0
                    if self.plan.delay_line_prob > 0:
                        if self._rng.random() < self.plan.delay_line_prob:
                            lo, hi = self.plan.delay_s
                            delay = float(self._rng.uniform(lo, hi))
                            self.delayed_lines += 1
                    if tear:
                        self.torn_responses += 1
                    if drop:
                        self.dropped_connections += 1
                if drop:
                    return
                if delay > 0:
                    time.sleep(delay)
                if tear:
                    client.sendall(line[:max(1, len(line) // 2)])
                    return
                client.sendall(line)
        except OSError:
            return
        finally:
            try:
                rfile.close()
            except OSError:
                pass
