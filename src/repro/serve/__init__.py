"""`repro.serve` -- persistent multi-tenant experiment serving.

The paper's object is amortizing a fixed cost (communication) against
useful work (computation); this package is the serving analog -- amortize
XLA compilation and device dispatch across many incoming `ExperimentSpec`
requests:

  * `ExperimentServer` -- long-lived worker-pool server with a stdlib
    TCP JSON-lines front door (`python -m repro.serve serve`);
  * `CompileCache` / `cache_signature` -- warm `DDASimulator`s keyed by
    the dense scan program's shape signature, so repeat traffic skips
    trace+lower+compile entirely;
  * `LanePacker` / `lane_key` -- shape-compatible specs from different
    requests batched into one `run_batch` vmap lane under a
    max-wait/max-width admission policy;
  * `Client` -- thin blocking client (`repro.serve.Client(host, port)`);
  * `comparable_result_dict` -- the canonicalization the differential
    serving gates compare under: served results must be BIT-IDENTICAL
    to cold solo `repro.run()` outside wall-clock and serve bookkeeping.

Quickstart (in-process):

    from repro.serve import ExperimentServer

    with ExperimentServer(workers=2) as srv:
        fut = srv.submit(spec)          # Future[RunResult]
        result = fut.result()
        print(result.metrics.counters)  # cache_hit, queue_wait_s, ...

Over TCP:

    host, port = srv.start()
    with Client(host, port) as c:
        result = c.run(spec)
"""

from __future__ import annotations

from typing import Any

from repro.serve.cache import CompileCache, cache_signature
from repro.serve.chaos import ChaosMonkey, ChaosPlan, ChaosProxy
from repro.serve.client import (Client, DeadlineExceededError,
                                OverloadedError, ServeError,
                                ShuttingDownError)
from repro.serve.packer import Lane, LanePacker, lane_key
from repro.serve.pool import (DeadlineExceeded, PoolError, WorkerCrashed,
                              WorkerPool, execute_requests)
from repro.serve.server import (ExperimentServer, Overloaded, ShuttingDown,
                                TRACE_CHUNK_ROWS)

__all__ = [
    "ChaosMonkey",
    "ChaosPlan",
    "ChaosProxy",
    "Client",
    "CompileCache",
    "DeadlineExceeded",
    "DeadlineExceededError",
    "ExperimentServer",
    "Lane",
    "LanePacker",
    "Overloaded",
    "OverloadedError",
    "PoolError",
    "ServeError",
    "ShuttingDown",
    "ShuttingDownError",
    "TRACE_CHUNK_ROWS",
    "WorkerCrashed",
    "WorkerPool",
    "cache_signature",
    "comparable_result_dict",
    "execute_requests",
    "lane_key",
]

#: extras keys that record HOW a result was executed, not WHAT it is --
#: batching and fallback bookkeeping legitimately differs between a solo
#: run and the same run served from a warm cache or packed lane
_EXECUTION_EXTRAS = ("vmap_lanes", "lane_width", "vmap_fallback",
                     "solo_reason")


def comparable_result_dict(result: Any) -> dict:
    """Canonical dict for exact ("bit-identical") result comparison.

    Strips the fields that measure the execution rather than define the
    run: `wall_s`, the whole `metrics` block (wall splits, serve
    counters), and the execution-bookkeeping extras. Everything else --
    spec, backend, the full trace, eps/target fields, predictions,
    remaining extras -- must match EXACTLY (`==` on the JSON dicts) for a
    served result to count as equivalent to its solo baseline. Accepts a
    `RunResult` or an already-serialized result dict.
    """
    d = result if isinstance(result, dict) else result.to_dict()
    d = dict(d)
    d.pop("wall_s", None)
    d.pop("metrics", None)
    extras = dict(d.get("extras") or {})
    for k in _EXECUTION_EXTRAS:
        extras.pop(k, None)
    d["extras"] = extras
    return d
