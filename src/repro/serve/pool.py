"""`WorkerPool`: supervised spawn-based worker processes for the server.

The PR 8 server runs every request in the parent process, which makes one
wedged or killed run a whole-server outage and leaves dense/netsim
throughput GIL-bound. This module moves execution into N spawn-based
worker processes, each owning its own `CompileCache`, with a supervisor
thread in the parent that:

  * ships jobs as canonical spec JSON over a duplex pipe (results come
    back as exact `RunResult.to_json` strings, so bit-identity survives
    the process boundary the same way it survives the TCP one);
  * watches every worker's process sentinel and pipe; a crash (SIGKILL,
    segfault, uncaught BaseException) is detected the moment the
    sentinel fires, the lost in-flight job is transparently re-enqueued
    at the front of the queue (safe: every run is deterministic and
    side-effect-free until its Future resolves), and the worker is
    restarted under capped exponential backoff;
  * enforces per-job deadlines -- a worker that blows its job's deadline
    is SIGKILLed and replaced, and the job fails with
    `DeadlineExceeded` (deadline overruns are never re-enqueued: the
    client's budget is already spent);
  * heartbeats idle workers (ping/pong) so a wedged-but-alive worker is
    detected and replaced even when no job is queued.

Execution semantics are shared with the in-process path through
`execute_requests` (solo `repro.run()` / cache-leased dense / packed
`run_batch` lane), so `--workers 0` stays byte-for-byte the PR 8 server
and `--workers N` is gated bit-identical by the same differential tier.

`worker_main` is injectable so the supervisor's crash/hang/deadline
machinery is unit-testable with a toy worker (`_toy_worker_main`) that
costs milliseconds instead of XLA compiles.
"""

from __future__ import annotations

import collections
import dataclasses
import multiprocessing
import os
import signal
import threading
import time
from concurrent.futures import Future
from multiprocessing import connection as mp_connection
from typing import Any, Callable

__all__ = ["DeadlineExceeded", "PoolError", "WorkerCrashed", "WorkerPool",
           "execute_requests"]


class PoolError(RuntimeError):
    """Pool-level failure (closed pool, unserviceable job)."""


class WorkerCrashed(PoolError):
    """A job died with its worker more times than the re-enqueue cap."""


class DeadlineExceeded(PoolError):
    """The job's deadline passed -- either shed before dispatch or its
    worker was killed mid-run. `shed` distinguishes the two."""

    def __init__(self, msg: str, shed: bool = False):
        super().__init__(msg)
        self.shed = shed


# ---------------------------------------------------------------------------
# shared execution semantics (parent in-process path AND worker processes)
# ---------------------------------------------------------------------------


def execute_requests(specs: list, backends: list, cache) -> tuple[list, dict]:
    """Run one job -- solo when a single spec, else one packed `run_batch`
    vmap lane -- and return `(results, meta)`.

    This is the single definition of serving execution semantics: the
    in-process server path and every worker process call it, which is
    what keeps `--workers 0` byte-for-byte identical to PR 8 and
    `--workers N` bit-identical through the pipe. `meta` carries lane
    bookkeeping (`cache_hit` for multi-spec lanes) that the caller folds
    into `RunMetrics` counters -- never into the scientific payload.
    """
    from repro.experiments.runner import (_build_schedule,
                                          _dense_batch_results, _dense_parts,
                                          _dense_sim, _resolve_backend,
                                          _run_dense)
    from repro.experiments.runner import run as _run

    if len(specs) == 1:
        backend = _resolve_backend(specs[0], backends[0])
        if backend.kind == "dense":
            return [_run_dense(specs[0], backend, sim_cache=cache)], {}
        return [_run(specs[0], backend=backend)], {}

    import jax.numpy as jnp
    import numpy as np

    resolved = [_resolve_backend(s, b) for s, b in zip(specs, backends)]
    parts = _dense_parts(specs[0], resolved[0])
    problem, graph = parts["problem"], parts["graph"]
    schedules = [_build_schedule(c) for c in specs]
    masks = np.stack([s.comm_mask(0, specs[0].T) for s in schedules])
    with cache.lease(specs[0], resolved[0],
                     lambda: _dense_sim(specs[0], parts)) as (sim, hit):
        sim.schedule = schedules[0]
        sim.r = specs[0].r
        x0 = jnp.zeros((problem.n, problem.d))
        t0 = time.perf_counter()
        traces = sim.run_batch(x0, specs[0].T, specs[0].eval_every,
                               masks, seeds=[c.seed for c in specs],
                               rs=[c.r for c in specs])
        wall = time.perf_counter() - t0
        results = _dense_batch_results(
            specs, resolved, sim, problem, graph, schedules,
            traces, wall, lane_counter="lane_width")
    return results, {"cache_hit": hit}


def _ser_backend(backend: Any) -> Any:
    """Backend selectors are None | str | int | ComponentSpec; only the
    last needs explicit serialization for the pipe."""
    from repro.experiments.spec import ComponentSpec

    if isinstance(backend, ComponentSpec):
        return {"__component__": backend.to_dict()}
    return backend


def _deser_backend(ser: Any) -> Any:
    from repro.experiments.spec import ComponentSpec

    if isinstance(ser, dict) and "__component__" in ser:
        return ComponentSpec.from_dict(ser["__component__"])
    return ser


# ---------------------------------------------------------------------------
# worker process mains (module-level: spawn requires picklable targets)
# ---------------------------------------------------------------------------


def _worker_main(conn, cache_entries: int = 32) -> None:
    """Real worker: owns a private CompileCache, loops on the pipe.

    Protocol (tuples over the duplex pipe):
      -> ("run", job_id, [spec_json, ...], [backend_ser, ...])
      <- ("ok", job_id, [result_json, ...], meta) | ("err", job_id, type, msg)
      -> ("ping", token)   <- ("pong", token)
      -> ("stop",)         (worker exits cleanly)

    Only `Exception` is caught per job; a BaseException (or SIGKILL)
    takes the process down and the supervisor's sentinel watch handles
    it -- that IS the crash path, not an error to mask.
    """
    # the parent owns lifecycle: a terminal Ctrl-C must not race the
    # supervisor's graceful drain
    signal.signal(signal.SIGINT, signal.SIG_IGN)
    from repro.experiments.result import RunResult  # noqa: F401 (warm import)
    from repro.experiments.spec import ExperimentSpec
    from repro.serve.cache import CompileCache

    cache = CompileCache(max_entries=cache_entries)
    conn.send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return  # parent went away
        op = msg[0]
        if op == "stop":
            return
        if op == "ping":
            conn.send(("pong", msg[1]))
            continue
        if op != "run":
            continue
        _, job_id, spec_jsons, backend_sers = msg
        try:
            specs = [ExperimentSpec.from_json(s) for s in spec_jsons]
            backends = [_deser_backend(b) for b in backend_sers]
            results, meta = execute_requests(specs, backends, cache)
            payload = [r.to_json() for r in results]
            conn.send(("ok", job_id, payload, meta))
        except Exception as e:  # noqa: BLE001 -- per-job failure surface
            conn.send(("err", job_id, type(e).__name__, str(e)))


def _toy_worker_main(conn, cache_entries: int = 32) -> None:
    """Test double for the supervisor: interprets each spec_json as a
    JSON command dict instead of an ExperimentSpec.

      {"action": "echo", "value": x}       -> result json '{"value": x}'
      {"action": "sleep", "s": 1.0, ...}   -> sleeps, then echoes
      {"action": "crash"}                  -> os._exit(13) (simulated kill)
      {"action": "crash_once", "marker": p} -> crashes only while the
          marker file does not exist (touches it first), so a re-enqueued
          job succeeds on the retry -- the transparent-re-enqueue test.
    """
    import json

    signal.signal(signal.SIGINT, signal.SIG_IGN)
    conn.send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        if msg[0] == "stop":
            return
        if msg[0] == "ping":
            conn.send(("pong", msg[1]))
            continue
        _, job_id, spec_jsons, _backends = msg
        try:
            out = []
            for s in spec_jsons:
                cmd = json.loads(s)
                action = cmd.get("action", "echo")
                if action == "sleep":
                    time.sleep(float(cmd.get("s", 0.1)))
                elif action == "crash":
                    os._exit(13)
                elif action == "crash_once":
                    marker = cmd["marker"]
                    if not os.path.exists(marker):
                        with open(marker, "w") as f:
                            f.write(str(os.getpid()))
                        os._exit(13)
                elif action == "raise":
                    raise ValueError(cmd.get("msg", "toy failure"))
                out.append(json.dumps({"value": cmd.get("value"),
                                       "pid": os.getpid()}))
            conn.send(("ok", job_id, out, {"pid": os.getpid()}))
        except Exception as e:  # noqa: BLE001
            conn.send(("err", job_id, type(e).__name__, str(e)))


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Job:
    id: int
    spec_jsons: list
    backend_sers: list
    future: Future
    deadline: float | None  # absolute time.monotonic(), None = unbounded
    reenqueues: int = 0


class _Slot:
    """One worker seat: its live process/pipe plus restart bookkeeping."""

    def __init__(self, slot_id: int):
        self.id = slot_id
        self.proc = None
        self.conn = None
        self.ready = False
        self.job: _Job | None = None
        self.dispatched_at = 0.0
        self.spawned = 0            # lifetime spawn count for this seat
        self.consec_failures = 0    # resets on a completed job
        self.backoff_until = 0.0
        self.last_hb = 0.0
        self.awaiting_pong = False
        self.pong_deadline = 0.0


class WorkerPool:
    """N supervised spawn workers behind a `submit() -> Future` facade.

    Args:
      processes: worker count (>= 1; the server's `processes=0` means "no
        pool at all", not a zero-width pool).
      cache_entries: per-worker CompileCache capacity.
      max_reenqueues: how many times a job lost to a worker crash is
        transparently retried before failing with `WorkerCrashed`.
      backoff_base_s / backoff_cap_s: capped exponential restart backoff
        (base * 2**(consecutive_failures-1), clamped to the cap; resets
        once a worker completes a job).
      heartbeat_s / heartbeat_timeout_s: idle-worker ping cadence and how
        long a missing pong is tolerated before the worker is replaced.
      chaos: optional `ChaosMonkey`; `on_dispatch(ordinal, proc)` is
        called after every job dispatch so a seeded plan can SIGKILL
        workers mid-run.
      worker_main: injectable process target (tests use
        `_toy_worker_main`); must be module-level picklable.
    """

    def __init__(self, processes: int, *, cache_entries: int = 32,
                 max_reenqueues: int = 2, backoff_base_s: float = 0.25,
                 backoff_cap_s: float = 5.0, heartbeat_s: float = 5.0,
                 heartbeat_timeout_s: float = 30.0, chaos=None,
                 worker_main: Callable = _worker_main):
        if processes < 1:
            raise ValueError("WorkerPool needs processes >= 1 "
                             "(use the in-process server path for 0)")
        self.max_reenqueues = max_reenqueues
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.chaos = chaos
        self._cache_entries = cache_entries
        self._worker_main = worker_main
        self._ctx = multiprocessing.get_context("spawn")
        self._slots = [_Slot(i) for i in range(processes)]
        self._pending: collections.deque[_Job] = collections.deque()
        self._lock = threading.Lock()
        self._wake_r, self._wake_w = self._ctx.Pipe(duplex=False)
        self._closing = False
        self._drain = True
        self._job_seq = 0
        self._dispatches = 0
        self._rr = 0
        self._hb_seq = 0
        # robustness counters (surfaced on server stats / RunMetrics)
        self.worker_restarts = 0
        self.reenqueues = 0
        self.deadline_missed = 0
        self.jobs_ok = 0
        self.jobs_failed = 0
        self._supervisor = threading.Thread(
            target=self._supervise, name="repro-serve-pool", daemon=True)
        self._supervisor.start()

    # -- public API ----------------------------------------------------------

    def submit(self, spec_jsons: list, backend_sers: list,
               deadline: float | None = None) -> Future:
        """Enqueue one job (a solo request or a whole packed lane).

        Resolves to `(result_jsons, meta)`; meta carries `cache_hit`,
        `reenqueues`, `dispatched_at`, and the worker slot id."""
        with self._lock:
            if self._closing:
                raise PoolError("worker pool is closed")
            self._job_seq += 1
            job = _Job(id=self._job_seq, spec_jsons=list(spec_jsons),
                       backend_sers=list(backend_sers), future=Future(),
                       deadline=deadline)
            self._pending.append(job)
        self._wake()
        return job.future

    def stats(self) -> dict[str, Any]:
        with self._lock:
            pending = len(self._pending)
        busy = sum(1 for s in self._slots if s.job is not None)
        alive = sum(1 for s in self._slots
                    if s.proc is not None and s.proc.is_alive())
        return {
            "processes": len(self._slots),
            "alive": alive,
            "busy": busy,
            "pending": pending,
            "dispatches": self._dispatches,
            "jobs_ok": self.jobs_ok,
            "jobs_failed": self.jobs_failed,
            "worker_restarts": self.worker_restarts,
            "reenqueues": self.reenqueues,
            "deadline_missed": self.deadline_missed,
        }

    def close(self, drain: bool = True, timeout: float | None = None) -> None:
        """Stop the pool. `drain=True` finishes queued + in-flight jobs
        first; `drain=False` fails them all with `PoolError`."""
        with self._lock:
            self._closing = True
            self._drain = drain
        self._wake()
        self._supervisor.join(timeout)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- supervisor loop -----------------------------------------------------

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    def _supervise(self) -> None:
        try:
            self._supervise_loop()
        finally:
            self._stop_workers()
            self._abort_pending(PoolError("worker pool is closed"))

    def _supervise_loop(self) -> None:
        while True:
            now = time.monotonic()
            with self._lock:
                closing, drain = self._closing, self._drain
            if closing and not drain:
                for s in self._slots:
                    if s.job is not None:
                        self._fail_job(s.job, PoolError("worker pool closed "
                                                        "without drain"))
                        s.job = None
                        self._kill_slot(s)
                return
            if closing and self._idle():
                return
            for s in self._slots:
                if s.proc is None and now >= s.backoff_until:
                    self._spawn(s)
            self._dispatch_jobs()
            if closing and self._idle():
                return
            ready = self._wait(now)
            if self._wake_r in ready:
                while self._wake_r.poll(0):
                    try:
                        self._wake_r.recv()
                    except (EOFError, OSError):
                        break
            for s in self._slots:
                if s.conn is not None and s.conn in ready:
                    self._drain_conn(s)
            for s in self._slots:
                if (s.proc is not None and s.proc.sentinel in ready
                        and not s.proc.is_alive()):
                    self._on_death(s, "worker process died")
            self._enforce_deadlines()
            self._heartbeat()

    def _idle(self) -> bool:
        with self._lock:
            if self._pending:
                return False
        return all(s.job is None for s in self._slots)

    def _wait(self, now: float) -> set:
        waits: list[Any] = [self._wake_r]
        wake_times = []
        for s in self._slots:
            if s.conn is not None:
                waits.append(s.conn)
            if s.proc is not None:
                waits.append(s.proc.sentinel)
            else:
                wake_times.append(s.backoff_until)
            if s.job is not None and s.job.deadline is not None:
                wake_times.append(s.job.deadline)
            if s.awaiting_pong:
                wake_times.append(s.pong_deadline)
        wake_times.append(now + self.heartbeat_s)
        timeout = max(0.0, min(wake_times) - now)
        try:
            ready = mp_connection.wait(waits, timeout)
        except OSError:
            ready = []
        return set(ready)

    # -- spawning / death ----------------------------------------------------

    def _spawn(self, s: _Slot) -> None:
        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=self._worker_main, args=(child_conn, self._cache_entries),
            name=f"repro-serve-worker-{s.id}", daemon=True)
        proc.start()
        child_conn.close()
        s.proc, s.conn, s.ready = proc, parent_conn, False
        s.spawned += 1
        s.last_hb = time.monotonic()
        s.awaiting_pong = False
        if s.spawned > 1:
            self.worker_restarts += 1

    def _on_death(self, s: _Slot, why: str) -> None:
        job, s.job = s.job, None
        if s.conn is not None:
            try:
                s.conn.close()
            except OSError:
                pass
        if s.proc is not None:
            s.proc.join(timeout=0)
        s.proc, s.conn, s.ready = None, None, False
        s.awaiting_pong = False
        s.consec_failures += 1
        backoff = min(self.backoff_cap_s,
                      self.backoff_base_s * 2 ** (s.consec_failures - 1))
        s.backoff_until = time.monotonic() + backoff
        if job is not None:
            job.reenqueues += 1
            self.reenqueues += 1
            if job.reenqueues > self.max_reenqueues:
                self._fail_job(job, WorkerCrashed(
                    f"job lost to {job.reenqueues} worker crashes "
                    f"(cap {self.max_reenqueues}): {why}"))
            else:
                with self._lock:
                    self._pending.appendleft(job)

    def _kill_slot(self, s: _Slot) -> None:
        if s.proc is not None:
            try:
                s.proc.kill()
            except (OSError, AttributeError):
                pass
            s.proc.join(timeout=5)
        self._on_death_cleanup(s)

    def _on_death_cleanup(self, s: _Slot) -> None:
        if s.conn is not None:
            try:
                s.conn.close()
            except OSError:
                pass
        s.proc, s.conn, s.ready = None, None, False
        s.awaiting_pong = False
        s.consec_failures += 1
        s.backoff_until = time.monotonic() + min(
            self.backoff_cap_s,
            self.backoff_base_s * 2 ** (s.consec_failures - 1))

    # -- pipe traffic --------------------------------------------------------

    def _drain_conn(self, s: _Slot) -> None:
        while s.conn is not None and s.conn.poll(0):
            try:
                msg = s.conn.recv()
            except (EOFError, OSError):
                self._on_death(s, "worker pipe closed")
                return
            self._handle_msg(s, msg)

    def _handle_msg(self, s: _Slot, msg: tuple) -> None:
        kind = msg[0]
        if kind == "ready":
            s.ready = True
        elif kind == "pong":
            s.awaiting_pong = False
        elif kind == "ok":
            _, job_id, payload, meta = msg
            if s.job is not None and s.job.id == job_id:
                job, s.job = s.job, None
                s.consec_failures = 0
                self.jobs_ok += 1
                meta = dict(meta)
                meta.setdefault("reenqueues", job.reenqueues)
                meta.setdefault("worker", s.id)
                meta.setdefault("dispatched_at", s.dispatched_at)
                if not job.future.set_running_or_notify_cancel():
                    return
                job.future.set_result((payload, meta))
        elif kind == "err":
            _, job_id, type_name, text = msg
            if s.job is not None and s.job.id == job_id:
                job, s.job = s.job, None
                s.consec_failures = 0  # the worker itself is healthy
                self._fail_job(job, _revive_exception(type_name, text))

    # -- dispatch / deadlines / heartbeats ----------------------------------

    def _dispatch_jobs(self) -> None:
        now = time.monotonic()
        # round-robin over slots (not first-free) so successive jobs
        # spread across workers: each worker's private compile cache
        # warms instead of one hot worker absorbing every dispatch
        n = len(self._slots)
        order = [self._slots[(self._rr + i) % n] for i in range(n)]
        for s in order:
            if s.proc is None or not s.ready or s.job is not None:
                continue
            while True:  # shed expired heads without wasting the slot
                with self._lock:
                    job = self._pending.popleft() if self._pending else None
                if job is None:
                    return
                if job.deadline is not None and now > job.deadline:
                    self.deadline_missed += 1
                    self._fail_job(job, DeadlineExceeded(
                        "deadline expired before dispatch", shed=True))
                    continue
                break
            self._dispatches += 1
            self._rr = (self._slots.index(s) + 1) % n
            s.job, s.dispatched_at = job, now
            try:
                s.conn.send(("run", job.id, job.spec_jsons, job.backend_sers))
            except OSError:
                self._on_death(s, "worker pipe broken at dispatch")
                continue
            if self.chaos is not None:
                self.chaos.on_dispatch(self._dispatches, s.proc)

    def _enforce_deadlines(self) -> None:
        now = time.monotonic()
        for s in self._slots:
            job = s.job
            if job is not None and job.deadline is not None \
                    and now > job.deadline:
                self.deadline_missed += 1
                s.job = None
                self._fail_job(job, DeadlineExceeded(
                    f"deadline exceeded {now - job.deadline:.3f}s into "
                    "the run; worker killed"))
                self._kill_slot(s)

    def _heartbeat(self) -> None:
        now = time.monotonic()
        for s in self._slots:
            if s.proc is None or s.conn is None:
                continue
            if s.awaiting_pong and now > s.pong_deadline:
                self._fail_job_of(s, "worker unresponsive to heartbeat")
                self._kill_slot(s)
                continue
            if (s.ready and s.job is None and not s.awaiting_pong
                    and now - s.last_hb >= self.heartbeat_s):
                self._hb_seq += 1
                try:
                    s.conn.send(("ping", self._hb_seq))
                except OSError:
                    self._on_death(s, "worker pipe broken at heartbeat")
                    continue
                s.awaiting_pong = True
                s.last_hb = now
                s.pong_deadline = now + self.heartbeat_timeout_s

    def _fail_job_of(self, s: _Slot, why: str) -> None:
        job, s.job = s.job, None
        if job is not None:
            job.reenqueues += 1
            self.reenqueues += 1
            if job.reenqueues > self.max_reenqueues:
                self._fail_job(job, WorkerCrashed(why))
            else:
                with self._lock:
                    self._pending.appendleft(job)

    # -- teardown ------------------------------------------------------------

    def _fail_job(self, job: _Job, exc: BaseException) -> None:
        self.jobs_failed += 1
        if not job.future.done():
            job.future.set_exception(exc)

    def _abort_pending(self, exc: BaseException) -> None:
        while True:
            with self._lock:
                job = self._pending.popleft() if self._pending else None
            if job is None:
                return
            self._fail_job(job, exc)

    def _stop_workers(self) -> None:
        for s in self._slots:
            if s.conn is not None:
                try:
                    s.conn.send(("stop",))
                except OSError:
                    pass
        for s in self._slots:
            if s.proc is not None:
                s.proc.join(timeout=5)
                if s.proc.is_alive():
                    try:
                        s.proc.kill()
                    except OSError:
                        pass
                    s.proc.join(timeout=5)
            if s.conn is not None:
                try:
                    s.conn.close()
                except OSError:
                    pass
            s.proc, s.conn, s.ready = None, None, False


def _revive_exception(type_name: str, text: str) -> Exception:
    """Rebuild a worker-reported exception: builtin types round-trip
    (ValueError stays ValueError for the client's error event), anything
    else degrades to a RuntimeError carrying the remote type name."""
    import builtins

    cls = getattr(builtins, type_name, None)
    if isinstance(cls, type) and issubclass(cls, Exception):
        try:
            return cls(text)
        except Exception:  # noqa: BLE001 -- exotic constructor signature
            pass
    return RuntimeError(f"{type_name}: {text}")
