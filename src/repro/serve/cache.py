"""Compile cache: warm `DDASimulator` instances keyed by program shape.

The cost structure the server amortizes is XLA compilation: a cold
`repro.run()` on the dense backend traces + lowers + compiles the scanned
program (seconds) and then executes it (milliseconds). Every compiled
executable lives in `DDASimulator._compiled`, keyed by argument
shapes/dtypes -- so holding the *simulator* across requests is holding the
compile cache. `CompileCache` does exactly that: one simulator per
**cache signature**, leased to one run at a time.

The signature is the dense scan program's shape identity -- everything
that changes what gets compiled or the constants baked into it:

  * the problem component verbatim (kind AND params: n, d and the data
    seed -- problem arrays are closure constants in the XLA program);
  * the topology component verbatim (k, graph seed -- the mixing matrix
    is a baked constant);
  * the stepsize component verbatim (a(t) closure constants);
  * T and eval_every (scan lengths / segment shapes);
  * the schedule component's KIND only -- its params (h, p) are the comm
    MASK, which is *data* to the scanned program, not shape. The kind
    stays in the key per the issue's contract; note "every" vs "periodic"
    also picks the cond-free all-comm program variant;
  * the resolved backend component (mix / loop / compress_keep shape the
    program realization);
  * controller presence/params (an adaptive run drives the per-segment
    program; a plain run drives the whole-run scan).

Deliberately NOT in the key -- the per-request knobs a warm simulator is
rebound with before each run: `seed` (PRNG fold, data), `r` (host-side
time-axis bookkeeping), `eps_frac`/`name` (host-side bookkeeping).

Thread-safety: a global lock guards the table; each entry has its own
RLock held for the duration of a lease, so two requests with the same
signature serialize on the simulator (its run methods mutate
`last_timings`) while different signatures run concurrently. Eviction is
LRU over non-leased entries only.
"""

from __future__ import annotations

import contextlib
import json
import threading
from collections import OrderedDict
from typing import Any, Callable, Iterator

from repro.experiments.spec import ComponentSpec, ExperimentSpec

__all__ = ["CompileCache", "cache_signature"]

#: spec fields that never shape the compiled program (rebound per lease)
CACHE_FREE_FIELDS = ("name", "seed", "r", "eps_frac")


def cache_signature(spec: ExperimentSpec,
                    backend: ComponentSpec | None = None) -> str:
    """Canonical JSON string identifying the compiled-program family a
    dense spec runs on; see the module docstring for what is in and out.
    Two specs with equal signatures can safely share one warm
    `DDASimulator` (per-request knobs rebound under the lease)."""
    d = spec.to_dict()
    d.pop("spec_version", None)
    for f in CACHE_FREE_FIELDS:
        d.pop(f, None)
    d.pop("backends", None)  # the RESOLVED backend is keyed instead
    d["schedule"] = d["schedule"]["kind"]  # params are mask data
    b = backend.to_dict() if backend is not None else None
    return json.dumps([d, b], sort_keys=True)


class _Entry:
    __slots__ = ("sim", "lock", "active", "hits")

    def __init__(self):
        self.sim: Any = None
        self.lock = threading.RLock()
        self.active = 0  # leases currently held (never evict while > 0)
        self.hits = 0


class CompileCache:
    """LRU table of warm simulators, one per cache signature.

    `lease(spec, backend, factory)` is the whole API: a context manager
    yielding `(sim, hit)`. On a miss `factory()` builds the simulator
    (under the entry lock, so concurrent first requests for one signature
    build once and the rest wait and hit). The caller must treat the
    simulator as exclusively theirs for the lease's duration and rebind
    any per-request knobs (`sim.schedule`, `sim.r`) before running.
    """

    def __init__(self, max_entries: int = 32):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: OrderedDict[str, _Entry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @contextlib.contextmanager
    def lease(self, spec: ExperimentSpec, backend: ComponentSpec,
              factory: Callable[[], Any]) -> Iterator[tuple[Any, bool]]:
        sig = cache_signature(spec, backend)
        with self._lock:
            entry = self._entries.get(sig)
            hit = entry is not None
            if hit:
                self._entries.move_to_end(sig)
                self.hits += 1
                entry.hits += 1
            else:
                entry = _Entry()
                self._entries[sig] = entry
                self.misses += 1
            entry.active += 1
        try:
            with entry.lock:
                if entry.sim is None:
                    entry.sim = factory()
                yield entry.sim, hit
        finally:
            with self._lock:
                entry.active -= 1
                self._evict_locked()

    def _evict_locked(self) -> None:
        while len(self._entries) > self.max_entries:
            victim = next((sig for sig, e in self._entries.items()
                           if e.active == 0), None)
            if victim is None:  # every entry leased: nothing evictable now
                return
            del self._entries[victim]
            self.evictions += 1

    def stats(self) -> dict[str, Any]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "capacity": self.max_entries,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / total) if total else 0.0,
            }
