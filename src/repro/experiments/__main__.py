"""CLI: run checked-in experiment manifests and inspect their artifacts.

    PYTHONPATH=src python -m repro.experiments run benchmarks/manifests/complete_every.json \
        [--backend netsim] [--out results/run_smoke]
    PYTHONPATH=src python -m repro.experiments trace results/run_smoke/complete_every__dense.json
    PYTHONPATH=src python -m repro.experiments list

`run` executes the manifest on every backend it declares (or just
`--backend`), prints one summary line per run, and (with --out) writes each
`RunResult` as `<out>/<spec.name>__<backend-kind>[-<engine>].json` -- the
artifact the CI run-smoke job uploads -- plus, per run, a detail event
timeline as `...__<tag>.trace.json` (Perfetto/chrome://tracing loadable)
and `...__<tag>.trace.jsonl` (raw event stream). `trace` renders the phase
breakdown / counters / r-hat-vs-r summary of saved RunResult JSONs.
`list` prints the registries, i.e. every kind a manifest may name.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.experiments import (ExperimentSpec, backends, faultplans,
                               problems, run, schedules, stepsizes,
                               topologies)
from repro.obs import Tracer, render_summary, write_chrome_trace, write_jsonl


def _result_tag(result) -> str:
    tag = result.backend.kind
    engine = result.backend.params.get("engine") or result.extras.get("engine")
    if result.backend.kind == "netsim" and engine:
        tag += f"-{engine}"
    if result.backend.params.get("dryrun"):
        tag += "-dryrun"
    return tag


def _cmd_run(args) -> int:
    spec = ExperimentSpec.from_file(args.manifest)
    targets = (spec.backends if args.backend is None
               else [b for b in spec.backends if b.kind == args.backend])
    if not targets:
        print(f"[experiments] manifest {spec.name!r} declares no backend "
              f"{args.backend!r} (has {[b.kind for b in spec.backends]})")
        return 2
    out_dir = pathlib.Path(args.out) if args.out else None
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
    tags_used: dict[str, int] = {}
    for backend in targets:
        # with --out, capture the full per-event timeline for the trace
        # artifacts; without it, run() makes its own phase-level tracer
        tracer = Tracer(detail=True) if out_dir is not None else None
        result = run(spec, backend=backend, tracer=tracer)
        final = result.trace.fvals[-1] if result.trace.fvals else None
        tta = result.time_to_target
        tag = _result_tag(result)
        # two declared backends can share a tag (same kind+engine, params
        # differing elsewhere); suffix instead of silently clobbering
        n_seen = tags_used.get(tag, 0)
        tags_used[tag] = n_seen + 1
        if n_seen:
            tag = f"{tag}-{n_seen + 1}"
        print(f"[experiments] {spec.name} on {tag}: "
              f"wall={result.wall_s:.2f}s "
              f"final_F={'n/a' if final is None else f'{final:.4g}'} "
              f"tta={'n/a' if tta is None else f'{tta:.4g}'}")
        if out_dir is not None:
            path = out_dir / f"{spec.name}__{tag}.json"
            path.write_text(result.to_json())
            print(f"[experiments] wrote {path}")
            run_name = f"{spec.name}__{tag}"
            tpath = write_chrome_trace(tracer, out_dir / f"{run_name}.trace.json",
                                       run_name=run_name)
            lpath = write_jsonl(tracer, out_dir / f"{run_name}.trace.jsonl")
            print(f"[experiments] wrote {tpath} and {lpath}")
    return 0


def _cmd_trace(args) -> int:
    status = 0
    for i, path in enumerate(args.results):
        if i:
            print()
        try:
            result = json.loads(pathlib.Path(path).read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[experiments] cannot read {path}: {e}")
            status = 2
            continue
        print(render_summary(result))
    return status


def _cmd_list(_args) -> int:
    for reg in (problems, topologies, schedules, stepsizes, backends,
                faultplans):
        print(f"{reg.kind} kinds: {', '.join(reg.names())}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    runp = sub.add_parser("run", help="run a spec manifest")
    runp.add_argument("manifest", help="path to an ExperimentSpec JSON")
    runp.add_argument("--backend", default=None,
                      help="only this declared backend kind")
    runp.add_argument("--out", default=None,
                      help="directory for RunResult JSON artifacts")
    runp.set_defaults(fn=_cmd_run)
    tracep = sub.add_parser("trace",
                            help="summarize saved RunResult JSON artifacts")
    tracep.add_argument("results", nargs="+",
                        help="RunResult JSON file(s) from `run --out`")
    tracep.set_defaults(fn=_cmd_trace)
    listp = sub.add_parser("list", help="print the component registries")
    listp.set_defaults(fn=_cmd_list)
    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        # downstream pager/head closed the pipe mid-summary: not an error
        sys.stderr.close()
        return 0


if __name__ == "__main__":
    sys.exit(main())
