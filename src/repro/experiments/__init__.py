"""One experiment API: declarative `ExperimentSpec` -> `repro.run()`.

The paper's whole point is comparing ONE algorithm across regimes -- n,
k-regular expander vs complete graph, schedule h(t), measured tradeoff r --
yet the repo grew three execution modes with three incompatible front doors
(dense `DDASimulator`, event-driven `NetSimulator`, the shard_map
launcher). This package makes the comparison declarative:

    import repro

    spec = repro.ExperimentSpec(
        name="expander_periodic",
        problem={"kind": "quadratic_consensus",
                 "params": {"n": 16, "d": 10}},
        topology={"kind": "expander", "params": {"k": 4}},
        schedule={"kind": "periodic", "params": {"h": 2}},
        backends=[{"kind": "netsim", "params": {"scenario": "homogeneous",
                                                "engine": "auto"}}],
        stepsize={"kind": "inv_sqrt", "params": {"A": 0.5}},
        T=2000, eval_every=5, r=0.05, eps_frac=0.02)

    result = repro.run(spec)                     # -> RunResult
    grid = repro.run_sweep(spec, "schedule.params.h", [1, 2, 4, 8])

Components resolve through string-keyed registries (problems, topologies,
schedules, stepsizes, backends), specs round-trip through JSON exactly
(checked-in manifests under benchmarks/manifests/ ARE the experiments), and
every backend returns the same canonical `RunResult` (trace + wall-clock +
empirical r + the paper's h_opt/n_opt/tau predictions).
"""

from repro.experiments.components import (LMProblem, Problem, problems,
                                          schedules, stepsizes, topologies)
from repro.experiments.registry import Registry
from repro.experiments.result import RunResult
from repro.experiments.runner import backends, run, run_all, run_sweep
from repro.experiments.spec import ComponentSpec, ExperimentSpec


def __getattr__(name):
    # lazy: repro.faults.plan itself imports this package's registry module,
    # so an eager import here would be circular when repro.faults loads first
    if name in ("FaultPlan", "faultplans"):
        from repro.faults.plan import FaultPlan, faultplans
        return {"FaultPlan": FaultPlan, "faultplans": faultplans}[name]
    if name in ("Compressor", "compressors"):
        from repro.compress import Compressor, compressors
        return {"Compressor": Compressor, "compressors": compressors}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ComponentSpec",
    "Compressor",
    "ExperimentSpec",
    "FaultPlan",
    "LMProblem",
    "Problem",
    "Registry",
    "RunResult",
    "backends",
    "compressors",
    "faultplans",
    "problems",
    "run",
    "run_all",
    "run_sweep",
    "schedules",
    "stepsizes",
    "topologies",
]
