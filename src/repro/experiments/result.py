"""Canonical run result: one type for all three backends.

`RunResult` unifies what the three front doors used to return separately:
the evaluation trace (`core.dda.SimTrace`, whatever its time axis means on
that backend), host wall-clock, the empirical tradeoff measurement
(`netsim.RMeasurement`, when the backend observes messages), and the
paper's closed-loop predictions (`h_opt` / `n_opt` / `tau_eps` from
`core.tradeoff`). `to_json` emits strict-RFC JSON (via
`core.dda.json_sanitize`: inf/nan -> null, so a diverged run is still a
readable artifact); `from_json` reconstructs the dataclasses. The one lossy
edge: numeric fields that were inf/nan come back as None -- exactly the
convention the convergence tier's artifacts already use.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.dda import SimTrace, TRACE_FIELDS, json_sanitize
from repro.experiments.spec import ExperimentSpec, ComponentSpec
from repro.netsim.simulator import RMeasurement
from repro.obs.metrics import RunMetrics

__all__ = ["RunResult"]

RESULT_VERSION = 1


@dataclasses.dataclass
class RunResult:
    """Outcome of one `repro.experiments.run` call.

    Fields:
      spec:           the spec as run.
      backend:        the resolved backend component (spec.backends entry,
                      params included -- engine, scenario, mesh...).
      trace:          SimTrace; sim_time is simulated time (dense), the
                      event clock (netsim) or eq.-9 time units (launch).
      wall_s:         host wall-clock of the backend run.
      eps_value:      resolved accuracy target (None without eps_frac).
      time_to_target: first trace time at or below eps_value; None when no
                      target was set or it was never reached.
      r_measurement:  empirical r recovered from the run's own timeline
                      (netsim backends; None elsewhere).
      predictions:    paper design-rule outputs (n_opt, h_opt, tau_eps)
                      from the empirical r when measured, else from the
                      configured spec.r.
      extras:         backend-specific observability (engine name, drop
                      counts, controller retune path, launch losses...).
      metrics:        `repro.obs.RunMetrics` -- the structured metrics
                      block (compile/execute wall split, message/byte
                      counters, retune history, step-time quantiles,
                      r-hat trajectory). Populated by every `repro.run()`
                      on every backend; optional in the JSON schema so
                      pre-metrics result files still load.
    """

    spec: ExperimentSpec
    backend: ComponentSpec
    trace: SimTrace
    wall_s: float
    eps_value: float | None = None
    time_to_target: float | None = None
    r_measurement: RMeasurement | None = None
    predictions: dict[str, Any] | None = None
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)
    metrics: RunMetrics | None = None

    @property
    def final_f(self) -> float:
        return self.trace.fvals[-1]

    def to_dict(self) -> dict:
        pred = None
        if self.predictions is not None:
            pred = {k: (dataclasses.asdict(v)
                        if dataclasses.is_dataclass(v) else v)
                    for k, v in self.predictions.items()}
        d = {
            "result_version": RESULT_VERSION,
            "spec": self.spec.to_dict(),
            "backend": self.backend.to_dict(),
            "trace": {f: list(getattr(self.trace, f))
                      for f in TRACE_FIELDS},
            "wall_s": self.wall_s,
            "eps_value": self.eps_value,
            "time_to_target": self.time_to_target,
            "r_measurement": (None if self.r_measurement is None
                              else dataclasses.asdict(self.r_measurement)),
            "predictions": pred,
            "extras": self.extras,
            "metrics": (None if self.metrics is None
                        else self.metrics.to_dict()),
        }
        return json_sanitize(d)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_dict(cls, d: dict) -> "RunResult":
        version = d.get("result_version", RESULT_VERSION)
        if version != RESULT_VERSION:
            raise ValueError(f"unsupported result_version {version!r}")
        meas = d.get("r_measurement")
        metrics = d.get("metrics")
        return cls(
            spec=ExperimentSpec.from_dict(d["spec"]),
            backend=ComponentSpec.from_dict(d["backend"]),
            trace=SimTrace(**{f: list(d["trace"].get(f, []))
                              for f in TRACE_FIELDS}),
            wall_s=d["wall_s"],
            eps_value=d.get("eps_value"),
            time_to_target=d.get("time_to_target"),
            r_measurement=None if meas is None else RMeasurement(**meas),
            predictions=d.get("predictions"),
            extras=dict(d.get("extras") or {}),
            metrics=None if metrics is None else RunMetrics.from_dict(metrics),
        )

    @classmethod
    def from_json(cls, text: str) -> "RunResult":
        return cls.from_dict(json.loads(text))
