"""`run(spec) -> RunResult`: one dispatcher over all three execution modes.

The three front doors this replaces -- `core.dda.DDASimulator` (dense,
synchronous, one device), `netsim.NetSimulator` (event-driven async
cluster), `launch.train.train_consensus_lm` (shard_map consensus LM
training) -- stay as the engines; this module only WIRES them from an
`ExperimentSpec`, so benchmarks and examples declare experiments as data
instead of hand-assembling problems, topologies, schedules and traces per
mode. Every build happens fresh per call: specs are immutable, runs are
deterministic for a fixed spec (netsim backends bit-identically so), and
mutable schedule state can never leak between runs.

Backends (the `backends` registry):

  * "dense"  -- DDASimulator on the stacked jax path. With a
    "dense_adaptive" controller the segment loop is driven here, timing
    uniform-comm chunks and feeding `adaptive.DenseController` so h retunes
    from WALL-CLOCK iteration timings (the eq. 9 inversion of
    DenseRTracker).
  * "netsim" -- NetSimulator on a scenario preset (params pick the preset
    and its knobs, plus engine / algorithm / adaptive controller).
  * "launch" -- train_consensus_lm on a host mesh (params pick mesh shape,
    optimizer knobs; the problem must be the "lm" kind). `dryrun: true`
    compiles both step programs and runs zero steps.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.dda import (DDASimulator, SimTrace, trace_time_to_reach)
from repro.core import consensus as _cons
from repro.core import tradeoff as _tradeoff
from repro.core.graphs import CommGraph, GraphSequence
from repro.experiments import components as C
from repro.experiments.registry import Registry
from repro.experiments.result import RunResult
from repro.experiments.spec import ComponentSpec, ExperimentSpec
from repro.obs import RunMetrics, Tracer, profile_ctx, sample_quantiles

#: bytes per scalar in a dense/launch gossip payload (float32)
_DENSE_SCALAR_BYTES = 4

__all__ = ["backends", "batch_compat_report", "run", "run_all",
           "run_sweep"]

backends = Registry("backend")

#: eps the closed-loop predictions are quoted at (L = R = 1 units), matching
#: `NetSimulator.predict`'s convention
PREDICT_EPS = 0.1


# ---------------------------------------------------------------------------
# shared build helpers
# ---------------------------------------------------------------------------


#: built problems, keyed by canonical (kind, params) JSON. Problem builders
#: are deterministic and their closures stateless, so instances are safely
#: shared across runs; what the cache buys is F* -- `Problem.fstar` is
#: lazily computed and instance-cached, and for the non-smooth problem it
#: is an 800-iteration centralized subgradient descent that a sweep grid
#: would otherwise redo per cell. Bounded FIFO: sweeps revisit few kinds.
_PROBLEM_CACHE: dict[str, Any] = {}
_PROBLEM_CACHE_MAX = 32


def _build_problem(spec: ExperimentSpec):
    import json as _json
    key = _json.dumps([spec.problem.kind,
                       sorted(spec.problem.params.items())])
    hit = _PROBLEM_CACHE.get(key)
    if hit is None:
        hit = C.build_component(C.problems, spec.problem.kind,
                                spec.problem.params)
        if len(_PROBLEM_CACHE) >= _PROBLEM_CACHE_MAX:
            _PROBLEM_CACHE.pop(next(iter(_PROBLEM_CACHE)))
        _PROBLEM_CACHE[key] = hit
    return hit


def _build_topology(spec: ExperimentSpec, n: int):
    return C.build_component(C.topologies, spec.topology.kind,
                             spec.topology.params, n=n)


def _build_schedule(spec: ExperimentSpec):
    return C.build_component(C.schedules, spec.schedule.kind,
                             spec.schedule.params)


def _build_stepsize(spec: ExperimentSpec):
    return C.build_component(C.stepsizes, spec.stepsize.kind,
                             spec.stepsize.params)


def _require(condition: bool, msg: str) -> None:
    if not condition:
        raise ValueError(msg)


def _eps_value(spec: ExperimentSpec, problem) -> float | None:
    if spec.eps_frac is None:
        return None
    return problem.eps_value(spec.eps_frac)


def _target_fields(trace: SimTrace, eps_value: float | None
                   ) -> tuple[float | None, float | None]:
    if eps_value is None:
        return None, None
    tta = trace_time_to_reach(trace, eps_value)
    return eps_value, (None if math.isinf(tta) else tta)


def _dense_predictions(graph: CommGraph, r: float, schedule,
                       lam2: float, c: float = 1.0) -> dict[str, Any]:
    """Paper design-rule outputs for a dense run -- one definition shared
    by the serial backend and the vmapped sweep executor, so the two can
    never drift. `c` is the compressor's bytes-on-wire ratio: every
    optimum is quoted at the effective tradeoff r*c (see core.tradeoff)."""
    return {
        "r": r,
        "wire_ratio": c,
        "n_opt": _tradeoff.n_opt_complete(r, c),
        "h_opt": _tradeoff.h_opt_int(graph.n, graph.degree, r, lam2, c),
        "tau_eps": _tradeoff.time_to_accuracy(
            PREDICT_EPS, graph.n, graph.degree, r, lam2,
            schedule=schedule, c=c),
    }


def _compression_block(kind: str, ratio: float, full_bytes: float,
                       wire_bytes: float, residual_norms
                       ) -> dict[str, Any]:
    """The canonical `RunMetrics.compression` record -- one definition for
    dense, vmapped and netsim runs: the compressor kind, its bytes-on-wire
    ratio, how many bytes compression kept off the wire, and the mean
    per-node error-feedback residual norm at each trace point."""
    if residual_norms is None:
        rns: list[float] = []
    else:
        rns = [float(v) for v in np.asarray(residual_norms).ravel()]
    return {"kind": kind, "wire_ratio": float(ratio),
            "bytes_saved": float(max(full_bytes - wire_bytes, 0.0)),
            "residual_norms": rns}


# ---------------------------------------------------------------------------
# dense backend
# ---------------------------------------------------------------------------


def _dense_message_counts(trace: SimTrace, n: int, k: int, d: int,
                          ratio: float = 1.0) -> dict[str, Any]:
    """Closed-form message accounting for a dense run: each gossip round
    is every node shipping its d-vector to its k neighbors; `ratio` is the
    compressor's wire ratio (bytes actually crossing the wire)."""
    rounds = int(trace.comms[-1]) if trace.comms else 0
    msgs = rounds * n * k
    return {"gossip_rounds": rounds, "msgs": msgs,
            "bytes_on_wire": float(msgs * d * _DENSE_SCALAR_BYTES * ratio)}


def _dense_parts(spec: ExperimentSpec, backend: ComponentSpec
                 ) -> dict[str, Any]:
    """Validate a dense run and build everything BUT the simulator: the
    problem, graph, schedule and stepsize closures plus the parsed backend
    params. One definition shared by the serial backend, the vmapped sweep
    executor and the serving layer, so their validation can never drift."""
    _require(spec.faults is None,
             "fault injection is event-driven (netsim backends only); the "
             "dense synchronous loop has no crash/recover semantics")
    params = dict(backend.params)
    compress_keep = params.pop("compress_keep", None)
    mix = params.pop("mix", "auto")
    loop = params.pop("loop", "scan")
    _require(not params, f"dense backend has unknown params {sorted(params)}")
    compression = None
    if spec.compression is not None:
        _require(compress_keep is None,
                 "backend param 'compress_keep' and spec.compression are "
                 "mutually exclusive; spec.compression is the canonical "
                 "compression axis (kind 'topk' subsumes compress_keep)")
        from repro.compress import build_compressor
        compression = build_compressor(spec.compression.kind,
                                       dict(spec.compression.params))
    problem = _build_problem(spec)
    _require(isinstance(problem, C.Problem),
             f"dense backend cannot run problem kind "
             f"{spec.problem.kind!r}")
    _require(problem.subgrad_stack is not None,
             f"problem {problem.name!r} has no stacked jax subgradient")
    _require(spec.stepsize.kind != "inv_sqrt",
             'stepsize "inv_sqrt" is host-only; use "sqrt" on dense')
    graph = _build_topology(spec, problem.n)
    _require(isinstance(graph, CommGraph),
             "dense backend needs a fixed CommGraph topology "
             "(time-varying sequences are netsim-only)")
    _require(spec.time_limit is None,
             "time_limit is event-clock only (netsim backends)")
    return dict(problem=problem, graph=graph,
                schedule=_build_schedule(spec),
                a_fn=_build_stepsize(spec),
                compress_keep=compress_keep, compression=compression,
                mix=mix, loop=loop)


def _dense_sim(spec: ExperimentSpec, parts: dict[str, Any]) -> DDASimulator:
    """Fresh DDASimulator from `_dense_parts` output. Everything that
    shapes the simulator's compiled programs (problem closures, graph,
    stepsize, mix/compression realization) comes from fields the serving
    layer's `cache_signature` pins, which is what makes instances reusable
    across requests: per-request knobs (schedule, r) are rebound by the
    caller before each run."""
    import jax
    problem = parts["problem"]
    return DDASimulator(problem.subgrad_stack, jax.jit(problem.objective),
                        parts["graph"], parts["schedule"],
                        a_fn=parts["a_fn"], r=spec.r,
                        compress_keep=parts["compress_keep"],
                        compression=parts["compression"],
                        mix=parts["mix"], projection=problem.projection)


@backends.register("dense")
def _run_dense(spec: ExperimentSpec, backend: ComponentSpec,
               tracer: Tracer | None = None,
               sim_cache=None) -> RunResult:
    """Dense backend. `sim_cache` (optional, a `repro.serve.CompileCache`
    or anything with its `lease(spec, backend, factory)` contract) makes
    the simulator -- and with it the AOT-compiled scan programs in
    `DDASimulator._compiled` -- persistent across calls: repeat traffic
    with the same cache signature skips trace+compile entirely. The lease
    holds a per-entry lock for the duration of the run, and per-request
    knobs outside the signature (schedule, r) are rebound under it."""
    import contextlib

    import jax.numpy as jnp

    tr = tracer if tracer is not None else Tracer()
    with tr.span("build"):
        parts = _dense_parts(spec, backend)
        problem, graph = parts["problem"], parts["graph"]
        schedule, loop = parts["schedule"], parts["loop"]
        if sim_cache is None:
            lease = contextlib.nullcontext((_dense_sim(spec, parts), False))
        else:
            lease = sim_cache.lease(spec, backend,
                                    lambda: _dense_sim(spec, parts))
        x0 = jnp.zeros((problem.n, problem.d))
    with lease as (sim, cache_hit):
        if sim_cache is not None:
            # a cached simulator may have been built for a different lane
            # of the same signature: rebind the per-request knobs the
            # signature deliberately leaves free
            sim.schedule = schedule
            sim.r = spec.r
            tr.count("cache_hit" if cache_hit else "cache_miss")
        return _run_dense_leased(spec, backend, tr, sim, problem, graph,
                                 schedule, loop, x0)


def _run_dense_leased(spec: ExperimentSpec, backend: ComponentSpec,
                      tr: Tracer, sim: DDASimulator, problem, graph,
                      schedule, loop: str, x0) -> RunResult:
    import jax.numpy as jnp  # noqa: F401  (kept: jnp used below)
    extras: dict[str, Any] = {"mix_mode": sim.mix_mode}

    metrics_fields: dict[str, Any] = {}
    if spec.controller is not None:
        _require(loop == "scan",
                 "a dense_adaptive run drives its own wall-clock chunked "
                 "segment loop; leave the 'loop' param unset")
        _require(spec.controller.kind == "dense_adaptive",
                 f"dense backend needs a 'dense_adaptive' controller, got "
                 f"{spec.controller.kind!r}")
        from repro.adaptive import AdaptiveSchedule, DenseController
        _require(isinstance(schedule, AdaptiveSchedule),
                 "a controller run needs schedule kind 'adaptive'")
        ctrl_params = dict(spec.controller.params)
        if sim.compression is not None:
            # the dense tracker's r_hat comes from wall-clock timings that
            # do NOT shrink with compression; tell the controller the wire
            # ratio so its retunes target the effective tradeoff r*c
            ctrl_params.setdefault("wire_ratio",
                                   sim.wire_ratio(problem.d))
        ctrl = DenseController(schedule, **ctrl_params)
        ctrl.attach_tracer(tr)
        timings: dict[str, Any] = {"compile_s": 0.0, "iter_walls": []}
        t0 = time.perf_counter()
        with tr.span("execute"), profile_ctx(spec.profile_dir):
            trace = _dense_adaptive_run(sim, ctrl, x0, spec.T,
                                        spec.eval_every, spec.seed,
                                        timings=timings)
        wall = time.perf_counter() - t0
        extras["retunes"] = [(rt.from_t, rt.h) for rt in schedule.retunes]
        extras["h_final"] = schedule.h_current
        extras["r_hat"] = ctrl.tracker.r_hat
        metrics_fields.update(
            compile_s=timings["compile_s"],
            retunes=len(schedule.retunes),
            retune_history=schedule.retunes,
            r_hat=ctrl.tracker.r_hat,
            r_hat_trajectory=ctrl.r_hat_history,
            step_time_quantiles=sample_quantiles(timings["iter_walls"],
                                                 "host"))
    else:
        t0 = time.perf_counter()
        with profile_ctx(spec.profile_dir):
            trace = sim.run(x0, spec.T, eval_every=spec.eval_every,
                            seed=spec.seed, loop=loop)
        wall = time.perf_counter() - t0
        tr.add_host_span("compile", tr.now() - wall,
                         sim.last_timings["compile_s"])
        tr.add_host_span("execute", tr.now() - wall
                         + sim.last_timings["compile_s"],
                         wall - sim.last_timings["compile_s"])
        metrics_fields.update(compile_s=sim.last_timings["compile_s"])
        if sim.last_timings["eval_s"]:
            metrics_fields.update(eval_s=sim.last_timings["eval_s"])
        tr.count("device_execute_s", sim.last_timings["execute_s"])

    # execute_s is defined as the non-compile remainder of the backend
    # wall, so compile_s + execute_s == wall_s exactly (JSON back-compat:
    # wall_s stays the lump sum). Pure device time is the
    # "device_execute_s" counter.
    compile_s = float(metrics_fields.get("compile_s", 0.0))
    metrics_fields["execute_s"] = max(wall - compile_s, 0.0)
    metrics_fields["compile_s"] = min(compile_s, wall)
    eps_value, tta = _target_fields(trace, _eps_value(spec, problem))
    ratio = sim.wire_ratio(problem.d)
    predictions = _dense_predictions(graph, spec.r, schedule,
                                     graph.lambda2(), c=ratio)
    counts = _dense_message_counts(trace, problem.n, graph.degree,
                                   problem.d, ratio=ratio)
    if sim.compression is not None:
        comp_block = _compression_block(
            sim.compression.kind, ratio,
            full_bytes=float(counts["msgs"] * problem.d
                             * _DENSE_SCALAR_BYTES),
            wire_bytes=counts["bytes_on_wire"],
            residual_norms=sim.last_res_norms)
        extras["compression"] = comp_block
        metrics_fields["compression"] = comp_block
    metrics = RunMetrics.from_tracer(tr, **metrics_fields, **counts)
    return RunResult(spec=spec, backend=backend, trace=trace, wall_s=wall,
                     eps_value=eps_value, time_to_target=tta,
                     predictions=predictions, extras=extras,
                     metrics=metrics)


def _dense_adaptive_run(sim: DDASimulator, ctrl, x0, T: int,
                        eval_every: int, seed: int,
                        timer: Callable[[], float] = time.perf_counter,
                        timings: dict[str, Any] | None = None
                        ) -> SimTrace:
    """DDASimulator.run with the measure->predict->act loop on wall-clock.

    Mirrors the plain segment loop but splits each evaluation segment into
    uniform-comm chunks, dispatches each chunk through the scanned segment
    program's AOT compile cache (`DDASimulator._get_compiled`, shape-keyed;
    the comm mask is data), times every chunk on the host clock (blocking
    on device completion), feeds `DenseController.observe`, and lets the
    controller splice a re-solved h at each segment boundary -- the
    frontier is `done`, the number of iterations already executed, so the
    splice only shapes masks not yet built.

    Compiling AOT *outside* the timed window is what keeps the controller's
    measurements clean: timing a compile-bearing call would poison
    t_plain/t_comm by orders of magnitude (with h0=1 the single t=1 plain
    chunk is the ONLY plain sample until the first retune, and a
    compile-inflated t_plain latches r_hat at 0 forever). The compiled
    executables land in the same `sim._compiled` cache `run`/`run_batch`
    use, so a warm simulator (e.g. held by the serving layer's compile
    cache) pays no compile at all -- adaptive runs ride the same warm
    executables as packable plain runs. (Earlier revisions instead warmed
    the jit cache on a discarded duplicate call, paying one full chunk of
    wasted compute per new chunk length.)

    `timings` (optional dict) receives the observability record: the AOT
    compile walls (always on the REAL clock -- the injected `timer` only
    drives the controller's measurements) accumulate into
    `timings["compile_s"]`, and each iteration's measured wall appends to
    `timings["iter_walls"]`.
    """
    import jax
    import jax.numpy as jnp

    n, k = sim.graph.n, sim.graph.degree
    r_eff = sim.r * sim.wire_ratio(int(np.prod(x0.shape[1:])))
    ctrl.bind(n, k, sim.graph.lambda2())
    sched = sim.schedule
    z = jnp.zeros_like(x0)
    x = x0
    xhat = x0
    res = jnp.zeros_like(x0)
    t = jnp.asarray(0.0, jnp.float32)
    trace = SimTrace([], [], [], [], [])
    res_norms: list[float] = []
    sim_time = 0.0
    comm_total = 0
    root = jax.random.PRNGKey(seed)

    done = 0
    while done < T:
        seg_end = min(done + eval_every, T)
        while done < seg_end:
            comm = sched.is_comm_step(done + 1)
            chunk = 1
            while (done + chunk < seg_end
                   and sched.is_comm_step(done + chunk + 1) == comm):
                chunk += 1
            mask = np.full(chunk, comm)
            keys = jax.random.split(jax.random.fold_in(root, done), chunk)
            args = (z, x, xhat, res, t, jnp.asarray(mask), keys)
            tw = time.perf_counter()
            entry = sim._get_compiled(("segment",), sim._segment, args)
            if timings is not None:
                timings["compile_s"] += time.perf_counter() - tw
            fn = sim._segment if entry is None else entry
            t0 = timer()
            z, x, xhat, res, t = fn(*args)
            jax.block_until_ready(xhat)
            per_iter = max(timer() - t0, 0.0) / chunk
            if timings is not None:
                timings["iter_walls"].extend([per_iter] * chunk)
            for _ in range(chunk):
                ctrl.observe(per_iter, comm)
            done += chunk
            if comm:
                comm_total += chunk
                sim_time += chunk * (1.0 / n + k * r_eff)
            else:
                sim_time += chunk * (1.0 / n)
        xbar = jnp.mean(xhat, axis=0)
        trace.iters.append(done)
        trace.sim_time.append(sim_time)
        trace.fvals.append(float(jnp.mean(jax.vmap(sim.eval_fn)(xhat))))
        trace.fvals_consensus.append(float(sim.eval_fn(xbar)))
        trace.comms.append(comm_total)
        trace.disagreement.append(float(_cons.disagreement(z)))
        if sim.compression is not None:
            res_norms.append(float(jnp.mean(jnp.linalg.norm(
                res.reshape(n, -1), axis=1))))
        if done < T:  # a splice at the frontier T would shape zero
            ctrl.maybe_retune(done)  # iterations: don't record phantoms
    sim.last_res_norms = (np.asarray(res_norms)
                          if sim.compression is not None else None)
    return trace


# ---------------------------------------------------------------------------
# netsim backend
# ---------------------------------------------------------------------------

_SCENARIO_KNOBS = {
    "homogeneous": (),
    "lossy": ("loss", "jitter", "retries", "retry_timeout"),
    "straggler": ("slow_factor", "n_slow"),
    "adversarial": ("loss", "slow_factor", "n_slow", "rewire_every",
                    "retries", "retry_timeout"),
    "time_varying": ("rewire_every", "loss"),
}


def _build_scenario(kind: str, n: int, r: float, topology,
                    message_bytes: float, knobs: dict[str, Any]):
    from repro.netsim import scenarios as S
    allowed = _SCENARIO_KNOBS.get(kind)
    if allowed is None:
        raise KeyError(f"unknown scenario {kind!r}; have "
                       f"{sorted(_SCENARIO_KNOBS)}")
    unknown = set(knobs) - set(allowed)
    if unknown:
        raise ValueError(f"scenario {kind!r} has unknown knobs "
                         f"{sorted(unknown)} (allowed: {list(allowed)})")
    builder = {"homogeneous": S.homogeneous, "lossy": S.lossy,
               "straggler": S.straggler, "adversarial": S.adversarial,
               "time_varying": S.time_varying_expander}[kind]
    if kind == "time_varying" and "rewire_every" not in knobs:
        raise ValueError("time_varying scenario needs rewire_every")
    return builder(n, r, message_bytes=message_bytes, graph=topology,
                   **knobs)


@backends.register("netsim")
def _run_netsim(spec: ExperimentSpec, backend: ComponentSpec,
                tracer: Tracer | None = None) -> RunResult:
    from repro.netsim import NetSimulator

    tr = tracer if tracer is not None else Tracer()
    _require(spec.profile_dir is None,
             "profile_dir wraps the dense scanned program; the netsim "
             "event loops are host numpy (nothing for jax.profiler to see)")
    params = dict(backend.params)
    scenario_kind = params.pop("scenario", "homogeneous")
    engine = params.pop("engine", "auto")
    algorithm = params.pop("algorithm", "dda")
    message_bytes = params.pop("message_bytes", None)
    pushsum_w_floor = params.pop("pushsum_w_floor", 0.5)
    pushsum_inject = params.pop("pushsum_inject", "plain")
    knobs = {k: params.pop(k)
             for k in list(params)
             if k in {"loss", "jitter", "slow_factor", "n_slow",
                      "rewire_every", "retries", "retry_timeout"}}
    _require(not params,
             f"netsim backend has unknown params {sorted(params)}")

    with tr.span("build"):
        problem = _build_problem(spec)
        _require(isinstance(problem, C.Problem),
                 f"netsim backend cannot run problem kind "
                 f"{spec.problem.kind!r}")
        topology = _build_topology(spec, problem.n)
        if scenario_kind == "time_varying" or knobs.get("rewire_every"):
            _require(isinstance(topology, GraphSequence),
                     "a rewiring scenario needs an 'expander_sequence' "
                     "topology")

        if message_bytes is None:
            from repro.netsim.scenarios import DEFAULT_MESSAGE_BYTES
            message_bytes = DEFAULT_MESSAGE_BYTES
        scenario = _build_scenario(scenario_kind, problem.n, spec.r,
                                   topology, message_bytes, knobs)
        a_fn = _build_stepsize(spec)
        schedule = _build_schedule(spec)

        ctrl = None
        if spec.controller is not None:
            _require(spec.controller.kind == "adaptive",
                     f"netsim backend needs an 'adaptive' controller, got "
                     f"{spec.controller.kind!r}")
            from repro.adaptive import AdaptiveController, AdaptiveSchedule
            _require(isinstance(schedule, AdaptiveSchedule),
                     "a controller run needs schedule kind 'adaptive'")
            ctrl = AdaptiveController(schedule, **spec.controller.params)

        plan = None
        if spec.faults is not None:
            from repro.faults import faultplans
            plan = C.build_component(faultplans, spec.faults.kind,
                                     spec.faults.params, n=problem.n)

        compression = None
        if spec.compression is not None:
            from repro.compress import build_compressor
            compression = build_compressor(spec.compression.kind,
                                           dict(spec.compression.params))

        sim = NetSimulator(scenario, problem.grad_fn, problem.eval_fn,
                           a_fn=a_fn,
                           schedule=None if ctrl is not None else schedule,
                           algorithm=algorithm, seed=spec.seed,
                           pushsum_w_floor=pushsum_w_floor,
                           pushsum_inject=pushsum_inject,
                           engine=engine, controller=ctrl, tracer=tr,
                           faults=plan, compression=compression)
    x0 = np.zeros((problem.n, problem.d))
    time_limit = math.inf if spec.time_limit is None else spec.time_limit
    t0 = time.perf_counter()
    with tr.span("execute"):
        trace = sim.run(x0, spec.T, eval_every=spec.eval_every,
                        time_limit=time_limit)
    wall = time.perf_counter() - t0

    eps_value, tta = _target_fields(trace, _eps_value(spec, problem))
    measurement = None
    predictions = None
    if sim.msg_flights and sim.compute_times:
        predictions = sim.predict(eps=PREDICT_EPS)
        measurement = predictions.pop("measurement")
    extras: dict[str, Any] = {
        "engine": sim._engine_inst.name,
        "scenario": scenario.name,
        "sent": sim.sent, "drops": sim.drops, "rewires": sim.rewires,
    }
    metrics_fields: dict[str, Any] = dict(
        compile_s=0.0,  # event loops are host numpy: nothing compiles
        execute_s=wall,
        msgs=sim.sent,
        # wire_bytes is message_bytes scaled by the compressor's ratio
        # (identical when uncompressed): bytes that actually crossed links
        bytes_on_wire=float(sim.sent * sim.net.wire_bytes),
        drops=sim.drops,
        gossip_rounds=int(trace.comms[-1]) if trace.comms else 0,
        step_time_quantiles=sample_quantiles(sim.compute_times, "sim"))
    if sim.compression is not None:
        comp_block = _compression_block(
            sim.compression.kind,
            sim.net.wire_bytes / sim.net.message_bytes,
            full_bytes=float(sim.sent * sim.net.message_bytes),
            wire_bytes=float(sim.sent * sim.net.wire_bytes),
            residual_norms=sim.comp_res_norms)
        extras["compression"] = comp_block
        metrics_fields["compression"] = comp_block
    if plan is not None:
        faults_block = {**(sim.fault_stats or {}),
                        "retransmits": sim.retransmits}
        extras["faults"] = faults_block
        metrics_fields["faults"] = faults_block
    elif sim.retransmits:
        metrics_fields["faults"] = {"retransmits": sim.retransmits}
    if ctrl is not None:
        extras["retunes"] = [(rt.from_t, rt.h)
                             for rt in ctrl.schedule.retunes]
        extras["h_final"] = ctrl.schedule.h_current
        extras["h_opt_hat"] = ctrl.schedule.h_opt_hat
        extras["r_hat"] = ctrl.tracker.r_hat
        if ctrl.reweighter is not None:
            extras["lam2_eff"] = ctrl.reweighter.last_lam2
        extras["reweight_gossip"] = ctrl.reweight_gossip
        metrics_fields.update(retunes=len(ctrl.schedule.retunes),
                              retune_history=ctrl.schedule.retunes,
                              r_hat=ctrl.tracker.r_hat,
                              r_hat_trajectory=ctrl.r_hat_history)
    metrics = RunMetrics.from_tracer(tr, **metrics_fields)
    return RunResult(spec=spec, backend=backend, trace=trace, wall_s=wall,
                     eps_value=eps_value, time_to_target=tta,
                     r_measurement=measurement, predictions=predictions,
                     extras=extras, metrics=metrics)


# ---------------------------------------------------------------------------
# launch backend
# ---------------------------------------------------------------------------


@backends.register("launch")
def _run_launch(spec: ExperimentSpec, backend: ComponentSpec,
                tracer: Tracer | None = None) -> RunResult:
    import jax

    from repro.launch.mesh import make_mesh
    from repro.launch.train import train_consensus_lm
    from repro.models import registry as _models
    from repro.optim import adamw, cosine_lr

    tr = tracer if tracer is not None else Tracer()
    _require(spec.faults is None,
             "fault injection is event-driven (netsim backends only); "
             "launch runs real processes")
    _require(spec.profile_dir is None,
             "profile_dir wraps the dense scanned program; profile the "
             "launch path with jax.profiler around train_consensus_lm "
             "directly")
    params = dict(backend.params)
    mesh_shape = tuple(params.pop("mesh", None) or (1, 1, 1))
    dryrun = params.pop("dryrun", False)
    lr = params.pop("lr", 3e-4)
    mix_target = params.pop("mix_target", "params")
    log_every = params.pop("log_every", 0)
    _require(not params,
             f"launch backend has unknown params {sorted(params)}")

    with tr.span("build"):
        problem = _build_problem(spec)
        _require(isinstance(problem, C.LMProblem),
                 'launch backend needs the "lm" problem kind')
        _require(len(mesh_shape) == 3, "mesh must be (pod, data, model)")
        _require(spec.controller is None,
                 "the launch backend has no controller hook yet (ROADMAP)")
        # reject spec fields this backend cannot honor rather than silently
        # dropping them -- the other backends validate the same way
        _require(spec.eps_frac is None,
                 "launch has no F* to target; eps_frac is dense/netsim-only")
        _require(spec.time_limit is None,
                 "time_limit is event-clock only (netsim backends)")
        _require(spec.stepsize == ComponentSpec("sqrt", {"A": 1.0}),
                 "the launch optimizer's LR schedule is the backend's 'lr' "
                 "param; leave spec.stepsize at its default")
        n_pods = mesh_shape[0]
        if int(np.prod(mesh_shape)) > jax.device_count():
            raise ValueError(
                f"mesh {mesh_shape} needs {int(np.prod(mesh_shape))} "
                f"devices, have {jax.device_count()} (set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=... "
                f"before any jax import, as launch/dryrun.py does)")
        mesh = make_mesh(mesh_shape, ("pod", "data", "model"))
        graph = _build_topology(spec, n_pods)
        _require(isinstance(graph, CommGraph),
                 "launch backend needs a fixed CommGraph topology")
        schedule = _build_schedule(spec)

        cfg = _models.get_config(problem.arch, problem.variant)
        optimizer = adamw(cosine_lr(lr, max(spec.T, 1)))
    t0 = time.perf_counter()
    with tr.span("execute"):
        report = train_consensus_lm(
            cfg, optimizer, mesh, steps=spec.T, schedule=schedule,
            graph=graph, r_estimate=spec.r,
            batch_per_node=problem.batch_per_node,
            seq_len=problem.seq_len, seed=spec.seed, log_every=log_every,
            mix_target=mix_target, dryrun=dryrun, tracer=tr)
    wall = time.perf_counter() - t0

    # fold the per-step losses into the canonical trace shape at the spec's
    # eval cadence; sim_time is the closed-form eq. 9/19 charge
    n, k = graph.n, graph.degree
    trace = SimTrace([], [], [], [], [])
    for step in range(spec.eval_every, report.steps + 1, spec.eval_every):
        H = schedule.H(step)
        trace.iters.append(step)
        trace.sim_time.append(step * (1.0 / n) + H * k * spec.r)
        trace.fvals.append(float(report.losses[step - 1]))
        # the recorded loss is already the pod-mean, which is the closest
        # thing this mode has to F at the consensus average; keep the
        # column populated so all six SimTrace fields stay row-aligned
        trace.fvals_consensus.append(float(report.losses[step - 1]))
        trace.comms.append(H)
        trace.disagreement.append(0.0)
    extras = {"arch": problem.arch, "variant": problem.variant,
              "mesh": list(mesh_shape), "comm_rounds": report.comm_rounds,
              "sim_time_units": report.sim_time_units, **report.extras}

    # message accounting mirrors the dense closed form: every gossip round
    # is each pod shipping its (pod-sharded) parameter payload to its k
    # graph neighbors; param_bytes comes measured from the train loop
    compile_s = float(report.extras.get("local_compile_s", 0.0)
                      + report.extras.get("fused_compile_s", 0.0))
    msgs = report.comm_rounds * n_pods * k
    metrics_fields: dict[str, Any] = dict(
        compile_s=min(compile_s, wall),
        execute_s=max(wall - compile_s, 0.0),
        msgs=msgs,
        bytes_on_wire=float(msgs * report.extras.get("param_bytes", 0.0)),
        gossip_rounds=report.comm_rounds)
    step_walls = report.extras.get("step_walls")
    if step_walls:
        metrics_fields["step_time_quantiles"] = sample_quantiles(
            step_walls, "host")
    metrics = RunMetrics.from_tracer(tr, **metrics_fields)
    return RunResult(spec=spec, backend=backend, trace=trace, wall_s=wall,
                     extras=extras, metrics=metrics)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def _resolve_backend(spec: ExperimentSpec,
                     backend: int | str | ComponentSpec | None
                     ) -> ComponentSpec:
    if backend is None:
        return spec.backends[0]
    if isinstance(backend, ComponentSpec):
        return backend
    if isinstance(backend, int):
        return spec.backends[backend]
    for b in spec.backends:
        if b.kind == backend:
            return b
    # a kind the spec does not declare is still runnable (explicit ask)
    if backend in backends:
        return ComponentSpec(backend)
    raise KeyError(f"unknown backend {backend!r}; spec declares "
                   f"{[b.kind for b in spec.backends]}, registry has "
                   f"{backends.names()}")


def run(spec: ExperimentSpec,
        backend: int | str | ComponentSpec | None = None,
        tracer: Tracer | None = None) -> RunResult:
    """Run one spec on one backend (default: the first it declares).

    `tracer` (optional `repro.obs.Tracer`) collects the run's spans and
    counters; every backend populates `RunResult.metrics` from it either
    way (an internal tracer is created when none is given). Pass
    `Tracer(detail=True)` to additionally capture per-event timelines
    (netsim node steps / message flights, launch per-step walls) for
    Chrome-trace export via `repro.obs.write_chrome_trace`.
    """
    b = _resolve_backend(spec, backend)
    return backends.builder(b.kind)(spec, b, tracer=tracer)


def run_all(spec: ExperimentSpec) -> list[RunResult]:
    """Run a spec on EVERY backend it declares, in declaration order."""
    return [run(spec, b) for b in spec.backends]


def run_sweep(spec: ExperimentSpec, axis: str, values: Sequence[Any],
              backend: int | str | ComponentSpec | None = None,
              parallel: str | None = None,
              processes: int | None = None) -> list[RunResult]:
    """One run per value of a dotted-path axis -- the paper's grids as one
    call: `run_sweep(spec, "schedule.params.h", [1, 2, 4, 8, 16])`,
    `run_sweep(spec, "problem.params.n", [4, 8, 16])`,
    `run_sweep(spec, "r", [0.001, 0.01, 0.1])`.

    `parallel` picks the executor (results are index-aligned with `values`
    and cell-for-cell identical to the serial path up to float fusion):

      * None / "serial" -- one `run()` per cell, in order (the baseline).
      * "vmap" -- dense-backend grids whose cells differ only along
        data-batchable axes (seed / r / the whole schedule component /
        eps_frac / name) are stacked into ONE vmapped+jitted scanned run
        (`DDASimulator.run_batch`): one compile and one batched dispatch
        for the grid instead of a fresh trace+compile per cell. Grids that
        are not batchable (different shapes, controllers, netsim/launch
        backends, host-only knobs) silently fall back to the serial path.
      * "process" -- fan cells out across OS processes (spawn context, so
        no forked jax runtime). Meant for the netsim backends, whose
        event-driven runs are pure host numpy and deterministic for a
        fixed spec -- results merge back in order, bit-identical to
        serial. `processes` caps the pool (default: cell count capped by
        CPU count).
    """
    cells = [spec.with_value(axis, v) for v in values]
    if parallel in (None, "serial"):
        return [run(c, backend=backend) for c in cells]
    if parallel == "vmap":
        out, reason = _run_sweep_vmap(cells, backend)
        if out is not None:
            return out
        # fall back to serial -- but LOUDLY: every result's metrics carry
        # the reason the grid did not pack, so "my sweep got slow" is
        # diagnosable from the artifacts instead of a silent degradation
        results = [run(c, backend=backend) for c in cells]
        for r in results:
            if r.metrics is not None:
                r.metrics = dataclasses.replace(
                    r.metrics,
                    notes={**r.metrics.notes, "vmap_fallback": reason})
            r.extras["vmap_fallback"] = reason
        return results
    if parallel == "process":
        return _run_sweep_process(cells, backend, processes)
    raise ValueError(f"parallel must be None/'serial'/'vmap'/'process', "
                     f"got {parallel!r}")


# ---------------------------------------------------------------------------
# sweep executors
# ---------------------------------------------------------------------------


#: spec fields a vmapped sweep may vary per lane: everything else must be
#: identical across cells so one program (one problem, topology, stepsize
#: and shape) serves every lane. The schedule varies because the scanned
#: loop consumes it as a precomputed comm MASK (data); seed is the PRNG
#: fold; r only shapes the host-side time axis; eps_frac/name are
#: host-side bookkeeping.
_VMAP_LANE_FIELDS = ("name", "seed", "r", "schedule", "eps_frac")


def _vmap_signature(spec: ExperimentSpec, backend: ComponentSpec) -> str:
    import json as _json
    d = spec.to_dict()
    for f in _VMAP_LANE_FIELDS:
        d.pop(f)
    d.pop("backends")
    return _json.dumps([d, backend.to_dict()], sort_keys=True)


def batch_compat_report(spec: ExperimentSpec,
                        backend: ComponentSpec) -> str | None:
    """Why this (spec, backend) cannot ride a vmapped `run_batch` lane --
    None when it can. One definition shared by the sweep executor's
    fallback diagnostics and the serving layer's lane packer, so "why
    didn't this pack" always has the same answer. Deliberately
    side-effect-light: builds at most the (cached) problem and topology."""
    if backend.kind != "dense":
        return (f"backend {backend.kind!r} is not dense (vmap lanes are the "
                f"dense scanned program; netsim/launch runs are host loops)")
    if spec.controller is not None:
        return ("a controller run drives its own wall-clock chunk loop and "
                "retunes its schedule online; lanes share one comm mask")
    if spec.time_limit is not None:
        return "time_limit is event-clock only (netsim backends)"
    if spec.profile_dir is not None:
        return "profiling wants one run per capture"
    if spec.faults is not None:
        return "fault injection is event-driven (netsim backends only)"
    params = dict(backend.params)
    params.pop("compress_keep", None)
    params.pop("mix", None)
    if params.pop("loop", "scan") != "scan":
        return "loop='segment' is the host-loop baseline (one lane per run)"
    if params:
        return f"dense backend has unknown params {sorted(params)}"
    if spec.stepsize.kind == "inv_sqrt":
        return 'stepsize "inv_sqrt" is host-only; lanes need the jnp path'
    problem = _build_problem(spec)
    if not isinstance(problem, C.Problem) or problem.subgrad_stack is None:
        return (f"problem kind {spec.problem.kind!r} has no stacked jax "
                f"subgradient")
    graph = _build_topology(spec, problem.n)
    if not isinstance(graph, CommGraph):
        return ("topology is a time-varying sequence (netsim-only); lanes "
                "need one fixed CommGraph")
    return None


def _vmap_pool_report(cells: Sequence[ExperimentSpec],
                      resolved: Sequence[ComponentSpec]) -> str | None:
    """Why this POOL of cells cannot batch into one vmapped dispatch --
    None when it can: every cell individually batchable, plus pairwise
    shape compatibility (identical outside the per-lane fields)."""
    for c, b in zip(cells, resolved):
        reason = batch_compat_report(c, b)
        if reason is not None:
            return f"cell {c.name!r}: {reason}"
    sigs = {_vmap_signature(c, b) for c, b in zip(cells, resolved)}
    if len(sigs) != 1:
        return (f"cells differ outside the batchable lane fields "
                f"{_VMAP_LANE_FIELDS} ({len(sigs)} distinct shape "
                f"signatures; every lane must share one compiled program)")
    return None


def _dense_batch_results(cells: Sequence[ExperimentSpec],
                         resolved: Sequence[ComponentSpec],
                         sim: DDASimulator, problem, graph,
                         schedules: Sequence[Any],
                         traces: Sequence[SimTrace], wall: float,
                         lane_counter: str = "vmap_lanes"
                         ) -> list[RunResult]:
    """Per-lane RunResults for one `run_batch` dispatch -- the assembly
    shared by the vmapped sweep executor and the serving layer's lane
    packer (identical bookkeeping: amortized wall split, closed-form
    message counts, per-lane predictions)."""
    B = len(cells)
    lam2 = graph.lambda2()
    lane_wall = wall / B
    # one compile serves every lane: amortize it evenly so per-lane
    # compile_s + execute_s == wall_s holds just like the serial path
    lane_compile = min(sim.last_timings["compile_s"] / B, lane_wall)
    ratio = sim.wire_ratio(problem.d)
    rn_all = sim.last_res_norms  # (B, S) from run_batch, or None
    results = []
    for i, (c, bk, sched, trc) in enumerate(zip(cells, resolved,
                                                schedules, traces)):
        eps_value, tta = _target_fields(trc, _eps_value(c, problem))
        predictions = _dense_predictions(graph, c.r, sched, lam2, c=ratio)
        counts = _dense_message_counts(trc, problem.n, graph.degree,
                                       problem.d, ratio=ratio)
        extras = {"mix_mode": sim.mix_mode, lane_counter: B}
        comp_block = None
        if sim.compression is not None:
            comp_block = _compression_block(
                sim.compression.kind, ratio,
                full_bytes=float(counts["msgs"] * problem.d
                                 * _DENSE_SCALAR_BYTES),
                wire_bytes=counts["bytes_on_wire"],
                residual_norms=None if rn_all is None else rn_all[i])
            extras["compression"] = comp_block
        metrics = RunMetrics(
            compile_s=lane_compile,
            execute_s=max(lane_wall - lane_compile, 0.0),
            counters={lane_counter: float(B)},
            compression=comp_block,
            **counts)
        results.append(RunResult(
            spec=c, backend=bk, trace=trc, wall_s=lane_wall,
            eps_value=eps_value, time_to_target=tta,
            predictions=predictions,
            extras=extras,
            metrics=metrics))
    return results


def _run_sweep_vmap(cells: Sequence[ExperimentSpec], backend
                    ) -> tuple[list[RunResult] | None, str | None]:
    """Batched executor for shape-compatible dense cells. Returns
    (results, None) when the pool batched, (None, reason) when it did not
    (the caller falls back to serial -- which also surfaces any real
    validation errors with the serial path's messages -- and attaches the
    reason to the fallback results' metrics)."""
    resolved = [_resolve_backend(c, backend) for c in cells]
    reason = _vmap_pool_report(cells, resolved)
    if reason is not None:
        return None, reason
    spec0 = cells[0]

    import jax.numpy as jnp
    parts = _dense_parts(spec0, resolved[0])
    problem, graph = parts["problem"], parts["graph"]
    sim = _dense_sim(spec0, parts)
    schedules = [_build_schedule(c) for c in cells]
    masks = np.stack([s.comm_mask(0, spec0.T) for s in schedules])
    x0 = jnp.zeros((problem.n, problem.d))
    t0 = time.perf_counter()
    traces = sim.run_batch(x0, spec0.T, spec0.eval_every, masks,
                           seeds=[c.seed for c in cells],
                           rs=[c.r for c in cells])
    wall = time.perf_counter() - t0
    return _dense_batch_results(cells, resolved, sim, problem, graph,
                                schedules, traces, wall), None


def _process_cell(payload) -> RunResult:
    """Top-level worker (picklable) for `parallel="process"`."""
    spec_json, backend_ser = payload
    spec = ExperimentSpec.from_json(spec_json)
    backend = (ComponentSpec.from_dict(backend_ser)
               if isinstance(backend_ser, dict) else backend_ser)
    return run(spec, backend=backend)


def _run_sweep_process(cells: Sequence[ExperimentSpec], backend,
                       processes: int | None) -> list[RunResult]:
    import multiprocessing as mp
    import os
    backend_ser = (backend.to_dict() if isinstance(backend, ComponentSpec)
                   else backend)
    payloads = [(c.to_json(indent=None), backend_ser) for c in cells]
    n_proc = max(1, min(len(cells), processes or os.cpu_count() or 1))
    ctx = mp.get_context("spawn")  # never fork an initialized jax runtime
    with ctx.Pool(n_proc) as pool:
        return pool.map(_process_cell, payloads, chunksize=1)
