"""Component registries: problems, topologies, schedules, stepsizes.

Each registry maps a string kind + JSON-able kwargs (exactly what a
`ComponentSpec` carries) to a built component. Problems bundle BOTH
execution styles -- per-node numpy closures for the event-driven netsim and
stacked jax closures for the dense simulator -- so one spec runs unchanged
on every backend that can host its problem class.

Bit-identity note: the numpy closures here are the exact code previously
inlined in `benchmarks/fig_async.py` / `netsim.problems`, moved -- not
rewritten -- so the migrated benchmark drivers reproduce their pre-redesign
seeded traces bit-for-bit (gated in tests/test_experiments_migration.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import numpy as np

from repro.core import graphs as _graphs
from repro.core import schedules as _sched
from repro.core.dda import stepsize_sqrt
from repro.data.pipeline import metric_learning_pairs
from repro.experiments.registry import Registry
from repro.netsim.problems import quadratic_consensus as _quadratic

__all__ = [
    "Problem",
    "LMProblem",
    "problems",
    "topologies",
    "schedules",
    "stepsizes",
]

problems = Registry("problem")
topologies = Registry("topology")
schedules = Registry("schedule")
stepsizes = Registry("stepsize")


# ---------------------------------------------------------------------------
# problems
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Problem:
    """One distributed problem instance, in both execution styles.

    netsim/dense front halves:
      grad_fn:       per-node numpy `(i, x_i, t) -> g` (NetSimulator).
      eval_fn:       numpy `x -> float` full objective (NetSimulator).
      subgrad_stack: jax `(x_stack, t, key) -> g_stack` (DDASimulator).
      objective:     jax `x -> scalar` full objective (DDASimulator).
      projection:    optional stacked Proj_X for constrained problems
                     (jax; applied by DDASimulator after the prox step).

    `fstar_fn` computes (or looks up) the centralized optimum F*; it can be
    expensive (subgradient descent for the non-smooth problem), so it is
    called lazily and cached by `fstar`.
    """

    name: str
    n: int
    d: int
    grad_fn: Callable[[int, np.ndarray, int], np.ndarray]
    eval_fn: Callable[[np.ndarray], float]
    subgrad_stack: Callable | None = None
    objective: Callable | None = None
    projection: Callable | None = None
    fstar_fn: Callable[[], float] | None = None
    _fstar: float | None = dataclasses.field(default=None, repr=False)

    @property
    def fstar(self) -> float:
        if self._fstar is None:
            if self.fstar_fn is None:
                raise ValueError(f"problem {self.name!r} has no known F*")
            self._fstar = float(self.fstar_fn())
        return self._fstar

    def f0(self) -> float:
        """F at the canonical start x0 = 0."""
        return float(self.eval_fn(np.zeros(self.d)))

    def eps_value(self, eps_frac: float) -> float:
        """Accuracy target F* + eps_frac * (F(0) - F*)."""
        return self.fstar + float(eps_frac) * (self.f0() - self.fstar)


@dataclasses.dataclass(frozen=True)
class LMProblem:
    """Marker problem for the `launch` backend: the 'problem' is consensus
    data-parallel LM training of a registry architecture, not a convex
    objective -- dense/netsim backends reject it."""

    arch: str
    variant: str = "smoke"
    batch_per_node: int = 8
    seq_len: int = 64


@problems.register("quadratic_consensus", aliases=("quadratic",))
def _quadratic_problem(n: int, d: int, seed: int = 0,
                       batchable: bool = False) -> Problem:
    """`netsim.problems.quadratic_consensus` plus its dense jax half.
    `batchable` selects the eval form exactly as the netsim tests/bench do
    (the non-batchable form is what fig_adaptive's seeded traces used)."""
    centers, grad_fn, eval_fn = _quadratic(n, d, seed=seed,
                                           batchable=batchable)
    cbar = centers.mean(axis=0)
    spread = float(np.mean(np.sum(centers ** 2, axis=1))
                   - np.sum(cbar ** 2))

    import jax.numpy as jnp
    centers_j = jnp.asarray(centers)
    cbar_j = jnp.asarray(cbar)

    def subgrad_stack(x_stack, t, key):
        return 2.0 * (x_stack - centers_j)

    def objective(x):
        return jnp.sum((x - cbar_j) ** 2) + spread

    return Problem(name="quadratic_consensus", n=n, d=d,
                   grad_fn=grad_fn, eval_fn=eval_fn,
                   subgrad_stack=subgrad_stack, objective=objective,
                   fstar_fn=lambda: float(eval_fn(centers.mean(axis=0))))


def nonsmooth_centers(n: int, M: int, d: int, seed: int) -> np.ndarray:
    """The registry nonsmooth problem's center tensor (n, M, 2, d). Public
    so drivers that need problem GEOMETRY (fig2's R_est radius estimate)
    read the exact centers the problem optimizes instead of regenerating
    with their own copy of the center_scale constant."""
    from repro.data.pipeline import nonsmooth_quadratic_problem
    return nonsmooth_quadratic_problem(n, M, d, seed,
                                       center_scale=1.5).astype(np.float64)


def nonsmooth_centralized_optimum(centers: np.ndarray,
                                  iters: int = 800) -> float:
    """Reference F* via centralized subgradient descent on the mean
    objective (moved verbatim from benchmarks/fig_async.py; mirrors
    NonsmoothQuadratics.optimum_value)."""
    n, M, _, d = centers.shape

    def full_grad(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        pick = np.argmax(q, axis=-1)
        chosen = np.take_along_axis(diff, pick[..., None, None],
                                    axis=2)[:, :, 0]
        return 2.0 * np.sum(chosen, axis=(0, 1)) / n

    def value(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    x = np.zeros(d)
    best = value(x)
    lr0 = 1.0 / (4.0 * M)
    for t in range(1, iters + 1):
        x = x - (lr0 / math.sqrt(t)) * full_grad(x)
        if t % 50 == 0:
            best = min(best, value(x))
    return best


@problems.register("nonsmooth")
def _nonsmooth_problem(n: int, M: int = 30, d: int = 20,
                       seed: int = 0) -> Problem:
    """Paper section V.B non-smooth quadratics, f_i = sum_j max(l1, l2).
    Numpy closures moved verbatim from benchmarks/fig_async.build_problem;
    the jax half mirrors benchmarks/paper_problems.NonsmoothQuadratics."""
    centers = nonsmooth_centers(n, M, d, seed)

    def grad_fn(i, x, t):
        diff = x[None, None, :] - centers[i]          # (M, 2, d)
        q = np.sum(diff * diff, axis=-1)              # (M, 2)
        pick = np.argmax(q, axis=-1)                  # (M,)
        chosen = np.take_along_axis(
            diff, pick[:, None, None], axis=1)[:, 0]  # (M, d)
        return 2.0 * np.sum(chosen, axis=0)

    def eval_fn(x):
        diff = x[None, None, None, :] - centers       # (n, M, 2, d)
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    import jax.numpy as jnp
    centers_j = jnp.asarray(centers)

    def subgrad_stack(x_stack, t, key):
        diff = x_stack[:, None, None, :] - centers_j      # (n, M, 2, d)
        q = jnp.sum(diff * diff, axis=-1)                 # (n, M, 2)
        pick = jnp.argmax(q, axis=-1)                     # (n, M)
        chosen = jnp.take_along_axis(
            diff, pick[..., None, None], axis=2)[:, :, 0]  # (n, M, d)
        return 2.0 * jnp.sum(chosen, axis=1)

    def objective(x):
        diff = x[None, None, None, :] - centers_j
        q = jnp.sum(diff * diff, axis=-1)
        return jnp.mean(jnp.sum(jnp.max(q, axis=-1), axis=-1))

    return Problem(name="nonsmooth", n=n, d=d, grad_fn=grad_fn,
                   eval_fn=eval_fn, subgrad_stack=subgrad_stack,
                   objective=objective,
                   fstar_fn=lambda: nonsmooth_centralized_optimum(centers))


@problems.register("least_squares")
def _least_squares_problem(n: int, d: int = 64, m_per_node: int = 200,
                           seed: int = 0) -> Problem:
    """Node-specific least squares (the quickstart problem): f_i(x) =
    ||A_i x - b_i||^2 with per-node solutions, so consensus is required."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, m_per_node, d)) / np.sqrt(d)
    x_true = rng.normal(size=(d,))
    b = np.einsum("nmd,d->nm", A, x_true) + rng.normal(
        scale=0.1 + 0.5 * rng.random((n, 1)), size=(n, m_per_node))

    def grad_fn(i, x, t):
        res = A[i] @ x - b[i]
        return 2.0 * (A[i].T @ res)

    def eval_fn(x):
        res = np.einsum("nmd,d->nm", A, x) - b
        return float(np.mean(np.sum(res * res, axis=1)))

    import jax.numpy as jnp
    A_j, b_j = jnp.asarray(A), jnp.asarray(b)

    def subgrad_stack(x_stack, t, key):
        res = jnp.einsum("nmd,nd->nm", A_j, x_stack) - b_j
        return 2.0 * jnp.einsum("nmd,nm->nd", A_j, res)

    def objective(x):
        res = jnp.einsum("nmd,d->nm", A_j, x) - b_j
        return jnp.mean(jnp.sum(res * res, axis=1))

    def fstar():
        x_star, *_ = np.linalg.lstsq(A.reshape(n * m_per_node, d),
                                     b.reshape(-1), rcond=None)
        return eval_fn(x_star)

    return Problem(name="least_squares", n=n, d=d, grad_fn=grad_fn,
                   eval_fn=eval_fn, subgrad_stack=subgrad_stack,
                   objective=objective, fstar_fn=fstar)


@functools.lru_cache(maxsize=4)
def _metric_pairs_cached(m_pairs: int, d_feat: int, seed: int):
    """The pair set is independent of the node count, but the runner's
    problem cache keys on n -- without this, a fig1-style n sweep would
    regenerate the (2 m_pairs, d) synthetic dataset once per cell."""
    return metric_learning_pairs(m_pairs, d_feat, seed)


@problems.register("metric_learning")
def _metric_learning_problem(n: int, m_pairs: int = 2000, d_feat: int = 8,
                             seed: int = 0) -> Problem:
    """Paper section V.A metric learning: x = [vec(A) | b], hinge losses
    s_j * (dist_A(u_j, v_j) - b) + 1 over similar/dissimilar pairs, with
    Proj onto {A PSD, b >= 1}. The jax half mirrors
    benchmarks/paper_problems.MetricLearning (the fig1 driver's problem,
    now spec-addressable); pairs come from the same
    `data.pipeline.metric_learning_pairs` generator. The state dimension is
    d_feat^2 + 1 -- the paper's quadratic-in-d message-size regime. No
    closed-form F*, so eps targets must come from the driver (fig1 uses a
    fraction of F(0)).
    """
    u_np, v_np, s_np = _metric_pairs_cached(m_pairs, d_feat, seed)
    dim = d_feat * d_feat + 1
    base = m_pairs // n
    slices = [slice(i * base, (i + 1) * base) for i in range(n)]

    def _split_np(x):
        return x[:d_feat * d_feat].reshape(d_feat, d_feat), x[d_feat * d_feat]

    def grad_fn(i, x, t):
        A, b = _split_np(x)
        u, v, s = u_np[slices[i]], v_np[slices[i]], s_np[slices[i]]
        diff = u - v
        dist2 = np.einsum("md,de,me->m", diff, A, diff)
        w = np.where(s * (dist2 - b) + 1.0 > 0.0, s, 0.0)
        gA = np.einsum("m,md,me->de", w, diff, diff)
        return np.concatenate([gA.reshape(-1), [-np.sum(w)]])

    def eval_fn(x):
        A, b = _split_np(np.asarray(x))
        diff = u_np - v_np
        dist2 = np.einsum("md,de,me->m", diff, A, diff)
        return float(np.sum(np.maximum(0.0, s_np * (dist2 - b) + 1.0)))

    import jax
    import jax.numpy as jnp
    u_j, v_j, s_j = jnp.asarray(u_np), jnp.asarray(v_np), jnp.asarray(s_np)
    us = jnp.stack([u_j[sl] for sl in slices])
    vs = jnp.stack([v_j[sl] for sl in slices])
    ss = jnp.stack([s_j[sl] for sl in slices])

    def _split(x):
        return x[:d_feat * d_feat].reshape(d_feat, d_feat), x[d_feat * d_feat]

    def node_grad(x, u, v, s):
        A, b = _split(x)
        diff = u - v
        dist2 = jnp.einsum("md,de,me->m", diff, A, diff)
        w = jnp.where((s * (dist2 - b) + 1.0) > 0.0, s, 0.0)
        gA = jnp.einsum("m,md,me->de", w, diff, diff)
        return jnp.concatenate([gA.reshape(-1), -jnp.sum(w)[None]])

    def subgrad_stack(x_stack, t, key):
        return jax.vmap(node_grad)(x_stack, us, vs, ss)

    def objective(x):
        A, b = _split(x)
        diff = u_j - v_j
        dist2 = jnp.einsum("md,de,me->m", diff, A, diff)
        return jnp.sum(jnp.maximum(0.0, s_j * (dist2 - b) + 1.0))

    def _proj_one(x):
        A, b = _split(x)
        A = 0.5 * (A + A.T)
        evals, evecs = jnp.linalg.eigh(A)
        A = (evecs * jnp.maximum(evals, 0.0)) @ evecs.T
        return jnp.concatenate([A.reshape(-1),
                                jnp.maximum(b, 1.0)[None]])

    def projection(x_stack):
        return jax.vmap(_proj_one)(x_stack)

    return Problem(name="metric_learning", n=n, d=dim, grad_fn=grad_fn,
                   eval_fn=eval_fn, subgrad_stack=subgrad_stack,
                   objective=objective, projection=projection)


@problems.register("lm")
def _lm_problem(arch: str, variant: str = "smoke", batch_per_node: int = 8,
                seq_len: int = 64) -> LMProblem:
    return LMProblem(arch=arch, variant=variant,
                     batch_per_node=batch_per_node, seq_len=seq_len)


# ---------------------------------------------------------------------------
# topologies (n comes from the problem; params carry the shape knobs)
# ---------------------------------------------------------------------------


@topologies.register("complete")
def _complete(n: int) -> _graphs.CommGraph:
    return _graphs.complete_graph(n)


@topologies.register("ring")
def _ring(n: int) -> _graphs.CommGraph:
    return _graphs.ring_graph(n)


@topologies.register("torus")
def _torus(n: int) -> _graphs.CommGraph:
    return _graphs.torus_graph(n)


@topologies.register("hypercube")
def _hypercube(n: int) -> _graphs.CommGraph:
    return _graphs.hypercube_graph(n)


@topologies.register("expander")
def _expander(n: int, k: int = 4, seed: int = 0) -> _graphs.CommGraph:
    return _graphs.kregular_expander(n, k=k, seed=seed)


@topologies.register("rregular")
def _rregular(n: int, k: int = 4, seed: int = 0) -> _graphs.CommGraph:
    return _graphs.random_regular_expander(n, k=k, seed=seed)


@topologies.register("expander_sequence")
def _expander_seq(n: int, k: int = 4, length: int = 4,
                  seed: int = 0) -> _graphs.GraphSequence:
    return _graphs.expander_sequence(n, k=k, length=length, seed=seed)


# ---------------------------------------------------------------------------
# schedules (the registry `core.schedules.make_schedule` now routes through)
# ---------------------------------------------------------------------------


@schedules.register("every", aliases=("h1",))
def _every() -> _sched.CommSchedule:
    return _sched.EveryIteration()


@schedules.register("periodic")
def _periodic(h: int = 1) -> _sched.CommSchedule:
    return _sched.Periodic(h=h)


@schedules.register("sparse")
def _sparse(p: float = 0.3) -> _sched.CommSchedule:
    return _sched.IncreasinglySparse(p=p)


@schedules.register("piecewise")
def _piecewise(h: int = 1) -> _sched.CommSchedule:
    return _sched.PiecewisePeriodic(h=h)


@schedules.register("adaptive")
def _adaptive(h0: int = 1, p: float = 0.0, h_max: int = 512):
    from repro.adaptive.schedule import AdaptiveSchedule
    return AdaptiveSchedule(h0=h0, p=p, h_max=h_max)


# ---------------------------------------------------------------------------
# stepsizes
# ---------------------------------------------------------------------------


@stepsizes.register("sqrt")
def _sqrt(A: float = 1.0, q: float = 0.5) -> Callable:
    """a(t) = A / max(t, 1)^q -- `core.dda.stepsize_sqrt`, the canonical
    jax/numpy-generic default shared by every execution mode."""
    return stepsize_sqrt(A, q)


@stepsizes.register("inv_sqrt")
def _inv_sqrt(A: float = 1.0) -> Callable:
    """a(t) = A / sqrt(max(t, 1)) via `math.sqrt` on host floats -- the
    exact closure the netsim benchmarks historically inlined (kept distinct
    from "sqrt" because `x ** 0.5` and `math.sqrt(x)` are not guaranteed
    bit-equal, and the migration gate compares traces bitwise). Host-only:
    not traceable, so the dense backend rejects it."""
    def a(t):
        return A / math.sqrt(max(t, 1.0))
    return a


def build_component(registry: Registry, kind: str,
                    params: dict[str, Any], **extra: Any) -> Any:
    """Build `kind` from `registry` with spec params plus runner-provided
    context (e.g. the problem's n for topologies). Spec params win conflicts
    loudly: a manifest must not silently override runner context."""
    clash = set(params) & set(extra)
    if clash:
        raise ValueError(
            f"{registry.kind} {kind!r} params {sorted(clash)} are "
            f"runner-provided and cannot be set in the spec")
    return registry.build(kind, **params, **extra)
