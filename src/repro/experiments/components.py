"""Component registries: problems, topologies, schedules, stepsizes.

Each registry maps a string kind + JSON-able kwargs (exactly what a
`ComponentSpec` carries) to a built component. Problems bundle BOTH
execution styles -- per-node numpy closures for the event-driven netsim and
stacked jax closures for the dense simulator -- so one spec runs unchanged
on every backend that can host its problem class.

Bit-identity note: the numpy closures here are the exact code previously
inlined in `benchmarks/fig_async.py` / `netsim.problems`, moved -- not
rewritten -- so the migrated benchmark drivers reproduce their pre-redesign
seeded traces bit-for-bit (gated in tests/test_experiments_migration.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import numpy as np

from repro.core import graphs as _graphs
from repro.core import schedules as _sched
from repro.core.dda import stepsize_sqrt
from repro.experiments.registry import Registry
from repro.netsim.problems import quadratic_consensus as _quadratic

__all__ = [
    "Problem",
    "LMProblem",
    "problems",
    "topologies",
    "schedules",
    "stepsizes",
]

problems = Registry("problem")
topologies = Registry("topology")
schedules = Registry("schedule")
stepsizes = Registry("stepsize")


# ---------------------------------------------------------------------------
# problems
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Problem:
    """One distributed problem instance, in both execution styles.

    netsim/dense front halves:
      grad_fn:       per-node numpy `(i, x_i, t) -> g` (NetSimulator).
      eval_fn:       numpy `x -> float` full objective (NetSimulator).
      subgrad_stack: jax `(x_stack, t, key) -> g_stack` (DDASimulator).
      objective:     jax `x -> scalar` full objective (DDASimulator).

    `fstar_fn` computes (or looks up) the centralized optimum F*; it can be
    expensive (subgradient descent for the non-smooth problem), so it is
    called lazily and cached by `fstar`.
    """

    name: str
    n: int
    d: int
    grad_fn: Callable[[int, np.ndarray, int], np.ndarray]
    eval_fn: Callable[[np.ndarray], float]
    subgrad_stack: Callable | None = None
    objective: Callable | None = None
    fstar_fn: Callable[[], float] | None = None
    _fstar: float | None = dataclasses.field(default=None, repr=False)

    @property
    def fstar(self) -> float:
        if self._fstar is None:
            if self.fstar_fn is None:
                raise ValueError(f"problem {self.name!r} has no known F*")
            self._fstar = float(self.fstar_fn())
        return self._fstar

    def f0(self) -> float:
        """F at the canonical start x0 = 0."""
        return float(self.eval_fn(np.zeros(self.d)))

    def eps_value(self, eps_frac: float) -> float:
        """Accuracy target F* + eps_frac * (F(0) - F*)."""
        return self.fstar + float(eps_frac) * (self.f0() - self.fstar)


@dataclasses.dataclass(frozen=True)
class LMProblem:
    """Marker problem for the `launch` backend: the 'problem' is consensus
    data-parallel LM training of a registry architecture, not a convex
    objective -- dense/netsim backends reject it."""

    arch: str
    variant: str = "smoke"
    batch_per_node: int = 8
    seq_len: int = 64


@problems.register("quadratic_consensus", aliases=("quadratic",))
def _quadratic_problem(n: int, d: int, seed: int = 0,
                       batchable: bool = False) -> Problem:
    """`netsim.problems.quadratic_consensus` plus its dense jax half.
    `batchable` selects the eval form exactly as the netsim tests/bench do
    (the non-batchable form is what fig_adaptive's seeded traces used)."""
    centers, grad_fn, eval_fn = _quadratic(n, d, seed=seed,
                                           batchable=batchable)
    cbar = centers.mean(axis=0)
    spread = float(np.mean(np.sum(centers ** 2, axis=1))
                   - np.sum(cbar ** 2))

    import jax.numpy as jnp
    centers_j = jnp.asarray(centers)
    cbar_j = jnp.asarray(cbar)

    def subgrad_stack(x_stack, t, key):
        return 2.0 * (x_stack - centers_j)

    def objective(x):
        return jnp.sum((x - cbar_j) ** 2) + spread

    return Problem(name="quadratic_consensus", n=n, d=d,
                   grad_fn=grad_fn, eval_fn=eval_fn,
                   subgrad_stack=subgrad_stack, objective=objective,
                   fstar_fn=lambda: float(eval_fn(centers.mean(axis=0))))


def _nonsmooth_centers(n: int, M: int, d: int, seed: int) -> np.ndarray:
    from repro.data.pipeline import nonsmooth_quadratic_problem
    return nonsmooth_quadratic_problem(n, M, d, seed,
                                       center_scale=1.5).astype(np.float64)


def nonsmooth_centralized_optimum(centers: np.ndarray,
                                  iters: int = 800) -> float:
    """Reference F* via centralized subgradient descent on the mean
    objective (moved verbatim from benchmarks/fig_async.py; mirrors
    NonsmoothQuadratics.optimum_value)."""
    n, M, _, d = centers.shape

    def full_grad(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        pick = np.argmax(q, axis=-1)
        chosen = np.take_along_axis(diff, pick[..., None, None],
                                    axis=2)[:, :, 0]
        return 2.0 * np.sum(chosen, axis=(0, 1)) / n

    def value(x):
        diff = x[None, None, None, :] - centers
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    x = np.zeros(d)
    best = value(x)
    lr0 = 1.0 / (4.0 * M)
    for t in range(1, iters + 1):
        x = x - (lr0 / math.sqrt(t)) * full_grad(x)
        if t % 50 == 0:
            best = min(best, value(x))
    return best


@problems.register("nonsmooth")
def _nonsmooth_problem(n: int, M: int = 30, d: int = 20,
                       seed: int = 0) -> Problem:
    """Paper section V.B non-smooth quadratics, f_i = sum_j max(l1, l2).
    Numpy closures moved verbatim from benchmarks/fig_async.build_problem;
    the jax half mirrors benchmarks/paper_problems.NonsmoothQuadratics."""
    centers = _nonsmooth_centers(n, M, d, seed)

    def grad_fn(i, x, t):
        diff = x[None, None, :] - centers[i]          # (M, 2, d)
        q = np.sum(diff * diff, axis=-1)              # (M, 2)
        pick = np.argmax(q, axis=-1)                  # (M,)
        chosen = np.take_along_axis(
            diff, pick[:, None, None], axis=1)[:, 0]  # (M, d)
        return 2.0 * np.sum(chosen, axis=0)

    def eval_fn(x):
        diff = x[None, None, None, :] - centers       # (n, M, 2, d)
        q = np.sum(diff * diff, axis=-1)
        return float(np.mean(np.sum(np.max(q, axis=-1), axis=-1)))

    import jax.numpy as jnp
    centers_j = jnp.asarray(centers)

    def subgrad_stack(x_stack, t, key):
        diff = x_stack[:, None, None, :] - centers_j      # (n, M, 2, d)
        q = jnp.sum(diff * diff, axis=-1)                 # (n, M, 2)
        pick = jnp.argmax(q, axis=-1)                     # (n, M)
        chosen = jnp.take_along_axis(
            diff, pick[..., None, None], axis=2)[:, :, 0]  # (n, M, d)
        return 2.0 * jnp.sum(chosen, axis=1)

    def objective(x):
        diff = x[None, None, None, :] - centers_j
        q = jnp.sum(diff * diff, axis=-1)
        return jnp.mean(jnp.sum(jnp.max(q, axis=-1), axis=-1))

    return Problem(name="nonsmooth", n=n, d=d, grad_fn=grad_fn,
                   eval_fn=eval_fn, subgrad_stack=subgrad_stack,
                   objective=objective,
                   fstar_fn=lambda: nonsmooth_centralized_optimum(centers))


@problems.register("least_squares")
def _least_squares_problem(n: int, d: int = 64, m_per_node: int = 200,
                           seed: int = 0) -> Problem:
    """Node-specific least squares (the quickstart problem): f_i(x) =
    ||A_i x - b_i||^2 with per-node solutions, so consensus is required."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, m_per_node, d)) / np.sqrt(d)
    x_true = rng.normal(size=(d,))
    b = np.einsum("nmd,d->nm", A, x_true) + rng.normal(
        scale=0.1 + 0.5 * rng.random((n, 1)), size=(n, m_per_node))

    def grad_fn(i, x, t):
        res = A[i] @ x - b[i]
        return 2.0 * (A[i].T @ res)

    def eval_fn(x):
        res = np.einsum("nmd,d->nm", A, x) - b
        return float(np.mean(np.sum(res * res, axis=1)))

    import jax.numpy as jnp
    A_j, b_j = jnp.asarray(A), jnp.asarray(b)

    def subgrad_stack(x_stack, t, key):
        res = jnp.einsum("nmd,nd->nm", A_j, x_stack) - b_j
        return 2.0 * jnp.einsum("nmd,nm->nd", A_j, res)

    def objective(x):
        res = jnp.einsum("nmd,d->nm", A_j, x) - b_j
        return jnp.mean(jnp.sum(res * res, axis=1))

    def fstar():
        x_star, *_ = np.linalg.lstsq(A.reshape(n * m_per_node, d),
                                     b.reshape(-1), rcond=None)
        return eval_fn(x_star)

    return Problem(name="least_squares", n=n, d=d, grad_fn=grad_fn,
                   eval_fn=eval_fn, subgrad_stack=subgrad_stack,
                   objective=objective, fstar_fn=fstar)


@problems.register("lm")
def _lm_problem(arch: str, variant: str = "smoke", batch_per_node: int = 8,
                seq_len: int = 64) -> LMProblem:
    return LMProblem(arch=arch, variant=variant,
                     batch_per_node=batch_per_node, seq_len=seq_len)


# ---------------------------------------------------------------------------
# topologies (n comes from the problem; params carry the shape knobs)
# ---------------------------------------------------------------------------


@topologies.register("complete")
def _complete(n: int) -> _graphs.CommGraph:
    return _graphs.complete_graph(n)


@topologies.register("ring")
def _ring(n: int) -> _graphs.CommGraph:
    return _graphs.ring_graph(n)


@topologies.register("torus")
def _torus(n: int) -> _graphs.CommGraph:
    return _graphs.torus_graph(n)


@topologies.register("hypercube")
def _hypercube(n: int) -> _graphs.CommGraph:
    return _graphs.hypercube_graph(n)


@topologies.register("expander")
def _expander(n: int, k: int = 4, seed: int = 0) -> _graphs.CommGraph:
    return _graphs.kregular_expander(n, k=k, seed=seed)


@topologies.register("rregular")
def _rregular(n: int, k: int = 4, seed: int = 0) -> _graphs.CommGraph:
    return _graphs.random_regular_expander(n, k=k, seed=seed)


@topologies.register("expander_sequence")
def _expander_seq(n: int, k: int = 4, length: int = 4,
                  seed: int = 0) -> _graphs.GraphSequence:
    return _graphs.expander_sequence(n, k=k, length=length, seed=seed)


# ---------------------------------------------------------------------------
# schedules (the registry `core.schedules.make_schedule` now routes through)
# ---------------------------------------------------------------------------


@schedules.register("every", aliases=("h1",))
def _every() -> _sched.CommSchedule:
    return _sched.EveryIteration()


@schedules.register("periodic")
def _periodic(h: int = 1) -> _sched.CommSchedule:
    return _sched.Periodic(h=h)


@schedules.register("sparse")
def _sparse(p: float = 0.3) -> _sched.CommSchedule:
    return _sched.IncreasinglySparse(p=p)


@schedules.register("piecewise")
def _piecewise(h: int = 1) -> _sched.CommSchedule:
    return _sched.PiecewisePeriodic(h=h)


@schedules.register("adaptive")
def _adaptive(h0: int = 1, p: float = 0.0, h_max: int = 512):
    from repro.adaptive.schedule import AdaptiveSchedule
    return AdaptiveSchedule(h0=h0, p=p, h_max=h_max)


# ---------------------------------------------------------------------------
# stepsizes
# ---------------------------------------------------------------------------


@stepsizes.register("sqrt")
def _sqrt(A: float = 1.0, q: float = 0.5) -> Callable:
    """a(t) = A / max(t, 1)^q -- `core.dda.stepsize_sqrt`, the canonical
    jax/numpy-generic default shared by every execution mode."""
    return stepsize_sqrt(A, q)


@stepsizes.register("inv_sqrt")
def _inv_sqrt(A: float = 1.0) -> Callable:
    """a(t) = A / sqrt(max(t, 1)) via `math.sqrt` on host floats -- the
    exact closure the netsim benchmarks historically inlined (kept distinct
    from "sqrt" because `x ** 0.5` and `math.sqrt(x)` are not guaranteed
    bit-equal, and the migration gate compares traces bitwise). Host-only:
    not traceable, so the dense backend rejects it."""
    def a(t):
        return A / math.sqrt(max(t, 1.0))
    return a


def build_component(registry: Registry, kind: str,
                    params: dict[str, Any], **extra: Any) -> Any:
    """Build `kind` from `registry` with spec params plus runner-provided
    context (e.g. the problem's n for topologies). Spec params win conflicts
    loudly: a manifest must not silently override runner context."""
    clash = set(params) & set(extra)
    if clash:
        raise ValueError(
            f"{registry.kind} {kind!r} params {sorted(clash)} are "
            f"runner-provided and cannot be set in the spec")
    return registry.build(kind, **params, **extra)
