"""Minimal string-keyed component registry.

The experiment layer (`repro.experiments`) resolves every pluggable piece of
a run -- problem, topology, schedule, stepsize, backend -- through one of
these registries, so an `ExperimentSpec` can name components as plain
`(kind, params)` data and stay serializable. Follows the resolve-by-id
pattern of `models/registry.py` (`--arch <id>`), generalized: builders are
registered callables instead of one module per id, because experiment
components are small closures rather than config files.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Iterable

__all__ = ["Registry"]


class Registry:
    """Name -> builder mapping with aliases and kwargs filtering.

    Builders are plain callables; `build(name, **kwargs)` resolves the name
    (or any registered alias) and calls the builder. Unknown names raise
    `KeyError` listing what IS registered -- the error a typo in a checked-in
    manifest should produce.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._builders: dict[str, Callable[..., Any]] = {}
        self._aliases: dict[str, str] = {}

    def register(self, name: str, *, aliases: Iterable[str] = ()) -> Callable:
        """Decorator: `@registry.register("periodic")`."""
        def deco(fn: Callable) -> Callable:
            if name in self._builders or name in self._aliases:
                raise ValueError(f"{self.kind} {name!r} already registered")
            self._builders[name] = fn
            for a in aliases:
                if a in self._builders or a in self._aliases:
                    raise ValueError(f"{self.kind} alias {a!r} already taken")
                self._aliases[a] = name
            return fn
        return deco

    def canonical(self, name: str) -> str:
        """Resolve aliases to the registered name (raises on unknown)."""
        if name in self._builders:
            return name
        if name in self._aliases:
            return self._aliases[name]
        raise KeyError(
            f"unknown {self.kind} {name!r}; registered: {self.names()}")

    def builder(self, name: str) -> Callable[..., Any]:
        return self._builders[self.canonical(name)]

    def build(self, name: str, **kwargs: Any) -> Any:
        return self.builder(name)(**kwargs)

    def accepted(self, name: str, kwargs: dict[str, Any]) -> dict[str, Any]:
        """Subset of `kwargs` the builder's signature accepts.

        Back-compat helper for legacy shims (`core.schedules.make_schedule`
        uses it to keep `make_schedule("every", h=...)` legal) that
        historically passed every knob to every kind; new callers should
        pass exact params and get loud TypeErrors instead.
        """
        sig = inspect.signature(self.builder(name))
        if any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in sig.parameters.values()):
            return dict(kwargs)
        return {k: v for k, v in kwargs.items() if k in sig.parameters}

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._builders))

    def __contains__(self, name: str) -> bool:
        return name in self._builders or name in self._aliases
