"""Declarative experiment specification with exact JSON round-trip.

An `ExperimentSpec` is pure data: every pluggable piece is a
`ComponentSpec` -- a registry kind plus JSON-able kwargs -- and the scalar
knobs (T, seed, r, ...) are plain fields. `to_json`/`from_json` round-trip
EXACTLY (`spec == ExperimentSpec.from_json(spec.to_json())`, property-tested
in tests/test_experiments.py), which is what lets checked-in manifests under
benchmarks/manifests/ serve as the paper figures' experiment definitions:
the file IS the experiment.

The spec deliberately contains no callables and no built objects --
`repro.experiments.run` builds everything fresh per run, so mutable
schedules (PiecewisePeriodic splice history) can never leak between runs.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

__all__ = ["ComponentSpec", "ExperimentSpec", "SPEC_VERSION"]

SPEC_VERSION = 1

_JSON_SCALARS = (str, int, float, bool, type(None))


def _normalize(value: Any, where: str) -> Any:
    """Coerce to exact-round-trip JSON values: tuples -> lists, numpy
    scalars -> Python scalars; reject anything json.dumps would mangle or
    refuse (sets, arrays, callables)."""
    if isinstance(value, bool) or value is None or isinstance(value, str):
        return value
    if isinstance(value, int):
        return int(value)
    if isinstance(value, float):
        return float(value)
    if (hasattr(value, "item")
            and getattr(value, "shape", None) == ()):  # numpy scalar
        return _normalize(value.item(), where)
    if isinstance(value, (list, tuple)):
        return [_normalize(v, where) for v in value]
    if isinstance(value, dict):
        out = {}
        for k, v in value.items():
            if not isinstance(k, str):
                raise TypeError(f"{where}: dict keys must be str, got {k!r}")
            out[k] = _normalize(v, f"{where}.{k}")
        return out
    raise TypeError(
        f"{where}: {type(value).__name__} is not JSON-serializable "
        f"(specs hold plain data; build objects at run time)")


@dataclasses.dataclass(frozen=True)
class ComponentSpec:
    """One registry-resolved component: a kind string + builder kwargs."""

    kind: str
    params: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not isinstance(self.kind, str) or not self.kind:
            raise TypeError("ComponentSpec.kind must be a non-empty string")
        object.__setattr__(
            self, "params", _normalize(dict(self.params), self.kind))

    def to_dict(self) -> dict:
        return {"kind": self.kind, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Any) -> "ComponentSpec":
        if isinstance(d, str):  # shorthand: "complete" == {"kind": "complete"}
            return cls(kind=d)
        unknown = set(d) - {"kind", "params"}
        if unknown:
            raise ValueError(f"component has unknown keys {sorted(unknown)}")
        return cls(kind=d["kind"], params=dict(d.get("params") or {}))

    def replace(self, **params: Any) -> "ComponentSpec":
        """New ComponentSpec with `params` merged over the existing ones."""
        return ComponentSpec(self.kind, {**self.params, **params})


def _component(value) -> ComponentSpec:
    if isinstance(value, ComponentSpec):
        return value
    return ComponentSpec.from_dict(value)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Everything one run needs, as data.

    Fields:
      name:      manifest/run identifier (used in output filenames).
      problem:   problems-registry component; carries n and d.
      topology:  topologies-registry component (n is supplied by the
                 problem at build time, so params hold only k/seed/length).
      schedule:  schedules-registry component. Must be kind "adaptive" when
                 a controller is attached.
      backends:  one or more backends this spec declares it runs on, in
                 preference order; `run(spec)` uses the first unless told
                 otherwise. Params are backend-specific (scenario knobs for
                 netsim, mesh/arch knobs for launch).
      stepsize:  stepsizes-registry component for a(t).
      controller: optional adaptive-controller component ("adaptive" kind:
                 AdaptiveController knobs for netsim, "dense_adaptive":
                 DenseController knobs for the dense wall-clock loop).
      faults:    optional faultplans-registry component ("plan": explicit
                 FaultPlan fields, "churn": rotating crash/restart waves).
                 Netsim backends only; the builder receives the problem's n.
      compression: optional compressors-registry component ("topk",
                 "randk", "int8"; "none" is the same as leaving it unset).
                 Dense backend: compressed gossip with error feedback
                 inside the scanned program (sparse mix path when the
                 topology allows). Netsim: sender-side compression plus
                 wire_bytes scaling, so bandwidth-limited links feel the
                 ratio. Enters the serve cache signature and vmap lane
                 key like every other top-level field.
      T:         iterations per node (launch: training steps).
      eval_every: trace evaluation cadence (iterations per node).
      seed:      run RNG seed (problem seeds live in problem params).
      r:         configured communication/computation tradeoff: the dense
                 time charge, the netsim link serialization time, the
                 launch r_estimate (paper eq. 9 units).
      eps_frac:  optional accuracy target F* + eps_frac*(F(0)-F*); enables
                 time_to_target in the RunResult.
      time_limit: optional event-clock cap (netsim only).
      profile_dir: optional directory for a `jax.profiler` trace captured
                 around the dense backend's scanned program (dense only;
                 see repro.obs.profile_ctx). None (default) disables
                 profiling entirely.
    """

    name: str
    problem: ComponentSpec
    topology: ComponentSpec
    schedule: ComponentSpec
    backends: tuple[ComponentSpec, ...]
    stepsize: ComponentSpec = dataclasses.field(
        default_factory=lambda: ComponentSpec("sqrt", {"A": 1.0}))
    controller: ComponentSpec | None = None
    faults: ComponentSpec | None = None
    compression: ComponentSpec | None = None
    T: int = 1000
    eval_every: int = 25
    seed: int = 0
    r: float = 0.0
    eps_frac: float | None = None
    time_limit: float | None = None
    profile_dir: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "problem", _component(self.problem))
        object.__setattr__(self, "topology", _component(self.topology))
        object.__setattr__(self, "schedule", _component(self.schedule))
        object.__setattr__(self, "stepsize", _component(self.stepsize))
        if self.controller is not None:
            object.__setattr__(self, "controller",
                               _component(self.controller))
        if self.faults is not None:
            object.__setattr__(self, "faults", _component(self.faults))
        if self.compression is not None:
            object.__setattr__(self, "compression",
                               _component(self.compression))
        backends = tuple(_component(b) for b in self.backends)
        if not backends:
            raise ValueError("spec must declare at least one backend")
        object.__setattr__(self, "backends", backends)
        if self.T < 1:
            raise ValueError("T must be >= 1")
        if self.eval_every < 1:
            raise ValueError("eval_every must be >= 1")
        if self.r < 0:
            raise ValueError("r must be >= 0")
        object.__setattr__(self, "r", float(self.r))
        if self.eps_frac is not None:
            object.__setattr__(self, "eps_frac", float(self.eps_frac))
        if self.time_limit is not None:
            object.__setattr__(self, "time_limit", float(self.time_limit))
        if self.profile_dir is not None and not isinstance(self.profile_dir,
                                                           str):
            raise TypeError("profile_dir must be a path string or None")

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "spec_version": SPEC_VERSION,
            "name": self.name,
            "problem": self.problem.to_dict(),
            "topology": self.topology.to_dict(),
            "schedule": self.schedule.to_dict(),
            "backends": [b.to_dict() for b in self.backends],
            "stepsize": self.stepsize.to_dict(),
            "controller": (None if self.controller is None
                           else self.controller.to_dict()),
            "faults": (None if self.faults is None
                       else self.faults.to_dict()),
            "compression": (None if self.compression is None
                            else self.compression.to_dict()),
            "T": self.T,
            "eval_every": self.eval_every,
            "seed": self.seed,
            "r": self.r,
            "eps_frac": self.eps_frac,
            "time_limit": self.time_limit,
            "profile_dir": self.profile_dir,
        }
        return d

    def to_json(self, indent: int | None = 2) -> str:
        # allow_nan=False: a spec with inf/nan knobs would not round-trip
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(f"unsupported spec_version {version!r} "
                             f"(this build reads {SPEC_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"spec has unknown keys {sorted(unknown)}")
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- sweeps --------------------------------------------------------------

    def with_value(self, axis: str, value: Any) -> "ExperimentSpec":
        """New spec with one dotted-path field replaced.

        Axes: a scalar field ("T", "r", "seed", ...), a component kind
        ("schedule.kind"), or a component param ("schedule.params.h",
        "problem.params.n", "backends.0.params.engine"). This is the
        substrate of `run_sweep`: the paper's n/h/r grids are one axis each.
        """
        parts = axis.split(".")
        d = self.to_dict()
        cur: Any = d
        for p in parts[:-1]:
            cur = cur[int(p)] if isinstance(cur, list) else cur[p]
        leaf = parts[-1]
        if isinstance(cur, list):
            cur[int(leaf)] = value
        else:
            # new keys are legal inside a component's params (sweeping h
            # onto a schedule that used the default); top-level and
            # component fields must already exist (catches axis typos)
            in_params = len(parts) >= 2 and parts[-2] == "params"
            if leaf not in cur and not in_params:
                raise KeyError(f"axis {axis!r}: {leaf!r} not in "
                               f"{sorted(cur)}")
            cur[leaf] = value
        return ExperimentSpec.from_dict(d)
