"""Closed-loop adaptive communication scheduling.

The paper's pipeline is offline: measure r on the cluster, solve eq. (21)
for h_opt, configure the schedule, run. This package closes that loop
ONLINE, during a run:

    measure  -- `RTracker` streams an exponentially-windowed r_hat from the
                live event timeline (message flights + per-node step
                durations); `DenseRTracker` does the same from wall-clock
                iteration timings in the dense synchronous mode.
    predict  -- eq. (21) h_opt(n, k, r_hat, lambda2), with lambda2 itself
                refreshed from observed per-node step-time quantiles by
                `StragglerReweighter` (expected degraded mixing matrix,
                Sinkhorn-rebalanced, `lambda2_fast`).
    act      -- `AdaptiveSchedule` splices the re-solved interval into the
                running periodic / increasingly-sparse pattern through the
                append-only mutation protocol of
                `core.schedules.PiecewisePeriodic`, keeping H(t) /
                next_comm_step / next_comm_step_batch consistent across h
                changes.

`AdaptiveController` packages the three for `NetSimulator(controller=...)`;
both netsim engines thread it through their event loops (zero hot-path
branches when absent, preserving the engines' bit-identity contract).
benchmarks/fig_adaptive.py demonstrates the payoff: on heterogeneous/lossy
clusters the closed loop beats every fixed Periodic(h) in a swept grid on
simulated wall-clock to target accuracy.
"""

from repro.adaptive.controller import (AdaptiveController, DenseController,
                                       StragglerReweighter)
from repro.adaptive.rtracker import DenseRTracker, RTracker
from repro.adaptive.schedule import AdaptiveSchedule, Retune

__all__ = [
    "AdaptiveController",
    "AdaptiveSchedule",
    "DenseController",
    "DenseRTracker",
    "RTracker",
    "Retune",
    "StragglerReweighter",
]
