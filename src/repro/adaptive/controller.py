"""The closed loop: measure (RTracker) -> predict (h_opt, lambda2) -> act
(AdaptiveSchedule splice), plus straggler-aware mixing-weight refresh.

`AdaptiveController` is the object a `NetSimulator(controller=...)` run
threads through both execution engines. The engines call four hooks --
`on_steps`, `on_messages`, `on_rewire`, `maybe_retune` -- and otherwise run
their normal event loops; with no controller attached not a single extra
branch executes on the hot path, which is what keeps the controller-off
bit-identity guarantee intact (benchmarks/fig_adaptive.py --smoke gates it).

`StragglerReweighter` keeps the controller's spectral input honest: the
static lambda2 of the configured graph assumes every neighbor's message
lands every round, but observed per-node step-time quantiles say otherwise
on a straggler-ridden cluster. It folds on-time arrival probabilities into
P exactly as `runtime.fault_tolerance.arrival_reweighted_matrix` (the
expected `degraded_matrix` over Bernoulli arrivals), re-validates double
stochasticity via `sinkhorn_project` (which raises rather than return a
near-miss), and hands back `lambda2_fast` of the rebalanced matrix -- the
effective mixing rate h_opt should be solved against.
"""

from __future__ import annotations

import math

import numpy as np

from repro.adaptive.rtracker import RTracker
from repro.adaptive.schedule import AdaptiveSchedule
from repro.core.graphs import CommGraph
from repro.core.tradeoff import lambda2_fast
from repro.runtime.fault_tolerance import (arrival_reweighted_matrix,
                                           sinkhorn_project)

__all__ = ["AdaptiveController", "DenseController", "StragglerReweighter"]


class StragglerReweighter:
    """Fold observed per-node step-time quantiles into the mixing matrix.

    Args:
      deadline_factor: a message is modeled on-time when its sender's step
        time is within `deadline_factor` times the cluster median (the
        `fault_tolerance.StragglerModel.deadline` convention).
      floor: lower clamp on arrival probability, keeping the reweighted
        matrix irreducible even for an extreme straggler.
    """

    def __init__(self, graph: CommGraph, deadline_factor: float = 2.0,
                 floor: float = 0.05, cache_rtol: float = 1e-3):
        if deadline_factor <= 0.0:
            raise ValueError("deadline_factor must be positive")
        if not 0.0 < floor <= 1.0:
            raise ValueError("floor must be in (0, 1]")
        self.deadline_factor = deadline_factor
        self.floor = floor
        # skip the (Sinkhorn + eigendecomposition) refresh when the step
        # means moved less than this relative amount since the last update
        # -- EW means go stationary once the cluster's speeds are learned,
        # and a sub-0.1% shift cannot move lambda2 meaningfully. 0 disables.
        self.cache_rtol = cache_rtol
        self.set_graph(graph)
        self.last_P: np.ndarray | None = None
        self.last_lam2: float | None = None
        self.last_arrive_prob: np.ndarray | None = None

    def set_graph(self, graph: CommGraph) -> None:
        self.graph = graph
        self._P0 = graph.mixing_matrix()
        self._cached_q: np.ndarray | None = None  # topology changed

    def update(self, step_means: np.ndarray) -> tuple[np.ndarray, float]:
        """(effective P, its lambda2) from per-node EW step-time means.

        Nodes not yet observed (NaN) count as median-speed. The arrival
        model: node j's message lands on time with probability
        min(1, deadline / step_time_j), deadline = factor * median -- a 4x
        straggler under factor 2 is heard half the time.
        """
        q = np.asarray(step_means, dtype=np.float64)
        if q.shape != (self._P0.shape[0],):
            raise ValueError(
                f"need one step-time mean per node ({self._P0.shape[0]}), "
                f"got shape {q.shape}")
        if (self._cached_q is not None
                and np.allclose(q, self._cached_q, rtol=self.cache_rtol,
                                atol=0.0, equal_nan=True)):
            return self.last_P, self.last_lam2
        self._cached_q = q.copy()
        med = float(np.nanmedian(q))
        if math.isnan(med) or med <= 0.0:
            lam2 = lambda2_fast(self._P0)
            self.last_P, self.last_lam2 = self._P0, lam2
            self.last_arrive_prob = np.ones(len(q))
            return self._P0, lam2
        deadline = self.deadline_factor * med
        with np.errstate(invalid="ignore", divide="ignore"):
            a = deadline / q
        a = np.clip(np.where(np.isnan(a), 1.0, a), self.floor, 1.0)
        P_eff = sinkhorn_project(arrival_reweighted_matrix(self._P0, a))
        lam2 = lambda2_fast(P_eff)
        self.last_P, self.last_lam2, self.last_arrive_prob = P_eff, lam2, a
        return P_eff, lam2


class AdaptiveController:
    """Online h controller for netsim runs.

    Args:
      schedule: the AdaptiveSchedule the run shares (also pass it -- or let
        NetSimulator pick it up -- as the run's schedule).
      update_every: sim-time between retunes (event-clock units; eq. (9)
        normalization, so 1.0 = one full-data gradient on the reference
        node).
      halflife: RTracker EW window, in observations.
      r0: prior for r before the first messages land (None = wait).
      reweight: refresh lambda2 via StragglerReweighter each retune; when
        False the configured graph's static lambda2 is used.
      warmup_messages / warmup_steps: minimum observations before the first
        retune -- an h spliced off two noisy flights would thrash.
      wire_ratio: bytes-on-wire compression ratio c applied to the measured
        r_hat before each retune (h solved against the EFFECTIVE r*c, eq.
        21). Default 1.0 is correct for netsim runs with compression on:
        the observed flights already serialize `wire_bytes`, so r_hat IS
        the effective tradeoff. Set it explicitly (Compressor.wire_ratio)
        when the r feed is a raw/uncompressed measurement -- the dense
        backend's wall-clock tracker, or a netsim whose link calibration
        ignores wire_bytes.
    """

    def __init__(self, schedule: AdaptiveSchedule | None = None,
                 update_every: float = 0.5, halflife: float = 64.0,
                 r0: float | None = None, reweight: bool = True,
                 warmup_messages: int = 8, warmup_steps: int = 8,
                 reweight_gossip: bool = False,
                 wire_ratio: float = 1.0):
        self.schedule = schedule if schedule is not None else AdaptiveSchedule()
        if not isinstance(self.schedule, AdaptiveSchedule):
            raise TypeError("AdaptiveController needs an AdaptiveSchedule")
        if update_every <= 0.0:
            raise ValueError("update_every must be positive")
        if reweight_gossip and not reweight:
            raise ValueError("reweight_gossip needs reweight=True (the "
                             "effective P comes from the StragglerReweighter)")
        self.update_every = update_every
        self.halflife = halflife
        self.r0 = r0
        self.reweight = reweight
        # Apply the reweighter's effective P to the ACTUAL stale-gossip
        # mixing (Network.mix_weights), not just to the lambda2 estimate
        # h_opt is solved against. Stale-gossip DDA only: push-sum's mass
        # splitting is its own weighting scheme (NetSimulator validates).
        self.reweight_gossip = reweight_gossip
        if wire_ratio <= 0.0:
            raise ValueError("wire_ratio must be positive")
        self.wire_ratio = wire_ratio
        self.warmup_messages = warmup_messages
        self.warmup_steps = warmup_steps
        self.tracker: RTracker | None = None
        self.reweighter: StragglerReweighter | None = None
        # observability: every r_hat the controller computed at retune
        # cadence, as (event-clock time, r_hat) -- the durable record the
        # RunMetrics r_hat_trajectory is built from. `tracer` (an optional
        # repro.obs.Tracer, set via attach_tracer) additionally receives
        # the series and a retune counter; None costs nothing.
        self.r_hat_history: list[tuple[float, float]] = []
        self.tracer = None
        # single-slot (graph, lam2) cache: only the CURRENT graph can hit,
        # and holding the object rules out a recycled-id stale hit
        self._lam2_cache: tuple[CommGraph, float] | None = None
        self._next_update = update_every
        self._n = 0
        self._k = 0
        # fault-injection membership: when a FaultRuntime splices a reduced
        # graph in (node left/joined), this holds the int64 array of member
        # node ids and the controller retunes against the SUB-cluster
        # (n = len(members), lambda2 of the sub-graph) -- the embedded
        # full-size graph's self-loops would poison the spectral gap.
        self._members: np.ndarray | None = None

    # -- engine-facing hooks -------------------------------------------------

    def bind(self, net) -> None:
        """Attach to a Network at run start (re-binding resets the window
        AND the schedule's splice history: a new run is a new cluster and a
        new iteration timeline as far as the controller is concerned)."""
        self._n = net.n
        self._k = net.graph.degree
        self.r_hat_history = []
        self.tracker = RTracker(net.n, halflife=self.halflife, r0=self.r0,
                                tracer=self.tracer)
        self.reweighter = (StragglerReweighter(net.graph)
                           if self.reweight else None)
        self._lam2_cache = None
        self._graph = net.graph
        self._net = net
        self._members = None
        if self.reweight_gossip:
            net.mix_weights = None  # fresh run: no weights learned yet
        self._next_update = self.update_every
        self.schedule.reset()

    def on_steps(self, nodes: np.ndarray, durations: np.ndarray) -> None:
        self.tracker.observe_steps(nodes, durations)

    def on_messages(self, flights: np.ndarray) -> None:
        self.tracker.observe_messages(flights)

    def on_rewire(self, graph: CommGraph) -> None:
        if self._members is not None:
            # membership changed since bind: the scheduled rewire delivers
            # the PRE-fault full-size graph, which no longer describes the
            # live cluster. The FaultRuntime's spliced graph (delivered via
            # on_membership) stays authoritative until the next splice.
            return
        self._graph = graph
        self._k = graph.degree
        if self.reweighter is not None:
            self.reweighter.set_graph(graph)
        if self.reweight_gossip:
            # the learned P refers to the OLD edge set; fall back to the
            # configured uniform weights until the next retune relearns it
            self._net.mix_weights = None

    def on_membership(self, sub_graph: CommGraph,
                      members: np.ndarray) -> None:
        """A FaultRuntime spliced a rebuilt graph after a join/leave.

        `sub_graph` is the graph over the m CURRENT members (NOT embedded
        into full size: the identity self-loops the embedding adds for
        departed nodes would drive the estimated lambda2 toward 1 and
        poison h_opt), `members` the sorted full-cluster ids those m rows
        map to. From here on the controller solves the tradeoff for the
        m-node cluster; per-node step statistics are sliced down to the
        members at retune time so a departed straggler stops dragging the
        reweighter."""
        self._members = np.asarray(members, dtype=np.int64)
        self._n = int(sub_graph.n)
        self._k = max(sub_graph.degree, 1)
        self._graph = sub_graph
        self._lam2_cache = None
        if self.reweighter is not None:
            self.reweighter = StragglerReweighter(sub_graph)
        if self.reweight_gossip:
            self._net.mix_weights = None

    def on_partition_heal(self, now: float) -> None:
        """A link partition healed: the measured r/step statistics from the
        partition era are stale for the rejoined cluster, so pull the next
        retune forward to `now` instead of waiting out the cadence."""
        self._next_update = min(self._next_update, float(now))

    def retune_due(self, now: float) -> bool:
        """Cheap cadence test so engines only compute the (O(n)) iteration
        frontier when a retune will actually be attempted."""
        return now >= self._next_update

    def maybe_retune(self, now: float, frontier: int) -> int | None:
        """Run the predict->act half if the cadence is due.

        `frontier` is the max in-flight iteration across STILL-ACTIVE
        nodes. That is exactly the bound correctness needs: no splice ever
        rewrites an iteration an active node has executed or in flight, so
        cached next-comm answers and already-charged busy times stay valid
        (engines refresh the rest). It is deliberately NOT the global max:
        a finished node that ran ahead no longer constrains the future,
        and using its T would freeze the controller for the stragglers'
        entire remaining run. The flip side, accepted and documented: once
        iteration ranges diverge (a fast node finished under the old
        pattern), a later splice inside that range makes the schedule
        forward-looking for the nodes still running -- the finished node's
        actual communication history lives in its own `comm_iters`/trace
        counters, not in post-hoc `schedule.H` queries. If the frontier
        sits at or behind the latest splice point, the retune is skipped
        (re-splicing there would also disturb the pattern ACTIVE nodes are
        mid-way through) and resumes once the frontier catches up.

        Returns the splice point when the emitted pattern changed (the
        engine must then refresh cached next-comm answers beyond it), else
        None.
        """
        if now < self._next_update:
            return None
        # advance the cadence even on a failed warmup: retune_due must go
        # cheap-and-false again, or the engines would pay their O(n)
        # frontier scan on EVERY step event for the whole warmup stretch
        self._next_update = now + self.update_every
        if not self.tracker.ready(self.warmup_messages, self.warmup_steps):
            return None
        r_hat = self.tracker.r_hat
        if r_hat is None:
            return None
        # record the measurement even when the splice below is skipped: the
        # trajectory is what the controller OBSERVED, not what it acted on
        self.r_hat_history.append((float(now), float(r_hat)))
        if self.tracer is not None:
            self.tracer.record_series("r_hat", float(now), float(r_hat))
        cut = int(frontier)
        # '<=': a cut EQUAL to the latest splice start would take set_h's
        # replace-pending branch, which also rewrites (start, inf) -- and a
        # since-finished node may have executed iterations there
        if cut <= self.schedule.segments[-1][0]:
            return None  # see docstring: wait for the frontier to catch up
        if self.reweighter is not None:
            means = self.tracker.step_means
            if self._members is not None:
                means = means[self._members]
            P_eff, lam2 = self.reweighter.update(means)
            if self.reweight_gossip:
                if self._members is not None:
                    # lift the m x m effective P back to full size; departed
                    # nodes keep identity rows (they hold no gossip edges)
                    full = np.eye(self._net.n)
                    full[np.ix_(self._members, self._members)] = P_eff
                    self._net.mix_weights = full
                else:
                    self._net.mix_weights = P_eff
        else:
            lam2 = self._static_lam2()
        # history records what was OBSERVED (raw r_hat); the act half solves
        # against the effective per-message cost r_hat * wire_ratio
        changed = self.schedule.retune(cut, self._n, self._k,
                                       r_hat * self.wire_ratio, lam2)
        if changed and self.tracer is not None:
            self.tracer.count("retunes")
            self.tracer.add_instant("retune", float(now), track="controller",
                                    h=self.schedule.h_current, r_hat=r_hat)
        return cut if changed else None

    def attach_tracer(self, tracer) -> None:
        """Attach a repro.obs.Tracer; propagated to the RTracker at the
        next bind() (call before the run starts)."""
        self.tracer = tracer
        if self.tracker is not None:
            self.tracker.tracer = tracer

    def _static_lam2(self) -> float:
        hit = self._lam2_cache
        if hit is None or hit[0] is not self._graph:
            hit = (self._graph, self._graph.lambda2())
            self._lam2_cache = hit
        return hit[1]


class DenseController:
    """Wall-clock twin of `AdaptiveController` for the dense synchronous
    mode (`DDASimulator` segments, or a real shard_map launcher step).

    The dense mode has no event timeline -- only whole-iteration wall-clock
    durations -- so the measure half is `DenseRTracker` (inverts the eq. 9
    cost model from comm vs plain iteration timings) and there is no
    straggler reweighting (every node IS the same host). The act half is the
    same `AdaptiveSchedule` splice protocol; the driver
    (`repro.experiments.runner`, dense backend) times uniform-comm chunks,
    feeds `observe`, and calls `maybe_retune(frontier)` at trace-segment
    boundaries, where `frontier` is the number of iterations already
    executed -- the synchronous analogue of the netsim's in-flight frontier.

    Args:
      schedule: the AdaptiveSchedule the run shares.
      halflife: DenseRTracker EW window, in observed iterations.
      retune_every: minimum iterations between accepted retunes (None =
        retune whenever the driver asks).
      warmup_comm / warmup_plain: minimum timed iterations of each kind
        before the first retune (one noisy jit-compile segment would
        otherwise set h). warmup_plain defaults to 1 because an h0 = 1
        cold start has exactly ONE plain iteration (t = 1) until the first
        retune raises h -- a larger default would deadlock the loop.
      wire_ratio: compression byte ratio c applied to the measured r_hat
        before each retune. Unlike the netsim controller, the dense
        tracker's r_hat comes from wall-clock iteration timings that do
        NOT shrink with compression (the dense simulator computes full
        vectors either way), so a compressed dense run SHOULD pass its
        compressor's `wire_ratio(d)` here for h to land on the effective
        r*c optimum.
    """

    def __init__(self, schedule: AdaptiveSchedule | None = None,
                 halflife: float = 32.0, retune_every: int | None = None,
                 warmup_comm: int = 2, warmup_plain: int = 1,
                 wire_ratio: float = 1.0):
        self.schedule = schedule if schedule is not None else AdaptiveSchedule()
        if not isinstance(self.schedule, AdaptiveSchedule):
            raise TypeError("DenseController needs an AdaptiveSchedule")
        if retune_every is not None and retune_every < 1:
            raise ValueError("retune_every must be >= 1")
        self.halflife = halflife
        self.retune_every = retune_every
        self.warmup_comm = warmup_comm
        self.warmup_plain = warmup_plain
        if wire_ratio <= 0.0:
            raise ValueError("wire_ratio must be positive")
        self.wire_ratio = wire_ratio
        self.tracker = None
        self._lam2 = 0.0
        self._n = 0
        self._k = 0
        self._last_retune_t = 0
        # same observability contract as AdaptiveController: (frontier
        # iteration, r_hat) per computed estimate, optional obs.Tracer
        self.r_hat_history: list[tuple[float, float]] = []
        self.tracer = None

    def bind(self, n: int, k: int, lam2: float) -> None:
        """Attach to a run's graph; resets the window and splice history."""
        from repro.adaptive.rtracker import DenseRTracker
        self._n, self._k, self._lam2 = n, max(k, 1), float(lam2)
        self.tracker = DenseRTracker(n, max(k, 1), halflife=self.halflife)
        self._last_retune_t = 0
        self.r_hat_history = []
        self.schedule.reset()

    def observe(self, wall_seconds: float, was_comm: bool) -> None:
        self.tracker.observe_iteration(wall_seconds, was_comm)

    def maybe_retune(self, frontier: int) -> bool:
        """Re-solve h_opt from the streamed wall-clock r_hat and splice at
        `frontier` (iterations already executed; the splice only shapes the
        future). Returns True when the emitted pattern changed."""
        if (self.tracker is None
                or self.tracker.n_comm < self.warmup_comm
                or self.tracker.n_plain < self.warmup_plain):
            return False
        if (self.retune_every is not None
                and frontier - self._last_retune_t < self.retune_every):
            return False
        r_hat = self.tracker.r_hat
        if r_hat is None:
            return False
        self.r_hat_history.append((float(frontier), float(r_hat)))
        if self.tracer is not None:
            self.tracer.record_series("r_hat", float(frontier), float(r_hat))
        cut = int(frontier)
        if cut <= self.schedule.segments[-1][0]:
            return False  # same append-only guard as the netsim controller
        changed = self.schedule.retune(cut, self._n, self._k,
                                       r_hat * self.wire_ratio, self._lam2)
        if changed:
            self._last_retune_t = cut
            if self.tracer is not None:
                self.tracer.count("retunes")
                self.tracer.add_instant("retune", float(cut),
                                        track="controller",
                                        h=self.schedule.h_current,
                                        r_hat=r_hat)
        return changed

    def attach_tracer(self, tracer) -> None:
        """Attach a repro.obs.Tracer (DenseRTracker has no per-event feed;
        the series/counters come from this controller itself)."""
        self.tracer = tracer
