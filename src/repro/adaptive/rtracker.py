"""Streaming estimators of the communication/computation tradeoff r.

The paper measures r ONCE, offline (r = t_msg / t_full_grad, section V.A),
and derives the optimal schedule from it. `repro.netsim` already recovers r
from a finished run's event timeline (`measure_r_empirical`); this module is
the ONLINE version -- the "measure" third of the measure -> predict -> act
loop that `repro.adaptive.AdaptiveController` closes during a run.

Two variants, matching the repo's two execution styles:

  * `RTracker`      -- event-timeline mode, fed by the netsim engines: one
    exponentially-windowed mean over observed message flights, one
    EW-windowed per-node mean over observed step durations. The full-data
    gradient time is `median(per-node step means) * n` -- the same
    median-of-nodes robustness `measure_r_empirical` uses, so a single 4x
    straggler shifts the straggler quantiles (see StragglerReweighter) but
    not r_hat itself. Batch observations fold in one `ew_update` call per
    event batch, so the vectorized engine pays O(1) per batch, not O(batch).

  * `DenseRTracker` -- dense/synchronous mode, fed by WALL-CLOCK timings of
    whole iterations (e.g. `time.perf_counter()` around `DDASimulator`
    segments or a real shard_map step). It never sees individual messages;
    instead it inverts eq. (9): a communication iteration costs
    t_plain + k * t_msg, so t_msg = (t_comm - t_plain) / k and
    t_full_grad = n * t_plain (the local step is 1/n of the data).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.tradeoff import ew_alpha, ew_update

__all__ = ["RTracker", "DenseRTracker"]


class RTracker:
    """EW-windowed r estimate from per-event netsim observations."""

    def __init__(self, n: int, halflife: float = 64.0,
                 r0: float | None = None, tracer=None):
        if n < 1:
            raise ValueError("n must be >= 1")
        self.n = n
        self.alpha = ew_alpha(halflife)
        self.r0 = r0
        # optional repro.obs.Tracer: observation batches fold into its
        # counters (one branch per BATCH, preserving the O(1)-per-batch
        # cost); None (default) records nothing.
        self.tracer = tracer
        self._msg = math.nan                      # EW mean message flight
        self.step_means = np.full(n, np.nan)      # per-node EW step duration
        self.n_messages = 0
        self.n_steps = 0

    # -- feeding (engine hook targets) ---------------------------------------

    def observe_messages(self, flights: np.ndarray) -> None:
        """Fold a batch of observed send->receive flight times."""
        m = len(flights)
        if m == 0:
            return
        self._msg = ew_update(self._msg, float(np.mean(flights)), m,
                              self.alpha)
        self.n_messages += m
        if self.tracer is not None:
            self.tracer.count("rtracker.messages_observed", m)

    def observe_steps(self, nodes: np.ndarray, durations: np.ndarray) -> None:
        """Fold a batch of per-node local-step durations (nodes unique
        within a batch -- each node finishes at most one step per event)."""
        if len(nodes) == 0:
            return
        old = self.step_means[nodes]
        fresh = np.isnan(old)
        self.step_means[nodes] = np.where(
            fresh, durations, (1.0 - self.alpha) * old + self.alpha * durations)
        self.n_steps += len(nodes)
        if self.tracer is not None:
            self.tracer.count("rtracker.steps_observed", len(nodes))

    # -- reading -------------------------------------------------------------

    @property
    def t_msg(self) -> float:
        return self._msg

    @property
    def t_grad_full(self) -> float:
        """Median node's full-data gradient time (median * n, robust to
        stragglers exactly like `measure_r_empirical`)."""
        if np.isnan(self.step_means).all():
            return math.nan
        return float(np.nanmedian(self.step_means)) * self.n

    @property
    def r_hat(self) -> float | None:
        """Current estimate, or the r0 prior before both signals exist, or
        None with no prior (the controller then skips the retune)."""
        t_full = self.t_grad_full
        if math.isnan(self._msg) or math.isnan(t_full) or t_full <= 0.0:
            return self.r0
        return self._msg / t_full

    def ready(self, min_messages: int = 1, min_steps: int = 1) -> bool:
        return self.n_messages >= min_messages and self.n_steps >= min_steps


class DenseRTracker:
    """EW-windowed r estimate from wall-clock iteration timings (dense mode).

    `observe_iteration(wall, was_comm)` with the measured duration of one
    synchronous iteration; `r_hat` inverts the eq. (9) cost model. Returns
    None until both iteration kinds have been seen, and clamps at 0 when
    measurement noise makes a communication iteration look cheaper than a
    local one.
    """

    def __init__(self, n: int, k: int, halflife: float = 32.0):
        if n < 1 or k < 1:
            raise ValueError("need n >= 1 and k >= 1")
        self.n = n
        self.k = k
        self.alpha = ew_alpha(halflife)
        self._comm = math.nan
        self._plain = math.nan
        self.n_comm = 0
        self.n_plain = 0

    def observe_iteration(self, wall_seconds: float, was_comm: bool) -> None:
        if wall_seconds < 0.0:
            raise ValueError("iteration wall time must be >= 0")
        if was_comm:
            self._comm = ew_update(self._comm, wall_seconds, 1, self.alpha)
            self.n_comm += 1
        else:
            self._plain = ew_update(self._plain, wall_seconds, 1, self.alpha)
            self.n_plain += 1

    @property
    def r_hat(self) -> float | None:
        if math.isnan(self._comm) or math.isnan(self._plain) \
                or self._plain <= 0.0:
            return None
        t_msg = max(self._comm - self._plain, 0.0) / self.k
        return t_msg / (self.n * self._plain)
