"""Closed-loop communication schedule: h re-solved online from measured r.

`AdaptiveSchedule` is the "act" third of the measure -> predict -> act loop.
It extends `core.schedules.PiecewisePeriodic` (the mutation protocol: an
append-only sequence of anchored periodic segments with closed-form
H / next_comm_step / batch queries) with the paper-side policy:

  * each retune re-solves eq. (21), h_opt(n, k, r_hat, lambda2), with the
    STREAMED estimates (r_hat from `RTracker`, lambda2 optionally refreshed
    by `StragglerReweighter`) instead of the offline constants;
  * with `p > 0` the solved h_opt is spliced into the increasingly-sparse
    pattern of paper IV.B: the emitted interval is
    h(t) = h_opt_hat * (1 + H(t))^p, so gaps keep growing like j^p between
    retunes of the base -- communicating less and less as computation
    progresses, but with the BASE of the growth tracking the measured
    cluster instead of a precommitted constant. Convergence needs p < 1/2
    (paper eq. 31; p = 1 provably diverges, Fig. 2).

The splice point is always the caller-provided iteration frontier (max
in-flight iteration across nodes), so no node's already-made communication
decision is rewritten -- see PiecewisePeriodic's mutation contract.
"""

from __future__ import annotations

import dataclasses

from repro.core.schedules import PiecewisePeriodic
from repro.core.tradeoff import h_opt

__all__ = ["AdaptiveSchedule", "Retune"]


@dataclasses.dataclass(frozen=True)
class Retune:
    """One controller decision, kept for diagnostics/plots."""

    from_t: int      # splice point (iteration frontier at decision time)
    h: int           # emitted interval
    h_opt_raw: float # un-rounded eq. (21) solution
    r_hat: float
    lam2: float


class AdaptiveSchedule(PiecewisePeriodic):
    """Periodic/increasingly-sparse schedule with an online-tuned interval.

    Args:
      h0: initial interval until the first retune (1 = every iteration,
        the safe cold-start: mix aggressively until r is measured).
      p: sparse-growth exponent in [0, 1/2). 0 keeps the pure periodic
        policy (h tracks h_opt); p > 0 multiplies the measured base by
        (1 + H(t))^p, the paper's increasingly-sparse pattern.
      h_max: safety clamp on the emitted interval.
    """

    name: str = "adaptive"

    def __init__(self, h0: int = 1, p: float = 0.0, h_max: int = 512):
        super().__init__(h=h0)
        if not 0.0 <= p < 0.5:
            raise ValueError(f"p must be in [0, 0.5), got {p}"
                             " (p >= 1/2 loses the convergence guarantee)")
        if h_max < 1:
            raise ValueError("h_max must be >= 1")
        self.p = p
        self.h_max = h_max

    def reset(self) -> None:
        """Fresh run: drop the splice history AND the policy state."""
        super().reset()
        self.h_opt_hat = float(self._h0)
        self.retunes: list[Retune] = []

    def target_h(self, from_t: int) -> int:
        """Interval the policy wants to emit for iterations after from_t."""
        base = max(self.h_opt_hat, 1.0)
        if self.p > 0.0:
            base *= (1.0 + self.H(from_t)) ** self.p
        return int(min(max(1, round(base)), self.h_max))

    def retune(self, from_t: int, n: int, k: int, r_hat: float,
               lam2: float) -> bool:
        """Re-solve eq. (21) with fresh estimates and splice the result in.

        Returns True when the emitted pattern actually changed (the caller
        then refreshes any cached next_comm_step answers beyond from_t).
        """
        raw = h_opt(n, k, r_hat, lam2)
        self.h_opt_hat = raw
        h = self.target_h(from_t)
        if h == self.h_current:
            return False
        self.set_h(from_t, h)
        self.retunes.append(Retune(int(from_t), h, raw, float(r_hat),
                                   float(lam2)))
        return True
