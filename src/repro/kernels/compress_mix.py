"""Fused compress-mix: masked consensus accumulation in one memory pass.

Compressed gossip on a k-regular graph mixes SPARSIFIED neighbor messages
against the node's own exact state (the diagonal never compresses itself):

    out[i] = w_self[i] * z[i] + sum_j w_edge[i, j] * (msg_nbr * mask_nbr)

Materializing the masked message first (`sent = corrected * mask`, then
the plain weighted mix) reads the (k, n, M) neighbor tiles twice and
writes an (n, M) intermediate. This kernel fuses the mask multiply into
the same VMEM-resident accumulation pass `gossip_mix_weighted` uses --
the compress step rides along for free on an op that is purely
bandwidth-bound, which is exactly the regime where top-k/rand-k messages
would otherwise have forced the dense O(n^2 d) matmul split
(`DDASimulator`'s old `compress_keep`-disables-sparse restriction).

Layout mirrors `gossip_mix.gossip_mix_weighted`: (8, 1024) data tiles
over (nodes, dims), the k neighbor message AND mask stacks as leading-dim
operands with the small degree loop unrolled in-kernel, and the per-node
weight columns as (8, 1) blocks broadcasting across the lane dimension.
The caller (`ops.compress_mix_impl`) gathers/pads; the mask is 0/1 in the
message dtype so the multiply stays in the fp32 accumulation type.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gossip_mix import _LANES, _SUBLANES


def _compress_mix_kernel(self_ref, nbr_ref, mask_ref, wself_ref, wedge_ref,
                         out_ref, *, k: int):
    """One (nodes, dims) tile: acc = w_self⊙self
    + sum_j w_edge[:, j]⊙(msg_j⊙mask_j)."""
    acc = wself_ref[...] * self_ref[...].astype(jnp.float32)
    for j in range(k):  # k is small (graph degree); unrolled
        acc += wedge_ref[j] * (nbr_ref[j].astype(jnp.float32)
                               * mask_ref[j].astype(jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def compress_mix_weighted(self_buf: jax.Array, neighbor_msgs: jax.Array,
                          neighbor_masks: jax.Array, w_self: jax.Array,
                          w_edge: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """Stacked-node masked mix with per-edge weight vectors.

    self_buf: (n, M) exact own states; neighbor_msgs: (k, n, M) slot j
    holding the corrected message node i receives from its j-th
    in-neighbor (already gathered); neighbor_masks: (k, n, M) the matching
    0/1 supports; w_self: (n,); w_edge: (n, k). n must be a multiple of 8
    and M of 1024 (the caller pads; see ops.compress_mix_impl).
    """
    n, M = self_buf.shape
    k = neighbor_msgs.shape[0]
    assert n % _SUBLANES == 0, n
    assert M % _LANES == 0, M
    assert neighbor_masks.shape == neighbor_msgs.shape
    ws = w_self.astype(jnp.float32).reshape(n, 1)
    we = w_edge.astype(jnp.float32).T.reshape(k, n, 1)
    grid = (n // _SUBLANES, M // _LANES)
    return pl.pallas_call(
        functools.partial(_compress_mix_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_SUBLANES, _LANES), lambda i, j: (i, j)),
            pl.BlockSpec((k, _SUBLANES, _LANES), lambda i, j: (0, i, j)),
            pl.BlockSpec((k, _SUBLANES, _LANES), lambda i, j: (0, i, j)),
            pl.BlockSpec((_SUBLANES, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _SUBLANES, 1), lambda i, j: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((_SUBLANES, _LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, M), self_buf.dtype),
        interpret=interpret,
    )(self_buf, neighbor_msgs, neighbor_masks, ws, we)
