"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD reformulation turns the token recurrence into per-chunk MATMULS --
the MXU-friendly form (this is the hardware-adaptation insight: on TPU the
win comes from feeding the 128x128 systolic array, not from warp-level
shuffles as in the CUDA original):

  intra-chunk:  Y_intra = ((C K^T) o L) (dt o X)        two (Q,Q)/(Q,P) GEMMs
  inter-chunk:  Y_inter = decay0 o (C h0)               one (Q,N)x(N,P) GEMM
  state update: h_Q = exp(sum dA) h0 + (decay_t B)^T X  one (N,Q)x(Q,P) GEMM

Grid: (batch, heads, seq_chunks); the chunk axis iterates sequentially per
TPU core so the (P, N) state lives in VMEM scratch across chunks. Block
shapes: chunk Q=128 tokens (MXU-aligned), full P (head dim) and N (state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_ref,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    A = A_ref[0]                                    # scalar (negative)
    Bm = B_ref[0].astype(jnp.float32)               # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)               # (Q, N)

    dA = dt * A                                     # (Q,) log decays
    cum = jnp.cumsum(dA)                            # (Q,)
    # intra-chunk: L[s,t] = exp(cum_s - cum_t), s >= t
    rel = cum[:, None] - cum[None, :]               # (Q, Q)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    L = jnp.where(mask, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    W = scores * L                                  # (Q, Q)
    xdt = x * dt[:, None]                           # (Q, P)
    y = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: h0 (P, N) decayed into each position
    h0 = h_ref[...]                                 # (P, N)
    Ch = jax.lax.dot_general(Cm, h0, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, P)
    y = y + jnp.exp(cum)[:, None] * Ch
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update
    total = cum[-1]
    decay_t = jnp.exp(total - cum)                  # (Q,)
    dBx = jax.lax.dot_general(xdt * decay_t[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = jnp.exp(total) * h0 + dBx


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,) negative reals;
    B, C: (Bt, S, N). Returns y (Bt, S, H, P) fp32."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (Bt, H, S // chunk)
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P),
                               lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, H, P), f32),
        scratch_shapes=[pltpu.VMEM((P, N), f32)],
        interpret=interpret,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), B.astype(f32),
      C.astype(f32))
