"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels execute with interpret=True; on real
TPU hardware set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) to run
the compiled Mosaic kernels. `ref.py` holds the pure-jnp oracles used by the
property tests.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.compress_mix import compress_mix_weighted as _compress_w
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gossip_mix import (_LANES, _SUBLANES, _TILE,
                                      gossip_mix as _gossip,
                                      gossip_mix_weighted as _gossip_w)
from repro.kernels.selective_scan import selective_scan as _sscan
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan(x, dt, A, B, C, D_skip, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sscan(x, dt, A, B, C, D_skip, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, A, B, C, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, B, C, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("self_weight", "edge_weight",
                                    "interpret"))
def gossip_mix(self_buf, neighbor_bufs, self_weight: float,
               edge_weight: float, *, interpret: bool | None = None):
    """Pads the flat buffers to a whole tile count, mixes, and un-pads."""
    interpret = _default_interpret() if interpret is None else interpret
    (M,) = self_buf.shape
    pad = (-M) % _TILE
    sb = jnp.pad(self_buf, (0, pad))
    nb = jnp.pad(neighbor_bufs, ((0, 0), (0, pad)))
    out = _gossip(sb, nb, self_weight, edge_weight, interpret=interpret)
    return out[:M]


def gossip_gather_mix_impl(z, S_in, w_self, w_edge, *, msg=None,
                           interpret: bool | None = None,
                           use_kernel: bool | None = None):
    """Sparse consensus round on a stacked z: neighbor-index gather + the
    fused weighted accumulation (`gossip_mix_weighted`).

    z: (n, ...) stacked node states; S_in: (n, k) in-neighbor indices
    (S_in[i, j] = the node whose value node i receives in slot j);
    w_self: (n,); w_edge: (n, k). Equals `W @ z.reshape(n, -1)` for the
    mixing matrix W with diag(W) = w_self and W[i, S_in[i, j]] summing
    w_edge[i, j] over slots. `msg` (same shape as z) substitutes the
    TRANSMITTED stack for the neighbor gathers -- quantized gossip ships
    the dequantized `msg` while the diagonal keeps each node's exact own
    z -- and defaults to z itself (uncompressed).

    Dispatch: on compiled backends (`use_kernel=True`, the default when not
    interpreting) the gather feeds the Pallas kernel, which makes the k+1
    AXPYs one VMEM-resident pass. Under `interpret=True` (this CPU
    container) the Pallas interpreter costs ~ms per grid cell -- two orders
    off the fused XLA lowering -- so the default routes to the bitwise-
    equivalent jnp reference, which XLA fuses into a single gather+FMA loop
    (~6x the dense matmul at n=256, k=4, d=4096; see BENCH_dense.json).
    Tests pass `use_kernel=True` with `interpret=True` to validate the
    kernel body itself.
    """
    interpret = _default_interpret() if interpret is None else interpret
    use_kernel = (not interpret) if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.gossip_gather_mix_ref(z, S_in, w_self, w_edge, msg=msg)
    n, k = S_in.shape
    # the kernel consumes weight VECTORS; scalar (uniform) weights are just
    # constant columns
    if jnp.ndim(w_self) == 0:
        w_self = jnp.full((n,), w_self, jnp.float32)
    if jnp.ndim(w_edge) == 0:
        w_edge = jnp.full((n, k), w_edge, jnp.float32)
    zf = z.reshape(n, -1)
    mf = zf if msg is None else msg.reshape(n, -1)
    M = zf.shape[1]
    pad_n = (-n) % _SUBLANES
    pad_m = (-M) % _LANES
    sb = jnp.pad(zf, ((0, pad_n), (0, pad_m)))
    nbr = jnp.pad(jnp.moveaxis(mf[S_in], 1, 0),
                  ((0, 0), (0, pad_n), (0, pad_m)))
    ws = jnp.pad(w_self, (0, pad_n))
    we = jnp.pad(w_edge, ((0, pad_n), (0, 0)))
    out = _gossip_w(sb, nbr, ws, we, interpret=interpret)
    return out[:n, :M].astype(z.dtype).reshape(z.shape)


def compress_mix_impl(z, msg, mask, S_in, w_self, w_edge, *,
                      interpret: bool | None = None,
                      use_kernel: bool | None = None):
    """Fused sparsified consensus round: gather each in-neighbor's
    corrected message AND its 0/1 transmitted support, then accumulate
    `w_self[i] z[i] + sum_j w_edge[i, j] (msg ⊙ mask)[S_in[i, j]]` in one
    VMEM-resident pass (`compress_mix.compress_mix_weighted`) -- the
    sparsify multiply rides the bandwidth-bound mix for free, which is
    what lets top-k/rand-k gossip stay on the O(nkd) sparse path instead
    of forcing the dense matmul split.

    Shapes and the ref/kernel dispatch contract match
    `gossip_gather_mix_impl`; `mask` is 0/1 in z's dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    use_kernel = (not interpret) if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.compress_mix_ref(z, msg, mask, S_in, w_self, w_edge)
    n, k = S_in.shape
    if jnp.ndim(w_self) == 0:
        w_self = jnp.full((n,), w_self, jnp.float32)
    if jnp.ndim(w_edge) == 0:
        w_edge = jnp.full((n, k), w_edge, jnp.float32)
    zf = z.reshape(n, -1)
    mf = msg.reshape(n, -1)
    kf = mask.reshape(n, -1)
    M = zf.shape[1]
    pad_n = (-n) % _SUBLANES
    pad_m = (-M) % _LANES
    sb = jnp.pad(zf, ((0, pad_n), (0, pad_m)))
    nbr = jnp.pad(jnp.moveaxis(mf[S_in], 1, 0),
                  ((0, 0), (0, pad_n), (0, pad_m)))
    msk = jnp.pad(jnp.moveaxis(kf[S_in], 1, 0),
                  ((0, 0), (0, pad_n), (0, pad_m)))
    ws = jnp.pad(w_self, (0, pad_n))
    we = jnp.pad(w_edge, ((0, pad_n), (0, 0)))
    out = _compress_w(sb, nbr, msk, ws, we, interpret=interpret)
    return out[:n, :M].astype(z.dtype).reshape(z.shape)


#: jitted front doors; hot loops that are already inside their own jit call
#: the `_impl` functions directly so the mix inlines into the caller's
#: program (a nested pjit is a fusion boundary XLA will not cross)
gossip_gather_mix = functools.partial(
    jax.jit, static_argnames=("interpret", "use_kernel"))(
        gossip_gather_mix_impl)
compress_mix = functools.partial(
    jax.jit, static_argnames=("interpret", "use_kernel"))(
        compress_mix_impl)

__all__ = ["flash_attention", "selective_scan", "ssd_scan", "gossip_mix",
           "gossip_gather_mix", "gossip_gather_mix_impl",
           "compress_mix", "compress_mix_impl", "ref"]
