"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels execute with interpret=True; on real
TPU hardware set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) to run
the compiled Mosaic kernels. `ref.py` holds the pure-jnp oracles used by the
property tests.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gossip_mix import _TILE, gossip_mix as _gossip
from repro.kernels.selective_scan import selective_scan as _sscan
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan(x, dt, A, B, C, D_skip, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sscan(x, dt, A, B, C, D_skip, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, A, B, C, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, B, C, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("self_weight", "edge_weight",
                                    "interpret"))
def gossip_mix(self_buf, neighbor_bufs, self_weight: float,
               edge_weight: float, *, interpret: bool | None = None):
    """Pads the flat buffers to a whole tile count, mixes, and un-pads."""
    interpret = _default_interpret() if interpret is None else interpret
    (M,) = self_buf.shape
    pad = (-M) % _TILE
    sb = jnp.pad(self_buf, (0, pad))
    nb = jnp.pad(neighbor_bufs, ((0, 0), (0, pad)))
    out = _gossip(sb, nb, self_weight, edge_weight, interpret=interpret)
    return out[:M]


__all__ = ["flash_attention", "selective_scan", "ssd_scan", "gossip_mix",
           "ref"]
