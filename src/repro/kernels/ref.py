"""Pure-jnp oracles for every Pallas kernel (the allclose targets in
tests/test_kernels.py). Deliberately naive and readable."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sm_scale: float | None = None) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,KH,Sk,D). Plain softmax attention in fp32."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    group = H // KH
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def selective_scan_ref(x, dt, A, B, C, D_skip) -> jax.Array:
    """Mamba-1 recurrence, sequential over tokens.
    x, dt: (Bt, S, d); A: (d, N); B, C: (Bt, S, N); D_skip: (d,).
    Returns y: (Bt, S, d) fp32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = B.astype(jnp.float32)
    Cm = C.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)            # (Bt, d, N)
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    Bt, S, d = x.shape
    h0 = jnp.zeros((Bt, d, A.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    return y + x * D_skip


def ssd_scan_ref(x, dt, A, B, C) -> jax.Array:
    """Mamba-2 SSD recurrence, sequential oracle.
    x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,) negative; B, C: (Bt,S,N).
    Returns y: (Bt,S,H,P) fp32 (no D skip, no gating)."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = B.astype(jnp.float32)
    Cm = C.astype(jnp.float32)
    Bt, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                    # (Bt,H,P),(Bt,H),(Bt,N)
        dA = jnp.exp(dt_t * A)                       # (Bt,H)
        dBx = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
        h = dA[..., None, None] * h + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def gossip_mix_ref(self_buf, neighbor_bufs, self_weight, edge_weight
                   ) -> jax.Array:
    """out = sw * self + ew * sum_k neighbor_k.
    self_buf: (M,); neighbor_bufs: (K, M)."""
    acc = self_weight * self_buf.astype(jnp.float32)
    acc = acc + edge_weight * jnp.sum(neighbor_bufs.astype(jnp.float32), 0)
    return acc.astype(self_buf.dtype)


def gossip_mix_weighted_ref(self_buf, neighbor_bufs, w_self, w_edge
                            ) -> jax.Array:
    """out[i] = w_self[i] * self[i] + sum_j w_edge[i, j] * nbr[j, i].
    self_buf: (n, M); neighbor_bufs: (K, n, M); w_self: (n,);
    w_edge: (n, K)."""
    acc = w_self[:, None] * self_buf.astype(jnp.float32)
    acc = acc + jnp.einsum("nk,knm->nm", w_edge.astype(jnp.float32),
                           neighbor_bufs.astype(jnp.float32))
    return acc.astype(self_buf.dtype)


def gossip_gather_mix_ref(z, S_in, w_self, w_edge, msg=None) -> jax.Array:
    """One sparse consensus round on a stacked z, as a gather + weighted sum:
    out[i] = w_self[i] z[i] + sum_j w_edge[i, j] src[S_in[i, j]].
    z: (n, ...); S_in: (n, K) in-neighbor indices; w_self: (n,) or scalar;
    w_edge: (n, K) or scalar (uniform lazy weights: one multiply over the
    summed gathers instead of K weight broadcasts). `msg` (same shape as
    z) substitutes the TRANSMITTED stack for the neighbor gathers --
    compressed gossip ships `msg` while the diagonal keeps the node's
    exact own z -- and defaults to z itself (uncompressed)."""
    n, k = S_in.shape
    zf = z.reshape(n, -1).astype(jnp.float32)
    mf = zf if msg is None else msg.reshape(n, -1).astype(jnp.float32)
    if jnp.ndim(w_edge) == 0:
        acc = mf[S_in[:, 0]]
        for j in range(1, k):
            acc = acc + mf[S_in[:, j]]
        out = w_self * zf + w_edge * acc
        return out.astype(z.dtype).reshape(z.shape)
    acc = w_self[:, None] * zf
    for j in range(k):
        acc = acc + w_edge[:, j][:, None] * mf[S_in[:, j]]
    return acc.astype(z.dtype).reshape(z.shape)


def compress_mix_ref(z, msg, mask, S_in, w_self, w_edge) -> jax.Array:
    """Masked (sparsified) consensus round:
    out[i] = w_self[i] z[i]
             + sum_j w_edge[i, j] (msg ⊙ mask)[S_in[i, j]].
    z/msg/mask: (n, ...) with mask the 0/1 transmitted support; S_in:
    (n, K); weights as in `gossip_gather_mix_ref`. The allclose target for
    `compress_mix.compress_mix_weighted`."""
    n = S_in.shape[0]
    sent = (msg.reshape(n, -1).astype(jnp.float32)
            * mask.reshape(n, -1).astype(jnp.float32))
    return gossip_gather_mix_ref(z, S_in, w_self, w_edge,
                                 msg=sent.reshape(z.shape))
