"""Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation: the token recurrence h <- exp(dt*A) h + dt*B x is inherently
sequential, so the kernel keeps the (d_block, N) state resident in VMEM and
streams sequence chunks HBM->VMEM, amortizing transfers (the GPU version
keeps state in registers/SMEM; VMEM is the TPU analogue). The grid is
(batch, d_blocks, seq_chunks) -- the LAST dimension iterates sequentially on
a TPU core, so the state scratch carries across chunks. d (the channel dim)
is embarrassingly parallel and blocked to bound VMEM.

The inner per-token loop is a fori_loop of VPU elementwise ops on
(d_block, N) tiles; with N=16 and d_block=512 each step is a (512,16)
multiply-add -- latency-bound on real hardware, which is exactly why
Mamba-2's SSD (matmul form, see ssd_scan.py) replaced it. We implement both;
the roofline in EXPERIMENTS.md quantifies the gap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_ref,
                 *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...]                       # (dB, N)
    D_skip = D_ref[...]                  # (1, dB)

    def body(t, h):
        x_t = x_ref[0, t, :]             # (dB,)
        dt_t = dt_ref[0, t, :]           # (dB,)
        B_t = B_ref[0, t, :]             # (N,)
        C_t = C_ref[0, t, :]             # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                     # (dB, N)
        dBx = (dt_t * x_t)[:, None] * B_t[None, :]          # (dB, N)
        h = dA * h + dBx
        y = jnp.sum(h * C_t[None, :], axis=1)               # (dB,)
        y_ref[0, t, :] = y + x_t * D_skip[0]
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, body, h_ref[...])


def selective_scan(x, dt, A, B, C, D_skip, *, d_block: int = 512,
                   chunk: int = 256, interpret: bool = False) -> jax.Array:
    """x, dt: (Bt, S, d) ; A: (d, N) ; B, C: (Bt, S, N) ; D_skip: (d,).
    Returns y (Bt, S, d) fp32. S % chunk == 0, d % d_block == 0."""
    Bt, S, d = x.shape
    N = A.shape[1]
    d_block = min(d_block, d)
    chunk = min(chunk, S)
    assert d % d_block == 0 and S % chunk == 0

    grid = (Bt * (d // d_block), 1, S // chunk)  # (bd, unused, chunks)
    db = d // d_block

    def xmap(i, _, ci):
        return (i // db, ci, i % db)

    def bmap(i, _, ci):
        return (i // db, ci, 0)

    def amap(i, _, ci):
        return (i % db, 0)

    def dmap(i, _, ci):
        return (0, i % db)

    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), xmap),   # x
            pl.BlockSpec((1, chunk, d_block), xmap),   # dt
            pl.BlockSpec((d_block, N), amap),          # A
            pl.BlockSpec((1, chunk, N), bmap),         # B
            pl.BlockSpec((1, chunk, N), bmap),         # C
            pl.BlockSpec((1, d_block), dmap),          # D_skip
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), xmap),
        out_shape=jax.ShapeDtypeStruct((Bt, S, d), f32),
        scratch_shapes=[pltpu.VMEM((d_block, N), f32)],
        interpret=interpret,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), B.astype(f32),
      C.astype(f32), D_skip.astype(f32).reshape(1, d))
