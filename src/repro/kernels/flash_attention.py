"""Causal flash attention (forward) as a Pallas TPU kernel.

TPU adaptation of the FlashAttention-2 schedule: the grid is
(batch, q_heads, q_blocks, kv_blocks) with the LAST dimension iterated
sequentially per TPU core, so the running softmax state (m, l, acc) lives in
VMEM scratch that persists across kv steps. Block shapes keep the working
set in VMEM and the matmul operands MXU-aligned (multiples of 128 on the
contracting/lane dims). GQA is expressed in the kv BlockSpec index map
(kv_head = q_head // group) so repeated K/V are never materialized.

Causal skipping: kv blocks strictly above the diagonal contribute nothing;
their compute is predicated off with pl.when (the loads still happen --
block-level early exit is a grid-shape decision we keep simple here).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, sm_scale: float, block_q: int, block_k: int,
                  causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Upper-triangular blocks are fully masked under causality: skip.
    run = (not causal) or (qi * block_q + block_q - 1 >= ki * block_k)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (Bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                               # (Bq, Bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (Bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (Bq, Bk)
        corr = jnp.exp(m_prev - m_new)                 # (Bq, 1)
        l_new = corr * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KH, Sk, D) with H % KH == 0.
    Returns (B, H, Sq, D) in q.dtype."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    group = H // KH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)

    grid = (B, H, Sq // block_q, Sk // block_k)
    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale,
                               block_q=block_q, block_k=block_k,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            # acc, m, l running-softmax state in VMEM (f32); m/l are padded
            # to 128 lanes (TPU vector registers are (8,128) tiles).
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
