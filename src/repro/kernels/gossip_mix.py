"""Fused consensus mixing: out = w_self * own + w_edge * sum_k neighbor_k.

The consensus hot loop (paper eq. 3: z <- sum_j p_ij z_j) applied to a
k-regular graph materializes k received buffers; mixing them with k separate
AXPY passes reads the output k+1 times. This kernel fuses the weighted
accumulation into ONE pass over memory -- the op is purely bandwidth-bound,
so the fusion is worth ~(k+1)x on HBM traffic for the mixing step.

Blocks are (8, 1024) tiles over the flattened parameter buffer (the caller
pads/reshapes); neighbors are stacked on a leading dim and the small k loop
is unrolled inside the kernel (all operands for one tile resident in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 1024
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _mix_kernel(self_ref, nbr_ref, out_ref, *, k: int, self_weight: float,
                edge_weight: float):
    acc = self_weight * self_ref[...].astype(jnp.float32)
    for j in range(k):  # k is small (graph degree); unrolled
        acc += edge_weight * nbr_ref[j].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def gossip_mix(self_buf: jax.Array, neighbor_bufs: jax.Array,
               self_weight: float, edge_weight: float, *,
               interpret: bool = False) -> jax.Array:
    """self_buf: (M,) flat parameters; neighbor_bufs: (k, M) received
    buffers. M is padded to a whole number of (8,1024) tiles by the caller
    (see ops.gossip_mix_padded)."""
    (M,) = self_buf.shape
    k = neighbor_bufs.shape[0]
    assert M % _TILE == 0, M
    rows = M // _LANES
    s2 = self_buf.reshape(rows, _LANES)
    n2 = neighbor_bufs.reshape(k, rows, _LANES)
    grid = (rows // _SUBLANES,)
    out = pl.pallas_call(
        functools.partial(_mix_kernel, k=k, self_weight=self_weight,
                          edge_weight=edge_weight),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, _SUBLANES, _LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), self_buf.dtype),
        interpret=interpret,
    )(s2, n2)
    return out.reshape(M)
