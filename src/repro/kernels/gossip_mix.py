"""Fused consensus mixing: out = w_self * own + w_edge * sum_k neighbor_k.

The consensus hot loop (paper eq. 3: z <- sum_j p_ij z_j) applied to a
k-regular graph materializes k received buffers; mixing them with k separate
AXPY passes reads the output k+1 times. This kernel fuses the weighted
accumulation into ONE pass over memory -- the op is purely bandwidth-bound,
so the fusion is worth ~(k+1)x on HBM traffic for the mixing step.

Two kernels:

  * `gossip_mix` -- one node's flat buffer against k received buffers with
    scalar weights (the shard_map per-node layout). Blocks are (8, 1024)
    tiles over the flattened parameter buffer (the caller pads/reshapes);
    neighbors are stacked on a leading dim and the small k loop is unrolled
    inside the kernel (all operands for one tile resident in VMEM).
  * `gossip_mix_weighted` -- the STACKED (n, d) layout of the dense
    simulator with per-edge WEIGHT VECTORS: w_self is (n,) and w_edge is
    (n, k), so a reweighted mixing matrix (`AdaptiveController
    (reweight_gossip=True)`'s `Network.mix_weights`) runs through the same
    fused pass as the uniform lazy weights (which are just constant
    vectors). Blocks tile (nodes, dims); the per-node weight columns ride
    along as (rows, 1) blocks that broadcast across the lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 1024
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _mix_kernel(self_ref, nbr_ref, out_ref, *, k: int, self_weight: float,
                edge_weight: float):
    acc = self_weight * self_ref[...].astype(jnp.float32)
    for j in range(k):  # k is small (graph degree); unrolled
        acc += edge_weight * nbr_ref[j].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def gossip_mix(self_buf: jax.Array, neighbor_bufs: jax.Array,
               self_weight: float, edge_weight: float, *,
               interpret: bool = False) -> jax.Array:
    """self_buf: (M,) flat parameters; neighbor_bufs: (k, M) received
    buffers. M is padded to a whole number of (8,1024) tiles by the caller
    (see ops.gossip_mix_padded)."""
    (M,) = self_buf.shape
    k = neighbor_bufs.shape[0]
    assert M % _TILE == 0, M
    rows = M // _LANES
    s2 = self_buf.reshape(rows, _LANES)
    n2 = neighbor_bufs.reshape(k, rows, _LANES)
    grid = (rows // _SUBLANES,)
    out = pl.pallas_call(
        functools.partial(_mix_kernel, k=k, self_weight=self_weight,
                          edge_weight=edge_weight),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, _SUBLANES, _LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), self_buf.dtype),
        interpret=interpret,
    )(s2, n2)
    return out.reshape(M)


def _mix_kernel_weighted(self_ref, nbr_ref, wself_ref, wedge_ref, out_ref,
                         *, k: int):
    """One (nodes, dims) tile: acc = w_self⊙self + sum_j w_edge[:,j]⊙nbr_j.

    The weight blocks are (SUBLANES, 1) columns that broadcast across the
    lane dimension -- one extra scalar per node row, so the pass stays
    bandwidth-bound on the data tiles exactly like the uniform kernel.
    """
    acc = wself_ref[...] * self_ref[...].astype(jnp.float32)
    for j in range(k):  # k is small (graph degree); unrolled
        acc += wedge_ref[j] * nbr_ref[j].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def gossip_mix_weighted(self_buf: jax.Array, neighbor_bufs: jax.Array,
                        w_self: jax.Array, w_edge: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """Stacked-node mix with per-edge weight vectors.

    self_buf: (n, M) -- node-major rows of flattened parameters;
    neighbor_bufs: (k, n, M) -- slot j holds the buffer node i receives
    from its j-th in-neighbor (already gathered by the caller);
    w_self: (n,) diagonal weights; w_edge: (n, k) per-(node, slot) weights.
    n must be a multiple of 8 and M a multiple of 1024 (the caller pads;
    see ops.gossip_gather_mix).
    """
    n, M = self_buf.shape
    k = neighbor_bufs.shape[0]
    assert n % _SUBLANES == 0, n
    assert M % _LANES == 0, M
    ws = w_self.astype(jnp.float32).reshape(n, 1)
    we = w_edge.astype(jnp.float32).T.reshape(k, n, 1)
    grid = (n // _SUBLANES, M // _LANES)
    return pl.pallas_call(
        functools.partial(_mix_kernel_weighted, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_SUBLANES, _LANES), lambda i, j: (i, j)),
            pl.BlockSpec((k, _SUBLANES, _LANES), lambda i, j: (0, i, j)),
            pl.BlockSpec((_SUBLANES, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _SUBLANES, 1), lambda i, j: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((_SUBLANES, _LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, M), self_buf.dtype),
        interpret=interpret,
    )(self_buf, neighbor_bufs, ws, we)
