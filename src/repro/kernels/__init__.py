"""Pallas TPU kernels for the performance-critical compute layers, each with
a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.

  flash_attention     -- causal FA-2 schedule, VMEM-resident softmax state
  selective_scan      -- Mamba-1 recurrence, VMEM-resident (d,N) state
  ssd_scan            -- Mamba-2 SSD chunked matmul form (MXU-aligned)
  gossip_mix          -- fused consensus weighted accumulation (paper eq. 3)
  gossip_mix_weighted -- stacked-node variant with per-edge weight vectors
                         (ops.gossip_gather_mix = gather + this, the dense
                         simulator's k-regular fast path)
"""

from repro.kernels import ops, ref
