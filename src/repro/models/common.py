"""Shared model-definition machinery: configs, param construction with
logical sharding axes, norms, rotary embeddings, activations.

Every parameter is built through `p(key, shape, axes)` which returns a
`(array, axes)` pair; `split_axes` separates the two parallel trees. The
logical axis names are mapped to mesh axes by `launch/sharding.py` rules, so
the model code never mentions mesh axes directly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes any of the supported families.

    The layer stack is `prologue` blocks followed by `n_super` repetitions of
    `superblock`. Block kinds:
      "attn"        self-attention (GQA/RoPE) + MLP
      "attn_moe"    self-attention + MoE FFN
      "mla"         multi-head latent attention (DeepSeek) + MLP
      "mla_moe"     MLA + MoE FFN
      "cross_attn"  cross-attention to encoder states + MLP (VLM)
      "mamba1"      Mamba-1 selective-scan block (attn-free)
      "mamba2"      Mamba-2 SSD block
      "shared_attn" the hybrid's weight-shared attention block (zamba2)
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    superblock: tuple[str, ...]
    n_super: int
    prologue: tuple[str, ...] = ()
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"          # swiglu | squared_relu | gelu
    # Megatron TP-MLP (shard d_ff over 'model', gather/reduce the residual)
    # instead of the default pure sequence-parallel MLP. Preferable when the
    # per-layer weight bytes (3*D*F) exceed the microbatch activation bytes
    # (2*B_mb*S*D) -- i.e. very wide FFNs (see EXPERIMENTS.md section Perf).
    mlp_tp: bool = False
    # moe
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0                # expert hidden size (defaults to d_ff)
    moe_capacity_factor: float = 1.25
    # mla
    mla_kv_lora: int = 0
    mla_q_lora: int = 0
    mla_rope_head_dim: int = 64
    mla_v_head_dim: int = 0          # 0 -> head_dim
    # ssm
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64           # mamba2
    # hybrid / vlm / audio frontends
    shared_attn_lora: int = 64       # zamba2 per-invocation LoRA rank
    num_encoder_tokens: int = 0      # VLM: vision tokens; audio: frame count
    encoder_dim: int = 0             # stubbed frontend embedding dim
    # training
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    remat: bool = True
    # gradient-accumulation factor for the production train step (splits the
    # global batch; sized per arch so activations fit v5e HBM)
    train_microbatches: int = 1
    # bf16 Adam moments halve optimizer HBM (used by the 400B-class configs
    # where fp32 state alone exceeds the budget; updates stay fp32)
    opt_moments_bf16: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def num_layers(self) -> int:
        return len(self.prologue) + self.n_super * len(self.superblock)

    @property
    def blocks(self) -> tuple[str, ...]:
        return self.prologue + self.superblock * self.n_super

    def has_block(self, kind_prefix: str) -> bool:
        return any(b.startswith(kind_prefix) for b in self.blocks)

    @property
    def is_attention_free(self) -> bool:
        return not any(
            b in ("attn", "attn_moe", "mla", "mla_moe", "cross_attn",
                  "shared_attn") for b in self.blocks)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic memory path: SSM and hybrid families only."""
        return self.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# Params with logical axes
# ---------------------------------------------------------------------------


def p(key, shape: Sequence[int], axes: tuple[str | None, ...],
      dtype=jnp.bfloat16, scale: float | None = None):
    """Build one parameter leaf: (truncated-normal array, logical axes)."""
    assert len(shape) == len(axes), (shape, axes)
    if scale is None:
        fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    arr = scale * jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape),
                                              jnp.float32)
    return arr.astype(dtype), axes


def pz(shape: Sequence[int], axes: tuple[str | None, ...], dtype=jnp.bfloat16,
       fill: float = 0.0):
    """Constant-initialized parameter (biases, norm scales)."""
    assert len(shape) == len(axes), (shape, axes)
    return jnp.full(tuple(shape), fill, dtype), axes


def is_param_pair(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[1], tuple)
            and all(isinstance(a, (str, type(None))) for a in x[1]))


def split_axes(tree: PyTree) -> tuple[PyTree, PyTree]:
    """Split a tree of (array, axes) pairs into (arrays, axes) trees."""
    arrays = jax.tree.map(lambda x: x[0], tree, is_leaf=is_param_pair)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_param_pair)
    return arrays, axes


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def _barrier_transformable() -> bool:
    """Older jax (< 0.5) ships no differentiation/batching rules for the
    optimization_barrier primitive, so any model using it cannot be trained
    (grad) or pod-vmapped (consensus launcher). Probe trace-only via
    eval_shape -- no compilation, runs once at import."""
    try:
        jax.eval_shape(jax.grad(jax.lax.optimization_barrier), 1.0)
        jax.eval_shape(jax.vmap(jax.lax.optimization_barrier),
                       jax.ShapeDtypeStruct((1,), jnp.float32))
        return True
    except NotImplementedError:
        return False


if _barrier_transformable():
    def barrier(x: PyTree) -> PyTree:
        """Identity that XLA may not optimize across: pins layouts/carry
        dtypes (see call sites in models/attention.py, transformer.py)."""
        return jax.lax.optimization_barrier(x)
else:
    def barrier(x: PyTree) -> PyTree:
        """Plain identity fallback: this jax cannot differentiate or batch
        the barrier primitive. The pinning the barrier provides is a
        memory/perf optimization, not a correctness requirement, so old
        environments lose only that."""
        return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    angles = angles[..., None, :]                        # (..., s, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "squared_relu":
        r = jnp.maximum(x, 0.0)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"activation {kind} handled in mlp (swiglu) or unknown")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in fp32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
