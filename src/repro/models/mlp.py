"""Feed-forward blocks: dense (SwiGLU / squared-ReLU / GELU) and
Mixture-of-Experts with shared experts + top-k token-choice routing.

MoE dispatch uses the sort-based fixed-capacity scheme (no (tokens x experts
x capacity) one-hot): flatten token assignments, sort by expert id, compute
each token's slot inside its expert segment, and scatter into an
(experts, capacity, d) buffer (one overflow row absorbs drops). Experts are
sharded over the `model` mesh axis (EP); tokens are model-replicated after
the attention all-reduce, so dispatch/combine stay device-local and the only
MoE collective is the usual TP reduction of the output.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, p, pz, rms_norm
from repro.runtime.sharding import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> PyTree:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    prm = {
        "norm": pz((D,), ("embed",), jnp.float32),
        "w_up": p(ks[0], (D, F), ("embed", "mlp"), cfg.dtype),
        "w_down": p(ks[1], (F, D), ("mlp", "embed"), cfg.dtype),
    }
    if cfg.mlp_act == "swiglu":
        prm["w_gate"] = p(ks[2], (D, F), ("embed", "mlp"), cfg.dtype)
    return prm


def _ffn(prm, h, cfg: ModelConfig):
    # Default: sequence-parallel MLP -- tokens stay sharded over
    # ('data','model'), every device runs the FULL d_ff for its token shard.
    # Identical FLOPs to Megatron TP-MLP with ZERO model-axis activation
    # collectives, but each device gathers the full (D,F) weights per layer.
    # For very wide FFNs (qwen110b d_ff=49152) the weight gathers dominate,
    # so cfg.mlp_tp selects the classic Megatron split: d_ff sharded over
    # 'model', residual gathered/reduced. (EXPERIMENTS.md section Perf.)
    tok_axes = (("batch", "seq", "embed_act") if cfg.mlp_tp
                else ("batch", "seq_sp", "embed_act"))
    act_axes = (("batch", "seq", "mlp") if cfg.mlp_tp
                else ("batch", "seq_sp", None))
    h = constrain(h, tok_axes)
    up = jnp.einsum("bsd,df->bsf", h, prm["w_up"])
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", h, prm["w_gate"])
        act = jax.nn.silu(gate) * up
    elif cfg.mlp_act == "squared_relu":
        r = jnp.maximum(up, 0.0)
        act = r * r
    else:
        act = jax.nn.gelu(up)
    act = constrain(act, act_axes)
    return jnp.einsum("bsf,fd->bsd", act, prm["w_down"])


def mlp_apply(prm, x, cfg: ModelConfig, d_ff: int | None = None) -> jax.Array:
    h = rms_norm(x, prm["norm"])
    out = _ffn(prm, h, cfg)
    return constrain(out, ("batch", "seq_sp", "embed_act"))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    D, E = cfg.d_model, cfg.moe_experts
    F = cfg.moe_d_ff or cfg.d_ff
    prm = {
        "norm": pz((D,), ("embed",), jnp.float32),
        "router": p(ks[0], (D, E), ("embed", "experts"), jnp.float32),
        "w_up": p(ks[1], (E, D, F), ("experts", "embed", "expert_mlp"),
                  cfg.dtype),
        "w_gate": p(ks[2], (E, D, F), ("experts", "embed", "expert_mlp"),
                    cfg.dtype),
        "w_down": p(ks[3], (E, F, D), ("experts", "expert_mlp", "embed"),
                    cfg.dtype),
    }
    if cfg.moe_shared > 0:
        prm["shared"] = mlp_init(ks[4], cfg,
                                 d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.moe_shared)
        del prm["shared"]["norm"]  # shares the block norm
    return prm


def _dispatch_indices(expert_ids: jax.Array, num_experts: int, capacity: int):
    """Sort-based slotting. expert_ids: (A,) flat assignments.

    Returns flat destination index in [0, E*C] for each assignment, where
    E*C is the overflow slot (dropped tokens).
    """
    A = expert_ids.shape[0]
    sort_idx = jnp.argsort(expert_ids)                  # stable
    sorted_ids = expert_ids[sort_idx]
    seg_starts = jnp.searchsorted(sorted_ids, jnp.arange(num_experts))
    pos_in_expert = jnp.arange(A) - seg_starts[sorted_ids]
    dest_sorted = jnp.where(pos_in_expert < capacity,
                            sorted_ids * capacity + pos_in_expert,
                            num_experts * capacity)
    dest = jnp.zeros((A,), dest_sorted.dtype).at[sort_idx].set(dest_sorted)
    return dest


def _moe_grouped(tokens, router, w_up, w_gate, w_down, cfg: ModelConfig,
                 capacity: int):
    """Route and run experts for G dispatch groups. tokens: (G, Nl, D),
    G sharded over 'data', experts over 'model'.

    Dispatch is GATHER-based: a cheap per-group 1-D index scatter builds the
    inverse map slot -> source token, then the (G, E, C, D) expert inputs are
    a batched gather (scattering (Nl*K, D) token payloads lowers
    catastrophically in SPMD -- it materialized a u32[(EC+1), D] index
    tensor; gathers do not). All large intermediates carry explicit sharding
    constraints: (G -> data, E -> model)."""
    G, Nl, D = tokens.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    C = capacity
    logits = jnp.einsum("gnd,de->gne", tokens.astype(jnp.float32), router)
    gates, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), K)  # (G,Nl,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dest = jax.vmap(
        lambda i: _dispatch_indices(i, E, C))(ids.reshape(G, Nl * K))
    dest = constrain(dest, ("batch", None))                  # (G, Nl*K) int
    # inverse map per group: which assignment fills expert slot s
    slot_src = jnp.full((G, E * C + 1), Nl * K, jnp.int32)
    slot_src = jax.vmap(lambda s, d: s.at[d].set(
        jnp.arange(Nl * K, dtype=jnp.int32)))(slot_src, dest)
    slot_src = slot_src[:, :E * C]                           # (G, E*C)
    slot_valid = slot_src < Nl * K
    token_src = jnp.where(slot_valid, slot_src // K, 0)
    expert_in = jnp.take_along_axis(
        tokens, token_src[..., None], axis=1)                # (G, E*C, D)
    expert_in = jnp.where(slot_valid[..., None], expert_in, 0)
    expert_in = expert_in.reshape(G, E, C, D)
    expert_in = constrain(expert_in, ("batch", "experts", None, "embed_act"))

    up = jnp.einsum("gecd,edf->gecf", expert_in, w_up)
    gate = jnp.einsum("gecd,edf->gecf", expert_in, w_gate)
    act = jax.nn.silu(gate) * up
    act = constrain(act, ("batch", "experts", None, "expert_mlp"))
    expert_out = jnp.einsum("gecf,efd->gecd", act, w_down)
    expert_out = constrain(expert_out,
                           ("batch", "experts", None, "embed_act"))

    flat_out = jnp.concatenate(
        [expert_out.reshape(G, E * C, D),
         jnp.zeros((G, 1, D), expert_out.dtype)], axis=1)
    out = jnp.zeros((G, Nl, D), jnp.float32)
    for k in range(K):  # accumulate per assignment; no (G,Nl,K,D) tensor
        picked = jnp.take_along_axis(
            flat_out, dest.reshape(G, Nl, K)[:, :, k][..., None], axis=1)
        out = out + picked.astype(jnp.float32) * gates[:, :, k:k + 1]
    out = constrain(out, ("batch", None, "embed_act"))
    return out.astype(tokens.dtype)


def moe_apply(prm, x, cfg: ModelConfig, groups: int = 1) -> jax.Array:
    """Token-choice top-k MoE with fixed capacity and optional shared experts.

    x: (B,S,D). Router in fp32. `groups` partitions the tokens into
    independent dispatch groups (the launcher sets groups = data-axis size so
    each data shard routes its own tokens with a LOCAL capacity buffer --
    dispatch and combine then stay device-local; the only MoE collectives
    left are the FSDP weight gathers and the TP output reduction).
    Capacity per group: C = ceil(top_k * tokens_per_group * cf / E).
    """
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    h = rms_norm(x, prm["norm"])
    N = B * S
    G = groups if N % groups == 0 else 1
    Nl = N // G
    C = max(1, int(-(-K * Nl * cfg.moe_capacity_factor // E)))

    tokens = h.reshape(G, Nl, D)
    tokens = constrain(tokens, ("batch", None, "embed_act"))
    combined = _moe_grouped(tokens, prm["router"], prm["w_up"],
                            prm["w_gate"], prm["w_down"], cfg, C)

    out = combined.reshape(B, S, D)
    if "shared" in prm:
        out = out + _ffn(prm["shared"], h, cfg)
    return constrain(out, ("batch", "seq", "embed_act"))


def moe_aux_loss(prm, x, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    B, S, D = x.shape
    h = rms_norm(x, prm["norm"]).reshape(B * S, D)
    logits = jnp.einsum("nd,de->ne", h.astype(jnp.float32), prm["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.moe_experts, dtype=jnp.float32),
                    axis=0)
    prob = jnp.mean(probs, axis=0)
    return cfg.moe_experts * jnp.sum(frac * prob)
