"""Decoder assembly: embeddings + (prologue blocks + scanned superblocks) +
final norm + LM head, with forward (train/prefill) and one-token decode.

The layer stack is `cfg.prologue` followed by `cfg.n_super` repetitions of
`cfg.superblock`. Per-slot parameters are STACKED over the superblock
repetitions and the stack runs under `jax.lax.scan` (keeps HLO size O(1) in
depth -- essential for 80-100 layer dry-runs) with per-superblock remat.

Supported block kinds (see ModelConfig): attn, attn_moe, mla, mla_moe,
cross_attn, mamba1, mamba2, shared_attn. "shared_attn" uses ONE weight copy
(zamba2-style) plus per-repetition LoRA deltas that ARE stacked.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ModelConfig, barrier, cross_entropy_loss, p,
                                 pz, rms_norm, split_axes)
from repro.runtime.sharding import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# Per-block init/apply/decode dispatch
# ---------------------------------------------------------------------------


def _block_init(kind: str, key, cfg: ModelConfig) -> PyTree:
    if kind == "attn":
        k1, k2 = jax.random.split(key)
        return {"attn": attn.gqa_init(k1, cfg), "mlp": mlp_mod.mlp_init(k2, cfg)}
    if kind == "attn_moe":
        k1, k2 = jax.random.split(key)
        return {"attn": attn.gqa_init(k1, cfg), "moe": mlp_mod.moe_init(k2, cfg)}
    if kind == "mla":
        k1, k2 = jax.random.split(key)
        return {"attn": attn.mla_init(k1, cfg), "mlp": mlp_mod.mlp_init(k2, cfg)}
    if kind == "mla_moe":
        k1, k2 = jax.random.split(key)
        return {"attn": attn.mla_init(k1, cfg), "moe": mlp_mod.moe_init(k2, cfg)}
    if kind == "cross_attn":
        k1, k2 = jax.random.split(key)
        return {"attn": attn.cross_attn_init(k1, cfg),
                "mlp": mlp_mod.mlp_init(k2, cfg)}
    if kind == "mamba1":
        return {"mamba": ssm_mod.mamba1_init(key, cfg)}
    if kind == "mamba2":
        return {"mamba": ssm_mod.mamba2_init(key, cfg)}
    if kind == "shared_attn":
        # LoRA deltas only; shared weights live at top level.
        r = cfg.shared_attn_lora
        D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
        ks = jax.random.split(key, 4)
        return {
            "lora_q_a": p(ks[0], (D, r), ("embed", "lora"), cfg.dtype),
            "lora_q_b": pz((r, H, hd), ("lora", "q_heads", "head"), cfg.dtype),
            "lora_o_a": p(ks[1], (H, hd, r), ("q_heads", "head", "lora"),
                          cfg.dtype),
            "lora_o_b": pz((r, D), ("lora", "embed"), cfg.dtype),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _mixer_apply(kind: str, prm, x, cfg, positions, shared, enc):
    if kind in ("attn", "attn_moe"):
        return attn.gqa_apply(prm["attn"], x, cfg, positions)
    if kind in ("mla", "mla_moe"):
        return attn.mla_apply(prm["attn"], x, cfg, positions)
    if kind == "cross_attn":
        return attn.cross_attn_apply(prm["attn"], x, enc, cfg)
    if kind == "mamba1":
        return ssm_mod.mamba1_apply(prm["mamba"], x, cfg, positions)
    if kind == "mamba2":
        return ssm_mod.mamba2_apply(prm["mamba"], x, cfg, positions)
    if kind == "shared_attn":
        return _shared_attn_apply(prm, shared["attn"], x, cfg, positions)
    raise ValueError(kind)


def _block_apply(kind: str, prm, x, cfg: ModelConfig, positions, shared, enc,
                 moe_groups: int):
    x = x + _mixer_apply(kind, prm, x, cfg, positions, shared, enc)
    if kind.endswith("_moe"):
        x = x + mlp_mod.moe_apply(prm["moe"], x, cfg, groups=moe_groups)
    elif kind in ("attn", "mla", "cross_attn"):
        x = x + mlp_mod.mlp_apply(prm["mlp"], x, cfg)
    elif kind == "shared_attn" and shared.get("mlp") is not None:
        x = x + mlp_mod.mlp_apply(shared["mlp"], x, cfg)
    # mamba1/mamba2 blocks are mixer-only (falcon-mamba has d_ff=0);
    # zamba2's shared block carries the model's single (shared) FFN.
    # The residual stream BETWEEN blocks is sequence-parallel (seq_sp ->
    # model, Megatron SP): it is what the scan checkpoints, so this
    # constraint sets the saved-activation footprint.
    return constrain(x, ("batch", "seq_sp", "embed_act"))


def _shared_attn_apply(lora, shared, x, cfg: ModelConfig, positions):
    """zamba2-style weight-shared attention with per-repetition LoRA on the
    q and o projections (simplification of zamba2's shared-block LoRA;
    documented in DESIGN.md)."""
    base = attn.gqa_apply(shared, x, cfg, positions)
    h = rms_norm(x, shared["norm"])
    q_delta = jnp.einsum("bsd,dr->bsr", h, lora["lora_q_a"])
    q_delta = jnp.einsum("bsr,rhk->bshk", q_delta, lora["lora_q_b"])
    o_delta = jnp.einsum("bshk,hkr->bsr", q_delta, lora["lora_o_a"])
    o_delta = jnp.einsum("bsr,rd->bsd", o_delta, lora["lora_o_b"])
    return base + o_delta


# ---------------------------------------------------------------------------
# Cache dispatch
# ---------------------------------------------------------------------------


def _block_init_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
                      dtype) -> PyTree:
    if kind in ("attn", "attn_moe", "shared_attn"):
        return attn.gqa_init_cache(cfg, batch, max_seq, dtype)
    if kind in ("mla", "mla_moe"):
        return attn.mla_init_cache(cfg, batch, max_seq, dtype)
    if kind == "cross_attn":
        K, hd = cfg.num_kv_heads, cfg.hd
        n = cfg.num_encoder_tokens
        return {"ek": jnp.zeros((batch, n, K, hd), dtype),
                "ev": jnp.zeros((batch, n, K, hd), dtype)}
    if kind == "mamba1":
        return ssm_mod.mamba1_init_cache(cfg, batch, dtype)
    if kind == "mamba2":
        return ssm_mod.mamba2_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _block_decode(kind: str, prm, x, cache, cfg: ModelConfig, pos, shared,
                  moe_groups: int):
    if kind in ("attn", "attn_moe"):
        out, cache = attn.gqa_decode(prm["attn"], x, cache, cfg, pos)
    elif kind in ("mla", "mla_moe"):
        out, cache = attn.mla_decode(prm["attn"], x, cache, cfg, pos)
    elif kind == "cross_attn":
        out, cache = _cross_decode(prm["attn"], x, cache, cfg)
    elif kind == "mamba1":
        out, cache = ssm_mod.mamba1_decode(prm["mamba"], x, cache, cfg, pos)
    elif kind == "mamba2":
        out, cache = ssm_mod.mamba2_decode(prm["mamba"], x, cache, cfg, pos)
    elif kind == "shared_attn":
        out, cache = _shared_attn_decode(prm, shared["attn"], x, cache, cfg,
                                         pos)
    else:
        raise ValueError(kind)
    x = x + out.astype(x.dtype)  # cache dtype must not promote the carry
    if kind.endswith("_moe"):
        x = x + mlp_mod.moe_apply(prm["moe"], x, cfg, groups=moe_groups)
    elif kind in ("attn", "mla", "cross_attn"):
        x = x + mlp_mod.mlp_apply(prm["mlp"], x, cfg)
    elif kind == "shared_attn" and shared.get("mlp") is not None:
        x = x + mlp_mod.mlp_apply(shared["mlp"], x, cfg)
    return constrain(x, ("batch", "seq", "embed_act")), cache


def _cross_decode(prm, x, cache, cfg: ModelConfig):
    """Decode-time cross attention against PRE-COMPUTED encoder K/V (filled
    at prefill; serve_step receives them as part of the cache)."""
    h = rms_norm(x, prm["norm"])
    q = jnp.einsum("bsd,dhk->bshk", h, prm["wq"])
    B, S, H, hd = q.shape
    K = cache["ek"].shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,bnkh->bkgsn", qg, cache["ek"])
    scores = (scores / jnp.sqrt(hd)).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgsn,bnkh->bskgh", w, cache["ev"]).reshape(B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, prm["wo"])
    out = jnp.tanh(prm["gate"].astype(jnp.float32)).astype(x.dtype) * out
    return constrain(out, ("batch", "seq", "embed_act")), cache


def _shared_attn_decode(lora, shared, x, cache, cfg: ModelConfig, pos):
    base, cache = attn.gqa_decode(shared, x, cache, cfg, pos)
    h = rms_norm(x, shared["norm"])
    q_delta = jnp.einsum("bsd,dr->bsr", h, lora["lora_q_a"])
    q_delta = jnp.einsum("bsr,rhk->bshk", q_delta, lora["lora_q_b"])
    o_delta = jnp.einsum("bshk,hkr->bsr", q_delta, lora["lora_o_a"])
    o_delta = jnp.einsum("bsr,rd->bsd", o_delta, lora["lora_o_b"])
    return base + o_delta, cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def init(key, cfg: ModelConfig) -> tuple[PyTree, PyTree]:
    """Returns (params, logical_axes) trees."""
    keys = jax.random.split(key, 8)
    pairs: dict[str, Any] = {
        "embed": p(keys[0], (cfg.vocab_size, cfg.d_model),
                   ("vocab", "embed"), cfg.dtype, scale=1.0),
        "final_norm": pz((cfg.d_model,), ("embed",), jnp.float32),
    }
    if not cfg.tie_embeddings:
        pairs["lm_head"] = p(keys[1], (cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), cfg.dtype)
    if cfg.prologue:
        pk = jax.random.split(keys[2], len(cfg.prologue))
        pairs["prologue"] = [
            _block_init(kind, pk[i], cfg)
            for i, kind in enumerate(cfg.prologue)]
    if "shared_attn" in cfg.superblock:
        pairs["shared_attn"] = attn.gqa_init(keys[3], cfg)
        if cfg.d_ff > 0:
            pairs["shared_mlp"] = mlp_mod.mlp_init(keys[6], cfg)
    params, axes = split_axes(pairs)

    stack_params: dict[str, Any] = {}
    stack_axes: dict[str, Any] = {}
    for i, kind in enumerate(cfg.superblock):
        _, slot_axes = split_axes(_block_init(kind, keys[5], cfg))

        def one(j, kind=kind, i=i):
            arrays, _ = split_axes(_block_init(
                kind, jax.random.fold_in(keys[4], i * 1000 + j), cfg))
            return arrays

        stack_params[f"slot{i}"] = jax.vmap(one)(jnp.arange(cfg.n_super))
        stack_axes[f"slot{i}"] = jax.tree.map(
            lambda a: ("layers",) + a, slot_axes, is_leaf=_is_axes)
    params["stack"] = stack_params
    axes["stack"] = stack_axes
    return params, axes


def _embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x.astype(cfg.dtype), ("batch", "seq", "embed_act"))


def _unembed(params, x, cfg: ModelConfig):
    x = constrain(x, ("batch", "seq", "embed_act"))  # single seq gather
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return constrain(logits, ("batch", "seq", "vocab"))


def forward(params, tokens, cfg: ModelConfig, enc: jax.Array | None = None,
            moe_groups: int = 1) -> jax.Array:
    """Training/prefill forward -> logits (B,S,V). `enc`: (B,N,E) stubbed
    encoder states for VLM cross-attention (precomputed patch embeddings)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = _embed(params, tokens, cfg)

    shared = {"attn": params.get("shared_attn"),
              "mlp": params.get("shared_mlp")}
    for i, kind in enumerate(cfg.prologue):
        x = _block_apply(kind, params["prologue"][i], x, cfg, positions,
                         shared, enc, moe_groups)

    def superblock(x, slot_params):
        # The barrier pins the saved scan carry to bf16: without it XLA
        # hoists the rms_norm upcast through the carry history buffer and
        # stores the full (L, B, S, D) residual stack in f32 (2x memory).
        x = barrier(x)
        for i, kind in enumerate(cfg.superblock):
            x = _block_apply(kind, slot_params[f"slot{i}"], x, cfg, positions,
                             shared, enc, moe_groups)
        return x, None

    body = superblock
    if cfg.remat:
        body = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["stack"])
    return _unembed(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Decode cache pytree; stacked over superblock repetitions per slot."""
    cache: dict[str, Any] = {}
    if cfg.prologue:
        cache["prologue"] = [
            _block_init_cache(kind, cfg, batch, max_seq, dtype)
            for kind in cfg.prologue]

    def one_slot(kind):
        c = _block_init_cache(kind, cfg, batch, max_seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_super,) + a.shape), c)

    cache["stack"] = {f"slot{i}": one_slot(kind)
                      for i, kind in enumerate(cfg.superblock)}
    return cache


def cache_axes(cfg: ModelConfig) -> PyTree:
    """Logical axes for the cache (for sharding specs)."""
    def axes_for(kind, stacked: bool):
        lead = ("layers",) if stacked else ()
        if kind in ("attn", "attn_moe", "shared_attn"):
            a = ("batch", "cache_seq", "kv_heads", "head")
            return {"k": lead + a, "v": lead + a}
        if kind in ("mla", "mla_moe"):
            return {"ckv": lead + ("batch", "cache_seq", "kv_lora"),
                    "krope": lead + ("batch", "cache_seq", "head")}
        if kind == "cross_attn":
            a = ("batch", "enc_tokens", "kv_heads", "head")
            return {"ek": lead + a, "ev": lead + a}
        if kind == "mamba1":
            return {"conv": lead + ("batch", "conv", "ssm_inner"),
                    "h": lead + ("batch", "ssm_inner", "state")}
        if kind == "mamba2":
            return {"conv": lead + ("batch", "conv", "ssm_inner"),
                    "h": lead + ("batch", "ssm_heads", "head", "state")}
        raise ValueError(kind)

    axes: dict[str, Any] = {}
    if cfg.prologue:
        axes["prologue"] = [axes_for(k, False) for k in cfg.prologue]
    axes["stack"] = {f"slot{i}": axes_for(kind, True)
                     for i, kind in enumerate(cfg.superblock)}
    return axes


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                moe_groups: int = 1) -> tuple[jax.Array, PyTree]:
    """One-token decode. tokens: (B,1) int32; pos: scalar int32 (current
    write position; all sequences share it -- continuous batching slots are
    handled by the serving layer). Returns (logits (B,1,V), new cache)."""
    x = _embed(params, tokens, cfg)
    shared = {"attn": params.get("shared_attn"),
              "mlp": params.get("shared_mlp")}

    new_cache: dict[str, Any] = {}
    if cfg.prologue:
        new_cache["prologue"] = []
        for i, kind in enumerate(cfg.prologue):
            x, c = _block_decode(kind, params["prologue"][i], x,
                                 cache["prologue"][i], cfg, pos, shared, moe_groups)
            new_cache["prologue"].append(c)

    def superblock(x, slot_in):
        slot_params, slot_cache = slot_in
        new_c = {}
        for i, kind in enumerate(cfg.superblock):
            x, c = _block_decode(kind, slot_params[f"slot{i}"], x,
                                 slot_cache[f"slot{i}"], cfg, pos, shared,
                                 moe_groups)
            new_c[f"slot{i}"] = c
        return x, new_c

    x, stack_cache = jax.lax.scan(superblock, x,
                                  (params["stack"], cache["stack"]))
    new_cache["stack"] = stack_cache
    logits = _unembed(params, x, cfg)
    return logits, new_cache


def loss_fn(params, batch, cfg: ModelConfig, moe_groups: int = 1) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg, enc=batch.get("enc"),
                     moe_groups=moe_groups)
    return cross_entropy_loss(logits, batch["labels"])
