"""Architecture registry: --arch <id> resolution.

Full production configs live in `repro/configs/<id>.py` (one file per
assigned architecture, exact published hyperparameters). Each config module
exposes `FULL` (the published config), `SMOKE` (a reduced same-family config
for CPU tests) and `SHAPES` (the input-shape set assigned to the arch).
"""

from __future__ import annotations

import importlib
from typing import Any

ARCH_IDS = (
    "nemotron-4-15b",
    "llama3-8b",
    "codeqwen1.5-7b",
    "qwen1.5-110b",
    "musicgen-medium",
    "deepseek-v2-236b",
    "llama4-maverick-400b-a17b",
    "zamba2-2.7b",
    "falcon-mamba-7b",
    "llama-3.2-vision-90b",
)


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str, variant: str = "full"):
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = _module(arch_id)
    return mod.FULL if variant == "full" else mod.SMOKE


def get_shapes(arch_id: str) -> dict[str, Any]:
    return dict(_module(arch_id).SHAPES)


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS
