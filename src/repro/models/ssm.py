"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

Training/prefill paths are CHUNKED along the sequence: an outer `lax.scan`
carries the recurrent state across chunks (rematerialized inside), so peak
memory is O(chunk) not O(seq) -- the property that makes the `long_500k`
shape feasible for the SSM/hybrid families.

Mamba-1 runs a sequential inner scan (token recurrence); Mamba-2 uses the
SSD matmul formulation (intra-chunk attention-like matmuls + inter-chunk
state decay), which is the MXU-friendly form. Pallas kernels in
`repro/kernels/` implement the same chunk computations with explicit VMEM
tiling; `ref.py` oracles there mirror these functions.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, p, pz, rms_norm
from repro.runtime.sharding import constrain

PyTree = Any

_CHUNK = 256


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C); w: (C,K); b: (C,)."""
    K = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for k in range(K):
        out = out + pad[:, k:k + S, :] * w[:, k]
    return out + b


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
               b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token causal conv. x_t: (B,C); conv_state: (B,K-1,C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,ck->bc", window, w) + b
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def _m1_dims(cfg: ModelConfig) -> tuple[int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, -(-cfg.d_model // 16))
    return d_inner, dt_rank


def mamba1_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 8)
    D, N = cfg.d_model, cfg.ssm_state
    d_inner, dt_rank = _m1_dims(cfg)
    # S4D-real A init: A[:, n] = -(n+1)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "norm": pz((D,), ("embed",), jnp.float32),
        "in_proj": p(ks[0], (D, 2 * d_inner), ("embed", "ssm_inner"),
                     cfg.dtype),
        "conv_w": p(ks[1], (d_inner, cfg.ssm_conv), ("ssm_inner", "conv"),
                    cfg.dtype, scale=0.5),
        "conv_b": pz((d_inner,), ("ssm_inner",), cfg.dtype),
        "x_proj": p(ks[2], (d_inner, dt_rank + 2 * N), ("ssm_inner", None),
                    cfg.dtype),
        "dt_w": p(ks[3], (dt_rank, d_inner), (None, "ssm_inner"), cfg.dtype),
        "dt_b": pz((d_inner,), ("ssm_inner",), jnp.float32, fill=-4.6),
        "A_log": (jnp.log(A), ("ssm_inner", "state")),
        "D_skip": pz((d_inner,), ("ssm_inner",), jnp.float32, fill=1.0),
        "out_proj": p(ks[4], (d_inner, D), ("ssm_inner", "embed"), cfg.dtype),
    }


def _m1_scan_chunk(h0, dA, dBx, C):
    """Sequential inner scan over one chunk.
    h0: (B,di,N); dA,dBx: (B,Q,di,N); C: (B,Q,N). Returns (hQ, y (B,Q,di))."""
    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y
    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
          jnp.moveaxis(C, 1, 0))
    hQ, ys = jax.lax.scan(step, h0, xs)
    return hQ, jnp.moveaxis(ys, 0, 1)


def mamba1_mix(prm, xz: jax.Array, cfg: ModelConfig,
               chunk: int = _CHUNK) -> jax.Array:
    """Core selective-scan mixer. xz: (B,S,2*d_inner) post-in_proj."""
    d_inner, dt_rank = _m1_dims(cfg)
    N = cfg.ssm_state
    x, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_causal_conv(x, prm["conv_w"], prm["conv_b"]))
    x = constrain(x, ("batch", "seq", "ssm_inner"))

    proj = jnp.einsum("bsd,dk->bsk", x, prm["x_proj"])
    dt_r, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, prm["dt_w"]).astype(jnp.float32)
        + prm["dt_b"])                                        # (B,S,di)
    A = -jnp.exp(prm["A_log"])                                # (di,N)

    B, S, _ = x.shape
    Q = min(chunk, S)
    n_chunks = S // Q if S % Q == 0 else 1
    if S % Q != 0:
        Q = S

    def chunk_body(h, inp):
        x_c, dt_c, B_c, C_c = inp                              # (B,Q,...)
        dA = jnp.exp(dt_c[..., None] * A)                      # (B,Q,di,N)
        dBx = (dt_c * x_c.astype(jnp.float32))[..., None] * B_c[:, :, None, :]
        h, y = _m1_scan_chunk(h, dA, dBx, C_c.astype(jnp.float32))
        return h, y

    if cfg.remat:
        chunk_body = jax.checkpoint(chunk_body)

    h0 = jnp.zeros((B, d_inner, N), jnp.float32)
    resh = lambda a: jnp.moveaxis(
        a.reshape(B, n_chunks, Q, *a.shape[2:]), 1, 0)
    _, ys = jax.lax.scan(
        chunk_body, h0,
        (resh(x), resh(dt), resh(B_.astype(jnp.float32)), resh(C_)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_inner)          # (B,S,di)
    y = y + x.astype(jnp.float32) * prm["D_skip"]
    y = y.astype(xz.dtype) * jax.nn.silu(z)
    return y


def mamba1_apply(prm, x, cfg: ModelConfig, positions=None) -> jax.Array:
    h = rms_norm(x, prm["norm"])
    xz = jnp.einsum("bsd,de->bse", h, prm["in_proj"])
    xz = constrain(xz, ("batch", "seq", "ssm_inner"))
    y = mamba1_mix(prm, xz, cfg)
    out = jnp.einsum("bse,ed->bsd", y, prm["out_proj"])
    return constrain(out, ("batch", "seq", "embed_act"))


def mamba1_init_cache(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    d_inner, _ = _m1_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba1_decode(prm, x, cache, cfg: ModelConfig, pos=None):
    """One-token recurrent update. x: (B,1,D)."""
    d_inner, dt_rank = _m1_dims(cfg)
    N = cfg.ssm_state
    h_in = rms_norm(x[:, 0, :], prm["norm"])
    xz = jnp.einsum("bd,de->be", h_in, prm["in_proj"])
    x_t, z = jnp.split(xz, 2, axis=-1)
    x_t, conv_state = _conv_step(x_t, cache["conv"], prm["conv_w"],
                                 prm["conv_b"])
    x_t = jax.nn.silu(x_t)
    proj = jnp.einsum("bd,dk->bk", x_t, prm["x_proj"])
    dt_r, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,rd->bd", dt_r, prm["dt_w"]).astype(jnp.float32)
        + prm["dt_b"])
    A = -jnp.exp(prm["A_log"])
    dA = jnp.exp(dt[..., None] * A)                            # (B,di,N)
    dBx = (dt * x_t.astype(jnp.float32))[..., None] * B_[:, None, :].astype(jnp.float32)
    h_new = dA * cache["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h_new, C_.astype(jnp.float32))
    y = y + x_t.astype(jnp.float32) * prm["D_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, prm["out_proj"])[:, None, :]
    return constrain(out, ("batch", "seq", "embed_act")), {
        "conv": conv_state, "h": h_new}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def _m2_dims(cfg: ModelConfig) -> tuple[int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def mamba2_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    D, N = cfg.d_model, cfg.ssm_state
    d_inner, nheads = _m2_dims(cfg)
    conv_dim = d_inner + 2 * N  # x plus (B,C), single group
    d_proj = 2 * d_inner + 2 * N + nheads
    A = jnp.linspace(1.0, 16.0, nheads)
    return {
        "norm": pz((D,), ("embed",), jnp.float32),
        "in_proj": p(ks[0], (D, d_proj), ("embed", "ssm_inner"), cfg.dtype),
        "conv_w": p(ks[1], (conv_dim, cfg.ssm_conv), ("ssm_inner", "conv"),
                    cfg.dtype, scale=0.5),
        "conv_b": pz((conv_dim,), ("ssm_inner",), cfg.dtype),
        "A_log": (jnp.log(A), ("ssm_heads",)),
        "dt_bias": pz((nheads,), ("ssm_heads",), jnp.float32, fill=-4.6),
        "D_skip": pz((nheads,), ("ssm_heads",), jnp.float32, fill=1.0),
        "gate_norm": pz((d_inner,), ("ssm_inner",), jnp.float32),
        "out_proj": p(ks[2], (d_inner, D), ("ssm_inner", "embed"), cfg.dtype),
    }


def _ssd_chunk(h0, x_c, dt_c, B_c, C_c, A):
    """SSD matmul form for one chunk.
    h0: (B,H,P,N); x_c: (B,Q,H,P); dt_c: (B,Q,H); B_c,C_c: (B,Q,N);
    A: (H,) negative reals. Returns (hQ, y_c (B,Q,H,P))."""
    dA = dt_c * A                                    # (B,Q,H)  log-decay
    cum = jnp.cumsum(dA, axis=1)                     # (B,Q,H)
    # intra-chunk: L[s,t] = exp(cum_s - cum_t) for s >= t
    rel = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Q,Q,H)
    Q = x_c.shape[1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bsn,btn->bst", C_c, B_c)    # (B,Q,Q)
    W = scores[..., None] * L                        # (B,Q,Q,H)
    xdt = x_c * dt_c[..., None]                      # (B,Q,H,P)
    y_intra = jnp.einsum("bsth,bthp->bshp", W, xdt)
    # inter-chunk: contribution of h0 decayed to each position
    decay0 = jnp.exp(cum)                            # (B,Q,H)
    y_inter = jnp.einsum("bsn,bhpn,bsh->bshp", C_c, h0, decay0)
    # state update: hQ = exp(sum dA) h0 + sum_t exp(cum_Q - cum_t) dB_t x_t
    total = cum[:, -1, :]                            # (B,H)
    decay_t = jnp.exp(total[:, None, :] - cum)       # (B,Q,H)
    hQ = (jnp.exp(total)[..., None, None] * h0
          + jnp.einsum("bth,bthp,btn->bhpn", decay_t, xdt, B_c))
    return hQ, y_intra + y_inter


def mamba2_mix(prm, zxbcdt: jax.Array, cfg: ModelConfig,
               chunk: int = _CHUNK) -> jax.Array:
    """Core SSD mixer. zxbcdt: (B,S,2*di+2*N+H) post-in_proj."""
    d_inner, nheads = _m2_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N],
                               axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, prm["conv_w"], prm["conv_b"]))
    x, B_, C_ = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + prm["dt_bias"])
    A = -jnp.exp(prm["A_log"])                       # (H,)

    B, S, _ = zxbcdt.shape
    Q = min(chunk, S)
    n_chunks = S // Q if S % Q == 0 else 1
    if S % Q != 0:
        Q = S
    x = x.reshape(B, S, nheads, P)

    def chunk_body(h, inp):
        x_c, dt_c, B_c, C_c = inp
        h, y = _ssd_chunk(h, x_c.astype(jnp.float32), dt_c,
                          B_c.astype(jnp.float32), C_c.astype(jnp.float32), A)
        return h, y

    if cfg.remat:
        chunk_body = jax.checkpoint(chunk_body)

    h0 = jnp.zeros((B, nheads, P, N), jnp.float32)
    resh = lambda a: jnp.moveaxis(
        a.reshape(B, n_chunks, Q, *a.shape[2:]), 1, 0)
    _, ys = jax.lax.scan(chunk_body, h0, (resh(x), resh(dt), resh(B_),
                                          resh(C_)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nheads, P)
    y = y + x.astype(jnp.float32) * prm["D_skip"][:, None]
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y.astype(zxbcdt.dtype) * jax.nn.silu(z), prm["gate_norm"])
    return y


def mamba2_apply(prm, x, cfg: ModelConfig, positions=None) -> jax.Array:
    h = rms_norm(x, prm["norm"])
    zxbcdt = jnp.einsum("bsd,de->bse", h, prm["in_proj"])
    zxbcdt = constrain(zxbcdt, ("batch", "seq", "ssm_inner"))
    y = mamba2_mix(prm, zxbcdt, cfg)
    out = jnp.einsum("bse,ed->bsd", y, prm["out_proj"])
    return constrain(out, ("batch", "seq", "embed_act"))


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    d_inner, nheads = _m2_dims(cfg)
    conv_dim = d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state),
                       jnp.float32),
    }


def mamba2_decode(prm, x, cache, cfg: ModelConfig, pos=None):
    d_inner, nheads = _m2_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    h_in = rms_norm(x[:, 0, :], prm["norm"])
    zxbcdt = jnp.einsum("bd,de->be", h_in, prm["in_proj"])
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N],
                               axis=-1)
    xBC, conv_state = _conv_step(xBC, cache["conv"], prm["conv_w"],
                                 prm["conv_b"])
    xBC = jax.nn.silu(xBC)
    x_t, B_, C_ = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + prm["dt_bias"])  # (B,H)
    A = -jnp.exp(prm["A_log"])
    dA = jnp.exp(dt * A)                                       # (B,H)
    x_t = x_t.reshape(-1, nheads, P).astype(jnp.float32)
    dBx = jnp.einsum("bhp,bn->bhpn", x_t * dt[..., None],
                     B_.astype(jnp.float32))
    h_new = dA[..., None, None] * cache["h"] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_.astype(jnp.float32))
    y = y + x_t * prm["D_skip"][:, None]
    y = y.reshape(-1, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), prm["gate_norm"])
    out = jnp.einsum("be,ed->bd", y, prm["out_proj"])[:, None, :]
    return constrain(out, ("batch", "seq", "embed_act")), {
        "conv": conv_state, "h": h_new}
