from repro.models.common import ModelConfig, cross_entropy_loss
from repro.models.registry import ARCH_IDS, get_config, get_shapes, list_archs
from repro.models import transformer
