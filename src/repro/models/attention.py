"""Attention blocks: GQA self-attention, MLA (DeepSeek latent attention),
cross-attention (VLM), each with a prefill path and a KV-cache decode path.

All shapes follow (batch, seq, heads, head_dim). GQA repeats are expressed by
grouping q heads as (kv_heads, group) so the einsums contract natively
without materializing repeated K/V.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, apply_rope, barrier, p, pz,
                                 rms_norm)
from repro.runtime.sharding import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 5)
    H, K, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_model
    prm = {
        "wq": p(ks[0], (D, H, hd), ("embed", "q_heads", "head"), cfg.dtype),
        "wk": p(ks[1], (D, K, hd), ("embed", "kv_heads", "head"), cfg.dtype),
        "wv": p(ks[2], (D, K, hd), ("embed", "kv_heads", "head"), cfg.dtype),
        "wo": p(ks[3], (H, hd, D), ("q_heads", "head", "embed"), cfg.dtype),
        "norm": pz((D,), ("embed",), jnp.float32),
    }
    if cfg.qkv_bias:
        prm["bq"] = pz((H, hd), ("q_heads", "head"), cfg.dtype)
        prm["bk"] = pz((K, hd), ("kv_heads", "head"), cfg.dtype)
        prm["bv"] = pz((K, hd), ("kv_heads", "head"), cfg.dtype)
    return prm


def _qkv(prm, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, prm["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, prm["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, prm["wv"])
    if cfg.qkv_bias:
        q, k, v = q + prm["bq"], k + prm["bk"], v + prm["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # q and the attention output stay sequence-parallel; ONLY k/v are
    # gathered across the model axis (kv heads are small - this replaces
    # re-gathering the full residual, a ~8x collective-byte cut measured in
    # EXPERIMENTS.md section Perf). The double constraint pins k/v to be
    # COMPUTED sequence-sharded and THEN gathered (bf16, small), preventing
    # GSPMD from hoisting the gather up to the fp32 residual.
    q = constrain(q, ("batch", "seq_sp", "q_heads", "head"))
    k = constrain(k, ("batch", "seq_sp", "kv_heads", "head"))
    v = constrain(v, ("batch", "seq_sp", "kv_heads", "head"))
    k = barrier(k)
    v = barrier(v)
    k = constrain(k, ("batch", None, "kv_heads", "head"))
    v = constrain(v, ("batch", None, "kv_heads", "head"))
    return q, k, v


_CHUNK_THRESHOLD = 1024
_Q_CHUNK = 256
_KV_CHUNK = 1024


def _sdpa_causal_streamed(q, k, v):
    """Causal attention with the online-softmax (flash) recurrence over KV
    chunks, in plain XLA. q: (B,S,K,G-grouped H,hd); masks use GLOBAL row
    indices so the math is shard-layout independent."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    v_hd = v.shape[-1]
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    nc = T // _KV_CHUNK
    ks = jnp.moveaxis(k.reshape(B, nc, _KV_CHUNK, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, _KV_CHUNK, K, v_hd), 1, 0)
    rows = jnp.arange(S) + (T - S)                        # global positions

    def chunk_fn(carry, inp):
        m, l, acc = carry                  # (B,S,K,G,1) x2, (B,S,K,G,v_hd)
        k_c, v_c, ci = inp
        s = jnp.einsum("bskgh,btkh->bskgt", qg, k_c).astype(jnp.float32)
        s = s * scale
        cols = ci * _KV_CHUNK + jnp.arange(_KV_CHUNK)
        mask = rows[:, None] >= cols[None, :]             # (S, chunk)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bskgt,btkh->bskgh", p.astype(q.dtype), v_c)
        acc = acc * corr + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, K, G, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, K, G, 1), jnp.float32)
    acc0 = jnp.zeros((B, S, K, G, v_hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(chunk_fn), (m0, l0, acc0),
                                  (ks, vs, jnp.arange(nc)))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.reshape(B, S, H, v_hd)


def _sdpa_causal(q, k, v, cfg: ModelConfig):
    """Grouped causal attention. q: (B,S,H,hd); k,v: (B,T,K,hd).

    For long sequences the q dimension is processed in chunks under a
    rematerialized scan, so the (S x T) score matrix never materializes --
    the XLA-level analogue of flash attention (the Pallas kernel in
    repro/kernels is the TPU-tiled version; this path keeps cost_analysis
    exact for the dry-run and is the oracle in kernel tests)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    from repro.runtime.sharding import rules_active
    if rules_active() and T > _KV_CHUNK and T % _KV_CHUNK == 0:
        # production path: q rows stay sequence-parallel; stream the softmax
        # over KV chunks (flash recurrence in XLA) so the (S_loc x T) score
        # tensor never materializes. KV-chunking composes with seq_sp
        # sharding (q-chunking would slice the sharded dim).
        return _sdpa_causal_streamed(q, k, v)
    if S <= _CHUNK_THRESHOLD or S % _Q_CHUNK != 0 or rules_active():
        qg = q.reshape(B, S, K, G, hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(hd).astype(jnp.float32)
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask, scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(
            B, S, H, v.shape[-1])
        return out

    nc = S // _Q_CHUNK
    qs = jnp.moveaxis(
        q.reshape(B, nc, _Q_CHUNK, K, G, hd), 1, 0)       # (nc,B,c,K,G,hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    cols = jnp.arange(T)

    def chunk_fn(_, inp):
        qc, ci = inp                                      # (B,c,K,G,hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", qc,
                            k).astype(jnp.float32) * scale
        rows = ci * _Q_CHUNK + jnp.arange(_Q_CHUNK) + (T - S)
        mask = rows[:, None] >= cols[None, :]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", w, v)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(chunk_fn), None,
                           (qs, jnp.arange(nc)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, v.shape[-1])
    return out


def gqa_apply(prm, x, cfg: ModelConfig, positions) -> jax.Array:
    """Prefill/training forward (causal)."""
    h = rms_norm(x, prm["norm"])
    q, k, v = _qkv(prm, h, cfg, positions)
    out = _sdpa_causal(q, k, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, prm["wo"])
    return constrain(out, ("batch", "seq_sp", "embed_act"))


def gqa_init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> PyTree:
    K, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_seq, K, hd), dtype),
        "v": jnp.zeros((batch, max_seq, K, hd), dtype),
    }


def gqa_decode(prm, x, cache, cfg: ModelConfig, pos) -> tuple[jax.Array, PyTree]:
    """One-token decode. x: (B,1,D); pos: scalar current position; the cache
    is pre-allocated to max_seq and sequence-sharded for long contexts."""
    h = rms_norm(x, prm["norm"])
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = _qkv(prm, h, cfg, positions)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    ck = constrain(ck, ("batch", "cache_seq", "kv_heads", "head"))
    cv = constrain(cv, ("batch", "cache_seq", "kv_heads", "head"))
    B, _, H, hd = q.shape
    K = ck.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    # preferred_element_type runs the contraction bf16 x bf16 -> f32 WITHOUT
    # converting the cache operand (an .astype(f32) after the einsum made
    # XLA materialize an f32 copy of the whole layer-stacked cache: +8 GiB).
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = constrain(scores, ("batch", "kv_heads", None, "cache_seq"))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    T = ck.shape[1]
    valid = jnp.arange(T) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    w = constrain(w, ("batch", "kv_heads", None, "cache_seq"))
    out = jnp.einsum("bkgt,btkh->bkgh", w, cv).reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, prm["wo"])
    out = constrain(out, ("batch", "seq", "embed_act"))
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA -- multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 8)
    D, H = cfg.d_model, cfg.num_heads
    qk_nope, rope_hd = cfg.hd, cfg.mla_rope_head_dim
    v_hd = cfg.mla_v_head_dim or cfg.hd
    kvl, ql = cfg.mla_kv_lora, cfg.mla_q_lora
    return {
        "wq_a": p(ks[0], (D, ql), ("embed", "q_lora"), cfg.dtype),
        "q_norm": pz((ql,), ("q_lora",), jnp.float32),
        "wq_b": p(ks[1], (ql, H, qk_nope + rope_hd),
                  ("q_lora", "q_heads", "head"), cfg.dtype),
        "wkv_a": p(ks[2], (D, kvl + rope_hd), ("embed", "kv_lora"), cfg.dtype),
        "kv_norm": pz((kvl,), ("kv_lora",), jnp.float32),
        "wk_b": p(ks[3], (kvl, H, qk_nope), ("kv_lora", "q_heads", "head"),
                  cfg.dtype),
        "wv_b": p(ks[4], (kvl, H, v_hd), ("kv_lora", "q_heads", "head"),
                  cfg.dtype),
        "wo": p(ks[5], (H, v_hd, D), ("q_heads", "head", "embed"), cfg.dtype),
        "norm": pz((D,), ("embed",), jnp.float32),
    }


def _mla_q(prm, h, cfg: ModelConfig, positions):
    qk_nope, rope_hd = cfg.hd, cfg.mla_rope_head_dim
    ql = jnp.einsum("bsd,dq->bsq", h, prm["wq_a"])
    ql = rms_norm(ql, prm["q_norm"])
    q = jnp.einsum("bsq,qhk->bshk", ql, prm["wq_b"])
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(prm, h, cfg: ModelConfig, positions):
    kvl = cfg.mla_kv_lora
    kv = jnp.einsum("bsd,dq->bsq", h, prm["wkv_a"])
    c_kv, k_rope = kv[..., :kvl], kv[..., kvl:]
    c_kv = rms_norm(c_kv, prm["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(prm, x, cfg: ModelConfig, positions) -> jax.Array:
    """Prefill: expand the latent per head, then run the shared (chunked)
    causal attention with the rope dims concatenated onto q/k. The softmax
    scale uses the combined qk dim (nope+rope), matching DeepSeek-V2."""
    h = rms_norm(x, prm["norm"])
    q_nope, q_rope = _mla_q(prm, h, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(prm, h, cfg, positions)
    k_nope = jnp.einsum("bsq,qhk->bshk", c_kv, prm["wk_b"])
    v = jnp.einsum("bsq,qhk->bshk", c_kv, prm["wv_b"])
    B, S, H, _ = q_nope.shape
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, cfg.mla_rope_head_dim))], axis=-1)
    q_full = constrain(q_full, ("batch", "seq_sp", "q_heads", "head"))
    k_full = constrain(k_full, ("batch", "seq_sp", "q_heads", "head"))
    v = constrain(v, ("batch", "seq_sp", "q_heads", "head"))
    k_full = barrier(k_full)
    v = barrier(v)
    k_full = constrain(k_full, ("batch", None, "q_heads", "head"))
    v = constrain(v, ("batch", None, "q_heads", "head"))
    out = _sdpa_causal(q_full, k_full, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, prm["wo"])
    return constrain(out, ("batch", "seq_sp", "embed_act"))


def mla_init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> PyTree:
    """MLA caches ONLY the compressed latent + shared rope key:
    (kv_lora + rope_hd) per token -- 576 dims for DeepSeek-V2 vs
    2*128*128=32768 for an equivalent dense MHA cache (57x smaller)."""
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.mla_kv_lora), dtype),
        "krope": jnp.zeros((batch, max_seq, cfg.mla_rope_head_dim), dtype),
    }


def mla_decode(prm, x, cache, cfg: ModelConfig, pos) -> tuple[jax.Array, PyTree]:
    """Absorbed decode: attention runs in the 512-dim latent space.
    q_absorbed = q_nope @ wk_b  (per head), scores = q_abs . c_kv -- the
    per-head K/V are never materialized (the MLA serving optimization)."""
    h = rms_norm(x, prm["norm"])
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(prm, h, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(prm, h, cfg, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope.astype(cache["krope"].dtype), pos, axis=1)
    ckv = constrain(ckv, ("batch", "cache_seq", "kv_lora"))
    krope = constrain(krope, ("batch", "cache_seq", "head"))
    # absorb W_uk:  (B,1,H,nope) x (kvl,H,nope) -> (B,H,kvl)
    q_abs = jnp.einsum("bshk,qhk->bhq", q_nope, prm["wk_b"])
    scale = 1.0 / jnp.sqrt(cfg.hd + cfg.mla_rope_head_dim).astype(jnp.float32)
    scores = (jnp.einsum("bhq,btq->bht", q_abs, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bht", q_rope, krope,
                           preferred_element_type=jnp.float32))
    scores = scores * scale
    T = ckv.shape[1]
    valid = jnp.arange(T) <= pos
    scores = jnp.where(valid[None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bht,btq->bhq", w, ckv)           # latent context
    out = jnp.einsum("bhq,qhk->bhk", ctx, prm["wv_b"])  # expand V per head
    out = jnp.einsum("bhk,hkd->bd", out, prm["wo"])[:, None, :]
    out = constrain(out, ("batch", "seq", "embed_act"))
    return out, {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# Cross-attention (VLM decoder layers attending to stubbed vision tokens)
# ---------------------------------------------------------------------------


def cross_attn_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 5)
    H, K, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_model
    E = cfg.encoder_dim or D
    return {
        "wq": p(ks[0], (D, H, hd), ("embed", "q_heads", "head"), cfg.dtype),
        "wk": p(ks[1], (E, K, hd), ("enc_embed", "kv_heads", "head"), cfg.dtype),
        "wv": p(ks[2], (E, K, hd), ("enc_embed", "kv_heads", "head"), cfg.dtype),
        "wo": p(ks[3], (H, hd, D), ("q_heads", "head", "embed"), cfg.dtype),
        "norm": pz((D,), ("embed",), jnp.float32),
        "gate": pz((), (), jnp.float32),   # tanh-gated residual (llama3.2-V)
    }


_ENC_CHUNK = 1600


def cross_attn_apply(prm, x, enc, cfg: ModelConfig) -> jax.Array:
    """x: (B,S,D) decoder states; enc: (B,N,E) encoder tokens (no mask).

    q (and the output) stay sequence-parallel; the softmax over the N
    encoder tokens is STREAMED in chunks with a running (max, denom) -- the
    flash-attention recurrence in plain XLA -- so the (S x N) score tensor
    never materializes (it was a 100 GiB/device fp32 monster at the
    vision-90b train_4k cell; see EXPERIMENTS.md section Perf, iteration 3).
    """
    h = rms_norm(x, prm["norm"])
    # enc stays sharded over its token dim (model axis); k/v are projected
    # LOCALLY per enc shard and only the small k/v get gathered.
    enc = constrain(enc, ("batch", "enc_tokens", "enc_embed"))
    q = jnp.einsum("bsd,dhk->bshk", h, prm["wq"])
    q = constrain(q, ("batch", "seq_sp", "q_heads", "head"))
    k = jnp.einsum("bne,ehk->bnhk", enc, prm["wk"])
    v = jnp.einsum("bne,ehk->bnhk", enc, prm["wv"])
    k = constrain(k, ("batch", "enc_tokens", "kv_heads", "head"))
    v = constrain(v, ("batch", "enc_tokens", "kv_heads", "head"))
    k = barrier(k)
    v = barrier(v)
    k = constrain(k, ("batch", None, "kv_heads", "head"))
    v = constrain(v, ("batch", None, "kv_heads", "head"))
    B, S, H, hd = q.shape
    N, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    chunk = _ENC_CHUNK if (N % _ENC_CHUNK == 0 and N > _ENC_CHUNK) else N
    nc = N // chunk
    ks = jnp.moveaxis(k.reshape(B, nc, chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, chunk, K, hd), 1, 0)

    def chunk_fn(carry, inp):
        m, l, acc = carry                   # (B,S,K,G,1) x2, (B,S,K,G,hd)
        k_c, v_c = inp                      # (B,chunk,K,hd)
        s = jnp.einsum("bskgh,bnkh->bskgn", qg, k_c).astype(jnp.float32)
        s = s * scale
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bskgn,bnkh->bskgh", p.astype(x.dtype), v_c)
        acc = acc * corr + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, K, G, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, K, G, 1), jnp.float32)
    acc0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(chunk_fn), (m0, l0, acc0),
                                  (ks, vs))
    out = (acc / jnp.maximum(l, 1e-30)).astype(x.dtype)
    out = out.reshape(B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, prm["wo"])
    gate = prm["gate"]
    out = jnp.tanh(gate.astype(jnp.float32)).astype(x.dtype) * out
    return constrain(out, ("batch", "seq_sp", "embed_act"))
