"""`repro.compress` -- compressed gossip as a first-class tradeoff axis.

The paper's whole analysis hangs on r = (communication time)/(computation
time), and until now the repo could only move r by communicating less
OFTEN (the schedule axis). Compression is the orthogonal axis: it makes
each MESSAGE cheap, multiplying the effective per-round cost by the
compressor's wire ratio c and shifting every optimum the schedule axis is
tuned against (n_opt = 1/sqrt(rc), h_opt ~ sqrt(nkrc); pass `c=` to
`core.tradeoff`).

`build_compressor(kind, params)` is the registry front door -- the same
(kind, params) contract `ExperimentSpec.compression` carries, so a frozen
spec rebuilds the exact wire format on any backend:

    kind "none"   -- identity (ratio 1)
    kind "topk"   -- largest-|x| sparsification, value+index pairs
    kind "randk"  -- random sparsification with shared (seed, round)
                     randomness, values only
    kind "int8"   -- absmax int8 quantization, optional stochastic
                     rounding; codes + one scale

See `base.py` for the three halves every compressor implements (jax
stack, numpy per-message, byte model) and the error-feedback contract.
"""

from __future__ import annotations

from typing import Any

from repro.compress.base import (INDEX_BYTES, VALUE_BYTES, Compressor, Int8,
                                 NoCompression, RandK, TopK, keep_count,
                                 topk_indices_flat, topk_mask_jax,
                                 topk_mask_np)
from repro.experiments.registry import Registry

__all__ = [
    "COMPRESSORS",
    "compressors",
    "Compressor",
    "NoCompression",
    "TopK",
    "RandK",
    "Int8",
    "build_compressor",
    "keep_count",
    "topk_indices_flat",
    "topk_mask_jax",
    "topk_mask_np",
    "VALUE_BYTES",
    "INDEX_BYTES",
]

COMPRESSORS: dict[str, type[Compressor]] = {
    "none": NoCompression,
    "topk": TopK,
    "randk": RandK,
    "int8": Int8,
}

#: the experiments-layer registry (`ExperimentSpec.compression` resolves
#: here, following the faultplans pattern); builders are the frozen
#: dataclasses themselves, so registry params == constructor kwargs
compressors = Registry("compressor")
for _kind, _cls in COMPRESSORS.items():
    compressors.register(_kind)(_cls)
del _kind, _cls


def build_compressor(kind: str, params: dict[str, Any] | None = None
                     ) -> Compressor:
    """Build a compressor from its spec component (kind, params); raises
    ValueError on unknown kinds or params so a typo'd frozen spec fails
    loudly instead of silently running uncompressed."""
    cls = COMPRESSORS.get(kind)
    if cls is None:
        raise ValueError(f"unknown compression kind {kind!r} "
                         f"(have {sorted(COMPRESSORS)})")
    try:
        return cls(**dict(params or {}))
    except TypeError as e:
        raise ValueError(f"bad params for compression {kind!r}: {e}") from e
