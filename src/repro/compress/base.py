"""Compressor objects: the jax and numpy halves of one wire format.

Every compressor bundles THREE things, mirroring how `components.Problem`
carries both its numpy (netsim) and jax (dense) execution halves:

  * a jax-traceable stack API (`compress_jax(corrected, t)` on a stacked
    (n, d) array, `t` the traced iteration counter) used inside
    `DDASimulator`'s scanned body -- sparsifiers additionally expose
    `support_mask_jax` so the fused compress-mix Pallas pass can consume
    the 0/1 support directly instead of a materialized masked message;
  * a numpy per-message API (`compress_np(row, node, stamp)`) used by the
    event-driven netsim engines. Randomized compressors derive their RNG
    from `(seed, node, stamp)` -- a pure function of WHAT is being sent,
    never of global draw order -- which is what keeps the object and
    vectorized engines bit-identical under compression: each node's sends
    occur in increasing stamp order in both engines, so per-node residual
    sequences coincide exactly;
  * a per-message byte model (`wire_ratio(d)`), the generalized
    `core.compression.ratio_bytes`: the fraction of the uncompressed
    d-float payload that actually crosses the wire. This is the c in the
    paper's effective tradeoff r -> r*c (n_opt = 1/sqrt(rc), h_opt ~
    sqrt(nkrc)); `netsim.Network` scales its serialization times by it and
    `core.tradeoff` accepts it as the `c=` argument everywhere.

All compressors return the DENSE representation of the transmitted
message (zeros off the support for sparsifiers, dequantized values for
quantizers) so downstream mixing code sees one layout; bytes-on-wire are
accounted through `wire_ratio`, never through array sizes.

Error feedback (`error_feedback=True`, the default for every lossy
compressor) is owned by the CALLER -- the compressor is a pure function
of the corrected message `m + residual`; the caller keeps
`residual <- corrected - sent`. The telescoping identity
`sum sent = sum msg + res_0 - res_T` then makes the cumulative
transmitted mass unbiased (pinned by tests/test_compress.py).
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "VALUE_BYTES",
    "INDEX_BYTES",
    "Compressor",
    "NoCompression",
    "TopK",
    "RandK",
    "Int8",
    "keep_count",
    "topk_mask_jax",
    "topk_mask_np",
    "topk_indices_flat",
]

#: wire width of one transmitted float value / coordinate index
VALUE_BYTES = 4
INDEX_BYTES = 4


def keep_count(d: int, keep: float) -> int:
    """Entries kept per d-dim message at fraction `keep` (always >= 1)."""
    if not 0.0 < keep <= 1.0:
        raise ValueError(f"keep must be in (0, 1], got {keep}")
    return max(1, min(d, int(d * keep)))


# ---------------------------------------------------------------------------
# the one exact-top-k implementation (satellite: the dense simulator's old
# inline `mags >= thresh` mask kept MORE than k entries on magnitude ties;
# every top-k consumer now routes through these)
# ---------------------------------------------------------------------------


def topk_indices_flat(x: jax.Array, k: int) -> jax.Array:
    """Indices of the k largest-|x| entries of a flat vector; exactly k,
    ties broken toward the lower index (`lax.top_k` is stable)."""
    return jax.lax.top_k(jnp.abs(x.reshape(-1)), k)[1]


def topk_mask_jax(x: jax.Array, k: int) -> jax.Array:
    """Exactly-k per-row 0/1 support mask of the k largest-|x| entries.
    x: (n, d). A thresholding mask (`|x| >= kth largest`) is NOT
    equivalent: on magnitude ties it keeps every tied entry."""
    n = x.shape[0]
    idx = jax.lax.top_k(jnp.abs(x), k)[1]
    return jnp.zeros(x.shape, x.dtype).at[
        jnp.arange(n)[:, None], idx].set(1)


def topk_mask_np(row: np.ndarray, k: int) -> np.ndarray:
    """Numpy twin of `topk_mask_jax` for one (d,) message: stable argsort
    on -|x| breaks ties toward the lower index, matching `lax.top_k`."""
    idx = np.argsort(-np.abs(row), kind="stable")[:k]
    mask = np.zeros_like(row)
    mask[idx] = 1.0
    return mask


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------


class Compressor:
    """Interface; see the module docstring for the three halves."""

    kind: ClassVar[str] = "?"
    #: sparsifiers expose `support_mask_jax` and ride the fused
    #: compress-mix kernel; quantizers ship a dense dequantized message
    is_sparsifier: ClassVar[bool] = False
    error_feedback: bool = False

    def wire_ratio(self, d: int) -> float:
        """Bytes-on-wire fraction vs the uncompressed d-float message."""
        raise NotImplementedError

    def compress_jax(self, corrected: jax.Array, t: jax.Array) -> jax.Array:
        """Dense layout of what is transmitted, (n, d) -> (n, d)."""
        raise NotImplementedError

    def compress_np(self, row: np.ndarray, node: int,
                    stamp: int) -> np.ndarray:
        """One message, (d,) -> (d,); must return a fresh array."""
        raise NotImplementedError

    def params_dict(self) -> dict:
        """The spec params that rebuild this compressor (JSON-exact)."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


@dataclasses.dataclass(frozen=True)
class NoCompression(Compressor):
    """Identity wire format: ratio 1, no residual ever accumulates."""

    kind: ClassVar[str] = "none"
    error_feedback: bool = False

    def wire_ratio(self, d: int) -> float:
        return 1.0

    def compress_jax(self, corrected, t):
        return corrected

    def compress_np(self, row, node, stamp):
        return row.copy()


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Largest-|x| sparsification: keep `keep_count(d, keep)` coordinates,
    ship (value, index) pairs."""

    kind: ClassVar[str] = "topk"
    is_sparsifier: ClassVar[bool] = True
    keep: float = 0.1
    error_feedback: bool = True

    def __post_init__(self):
        keep_count(1, self.keep)  # validates the range eagerly

    def wire_ratio(self, d: int) -> float:
        k = keep_count(d, self.keep)
        return k * (VALUE_BYTES + INDEX_BYTES) / (d * VALUE_BYTES)

    def support_mask_jax(self, corrected, t):
        return topk_mask_jax(corrected, keep_count(corrected.shape[-1],
                                                   self.keep))

    def compress_jax(self, corrected, t):
        return corrected * self.support_mask_jax(corrected, t)

    def compress_np(self, row, node, stamp):
        return row * topk_mask_np(row, keep_count(row.shape[-1], self.keep))


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Uniform-random sparsification. The support is a pure function of
    (seed, round) -- shared randomness the receiver can replay -- so only
    the k VALUES cross the wire (no index bytes), which is why rand-k's
    ratio beats top-k's at equal keep."""

    kind: ClassVar[str] = "randk"
    is_sparsifier: ClassVar[bool] = True
    keep: float = 0.1
    seed: int = 0
    error_feedback: bool = True

    def __post_init__(self):
        keep_count(1, self.keep)

    def wire_ratio(self, d: int) -> float:
        return keep_count(d, self.keep) / d

    def support_mask_jax(self, corrected, t):
        k = keep_count(corrected.shape[-1], self.keep)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 t.astype(jnp.int32))
        # exactly-k random support per node row: top-k of i.i.d. scores
        scores = jax.random.uniform(key, corrected.shape)
        idx = jax.lax.top_k(scores, k)[1]
        return jnp.zeros(corrected.shape, corrected.dtype).at[
            jnp.arange(corrected.shape[0])[:, None], idx].set(1)

    def compress_jax(self, corrected, t):
        return corrected * self.support_mask_jax(corrected, t)

    def compress_np(self, row, node, stamp):
        d = row.shape[-1]
        k = keep_count(d, self.keep)
        rng = np.random.default_rng((self.seed, int(node), int(stamp)))
        out = np.zeros_like(row)
        idx = rng.permutation(d)[:k]
        out[idx] = row[idx]
        return out


@dataclasses.dataclass(frozen=True)
class Int8(Compressor):
    """Per-message absmax int8 quantization: scale s = max|x|/127, ship
    int8 codes + one float scale. `stochastic=True` rounds with
    floor(x/s + u), u ~ U[0,1) -- unbiased per entry (E[q] = x/s) -- the
    pattern `pltpu.stochastic_round` implements in hardware."""

    kind: ClassVar[str] = "int8"
    stochastic: bool = False
    seed: int = 0
    error_feedback: bool = True

    #: quantization levels on each side of zero
    LEVELS: ClassVar[int] = 127

    def wire_ratio(self, d: int) -> float:
        return (d * 1 + VALUE_BYTES) / (d * VALUE_BYTES)

    def _dequant(self, y, q, s):
        return jnp.clip(q, -self.LEVELS, self.LEVELS) * s

    def compress_jax(self, corrected, t):
        s = jnp.max(jnp.abs(corrected), axis=-1, keepdims=True) / self.LEVELS
        s = jnp.where(s > 0, s, 1.0)
        y = corrected / s
        if self.stochastic:
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                     t.astype(jnp.int32))
            q = jnp.floor(y + jax.random.uniform(key, y.shape))
        else:
            q = jnp.round(y)
        return self._dequant(y, q, s).astype(corrected.dtype)

    def compress_np(self, row, node, stamp):
        s = float(np.max(np.abs(row))) / self.LEVELS
        if s <= 0.0:
            return row.copy()
        y = row / s
        if self.stochastic:
            rng = np.random.default_rng((self.seed, int(node), int(stamp)))
            q = np.floor(y + rng.random(y.shape))
        else:
            q = np.round(y)
        return np.clip(q, -self.LEVELS, self.LEVELS) * s
