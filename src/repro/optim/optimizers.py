"""Optimizers as pure (init, update) pairs (optax-style, self-contained).

`dual_averaging` is the paper's inner update (eq. 3-4 without the consensus
term, which the launcher applies via `core.consensus`): the state carries the
accumulated subgradient z and the primal is x = -a(t) z. `adamw`/`sgd` are
the substrate optimizers for the consensus-SGD (section VI) LM training mode.

Adam moments are fp32 regardless of param dtype; updates are computed in
fp32 and cast back (bf16 params + fp32 state; no separate fp32 master copy
-- documented in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    inner: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]
    name: str = "opt"


def sgd(lr_fn, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        inner = (jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
                 if momentum else None)
        return OptState(jnp.zeros((), jnp.int32), inner)

    def update(grads, state, params):
        t = state.step + 1
        lr = lr_fn(t)

        if momentum:
            new_m = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.inner, grads)
            upd = new_m
        else:
            new_m = None
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p_, u: (p_.astype(jnp.float32)
                           - lr * (u + weight_decay * p_.astype(jnp.float32))
                           ).astype(p_.dtype),
            params, upd)
        return new_params, OptState(t, new_m)

    return Optimizer(init, update, "sgd")


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32) -> Optimizer:
    """moment_dtype=bfloat16 halves optimizer-state HBM (the standard
    large-model tradeoff; updates still computed in fp32)."""
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda x: jnp.zeros(x.shape, moment_dtype), params)
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": zeros(), "v": zeros()})

    def update(grads, state, params):
        t = state.step + 1
        lr = lr_fn(t)
        tf = t.astype(jnp.float32)
        c1 = 1.0 - b1 ** tf
        c2 = 1.0 - b2 ** tf

        def one(p_, g, m, v):
            g = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            upd = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
            newp = (p_.astype(jnp.float32)
                    - lr * (upd + weight_decay * p_.astype(jnp.float32)))
            return (newp.astype(p_.dtype), mf.astype(moment_dtype),
                    vf.astype(moment_dtype))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.inner["m"])
        flat_v = jax.tree.leaves(state.inner["v"])
        out = [one(p_, g, m, v) for p_, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_params, OptState(t, {"m": new_m, "v": new_v})

    return Optimizer(init, update, "adamw")


def dual_averaging(a_fn, projection: Callable[[PyTree], PyTree] | None = None
                   ) -> Optimizer:
    """DDA primal-dual update (paper eq. 3-4, local part):
        z <- z + g;   x <- Proj(-a(t) z)
    The consensus mixing of z happens OUTSIDE (launcher/mix step), exactly as
    the paper separates cheap and expensive iterations."""

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        {"z": jax.tree.map(
                            lambda x: jnp.zeros(x.shape, jnp.float32), params)})

    def update(grads, state, params):
        t = state.step + 1
        a_t = a_fn(t)
        new_z = jax.tree.map(lambda z, g: z + g.astype(jnp.float32),
                             state.inner["z"], grads)
        new_params = jax.tree.map(
            lambda p_, z: (-a_t * z).astype(p_.dtype), params, new_z)
        if projection is not None:
            new_params = projection(new_params)
        return new_params, OptState(t, {"z": new_z})

    return Optimizer(init, update, "dual_averaging")
