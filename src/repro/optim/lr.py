"""Learning-rate / DDA step-size schedules. All return f(step)->lr with
`step` a traced scalar (1-indexed)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda t: jnp.asarray(lr, jnp.float32)


def rsqrt_lr(A: float, q: float = 0.5):
    """The paper's a(t) = A / t^q (q=1/2 default, eq. 7; general q for the
    increasingly-sparse regime, section IV.B)."""
    return lambda t: A / jnp.maximum(t.astype(jnp.float32), 1.0) ** q


def cosine_lr(peak: float, total_steps: int, floor: float = 0.0):
    def f(t):
        frac = jnp.clip(t.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
    return f


def warmup_cosine(peak: float, warmup: int, total_steps: int,
                  floor: float = 0.0):
    def f(t):
        t = t.astype(jnp.float32)
        warm = peak * t / max(warmup, 1)
        frac = jnp.clip((t - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup, warm, cos)
    return f
