from repro.optim.optimizers import (Optimizer, adamw, dual_averaging, sgd,
                                    OptState)
from repro.optim.lr import constant_lr, cosine_lr, rsqrt_lr, warmup_cosine
