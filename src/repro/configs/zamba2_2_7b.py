"""zamba2-2.7b [hybrid] -- 54 blocks d_model=2560 32H (kv=32) d_ff=10240
vocab=32000 ssm_state=64; Mamba-2 backbone with a weight-SHARED attention
(+FFN) block invoked every 6th position, specialized per invocation by LoRA
adapters. [arXiv:2411.15242]

Simplifications vs. the HF checkpoint (DESIGN.md section 5): one shared
block (zamba2 alternates two), LoRA on q/o projections only, and the shared
block consumes the hidden state directly rather than concat(hidden, embed).
"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    d_model=2560, vocab_size=32000,
    superblock=("mamba2",) * 5 + ("shared_attn",), n_super=9,
    num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, mlp_act="gelu",
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    shared_attn_lora=128,
    rope_theta=10000.0,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    d_model=128, vocab_size=512,
    superblock=("mamba2",) * 2 + ("shared_attn",), n_super=2,
    num_heads=8, num_kv_heads=8, head_dim=16,
    d_ff=256, mlp_act="gelu",
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=32,
    shared_attn_lora=16,
    rope_theta=10000.0,
)

SHAPES = lm_shapes(long_ok=True)
