"""Architecture configs: one module per assigned architecture (exact
published hyperparameters) plus the paper's own experiment configs."""
