"""falcon-mamba-7b [ssm] -- 64L d_model=4096 attention-free d_ff=0
vocab=65024 ssm_state=16; pure Mamba-1. [arXiv:2410.05355]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    d_model=4096, vocab_size=65024,
    superblock=("mamba1",), n_super=64,
    d_ff=0, ssm_state=16, ssm_conv=4, ssm_expand=2,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    d_model=128, vocab_size=512,
    superblock=("mamba1",), n_super=2,
    d_ff=0, ssm_state=8, ssm_conv=4, ssm_expand=2,
)

SHAPES = lm_shapes(long_ok=True)
