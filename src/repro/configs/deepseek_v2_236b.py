"""deepseek-v2-236b [moe] -- 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400; MLA kv_lora=512 q_lora=1536 rope_head=64; MoE 2 shared + 160
routed top-6; first layer dense (d_ff 12288). [arXiv:2405.04434]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    d_model=5120, vocab_size=102400,
    prologue=("mla",),
    superblock=("mla_moe",), n_super=59,
    num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=12288, mlp_act="swiglu",
    moe_experts=160, moe_top_k=6, moe_shared=2, moe_d_ff=1536,
    mla_kv_lora=512, mla_q_lora=1536, mla_rope_head_dim=64,
    mla_v_head_dim=128,
    rope_theta=10000.0,
    train_microbatches=16,
    opt_moments_bf16=True,
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    d_model=128, vocab_size=512,
    prologue=("mla",),
    superblock=("mla_moe",), n_super=2,
    num_heads=8, num_kv_heads=8, head_dim=16,
    d_ff=256, mlp_act="swiglu",
    moe_experts=8, moe_top_k=2, moe_shared=1, moe_d_ff=64,
    mla_kv_lora=32, mla_q_lora=48, mla_rope_head_dim=8,
    mla_v_head_dim=16,
    rope_theta=10000.0,
)

SHAPES = lm_shapes(long_ok=False)
