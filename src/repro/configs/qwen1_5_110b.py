"""qwen1.5-110b [dense] -- 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064; QKV bias. [hf:Qwen/Qwen1.5-110B family]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-110b", family="dense",
    d_model=8192, vocab_size=152064,
    superblock=("attn",), n_super=80,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=49152, mlp_act="swiglu", qkv_bias=True,
    rope_theta=1000000.0,
    train_microbatches=8,
    mlp_tp=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke", family="dense",
    d_model=128, vocab_size=512,
    superblock=("attn",), n_super=3,
    num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=384, mlp_act="swiglu", qkv_bias=True,
    rope_theta=1000000.0,
)

SHAPES = lm_shapes(long_ok=False)
