"""musicgen-medium [audio] -- 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens. [arXiv:2306.05284]

Backbone only per the assignment: the EnCodec/conditioning frontend is a
stub -- `input_specs()` supplies precomputed audio-token ids (the 4 codebook
streams are collapsed to a single interleaved stream, the standard "delay
pattern" flattening).
"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", family="audio",
    d_model=1536, vocab_size=2048,
    superblock=("attn",), n_super=48,
    num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144, mlp_act="gelu",
    rope_theta=10000.0,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", family="audio",
    d_model=96, vocab_size=256,
    superblock=("attn",), n_super=2,
    num_heads=6, num_kv_heads=6, head_dim=16,
    d_ff=192, mlp_act="gelu",
    rope_theta=10000.0,
)

SHAPES = lm_shapes(long_ok=False)
