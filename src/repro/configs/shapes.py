"""The assigned input-shape set for the LM-family architectures.

Every arch gets the same 4 logical shapes; per-arch SHAPES dicts may mark
cells skipped (e.g. long_500k for pure full-attention archs) with a reason.

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> train-style forward (prefill)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     -> serve_step (1 new token)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"
    skip: str | None = None  # reason, if inapplicable to this arch


def lm_shapes(long_ok: bool, long_skip_reason: str = "") -> dict[str, ShapeCell]:
    cells = {
        "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
        "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
        "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
        "long_500k": ShapeCell(
            "long_500k", 524288, 1, "decode",
            skip=None if long_ok else (
                long_skip_reason or
                "pure full-attention arch: 500k dense KV cache is "
                "super-linear in memory; no sub-quadratic variant in the "
                "published config (DESIGN.md section 5)")),
    }
    return cells
