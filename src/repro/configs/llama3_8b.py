"""llama3-8b [dense] -- 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; SwiGLU, rope theta 500k. [arXiv:2407.21783]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama3-8b", family="dense",
    d_model=4096, vocab_size=128256,
    superblock=("attn",), n_super=32,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, mlp_act="swiglu",
    rope_theta=500000.0,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke", family="dense",
    d_model=128, vocab_size=512,
    superblock=("attn",), n_super=2,
    num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, mlp_act="swiglu",
    rope_theta=500000.0,
)

SHAPES = lm_shapes(long_ok=False)
