"""llama-3.2-vision-90b [vlm] -- 100 blocks (80 self + 20 cross-attn)
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; cross-attention image
layers every 5th block. The vision tower is a stub per the assignment:
`input_specs()` supplies precomputed patch embeddings (B, 6400, 7680).
[hf:meta-llama/Llama-3.2-90B-Vision family]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    d_model=8192, vocab_size=128256,
    superblock=("attn", "attn", "attn", "attn", "cross_attn"), n_super=20,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, mlp_act="swiglu",
    num_encoder_tokens=6400, encoder_dim=7680,
    rope_theta=500000.0,
    train_microbatches=16,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    d_model=128, vocab_size=512,
    superblock=("attn", "attn", "cross_attn"), n_super=2,
    num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, mlp_act="swiglu",
    num_encoder_tokens=16, encoder_dim=96,
    rope_theta=500000.0,
)

SHAPES = lm_shapes(long_ok=False)
