"""codeqwen1.5-7b [dense] -- 32L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=13440 vocab=92416; QKV bias (qwen1.5 arch). [hf:Qwen/CodeQwen1.5-7B]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    d_model=4096, vocab_size=92416,
    superblock=("attn",), n_super=32,
    num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=13440, mlp_act="swiglu", qkv_bias=True,
    rope_theta=1000000.0,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    d_model=128, vocab_size=512,
    superblock=("attn",), n_super=2,
    num_heads=8, num_kv_heads=8, head_dim=16,
    d_ff=256, mlp_act="swiglu", qkv_bias=True,
    rope_theta=1000000.0,
)

SHAPES = lm_shapes(long_ok=False)
