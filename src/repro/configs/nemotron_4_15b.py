"""nemotron-4-15b [dense] -- 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000; squared-ReLU MLP, no QKV bias. [arXiv:2402.16819]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b", family="dense",
    d_model=6144, vocab_size=256000,
    superblock=("attn",), n_super=32,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, mlp_act="squared_relu",
    rope_theta=10000.0,
    train_microbatches=2,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke", family="dense",
    d_model=128, vocab_size=512,
    superblock=("attn",), n_super=2,
    num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, mlp_act="squared_relu",
    rope_theta=10000.0,
)

SHAPES = lm_shapes(long_ok=False)
