"""llama4-maverick-400b-a17b [moe] -- 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE 128 routed top-1 + 1 shared expert, MoE layers
interleaved every 2nd layer (Maverick). Early-fusion multimodal frontend is
stubbed per the assignment. [hf:meta-llama/Llama-4 family]"""

from repro.configs.shapes import lm_shapes
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    d_model=5120, vocab_size=202048,
    superblock=("attn", "attn_moe"), n_super=24,
    num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, mlp_act="swiglu",
    moe_experts=128, moe_top_k=1, moe_shared=1, moe_d_ff=8192,
    rope_theta=500000.0,
    train_microbatches=16,
    opt_moments_bf16=True,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    d_model=128, vocab_size=512,
    superblock=("attn", "attn_moe"), n_super=2,
    num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, mlp_act="swiglu",
    moe_experts=8, moe_top_k=1, moe_shared=1, moe_d_ff=256,
    rope_theta=500000.0,
)

SHAPES = lm_shapes(long_ok=False)
