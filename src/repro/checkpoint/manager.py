"""Checkpointing: atomic msgpack+npz save/restore of arbitrary pytrees with
keep-k rotation and automatic resume -- the restart half of fault tolerance.

Layout: <dir>/step_<n>/ {tree.msgpack (structure + small leaves),
arrays.npz (numbered large leaves)} plus a COMMIT marker written LAST so a
crash mid-save never yields a checkpoint that restore would trust. Saves
run on a background thread (async checkpointing): the train loop hands off
host copies and keeps stepping.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# COMMIT marker content: restore trusts a checkpoint only when the marker
# holds exactly this token, so a crash that leaves a partial/empty COMMIT
# file behind reads as "not committed" instead of a torn restore source.
_COMMIT_TOKEN = "ok"


def _write_atomic(path: pathlib.Path, writer) -> None:
    """Write a file via temp-name + os.replace so it is all-or-nothing.

    `writer(tmp_path)` produces the full content at the temp path; the
    rename into place is atomic on POSIX, so readers never observe a
    half-written file even if the process dies mid-write."""
    tmp = path.with_name(path.name + ".part")
    writer(tmp)
    os.replace(tmp, path)


def _committed(path: pathlib.Path) -> bool:
    try:
        return (path / "COMMIT").read_text() == _COMMIT_TOKEN
    except OSError:
        return False

# numpy's npz cannot store ml_dtypes (bf16 etc.) natively: store a uint view
# plus a dtype tag.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_tree(path: pathlib.Path, tree: PyTree, *, extra: dict | None = None):
    """Atomic synchronous save of a pytree of arrays.

    Safe under concurrent writers: the staging dir is suffixed with the
    writer's pid (two processes saving the same step never share a tmp),
    and losing the commit race to an already-committed sibling is a
    no-op, not an error -- checkpoints are content-deterministic per
    step, so whichever writer wins committed the same bytes."""
    path = pathlib.Path(path)
    tmp = path.with_name(f"{path.name}.tmp-{os.getpid()}")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, l in enumerate(leaves):
        arr = np.asarray(l)
        name = arr.dtype.name if arr.dtype.names is None else str(arr.dtype)
        for tag, (dt, view) in _EXOTIC.items():
            if arr.dtype == dt:
                arr, name = arr.view(view), tag
                break
        arrays[f"a{i}"] = arr
        dtypes.append(name)
    def _savez(p):
        with open(p, "wb") as f:  # file handle: savez must not append .npz
            np.savez(f, **arrays)

    _write_atomic(tmp / "arrays.npz", _savez)
    meta = {"n_leaves": len(leaves), "dtypes": dtypes, "extra": extra or {}}
    _write_atomic(tmp / "meta.json",
                  lambda p: p.write_text(json.dumps(meta)))
    _write_atomic(tmp / "COMMIT", lambda p: p.write_text(_COMMIT_TOKEN))
    try:
        if path.exists():
            shutil.rmtree(path, ignore_errors=True)
        tmp.rename(path)
    except OSError:
        if _committed(path):
            # a concurrent writer committed this step first; theirs is
            # whole (COMMIT verified), so dropping our staging copy is
            # the correct outcome of the race
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            raise


def restore_tree(path: pathlib.Path, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of `like` (shape/dtype checked against
    leaves). Returns (tree, extra)."""
    path = pathlib.Path(path)
    if not _committed(path):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    data = np.load(path / "arrays.npz")
    meta = json.loads((path / "meta.json").read_text())
    leaves, treedef = _flatten(like)
    assert meta["n_leaves"] == len(leaves), "structure mismatch"
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        tag = meta["dtypes"][i]
        if tag in _EXOTIC:
            arr = arr.view(_EXOTIC[tag][0])
        ref_shape = getattr(ref, "shape", None)
        assert arr.shape == tuple(ref_shape), (i, arr.shape, ref_shape)
        new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), meta["extra"]


class CheckpointManager:
    """keep-k rotating checkpoints with async save and latest-resume.

    Multiple managers (including in different processes) may point at the
    same directory: saves stage under per-pid tmp names, rotation
    tolerates concurrent deletion (`FileNotFoundError` means a sibling
    rotated first) and never removes the snapshot this manager just
    wrote, so two writers cannot delete each other's newest work. A
    background-save failure is re-raised from the next `wait()` (or
    `save`/`restore_latest`, which wait first) instead of dying silently
    on the worker thread."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        self._error: BaseException | None = None

    def _step_dirs(self) -> list[tuple[int, pathlib.Path]]:
        out = []
        for p in self.dir.glob("step_*"):
            if _committed(p):
                try:
                    out.append((int(p.name.split("_")[1]), p))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def save(self, step: int, tree: PyTree, *, extra: dict | None = None,
             blocking: bool = False):
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now

        def work():
            try:
                with self._lock:
                    save_tree(self.dir / f"step_{step}", host_tree,
                              extra=extra)
                    self._rotate(protect=step)
            except BaseException as e:  # noqa: BLE001 -- re-raised by wait()
                self._error = e

        self.wait()
        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def _rotate(self, protect: int | None = None) -> None:
        """Delete committed snapshots beyond the `keep` newest. The
        listing is taken fresh (a sibling process may have rotated since
        the save), a vanished dir is a sibling's rotation (not an
        error), and `protect` pins the step this manager just wrote."""
        dirs = self._step_dirs()
        doomed = dirs[:-self.keep] if self.keep > 0 else dirs
        for step, p in doomed:
            if protect is not None and step >= protect:
                continue
            try:
                shutil.rmtree(p)
            except FileNotFoundError:
                continue

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: PyTree) -> tuple[int, PyTree, dict] | None:
        self.wait()
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = restore_tree(self.dir / f"step_{step}", like)
        return step, tree, extra
