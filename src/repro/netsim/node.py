"""Asynchronous DDA nodes for the event-driven cluster simulator.

Two variants, both host-side (numpy state; gradients may come from jitted
jax closures via the simulator's `grad_fn`):

  * `AsyncDDANode`   -- stale-gossip DDA. Mixing mirrors
    `core.consensus.mix_stale`: a communication iteration mixes with the
    LATEST values already received from each in-neighbor (one-or-more
    rounds stale, depending on link delay) via the shared
    `consensus.stale_combine`; the weight of any neighbor that has never
    delivered (or whose message was dropped) folds back into the self
    weight, keeping every update a convex combination exactly like
    `runtime.fault_tolerance.degraded_matrix`.

  * `PushSumDDANode` -- push-sum dual averaging with per-link cumulative
    mass counters (the sigma/rho construction of robust ratio consensus).
    Messages carry the cumulative mass ever sent on the link, so a dropped
    packet's mass is automatically recovered by the next successful one:
    total (value, weight) mass is conserved under arbitrary i.i.d. drops
    and directed/time-varying links -- the regime where plain stale gossip
    loses doubly-stochasticity. Estimates are the ratio y/w.

Iteration bookkeeping matches core.dda exactly (1-indexed iterations,
z <- mix(z) + g, x = -a(t) z, running xhat average), so traces are
comparable with `DDASimulator` runs step-for-step.

These classes are the OBJECT-engine representation (netsim.engine
ObjectEngine drives them one event at a time) and the interop surface of
the vectorized engine: after a vectorized run, `NetSimulator.nodes`
materializes equivalent instances from the struct-of-arrays state, so
diagnostics written against per-node objects (`pushsum_mass_audit`, direct
`.z_est` reads) work over either backend.
"""

from __future__ import annotations

from typing import Any, Callable

import numpy as np

from repro.core.consensus import stale_combine
from repro.core.schedules import CommSchedule, EveryIteration
from repro.netsim.network import Network

__all__ = ["AsyncDDANode", "PushSumDDANode", "pushsum_mass_audit"]

GradFn = Callable[[int, np.ndarray, int], np.ndarray]


class _NodeBase:
    def __init__(self, i: int, x0: np.ndarray, grad_fn: GradFn,
                 a_fn: Callable[[float], float],
                 schedule: CommSchedule | None = None,
                 projection: Callable[[np.ndarray], np.ndarray] | None = None):
        self.i = i
        self.x = np.array(x0, dtype=np.float64)
        self.xhat = self.x.copy()
        self.t = 0
        self.grad_fn = grad_fn
        self.a_fn = a_fn
        self.schedule = schedule or EveryIteration()
        self.projection = projection
        self.next_comm = self.schedule.next_comm_step(0)
        self.comm_iters = 0

    def is_comm_next(self) -> bool:
        """Will the iteration about to run (t+1) communicate?"""
        return self.t + 1 == self.next_comm

    def _advance(self, z_est: np.ndarray) -> None:
        t_new = self.t + 1
        a_t = float(self.a_fn(float(t_new)))
        x_new = -a_t * z_est
        if self.projection is not None:
            x_new = self.projection(x_new)
        self.xhat = (self.t * self.xhat + x_new) / t_new
        self.x = x_new
        self.t = t_new

    def finish_step(self, net: Network) -> list[tuple[int, Any]]:
        """Complete iteration t+1; returns (dst, payload) messages to ship."""
        raise NotImplementedError

    def receive(self, src: int, payload: Any) -> None:
        raise NotImplementedError

    @property
    def z_est(self) -> np.ndarray:
        """Current dual estimate (for disagreement diagnostics)."""
        raise NotImplementedError


class AsyncDDANode(_NodeBase):
    def __init__(self, i, x0, grad_fn, a_fn, schedule=None, projection=None,
                 compression=None):
        super().__init__(i, x0, grad_fn, a_fn, schedule, projection)
        self.z = np.zeros_like(self.x)
        # latest value per in-neighbor: src -> (sender iteration stamp, z)
        self.inbox: dict[int, tuple[int, np.ndarray]] = {}
        # Optional `repro.compress.Compressor`: outgoing payloads are
        # compressed with error feedback (the residual lives HERE, on the
        # sender), while the node's own z stays exact -- mirroring
        # DDASimulator's diagonal semantics where compression only touches
        # what crosses the wire. `compress_np` is a pure function of
        # (message, node, stamp), so the vectorized engine reproduces these
        # payloads bit-for-bit regardless of event interleaving.
        self.compression = compression
        self._comp_res = (None if compression is None
                          else np.zeros_like(self.x))

    @property
    def z_est(self) -> np.ndarray:
        return self.z

    def _stale_mix(self, net: Network) -> np.ndarray:
        g = net.graph
        W = net.mix_weights
        if W is None:
            acc = np.zeros_like(self.z)
            missing = 0
            for src in net.in_neighbors(self.i):
                entry = self.inbox.get(src)
                if entry is None:
                    missing += 1
                else:
                    acc += entry[1]
            # fold undelivered neighbors' weight into self: row stays
            # stochastic
            sw = g.self_weight + missing * g.edge_weight
            return stale_combine(self.z, g.edge_weight * acc, sw)
        # reweighted gossip: per-edge weights W[i, src] instead of the
        # uniform edge weight. W[i, src] is the TOTAL weight of the (i, src)
        # pair, so a src occupying multiple permutation slots contributes
        # W[i, src] / multiplicity per slot -- identical totals either way,
        # and the same convention the vectorized engine applies.
        in_nb = net.in_neighbors(self.i)
        mult: dict[int, int] = {}
        for src in in_nb:
            mult[src] = mult.get(src, 0) + 1
        acc = np.zeros_like(self.z)
        sw = float(W[self.i, self.i])
        for src in in_nb:
            w = float(W[self.i, src]) / mult[src]
            entry = self.inbox.get(src)
            if entry is None:
                sw += w
            else:
                acc += w * entry[1]
        return stale_combine(self.z, acc, sw)

    def finish_step(self, net: Network) -> list[tuple[int, Any]]:
        t_new = self.t + 1
        grad = np.asarray(self.grad_fn(self.i, self.x, self.t),
                          dtype=np.float64)
        msgs: list[tuple[int, Any]] = []
        if t_new == self.next_comm:
            comp = self.compression
            if comp is None:
                buf = self.z.copy()  # ship pre-mix z (mix_stale)
            else:
                corrected = self.z + self._comp_res
                buf = comp.compress_np(corrected, self.i, t_new)
                if comp.error_feedback:
                    self._comp_res = corrected - buf
            payload = (t_new, buf)
            msgs = [(dst, payload) for dst in net.out_neighbors(self.i)]
            z_new = self._stale_mix(net) + grad
            self.next_comm = self.schedule.next_comm_step(t_new)
            self.comm_iters += 1
        else:
            z_new = self.z + grad
        self.z = z_new
        self._advance(z_new)
        return msgs

    def receive(self, src: int, payload: tuple[int, np.ndarray]) -> None:
        stamp, value = payload
        cur = self.inbox.get(src)
        if cur is None or stamp > cur[0]:
            self.inbox[src] = (stamp, value)


class PushSumDDANode(_NodeBase):
    def __init__(self, i, x0, grad_fn, a_fn, schedule=None, projection=None,
                 y0: np.ndarray | None = None, w_floor: float = 0.5,
                 inject: str = "plain"):
        super().__init__(i, x0, grad_fn, a_fn, schedule, projection)
        self.y = (np.zeros_like(self.x) if y0 is None
                  else np.array(y0, dtype=np.float64))
        self.w = 1.0
        if inject not in ("plain", "scaled"):
            raise ValueError(f"inject must be 'plain' or 'scaled', "
                             f"got {inject!r}")
        # Gradient injection mode. "plain" adds the raw gradient to y each
        # step (the textbook subgradient-push update). "scaled" adds
        # w * grad instead: a node holding little weight mass injects
        # proportionally little value mass, so the ratio estimate sees the
        # gradient at its TRUE magnitude (w*g / w = g) instead of the
        # loss-amplified g / w. Where the plain+floor combination damps the
        # whole estimate by min(1, w/w_floor) whenever w < w_floor, scaled
        # injection leaves the mixed mass untouched and only attenuates the
        # newly injected gradient (by w/w_floor through the clamped
        # denominator) -- the bias applies to one step's gradient, not the
        # accumulated state, so it SHRINKS as mixing pulls w back toward 1
        # and vanishes above the floor. Opt-in ("plain" default) because
        # it changes seeded trajectories.
        self.inject = inject
        # Ratio guard: under sustained loss a standing fraction of weight
        # mass lives in the sigma-rho limbo, so held w_i dwells well below
        # 1 while freshly injected gradients sit in y at full magnitude --
        # the ratio y/w then amplifies them by 1/w and the primal feedback
        # loop x = -a(t) y/w can diverge. Clamping the DENOMINATOR only
        # (mass bookkeeping stays exact, so conservation and the audit
        # invariant are untouched) caps that amplification at 1/w_floor;
        # the estimate is conservatively damped instead, the same basin
        # guard as robust ratio-consensus clamps (z >= c*I).
        #
        # Quantified bias (tests/test_netsim.py::test_pushsum_w_floor_*):
        # because only the denominator is clamped, the guarded estimate is
        # EXACTLY the exact ratio damped per node,
        #   z_floor = (y/w) * min(1, w / w_floor),
        # so the relative bias is bounded by max(0, 1 - w/w_floor) -- at
        # most 100%, always a shrink toward zero (never a sign flip or
        # amplification), nonzero only while w dwells below the floor, and
        # decaying as mixing pulls w back toward 1. What it buys: under 60%
        # loss with gradient injection, the unguarded ratio (w_floor ~ 0)
        # blows the objective up by > 1e6x while the default guard keeps
        # the whole trajectory within ~10x of F(x0).
        self.w_floor = w_floor
        # cumulative mass SENT per out-link (dst -> totals)
        self.sigma_y: dict[int, np.ndarray] = {}
        self.sigma_w: dict[int, float] = {}
        # cumulative mass RECEIVED per in-link (src -> totals)
        self.rho_y: dict[int, np.ndarray] = {}
        self.rho_w: dict[int, float] = {}

    @property
    def z_est(self) -> np.ndarray:
        return self.y / max(self.w, self.w_floor)

    def finish_step(self, net: Network) -> list[tuple[int, Any]]:
        t_new = self.t + 1
        grad = np.asarray(self.grad_fn(self.i, self.x, self.t),
                          dtype=np.float64)
        msgs: list[tuple[int, Any]] = []
        if t_new == self.next_comm:
            out = net.out_neighbors(self.i)
            share = 1.0 / (len(out) + 1)
            y_share, w_share = self.y * share, self.w * share
            for dst in out:
                if dst not in self.sigma_y:
                    self.sigma_y[dst] = np.zeros_like(self.y)
                    self.sigma_w[dst] = 0.0
                self.sigma_y[dst] = self.sigma_y[dst] + y_share
                self.sigma_w[dst] += w_share
                # cumulative totals: a later delivery supersedes (and thereby
                # recovers) any dropped earlier message on this link
                msgs.append((dst, (self.sigma_y[dst].copy(),
                                   self.sigma_w[dst])))
            self.y, self.w = y_share, w_share
            self.next_comm = self.schedule.next_comm_step(t_new)
            self.comm_iters += 1
        if self.inject == "scaled":
            self.y = self.y + self.w * grad
        else:
            self.y = self.y + grad
        self._advance(self.z_est)
        return msgs

    def receive(self, src: int, payload: tuple[np.ndarray, float]) -> None:
        S_y, S_w = payload
        if src not in self.rho_y:
            self.rho_y[src] = np.zeros_like(self.y)
            self.rho_w[src] = 0.0
        if S_w >= self.rho_w[src]:  # ignore out-of-order older messages
            self.y = self.y + (S_y - self.rho_y[src])
            self.w += S_w - self.rho_w[src]
            self.rho_y[src] = S_y
            self.rho_w[src] = S_w


def pushsum_mass_audit(nodes: list[PushSumDDANode]
                       ) -> tuple[np.ndarray, float]:
    """Total (value, weight) mass held by the network, counting mass that is
    in flight or was dropped-but-recoverable on each directed link as
    (cumulative sent sigma) - (cumulative received rho).

    Invariant: with zero gradients the value total equals sum_i y_i(0) and
    the weight total equals n, at EVERY instant, under arbitrary packet loss
    -- this is the conservation property that makes push-sum's ratio
    estimate unbiased where plain gossip under drops is not
    (tests/test_netsim.py::test_pushsum_mass_conservation_under_drops).
    """
    y_total = np.sum([nd.y for nd in nodes], axis=0)
    w_total = float(sum(nd.w for nd in nodes))
    rho_y = {(src, nd.i): v for nd in nodes for src, v in nd.rho_y.items()}
    rho_w = {(src, nd.i): v for nd in nodes for src, v in nd.rho_w.items()}
    for nd in nodes:
        for dst, sig in nd.sigma_y.items():
            y_total = y_total + sig - rho_y.get((nd.i, dst), 0.0)
        for dst, sig in nd.sigma_w.items():
            w_total += sig - rho_w.get((nd.i, dst), 0.0)
    return y_total, w_total
