"""Event-driven asynchronous cluster simulation (the repo's third execution
mode, next to the dense synchronous DDASimulator and the shard_map launcher).

Simulates DDA on a modeled cluster: priority-queue event clock
(netsim.events, heap or bucketed-calendar backend), heterogeneous node
speeds + lossy/jittery links + optional time-varying topology
(netsim.network), async stale-gossip and drop-robust push-sum nodes
(netsim.node), scenario presets (netsim.scenarios), the per-node and
vectorized struct-of-arrays execution engines (netsim.engine) and the driver
with empirical-r recovery (netsim.simulator).

Engine selection
----------------
`NetSimulator(engine=...)` picks how the event loop executes:

  * ``"object"``     -- one Python node object per consensus node, one heap
    event per message. The reference implementation; linear in interpreter
    overhead, so practical up to ~100 nodes.
  * ``"vectorized"`` -- all node state in stacked (n, d) arrays, batch
    queue entries on a calendar-queue clock, whole-batch numpy updates,
    message payloads as index stamps into shared snapshot buffers. Orders
    of magnitude faster at n ~ 1000 (benchmarks/bench_netsim.py) and
    bit-identical to "object" on seeded scenarios
    (tests/test_netsim_engine.py).
  * ``"auto"``       -- the default: currently always the vectorized
    engine, since every scenario the presets can express is compatible
    with it (link jitter and per-edge overrides fall back to exact
    per-message sampling inside the engine; non-batchable grad_fn /
    eval_fn / projection callables fall back to per-node loops after a
    bitwise-verified probe). The rule exists so future features that only
    the object engine supports can be routed there without breaking
    callers.

Gradients can opt into a jitted jax path with
`NetSimulator(batch_grad_fn=engine.jax_batch_grad(grad_fn))`.
"""

from repro.netsim.engine import (ObjectEngine, VectorizedEngine,
                                 jax_batch_grad)
from repro.netsim.events import Event, EventQueue
from repro.netsim.network import LinkModel, Network, NodeSpec
from repro.netsim.node import (AsyncDDANode, PushSumDDANode,
                               pushsum_mass_audit)
from repro.netsim.problems import quadratic_consensus
from repro.netsim.scenarios import (Scenario, adversarial, homogeneous,
                                    lossy, straggler, time_varying_expander)
from repro.netsim.simulator import NetSimulator, RMeasurement
