"""Event-driven asynchronous cluster simulation (the repo's third execution
mode, next to the dense synchronous DDASimulator and the shard_map launcher).

Simulates DDA on a modeled cluster: priority-queue event clock
(netsim.events), heterogeneous node speeds + lossy/jittery links + optional
time-varying topology (netsim.network), async stale-gossip and drop-robust
push-sum nodes (netsim.node), scenario presets (netsim.scenarios) and the
driver with empirical-r recovery (netsim.simulator).
"""

from repro.netsim.events import Event, EventQueue
from repro.netsim.network import LinkModel, Network, NodeSpec
from repro.netsim.node import (AsyncDDANode, PushSumDDANode,
                               pushsum_mass_audit)
from repro.netsim.scenarios import (Scenario, homogeneous, lossy, straggler,
                                    time_varying_expander)
from repro.netsim.simulator import NetSimulator, RMeasurement
