"""Discrete-event simulation clock: a priority queue of timestamped events.

The netsim's single source of truth for time. Events are totally ordered by
(time, seq): `seq` is a monotone insertion counter, so simultaneous events
fire in schedule order and the whole simulation is deterministic for a fixed
seed (no dict/hash iteration order anywhere on the hot path).

Time is in the paper's normalized units: 1.0 = one full-data gradient on the
reference node (tradeoff.py eq. 9 normalization), so event timestamps are
directly comparable to `iteration_cost` / `time_to_accuracy` predictions.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any

__all__ = ["Event", "EventQueue"]


@dataclasses.dataclass(order=True, slots=True)
class Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    data: dict[str, Any] = dataclasses.field(compare=False,
                                             default_factory=dict)


class EventQueue:
    """Min-heap of events plus the simulation clock `now`.

    `now` only advances via `pop()`; scheduling in the past raises, so causal
    ordering cannot be violated by a buggy handler.
    """

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0
        self.now = 0.0

    def __len__(self) -> int:
        return len(self._heap)

    def empty(self) -> bool:
        return not self._heap

    def schedule(self, time: float, kind: str, **data: Any) -> Event:
        if time < self.now:
            raise ValueError(
                f"cannot schedule {kind!r} at {time} < now={self.now}")
        ev = Event(float(time), self._seq, kind, data)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def schedule_in(self, delay: float, kind: str, **data: Any) -> Event:
        return self.schedule(self.now + delay, kind, **data)

    def peek(self) -> Event:
        return self._heap[0]

    def pop(self) -> Event:
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev
