"""Discrete-event simulation clock: a priority queue of timestamped events.

The netsim's single source of truth for time. Events are totally ordered by
(time, prio, seq): `prio` ranks event KINDS at equal timestamps (message
arrivals before everything else -- see below), and `seq` is a monotone
insertion counter, so simultaneous same-kind events fire in schedule order
and the whole simulation is deterministic for a fixed seed (no dict/hash
iteration order anywhere on the hot path).

Why kind priority exists: the object engine interleaves message and
step-reschedule insertions per node, while the vectorized engine inserts a
whole batch's messages before its steps. Under pure (time, seq) order the
two engines could disagree ONLY when a message arrival tied a FUTURE step
completion to the exact float (link latency == remaining busy time to the
ulp) -- the one documented seam of the vectorized fast path. Ranking
in-flight arrivals ahead of other events at their (strictly future) target
time makes the insertion interleaving unobservable and closes that seam:
the engines are bit-identical even on constructed exact ties
(tests/test_netsim_engine.py::test_exact_float_tie_msg_vs_step_bit_identical).

The priority is deliberately NOT applied to a message scheduled at exactly
`now` (a zero-remaining-flight delivery emitted while processing the
current timestamp): simultaneous events must not causally affect each
other, so such a message stays behind the steps already due at `now` --
which is both engines' existing (and matching) behavior for the
ubiquitous zero-latency case. Non-tied timestamps are ordered by time
alone; all previously seeded traces are unchanged either way.

Two interchangeable backends behind the same API:

  * ``"heap"``     -- binary heap (heapq), O(log m) per operation. The
                      reference backend; always correct, never surprising.
  * ``"calendar"`` -- bucketed calendar queue (Brown 1988): events hash into
                      a circular array of time buckets of width w, inserts
                      bisect into their bucket, pops walk the calendar one
                      bucket per "day". For the netsim's workloads -- a
                      bounded number of in-flight events whose timestamps
                      cluster around now -- every operation is O(1)
                      amortized, which matters once the vectorized engine
                      has removed the per-node Python work and queue churn
                      is the next hot spot. The bucket count doubles when
                      the queue outgrows it, and the width is re-estimated
                      from observed inter-event gaps on each resize.

Both backends produce the exact same (time, prio, seq) total order,
including the tie-breaking of simultaneous events -- property-tested
against each other in tests/test_netsim_engine.py.

Time is in the paper's normalized units: 1.0 = one full-data gradient on the
reference node (tradeoff.py eq. 9 normalization), so event timestamps are
directly comparable to `iteration_cost` / `time_to_accuracy` predictions.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Any

__all__ = ["Event", "EventQueue"]


#: kinds that jump the queue at equal (strictly future) timestamps: message
#: arrivals. "fault" and "retry" events form their own classes below
#: arrivals but above everything else, so a crash scheduled at time tau
#: kills the node BEFORE its step completing at tau, identically on both
#: engines (whose seq numbering differs for batched vs per-node inserts).
#: Every other kind -- and an arrival at exactly `now` -- shares the lowest
#: class, preserving plain seq order among themselves.
_ARRIVAL_KINDS = frozenset({"msg", "msgs"})
_KIND_PRIO = {"fault": 1, "retry": 2}
_DEFAULT_PRIO = 3


@dataclasses.dataclass(order=True, slots=True)
class Event:
    time: float
    prio: int
    seq: int
    kind: str = dataclasses.field(compare=False)
    data: dict[str, Any] = dataclasses.field(compare=False,
                                             default_factory=dict)


class _HeapBackend:
    """Reference backend: one heapq entry per event."""

    __slots__ = ("_heap",)

    def __init__(self) -> None:
        self._heap: list[Event] = []

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, ev)

    def peek(self) -> Event:
        return self._heap[0]

    def pop(self) -> Event:
        return heapq.heappop(self._heap)


class _CalendarBackend:
    """Calendar queue: a circular array of sorted day-buckets.

    Every event is keyed by its absolute day ``day = floor(time / width)``
    and lives in bucket ``day % nb``. The queue walks days in order: the
    head of the current day's bucket is next iff its day matches; otherwise
    the calendar advances (fast-forwarding over empty stretches by scanning
    the heads of all buckets, which only happens when the queue is sparse
    relative to its year and is amortized against the events that put the
    calendar there).

    The in-this-day test recomputes ``_day_of(event.time)`` at pop time and
    compares it to the walker's day by exact integer equality -- immune to
    the float boundary cases that plague width-multiplication bound checks.
    This is consistent with the insert-side bucketing ONLY because
    ``_width`` never changes outside ``_resize``, which re-buckets every
    pending event under the new width; any future adaptive width retuning
    must do the same full re-insertion.

    Buckets are kept ascending by (time, seq) with a start-offset pointer
    instead of list.pop(0), so draining a bucket of m simultaneous events
    is O(m) total, not O(m^2). Because `seq` is globally monotone, the
    common insert (newest event among equal timestamps) lands at the tail
    of its bucket -- an O(log m) bisect plus an O(1) append.
    """

    __slots__ = ("_width", "_nb", "_buckets", "_starts", "_count", "_day")

    _MIN_WIDTH = 1e-12

    def __init__(self, width: float = 1.0, nbuckets: int = 8) -> None:
        self._width = float(width)
        self._nb = int(nbuckets)
        self._buckets: list[list[tuple[float, int, Event]]] = [
            [] for _ in range(self._nb)]
        self._starts = [0] * self._nb
        self._count = 0
        self._day = 0  # absolute day the calendar is currently serving

    def __len__(self) -> int:
        return self._count

    # -- internals ----------------------------------------------------------

    def _day_of(self, time: float) -> int:
        return int(time / self._width)

    def _insert(self, ev: Event) -> None:
        day = self._day_of(ev.time)
        b = self._buckets[day % self._nb]
        key = (ev.time, ev.prio, ev.seq, ev)
        if b and key < b[-1]:
            lo = self._starts[day % self._nb]
            bisect.insort(b, key, lo=lo)
        else:
            b.append(key)
        self._count += 1

    def _resize(self) -> None:
        """Double the bucket count and retune the width to the mean
        inter-event gap, then re-insert everything (O(m): each event is
        appended to a bucket and each bucket sorted once)."""
        events = [key for i, b in enumerate(self._buckets)
                  for key in b[self._starts[i]:]]
        times = sorted(key[0] for key in events)
        if len(times) >= 2 and times[-1] > times[0]:
            # mean gap over the occupied span; distinct-time collapse (all
            # events simultaneous) keeps the previous width instead
            width = (times[-1] - times[0]) / (len(times) - 1)
            self._width = max(width, self._MIN_WIDTH)
        self._nb *= 2
        self._buckets = [[] for _ in range(self._nb)]
        self._starts = [0] * self._nb
        if events:
            floor_day = min(self._day_of(key[0]) for key in events)
            self._day = min(self._day, floor_day)
        for key in sorted(events):
            day = self._day_of(key[0])
            self._buckets[day % self._nb].append(key)
        self._count = len(events)

    def _advance_to_next(self) -> None:
        """Move `_day` forward to the next day holding an event.

        Walks at most one full rotation bucket-by-bucket; if a whole year
        passes with nothing due, jumps straight to the earliest pending
        day (sparse-queue fast-forward)."""
        for _ in range(self._nb):
            idx = self._day % self._nb
            b = self._buckets[idx]
            s = self._starts[idx]
            if s < len(b) and self._day_of(b[s][0]) == self._day:
                return
            self._day += 1
        # full rotation without a hit: jump to the earliest pending event
        best = None
        for i, b in enumerate(self._buckets):
            s = self._starts[i]
            if s < len(b):
                d = self._day_of(b[s][0])
                if best is None or d < best:
                    best = d
        assert best is not None, "advance called on empty calendar"
        self._day = best

    # -- API ----------------------------------------------------------------

    def push(self, ev: Event) -> None:
        if not math.isfinite(ev.time):
            raise ValueError(f"calendar queue needs finite times, got {ev.time}")
        day = self._day_of(ev.time)
        if day < self._day:
            self._day = day  # pushing at/near `now`: rewind the walk
        self._insert(ev)
        if self._count > 2 * self._nb and self._nb < (1 << 20):
            self._resize()

    def _head(self) -> tuple[int, int]:
        """(bucket index, start offset) of the next event; advances days."""
        self._advance_to_next()
        idx = self._day % self._nb
        return idx, self._starts[idx]

    def peek(self) -> Event:
        if not self._count:
            raise IndexError("peek from an empty calendar queue")
        idx, s = self._head()
        return self._buckets[idx][s][-1]

    def pop(self) -> Event:
        if not self._count:
            raise IndexError("pop from an empty calendar queue")
        idx, s = self._head()
        b = self._buckets[idx]
        ev = b[s][-1]
        self._starts[idx] = s + 1
        self._count -= 1
        # compact lazily so a drained prefix doesn't pin memory
        if self._starts[idx] > 64 and self._starts[idx] * 2 >= len(b):
            del b[:self._starts[idx]]
            self._starts[idx] = 0
        return ev


class EventQueue:
    """Priority queue of events plus the simulation clock `now`.

    `now` only advances via `pop()`; scheduling in the past raises, so causal
    ordering cannot be violated by a buggy handler.

    `backend` selects the storage strategy ("heap" or "calendar", see module
    docstring); both realize the identical (time, prio, seq) total order,
    with prio derived from the event kind (message arrivals first).
    """

    def __init__(self, backend: str = "heap") -> None:
        if backend == "heap":
            self._q: _HeapBackend | _CalendarBackend = _HeapBackend()
        elif backend == "calendar":
            self._q = _CalendarBackend()
        else:
            raise ValueError(f"unknown EventQueue backend {backend!r}")
        self.backend = backend
        self._seq = 0
        self.now = 0.0

    def __len__(self) -> int:
        return len(self._q)

    def empty(self) -> bool:
        return len(self._q) == 0

    def schedule(self, time: float, kind: str, **data: Any) -> Event:
        if time < self.now:
            raise ValueError(
                f"cannot schedule {kind!r} at {time} < now={self.now}")
        prio = (0 if (kind in _ARRIVAL_KINDS and time > self.now)
                else _KIND_PRIO.get(kind, _DEFAULT_PRIO))
        ev = Event(float(time), prio, self._seq, kind, data)
        self._seq += 1
        self._q.push(ev)
        return ev

    def schedule_in(self, delay: float, kind: str, **data: Any) -> Event:
        return self.schedule(self.now + delay, kind, **data)

    def peek(self) -> Event:
        return self._q.peek()

    def pop(self) -> Event:
        ev = self._q.pop()
        self.now = ev.time
        return ev
