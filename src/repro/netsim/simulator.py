"""Event-driven asynchronous cluster simulator for DDA.

The third execution mode next to `core.dda.DDASimulator` (dense, synchronous,
one device) and `launch/` (shard_map, real collectives): a discrete-event
simulation of a *cluster* -- heterogeneous node speeds, per-link latency /
bandwidth / jitter / loss, and optionally a time-varying topology -- running
asynchronous stale-gossip DDA or drop-robust push-sum DDA.

Traces come out `SimTrace`-compatible but on a WALL-CLOCK time axis: sim_time
is the event-clock timestamp of each evaluation, not the closed-form
`iters * (1/n + k r)` charge of the dense simulator. That makes the paper's
predictions falsifiable here: `measure_r_empirical()` recovers r from the
observed message flights and step durations exactly as the paper measures it
on its cluster (r = t_msg / t_full_grad), and `predict()` feeds that
empirical r back into `core.tradeoff.h_opt` / `n_opt_complete` /
`time_to_accuracy` for closed-loop prediction-vs-observation checks
(benchmarks/fig_async.py).

Two engines drive the event loop (netsim.engine): the per-node `"object"`
reference and the struct-of-arrays `"vectorized"` fast path, selected by the
`engine` constructor argument. `"auto"` (the default) picks the vectorized
engine -- every scenario the presets can express is compatible with it, and
it is bit-identical to the object engine on seeded runs (the equivalence is
regression-tested, see tests/test_netsim_engine.py) while being orders of
magnitude faster at large n (benchmarks/bench_netsim.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import tradeoff as _tradeoff
from repro.core.dda import SimTrace, stepsize_sqrt, trace_time_to_reach
from repro.core.schedules import CommSchedule, EveryIteration
from repro.netsim.engine import ObjectEngine, VectorizedEngine, _EvalBatch, \
    _GradBatch
from repro.netsim.node import AsyncDDANode, GradFn, PushSumDDANode
from repro.netsim.scenarios import Scenario

__all__ = ["NetSimulator", "RMeasurement"]

_ENGINES = ("object", "vectorized", "auto")


@dataclasses.dataclass(frozen=True)
class RMeasurement:
    """Empirical communication/computation tradeoff from an event timeline,
    measured the way the paper measures it on its cluster (section V.A)."""

    r: float                  # t_msg / t_grad_full
    t_msg: float              # mean observed send->receive time per message
    t_grad_full: float        # median local step time * n (full-data grad)
    n_messages: int
    n_steps: int
    drop_rate: float          # fraction of messages lost in flight


class NetSimulator:
    """Drives one scenario to completion on the event clock.

    Args:
      scenario: cluster description (see netsim.scenarios).
      grad_fn: (node_index, x_i, t) -> subgradient of f_i at x_i; t is the
        0-indexed iteration counter, matching DDASimulator's subgrad_fn
        convention. May close over jitted jax functions; must return
        something `np.asarray` accepts.
      eval_fn: x -> scalar F(x) on the full objective. If it also accepts a
        stacked (n, d) batch and returns one scalar per node, trace
        evaluation happens in a single call (probed, verified bitwise).
      a_fn: stepsize a(t); default `core.dda.stepsize_sqrt(1.0)`, the same
        closure the dense simulator defaults to.
      schedule: communication schedule shared by all nodes (local iteration
        counts -- nodes drift apart in wall-clock, not in schedule logic).
      algorithm: "dda" (stale gossip) or "pushsum" (drop-robust ratio
        consensus; required for convergence under heavy loss or directed
        links).
      engine: "object" (per-node reference), "vectorized" (struct-of-arrays
        fast path), or "auto" (vectorized; bit-identical on seeded runs).
      batch_grad_fn: optional batched gradient `(idx, x_stack, t_array) ->
        (b, d)`; e.g. `engine.jax_batch_grad(grad_fn)` for a jitted
        `jax.vmap` path. When absent, `grad_fn` itself is probed with a
        stacked batch and used batched only if bitwise-equal to the loop.
      controller: optional `repro.adaptive.AdaptiveController` -- closes
        the measure->predict->act loop online: both engines feed it step
        durations and message flights and let it splice a re-solved h into
        its AdaptiveSchedule at the iteration frontier. The controller's
        schedule becomes the run's schedule (passing a different
        `schedule=` too is an error); with `controller=None` the engines
        run their uncontrolled (bit-identical) event loops.
      tracer: optional `repro.obs.Tracer`. With `tracer.detail` set, both
        engines emit per-event sim-time spans (node steps, message
        flights) and instants (drops, rewires, evals) -- purely observing
        the records they already produce, behind the same single-branch
        pattern as the controller hooks, so traced runs stay bit-identical
        to untraced ones. A non-detail (or absent) tracer never enters the
        event loops at all.
      faults: optional `repro.faults.FaultPlan` -- deterministic, seeded
        fault injection (crashes, restarts, joins, leaves, partitions,
        flapping links) executed as first-class simulation events by BOTH
        engines, which stay bit-identical under every plan. Requires
        algorithm="dda". After `run()`, `fault_stats` holds the counters
        (crashes/restarts/downtime_sim/partition_epochs/...).
      pushsum_inject: "plain" (default, textbook y += grad) or "scaled"
        (y += w * grad): under sustained loss the scaled form keeps the
        injected gradient at its true magnitude through the ratio estimate
        instead of amplifying it by 1/w (see PushSumDDANode). Push-sum
        only; opt-in because it changes seeded trajectories.
      compression: optional `repro.compress.Compressor` -- every gossip
        payload is compressed on the sender with error feedback (residuals
        live on the sender; receivers see dequantized/dense-layout
        messages, so the stale-mix code is unchanged) and the network's
        `wire_bytes` is scaled by the compressor's byte model, so
        bandwidth-limited links serialize compressed messages
        proportionally faster. Requires algorithm="dda"; both engines stay
        bit-identical because `compress_np` is a pure function of
        (message, node, stamp). Mutually exclusive with `faults`
        (checkpoint rows do not carry residual state).
    """

    def __init__(self, scenario: Scenario, grad_fn: GradFn,
                 eval_fn: Callable[[np.ndarray], float],
                 a_fn: Callable[[float], float] | None = None,
                 schedule: CommSchedule | None = None,
                 projection: Callable[[np.ndarray], np.ndarray] | None = None,
                 algorithm: str = "dda", seed: int = 0,
                 pushsum_y0: np.ndarray | None = None,
                 pushsum_w_floor: float = 0.5,
                 engine: str = "auto",
                 batch_grad_fn: Callable | None = None,
                 controller=None,
                 tracer=None,
                 faults=None,
                 pushsum_inject: str = "plain",
                 compression=None):
        if algorithm not in ("dda", "pushsum"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        if engine not in _ENGINES:
            raise ValueError(f"unknown engine {engine!r} (one of {_ENGINES})")
        if pushsum_inject not in ("plain", "scaled"):
            raise ValueError(f"pushsum_inject must be 'plain' or 'scaled', "
                             f"got {pushsum_inject!r}")
        if pushsum_inject == "scaled" and algorithm != "pushsum":
            raise ValueError("pushsum_inject applies to push-sum only")
        if faults is not None:
            from repro.faults.plan import FaultPlan
            if not isinstance(faults, FaultPlan):
                raise TypeError(f"faults must be a repro.faults.FaultPlan, "
                                f"got {type(faults).__name__}")
            if algorithm != "dda":
                raise ValueError(
                    "fault injection requires algorithm='dda': push-sum's "
                    "cumulative sigma/rho mass counters make crash/restore "
                    "a different protocol (a restored node would replay "
                    "already-sent mass); stale-gossip DDA tolerates a "
                    "reset inbox by folding missing weight into the "
                    "self-loop")
            faults.validate_for(scenario.topology.n)
        if compression is not None:
            from repro.compress import Compressor
            if not isinstance(compression, Compressor):
                raise TypeError(
                    f"compression must be a repro.compress.Compressor, "
                    f"got {type(compression).__name__}")
            if compression.kind == "none":
                compression = None  # normalize: uncompressed runs stay
                # byte-for-byte the seed event loop
            elif algorithm != "dda":
                raise ValueError(
                    "compression requires algorithm='dda': push-sum ships "
                    "cumulative sigma mass counters whose DIFFERENCES carry "
                    "the information -- quantizing the cumulative totals "
                    "breaks the conservation invariant mass recovery "
                    "depends on")
            elif faults is not None:
                raise ValueError(
                    "compression and faults are mutually exclusive: "
                    "checkpoint/restore rows do not carry error-feedback "
                    "residual state, so a restored node would replay "
                    "compression error it already corrected")
        if controller is not None:
            if schedule is not None and schedule is not controller.schedule:
                raise ValueError(
                    "controller and schedule both given but disagree; pass "
                    "the controller's schedule (or neither)")
            if (getattr(controller, "reweight_gossip", False)
                    and algorithm != "dda"):
                raise ValueError(
                    "reweight_gossip applies to the stale-gossip mix only; "
                    "push-sum's mass splitting is its own weighting scheme")
            schedule = controller.schedule
        self.controller = controller
        self.tracer = tracer
        if controller is not None and tracer is not None:
            controller.attach_tracer(tracer)
        self.scenario = scenario
        self.grad_fn = grad_fn
        self.eval_fn = eval_fn
        self.a_fn = a_fn or stepsize_sqrt(1.0)
        self.schedule = schedule or EveryIteration()
        self.projection = projection
        self.algorithm = algorithm
        self.seed = seed
        self.pushsum_y0 = pushsum_y0
        self.pushsum_w_floor = pushsum_w_floor
        self.pushsum_inject = pushsum_inject
        self.faults = faults
        self.fault_stats: dict | None = None
        self.compression = compression
        self.engine = engine
        self.net = scenario.build_network()
        self._engine_inst: ObjectEngine | VectorizedEngine | None = None
        self._nodes_cache: list[AsyncDDANode | PushSumDDANode] | None = []
        # batch-capability probes persist across runs (the probe verdict is a
        # property of grad_fn/eval_fn, not of one run)
        self._grad_batch = _GradBatch(grad_fn, batch_grad_fn)
        self._eval_batch = _EvalBatch(eval_fn)
        # observability: the "profiler trace" measure_r_empirical reads
        self.msg_flights: list[float] = []
        self.compute_times: list[float] = []
        self.drops = 0
        self.sent = 0
        self.rewires = 0
        self.retransmits = 0
        # mean error-feedback residual norm per trace point (compression on)
        self.comp_res_norms: list[float] = []

    # -- lifecycle ----------------------------------------------------------

    def _resolve_engine(self) -> ObjectEngine | VectorizedEngine:
        if self.engine == "object":
            return ObjectEngine(self)
        # "vectorized" and "auto": every scenario the presets express is
        # vectorizable (jitter and per-edge link overrides fall back to
        # exact per-message sampling inside the engine)
        return VectorizedEngine(self)

    # -- main loop ----------------------------------------------------------

    def run(self, x0_stack: np.ndarray, T: int,
            eval_every: int = 25, time_limit: float = math.inf) -> SimTrace:
        """Run every node for T iterations (or until time_limit); returns a
        SimTrace whose sim_time axis is the event clock."""
        x0_stack = np.asarray(x0_stack, dtype=np.float64)
        n = self.net.n
        if x0_stack.shape[0] != n:
            raise ValueError(f"x0 must be stacked ({n}, ...)")
        # compression shrinks what crosses the wire: links keep their
        # calibrated bandwidth (bw = message_bytes / r) but serialize
        # wire_ratio(d) of the bytes, so r_effective = r * c on
        # bandwidth-limited links (and measure_r_empirical sees it)
        d = int(np.prod(x0_stack.shape[1:]))
        self.net.wire_bytes = self.net.message_bytes * (
            1.0 if self.compression is None
            else self.compression.wire_ratio(d))
        eng = self._resolve_engine()
        self._engine_inst = eng
        trace = eng.run(x0_stack, T, eval_every, time_limit)
        # mirror the engine's observability into the accumulating lists the
        # public API (and measure_r_empirical) reads
        self.msg_flights.extend(eng.msg_flights)
        self.compute_times.extend(eng.compute_times)
        self.drops += eng.drops
        self.sent += eng.sent
        self.rewires += eng.rewires
        self.retransmits += eng.retransmits
        self.comp_res_norms.extend(eng.comp_res_norms)
        if eng._fr is not None:
            self.fault_stats = eng._fr.stats()
        self._nodes_cache = None  # re-materialize lazily from the new state
        return trace

    @property
    def nodes(self) -> list[AsyncDDANode | PushSumDDANode]:
        """Per-node views of the final state. For the object engine these
        ARE the simulation's nodes; the vectorized engine materializes
        equivalent objects from its struct-of-arrays state on first access
        (so a 1000-node run that never inspects them pays nothing)."""
        if self._nodes_cache is None:
            self._nodes_cache = self._engine_inst.materialize_nodes()
        return self._nodes_cache

    # -- closed-loop measurement --------------------------------------------

    def measure_r_empirical(self) -> RMeasurement:
        """Recover r from the observed event timeline, as the paper does on
        its cluster: mean message send->receive time over the median node's
        full-data gradient time (median is robust to stragglers)."""
        if not self.msg_flights or not self.compute_times:
            raise ValueError("run() first (needs observed messages and steps)")
        t_msg = float(np.mean(self.msg_flights))
        t_full = float(np.median(self.compute_times)) * self.net.n
        return RMeasurement(
            r=_tradeoff.measure_r(t_msg, t_full),
            t_msg=t_msg,
            t_grad_full=t_full,
            n_messages=len(self.msg_flights),
            n_steps=len(self.compute_times),
            drop_rate=self.drops / max(self.sent, 1))

    def predict(self, eps: float, L: float = 1.0, R: float = 1.0) -> dict:
        """Closed-loop paper predictions from the EMPIRICAL r: optimal
        cluster size (eq. 11), optimal communication interval (eq. 21) and
        tau(eps) (eq. 10/20/30) for this topology + schedule."""
        m = self.measure_r_empirical()
        g = self.net.graph
        lam2 = g.lambda2()
        return {
            "r_empirical": m.r,
            "n_opt": _tradeoff.n_opt_complete(m.r),
            "h_opt": _tradeoff.h_opt_int(g.n, g.degree, m.r, lam2),
            "tau_eps": _tradeoff.time_to_accuracy(
                eps, g.n, g.degree, m.r, lam2, L, R, self.schedule),
            "measurement": m,
        }

    def time_to_reach(self, trace: SimTrace, eps_value: float,
                      use_consensus: bool = False) -> float:
        """Same contract as DDASimulator.time_to_reach, on the event clock."""
        return trace_time_to_reach(trace, eps_value, use_consensus)
