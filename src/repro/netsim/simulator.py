"""Event-driven asynchronous cluster simulator for DDA.

The third execution mode next to `core.dda.DDASimulator` (dense, synchronous,
one device) and `launch/` (shard_map, real collectives): a discrete-event
simulation of a *cluster* -- heterogeneous node speeds, per-link latency /
bandwidth / jitter / loss, and optionally a time-varying topology -- running
asynchronous stale-gossip DDA or drop-robust push-sum DDA.

Traces come out `SimTrace`-compatible but on a WALL-CLOCK time axis: sim_time
is the event-clock timestamp of each evaluation, not the closed-form
`iters * (1/n + k r)` charge of the dense simulator. That makes the paper's
predictions falsifiable here: `measure_r_empirical()` recovers r from the
observed message flights and step durations exactly as the paper measures it
on its cluster (r = t_msg / t_full_grad), and `predict()` feeds that
empirical r back into `core.tradeoff.h_opt` / `n_opt_complete` /
`time_to_accuracy` for closed-loop prediction-vs-observation checks
(benchmarks/fig_async.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import tradeoff as _tradeoff
from repro.core.dda import SimTrace, trace_time_to_reach
from repro.core.schedules import CommSchedule, EveryIteration
from repro.netsim.events import EventQueue
from repro.netsim.node import AsyncDDANode, GradFn, PushSumDDANode
from repro.netsim.scenarios import Scenario

__all__ = ["NetSimulator", "RMeasurement"]


@dataclasses.dataclass(frozen=True)
class RMeasurement:
    """Empirical communication/computation tradeoff from an event timeline,
    measured the way the paper measures it on its cluster (section V.A)."""

    r: float                  # t_msg / t_grad_full
    t_msg: float              # mean observed send->receive time per message
    t_grad_full: float        # median local step time * n (full-data grad)
    n_messages: int
    n_steps: int
    drop_rate: float          # fraction of messages lost in flight


class NetSimulator:
    """Drives one scenario to completion on the event clock.

    Args:
      scenario: cluster description (see netsim.scenarios).
      grad_fn: (node_index, x_i, t) -> subgradient of f_i at x_i; t is the
        0-indexed iteration counter, matching DDASimulator's subgrad_fn
        convention. May close over jitted jax functions; must return
        something `np.asarray` accepts.
      eval_fn: x -> scalar F(x) on the full objective.
      a_fn: stepsize a(t); default a(t) = 1/sqrt(t).
      schedule: communication schedule shared by all nodes (local iteration
        counts -- nodes drift apart in wall-clock, not in schedule logic).
      algorithm: "dda" (stale gossip) or "pushsum" (drop-robust ratio
        consensus; required for convergence under heavy loss or directed
        links).
    """

    def __init__(self, scenario: Scenario, grad_fn: GradFn,
                 eval_fn: Callable[[np.ndarray], float],
                 a_fn: Callable[[float], float] | None = None,
                 schedule: CommSchedule | None = None,
                 projection: Callable[[np.ndarray], np.ndarray] | None = None,
                 algorithm: str = "dda", seed: int = 0,
                 pushsum_y0: np.ndarray | None = None,
                 pushsum_w_floor: float = 0.5):
        if algorithm not in ("dda", "pushsum"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        self.scenario = scenario
        self.grad_fn = grad_fn
        self.eval_fn = eval_fn
        self.a_fn = a_fn or (lambda t: 1.0 / math.sqrt(max(t, 1.0)))
        self.schedule = schedule or EveryIteration()
        self.projection = projection
        self.algorithm = algorithm
        self.seed = seed
        self.pushsum_y0 = pushsum_y0
        self.pushsum_w_floor = pushsum_w_floor
        self.net = scenario.build_network()
        self.nodes: list[AsyncDDANode | PushSumDDANode] = []
        # observability: the "profiler trace" measure_r_empirical reads
        self.msg_flights: list[float] = []
        self.compute_times: list[float] = []
        self.drops = 0
        self.sent = 0
        self.rewires = 0

    # -- lifecycle ----------------------------------------------------------

    def _make_nodes(self, x0_stack: np.ndarray) -> None:
        n = self.net.n
        self.nodes = []
        for i in range(n):
            if self.algorithm == "pushsum":
                y0 = None if self.pushsum_y0 is None else self.pushsum_y0[i]
                node = PushSumDDANode(i, x0_stack[i], self.grad_fn, self.a_fn,
                                      self.schedule, self.projection, y0=y0,
                                      w_floor=self.pushsum_w_floor)
            else:
                node = AsyncDDANode(i, x0_stack[i], self.grad_fn, self.a_fn,
                                    self.schedule, self.projection)
            self.nodes.append(node)

    def _step_busy(self, i: int) -> float:
        """Wall-clock the node is occupied by its NEXT iteration: local
        gradient plus (on communication iterations) serializing k messages
        out the NIC -- eq. (9)'s 1/n + k*r, per node, per link model."""
        node = self.nodes[i]
        busy = self.net.local_step_time(i)
        if node.is_comm_next():
            busy += self.net.send_busy_time(i)
        return busy

    # -- main loop ----------------------------------------------------------

    def run(self, x0_stack: np.ndarray, T: int,
            eval_every: int = 25, time_limit: float = math.inf) -> SimTrace:
        """Run every node for T iterations (or until time_limit); returns a
        SimTrace whose sim_time axis is the event clock."""
        x0_stack = np.asarray(x0_stack, dtype=np.float64)
        n = self.net.n
        if x0_stack.shape[0] != n:
            raise ValueError(f"x0 must be stacked ({n}, ...)")
        self._make_nodes(x0_stack)
        rng = np.random.default_rng(self.seed)
        q = EventQueue()
        trace = SimTrace([], [], [], [], [])

        for i in range(n):
            q.schedule(self._step_busy(i), "step", node=i)
        if self.scenario.rewire_every is not None:
            q.schedule(self.scenario.rewire_every, "rewire")

        total_steps = 0
        next_eval = eval_every * n
        active = n

        while not q.empty():
            ev = q.pop()
            if ev.time > time_limit:
                break
            if ev.kind == "step":
                i = ev.data["node"]
                node = self.nodes[i]
                self.compute_times.append(self.net.local_step_time(i))
                msgs = node.finish_step(self.net)
                for dst, payload in msgs:
                    self.sent += 1
                    flight = self.net.sample_flight(i, dst, rng)
                    if flight is None:
                        self.drops += 1
                        continue
                    self.msg_flights.append(flight)
                    # serialization already stalled the sender (step busy);
                    # only propagation + jitter remains in the air
                    extra = max(flight - self.net.serialize_time(i, dst), 0.0)
                    q.schedule_in(extra, "msg", src=i, dst=dst,
                                  payload=payload)
                total_steps += 1
                if node.t < T:
                    q.schedule_in(self._step_busy(i), "step", node=i)
                else:
                    active -= 1
                if total_steps >= next_eval:
                    self._record(trace, q.now, total_steps)
                    next_eval += eval_every * n
            elif ev.kind == "msg":
                self.nodes[ev.data["dst"]].receive(ev.data["src"],
                                                   ev.data["payload"])
            elif ev.kind == "rewire":
                self.net.rewire()
                self.rewires += 1
                if active > 0:
                    q.schedule_in(self.scenario.rewire_every, "rewire")

        if not trace.iters or trace.iters[-1] * n < total_steps:
            self._record(trace, q.now, total_steps)
        return trace

    def _record(self, trace: SimTrace, now: float, total_steps: int) -> None:
        n = self.net.n
        xhat = np.stack([nd.xhat for nd in self.nodes])
        z = np.stack([nd.z_est for nd in self.nodes])
        zbar = z.mean(axis=0, keepdims=True)
        diff = (z - zbar).reshape(n, -1)
        trace.iters.append(total_steps // n)
        trace.sim_time.append(float(now))
        trace.fvals.append(float(np.mean([self.eval_fn(x) for x in xhat])))
        trace.fvals_consensus.append(float(self.eval_fn(xhat.mean(axis=0))))
        trace.comms.append(int(sum(nd.comm_iters for nd in self.nodes) // n))
        trace.disagreement.append(float(np.linalg.norm(diff, axis=-1).max()))

    # -- closed-loop measurement --------------------------------------------

    def measure_r_empirical(self) -> RMeasurement:
        """Recover r from the observed event timeline, as the paper does on
        its cluster: mean message send->receive time over the median node's
        full-data gradient time (median is robust to stragglers)."""
        if not self.msg_flights or not self.compute_times:
            raise ValueError("run() first (needs observed messages and steps)")
        t_msg = float(np.mean(self.msg_flights))
        t_full = float(np.median(self.compute_times)) * self.net.n
        return RMeasurement(
            r=_tradeoff.measure_r(t_msg, t_full),
            t_msg=t_msg,
            t_grad_full=t_full,
            n_messages=len(self.msg_flights),
            n_steps=len(self.compute_times),
            drop_rate=self.drops / max(self.sent, 1))

    def predict(self, eps: float, L: float = 1.0, R: float = 1.0) -> dict:
        """Closed-loop paper predictions from the EMPIRICAL r: optimal
        cluster size (eq. 11), optimal communication interval (eq. 21) and
        tau(eps) (eq. 10/20/30) for this topology + schedule."""
        m = self.measure_r_empirical()
        g = self.net.graph
        lam2 = g.lambda2()
        return {
            "r_empirical": m.r,
            "n_opt": _tradeoff.n_opt_complete(m.r),
            "h_opt": _tradeoff.h_opt_int(g.n, g.degree, m.r, lam2),
            "tau_eps": _tradeoff.time_to_accuracy(
                eps, g.n, g.degree, m.r, lam2, L, R, self.schedule),
            "measurement": m,
        }

    def time_to_reach(self, trace: SimTrace, eps_value: float,
                      use_consensus: bool = False) -> float:
        """Same contract as DDASimulator.time_to_reach, on the event clock."""
        return trace_time_to_reach(trace, eps_value, use_consensus)
