"""Execution engines for the event-driven cluster simulator.

Two backends drive the same simulation behind `NetSimulator`:

  * `ObjectEngine`     -- the reference: one Python `AsyncDDANode` /
    `PushSumDDANode` object per node, one event per message. Simple,
    obviously correct, and O(interpreter) per event -- fine up to ~100
    nodes, hopeless at 1000.

  * `VectorizedEngine` -- the fast path: all node state lives in
    struct-of-arrays form (stacked (n, d) arrays for z/x/xhat, an (n, n)
    latest-stamp matrix plus growable per-edge value pools for the
    stale-gossip inboxes, per-edge cumulative sigma/rho mass pools for
    push-sum), events are BATCH entries (one queue entry per set of node
    steps or message arrivals sharing a timestamp), and every update is
    applied to the whole due batch with vectorized numpy. Message payloads
    are index stamps into shared snapshot buffers -- no per-message numpy
    copy ever happens.

Equivalence contract
--------------------
On the same seeded scenario the two engines produce BIT-IDENTICAL traces
(`SimTrace` and `measure_r_empirical`), not merely statistically equivalent
ones. That works because every vectorized operation is arranged to perform
the exact same float64 operations in the exact same order as the per-node
loop:

  * the drop/jitter RNG is consumed in the object engine's event order
    (numpy `Generator` block draws are stream-identical to scalar draws);
  * batched stale mixing accumulates in-neighbor slots in slot order via
    `core.consensus.stale_combine_batch`, folding undelivered neighbors'
    weight into the self weight per row exactly like the object node;
  * the stepsize is evaluated once per distinct iteration counter with the
    same scalar call the object node makes, then scattered to the batch;
  * `np.add.at` applies push-sum mass deltas unbuffered in event order.

The engines' message and step-reschedule queue insertions interleave
differently (per node vs whole-batch), but the event clock's
(time, prio, seq) total order makes that unobservable: in-flight arrivals
rank ahead of other events at their exact (strictly future) timestamp, so
even a constructed latency == busy float tie pops identically under both
engines (netsim.events; regression-tested with an exact tie in
tests/test_netsim_engine.py). Everything else -- loss, stragglers,
rewiring, partial batches, mid-batch trace records -- is exact.

Closed-loop control
-------------------
Both engines thread an optional `repro.adaptive.AdaptiveController`
(`NetSimulator(controller=...)`) through the loop: step durations and kept
message flights feed its RTracker, rewires refresh its reweighter, and
after each step event `maybe_retune` may splice a new interval into the
shared AdaptiveSchedule at the ACTIVE-node iteration frontier. A splice
invalidates cached `next_comm` answers beyond the splice point, so the
engine refreshes exactly those from the mutated schedule; active nodes'
in-flight iterations are always at or before the frontier, so no
already-charged busy time or already-made communication decision is
rewritten. (A node that already FINISHED may have run ahead of a later
splice -- its executed history is recorded in its own counters and is
deliberately not what post-hoc schedule queries describe; see
AdaptiveController.maybe_retune.) With `controller=None` none of these
branches run and the engines remain bit-identical to their uncontrolled
behavior.

Gradient / objective batching
-----------------------------
`grad_fn(i, x_i, t)` is a per-node callable by contract. The vectorized
engine PROBES it once with a stacked batch `(idx_array, x_batch, t_array)`
and keeps the batched call only if the result is bitwise identical to the
per-node loop on that batch; otherwise it falls back to the loop forever.
Callers with a jax-traceable gradient can skip the probe and hand
`NetSimulator(batch_grad_fn=jax_batch_grad(fn))` a jitted
`jax.vmap` wrapper. `eval_fn` is probed the same way at the first trace
record, so trace evaluation stops dominating small-`eval_every` runs.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from repro.core.consensus import stale_combine_batch
from repro.core.dda import SimTrace
from repro.netsim.events import EventQueue
from repro.netsim.node import AsyncDDANode, PushSumDDANode

if TYPE_CHECKING:  # pragma: no cover
    from repro.netsim.simulator import NetSimulator

__all__ = ["ObjectEngine", "VectorizedEngine", "jax_batch_grad"]


def jax_batch_grad(grad_fn: Callable, jit: bool = True) -> Callable:
    """Wrap a jax-traceable per-node `grad_fn(i, x_i, t)` into the batched
    convention `(idx_array, x_batch, t_array) -> (b, d) ndarray` via
    `jax.vmap` (optionally jitted). Pass the result as
    `NetSimulator(batch_grad_fn=...)`; note jax's float32 default means this
    path trades the bit-identical guarantee for speed unless x64 is enabled.
    """
    import jax

    f = jax.vmap(grad_fn, in_axes=(0, 0, 0))
    if jit:
        f = jax.jit(f)

    def batched(idx: np.ndarray, x: np.ndarray, t: np.ndarray) -> np.ndarray:
        return np.asarray(f(idx, x, t), dtype=np.float64)

    return batched


# ---------------------------------------------------------------------------
# batch-capability probes (shared by both engines via NetSimulator)
# ---------------------------------------------------------------------------


class _GradBatch:
    """Resolves per-node vs batched gradient evaluation.

    Modes: "explicit" (caller-supplied batch_grad_fn), "batch" (probe found
    grad_fn itself batchable, verified bitwise), "loop" (per-node calls).
    """

    def __init__(self, grad_fn: Callable, batch_grad_fn: Callable | None):
        self.grad_fn = grad_fn
        self.batch_grad_fn = batch_grad_fn
        self.mode: str | None = "explicit" if batch_grad_fn is not None else None

    def _loop(self, idx: np.ndarray, x: np.ndarray, t: np.ndarray) -> np.ndarray:
        return np.stack([
            np.asarray(self.grad_fn(int(idx[j]), x[j], int(t[j])),
                       dtype=np.float64)
            for j in range(len(idx))])

    def __call__(self, idx: np.ndarray, x: np.ndarray, t: np.ndarray) -> np.ndarray:
        if self.mode == "explicit":
            return np.asarray(self.batch_grad_fn(idx, x, t), dtype=np.float64)
        if self.mode == "loop":
            return self._loop(idx, x, t)
        per = self._loop(idx, x, t)
        # probe once, keep batch only if bit-identical -- and only on a
        # batch of >= 2, since a scalar-style callable can accidentally
        # survive a size-1 probe (e.g. `if t > 0` is valid on a 1-element
        # array) and then crash on the first real batch
        if self.mode is None and len(idx) >= 2:
            try:
                batch = np.asarray(self.grad_fn(idx, x, t), dtype=np.float64)
                ok = batch.shape == per.shape and np.array_equal(batch, per)
            except Exception:
                ok = False
            self.mode = "batch" if ok else "loop"
        return per

    def batch_or_loop(self, idx, x, t):
        if self.mode == "batch":
            return np.asarray(self.grad_fn(idx, x, t), dtype=np.float64)
        return self(idx, x, t)


class _EvalBatch:
    """Probe whether eval_fn accepts a stacked (n, d) batch and returns one
    scalar per node; keep the batched call only if it reproduces the
    per-node loop bitwise on the probe batch."""

    def __init__(self, eval_fn: Callable[[np.ndarray], float]):
        self.eval_fn = eval_fn
        self.mode: str | None = None

    def mean(self, xhat_stack: np.ndarray) -> float:
        n = xhat_stack.shape[0]
        if self.mode == "batch":
            return float(np.mean(np.asarray(self.eval_fn(xhat_stack))))
        per = [self.eval_fn(x) for x in xhat_stack]
        if self.mode is None and n >= 2:  # see _GradBatch: size-1 probes lie
            try:
                batch = np.asarray(self.eval_fn(xhat_stack))
                ok = (batch.shape == (n,)
                      and all(float(batch[j]) == float(per[j])
                              for j in range(n)))
            except Exception:
                ok = False
            self.mode = "batch" if ok else "loop"
        return float(np.mean(per))


class _RowBatch:
    """Same probe for a row-wise map (projection): batch if bitwise equal."""

    def __init__(self, fn: Callable[[np.ndarray], np.ndarray]):
        self.fn = fn
        self.mode: str | None = None

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        if self.mode == "batch":
            return np.asarray(self.fn(rows), dtype=np.float64)
        per = np.stack([np.asarray(self.fn(r), dtype=np.float64)
                        for r in rows])
        if self.mode is None and len(rows) >= 2:  # see _GradBatch: a size-1
            try:                                  # probe can lie
                batch = np.asarray(self.fn(rows), dtype=np.float64)
                ok = batch.shape == per.shape and np.array_equal(batch, per)
            except Exception:
                ok = False
            self.mode = "batch" if ok else "loop"
        return per


def _record_stacks(sim: "NetSimulator", trace: SimTrace, now: float,
                   total_steps: int, n: int, xhat: np.ndarray, z: np.ndarray,
                   comm_total: int, mask: np.ndarray | None = None) -> None:
    """Shared trace-point writer; both engines feed it stacked state.

    `mask` (fault injection only) restricts the objective / disagreement
    statistics to live member rows -- a crashed node's frozen iterate must
    not be averaged into the trace point. `iters` stays normalized by the
    full n so fault-free and faulted traces share an x-axis."""
    if mask is not None:
        xhat = xhat[mask]
        z = z[mask]
    zbar = z.mean(axis=0, keepdims=True)
    diff = (z - zbar).reshape(len(z), -1)
    trace.iters.append(total_steps // n)
    trace.sim_time.append(float(now))
    trace.fvals.append(sim._eval_batch.mean(xhat))
    trace.fvals_consensus.append(float(sim.eval_fn(xhat.mean(axis=0))))
    trace.comms.append(int(comm_total // n))
    trace.disagreement.append(float(np.linalg.norm(diff, axis=-1).max()))


# ---------------------------------------------------------------------------
# object engine (reference)
# ---------------------------------------------------------------------------


class ObjectEngine:
    """Per-node reference engine: one Python object per node, one event per
    message, a heapq event clock. This is PR 1's loop, extracted."""

    name = "object"

    def __init__(self, sim: "NetSimulator"):
        self.sim = sim
        self.net = sim.net
        self.nodes: list[AsyncDDANode | PushSumDDANode] = []
        self.msg_flights: list[float] = []
        self.compute_times: list[float] = []
        self.drops = 0
        self.sent = 0
        self.rewires = 0
        self.retransmits = 0
        # mean per-node error-feedback residual norm at each trace point
        # (empty when sim.compression is None)
        self.comp_res_norms: list[float] = []
        self._fr = None  # FaultRuntime when sim.faults is set
        # detail tracing resolves to one pre-computed local, so the hot
        # path carries exactly one `if tr is not None` branch per event
        # kind (the controller-hook pattern); a non-detail tracer is
        # equivalent to none at all here.
        tracer = getattr(sim, "tracer", None)
        self._tr = tracer if (tracer is not None and tracer.detail) else None

    def _make_nodes(self, x0_stack: np.ndarray) -> None:
        sim = self.sim
        self.nodes = []
        for i in range(self.net.n):
            if sim.algorithm == "pushsum":
                y0 = None if sim.pushsum_y0 is None else sim.pushsum_y0[i]
                node = PushSumDDANode(i, x0_stack[i], sim.grad_fn, sim.a_fn,
                                      sim.schedule, sim.projection, y0=y0,
                                      w_floor=sim.pushsum_w_floor,
                                      inject=sim.pushsum_inject)
            else:
                node = AsyncDDANode(i, x0_stack[i], sim.grad_fn, sim.a_fn,
                                    sim.schedule, sim.projection,
                                    compression=sim.compression)
            self.nodes.append(node)

    def _step_busy(self, i: int) -> float:
        """Wall-clock the node is occupied by its NEXT iteration: local
        gradient plus (on communication iterations) serializing k messages
        out the NIC -- eq. (9)'s 1/n + k*r, per node, per link model."""
        node = self.nodes[i]
        busy = self.net.local_step_time(i)
        if node.is_comm_next():
            busy += self.net.send_busy_time(i)
        return busy

    def run(self, x0_stack: np.ndarray, T: int, eval_every: int,
            time_limit: float) -> SimTrace:
        sim, net = self.sim, self.net
        n = net.n
        ctrl = sim.controller
        if ctrl is not None:
            ctrl.bind(net)  # resets the schedule's splice history, so it
            # must run BEFORE nodes cache their next_comm answers
        self._make_nodes(x0_stack)
        flt = None
        if sim.faults is not None:
            from repro.faults.runtime import FaultRuntime
            flt = FaultRuntime(sim.faults, n, tracer=sim.tracer)
        self._fr = flt
        self._T = T
        rng = np.random.default_rng(sim.seed)
        self.q = q = EventQueue(backend="heap")
        trace = SimTrace([], [], [], [], [])
        tr = self._tr
        retry_on = (net.link.retries > 0
                    or any(l.retries > 0 for l in net.link_overrides.values()))

        for i in range(n):
            if flt is None:
                q.schedule(self._step_busy(i), "step", node=i)
            else:
                q.schedule(self._step_busy(i), "step", node=i, gen=0)
        if sim.scenario.rewire_every is not None:
            q.schedule(sim.scenario.rewire_every, "rewire")
        if flt is not None:
            flt.bind(self)
            flt.schedule_initial(q)

        total_steps = 0
        next_eval = eval_every * n
        self.active = n

        while not q.empty():
            ev = q.pop()
            if ev.time > time_limit:
                break
            if ev.kind == "step":
                i = ev.data["node"]
                if flt is not None and (not flt.alive[i]
                                        or ev.data["gen"] != flt.step_gen[i]):
                    continue  # stale generation: node crashed/left meanwhile
                node = self.nodes[i]
                step_dur = net.local_step_time(i)
                self.compute_times.append(step_dur)
                if tr is not None:
                    tr.add_span("step", ev.time - step_dur, step_dur,
                                track=f"node{i}", node=i, t=int(node.t) + 1)
                n_flights = len(self.msg_flights)
                msgs = node.finish_step(net)
                for dst, payload in msgs:
                    if flt is not None and flt.blocked[i, dst]:
                        # partitioned/flapped link: refused at send time,
                        # BEFORE any loss/jitter draw, so the optimization
                        # RNG stream is identical to the unblocked run's
                        flt.blocked_sends += 1
                        continue
                    self.sent += 1
                    flight = net.sample_flight(i, dst, rng)
                    if flight is None:
                        self.drops += 1
                        if tr is not None:
                            tr.add_instant("drop", ev.time, track="net",
                                           src=i, dst=dst)
                        if retry_on:
                            link = net.link_for(i, dst)
                            if link.retries > 0:
                                q.schedule_in(link.retry_timeout, "retry",
                                              src=i, dst=dst,
                                              payload=payload, attempt=1)
                        continue
                    self.msg_flights.append(flight)
                    if tr is not None:
                        tr.add_span("flight", ev.time, flight, track="net",
                                    src=i, dst=dst)
                    # serialization already stalled the sender (step busy);
                    # only propagation + jitter remains in the air
                    extra = max(flight - net.serialize_time(i, dst), 0.0)
                    q.schedule_in(extra, "msg", src=i, dst=dst,
                                  payload=payload)
                total_steps += 1
                if node.t < T:
                    if flt is None:
                        q.schedule_in(self._step_busy(i), "step", node=i)
                    else:
                        q.schedule_in(self._step_busy(i), "step", node=i,
                                      gen=int(flt.step_gen[i]))
                else:
                    self.active -= 1
                if total_steps >= next_eval:
                    self._record(trace, q.now, total_steps)
                    next_eval += eval_every * n
                if ctrl is not None:
                    ctrl.on_steps(np.array([i]), np.array([step_dur]))
                    ctrl.on_messages(
                        np.asarray(self.msg_flights[n_flights:]))
                    if ctrl.retune_due(q.now):
                        # frontier over STILL-ACTIVE nodes: finished ones
                        # no longer constrain the future pattern (nor do
                        # crashed/departed ones, whose t is frozen)
                        front = max(
                            (nd.t for j, nd in enumerate(self.nodes)
                             if nd.t < T and (flt is None or
                                              (flt.alive[j]
                                               and flt.member[j]))),
                            default=None)
                        cut = (ctrl.maybe_retune(q.now, front + 1)
                               if front is not None else None)
                        if cut is not None:
                            self._refresh_next_comm(cut)
            elif ev.kind == "msg":
                if flt is not None and not (flt.alive[ev.data["src"]]
                                            and flt.alive[ev.data["dst"]]):
                    continue  # landed during downtime: silently dropped
                self.nodes[ev.data["dst"]].receive(ev.data["src"],
                                                   ev.data["payload"])
            elif ev.kind == "retry":
                src, dst = ev.data["src"], ev.data["dst"]
                if flt is not None and (not flt.alive[src]
                                        or flt.blocked[src, dst]):
                    continue  # no RNG draw: state-identical on both engines
                self.sent += 1
                self.retransmits += 1
                flight = net.sample_flight(src, dst, rng)
                if flight is None:
                    self.drops += 1
                    attempt = ev.data["attempt"]
                    link = net.link_for(src, dst)
                    if attempt < link.retries:
                        q.schedule_in(
                            link.retry_timeout
                            * link.retry_backoff ** attempt,
                            "retry", src=src, dst=dst,
                            payload=ev.data["payload"], attempt=attempt + 1)
                else:
                    self.msg_flights.append(flight)
                    if tr is not None:
                        tr.add_span("flight", ev.time, flight, track="net",
                                    src=src, dst=dst, retry=True)
                    if ctrl is not None:
                        ctrl.on_messages(np.array([flight]))
                    # the sender is NOT busy-charged for a retransmit, so
                    # the full flight (serialize + propagate) is in the air
                    q.schedule_in(flight, "msg", src=src, dst=dst,
                                  payload=ev.data["payload"])
            elif ev.kind == "fault":
                flt.handle(q, ev.data)
            elif ev.kind == "rewire":
                net.rewire()
                self.rewires += 1
                if tr is not None:
                    tr.add_instant("rewire", ev.time, track="net")
                if ctrl is not None:
                    ctrl.on_rewire(net.graph)
                if self.active > 0:
                    q.schedule_in(sim.scenario.rewire_every, "rewire")

        if not trace.iters or trace.iters[-1] * n < total_steps:
            self._record(trace, q.now, total_steps)
        return trace

    def _refresh_next_comm(self, cut: int) -> None:
        """A schedule splice at `cut` invalidated cached next-comm answers
        beyond it; re-query the mutated schedule for exactly those. Values
        at or before the cut are still correct (the past is immutable under
        the mutation protocol)."""
        sched = self.sim.schedule
        for nd in self.nodes:
            if nd.next_comm > cut:
                nd.next_comm = sched.next_comm_step(nd.t)

    def _record(self, trace: SimTrace, now: float, total_steps: int) -> None:
        n = self.net.n
        xhat = np.stack([nd.xhat for nd in self.nodes])
        z = np.stack([nd.z_est for nd in self.nodes])
        comm_total = sum(nd.comm_iters for nd in self.nodes)
        if self._tr is not None:
            self._tr.add_instant("eval", now, track="net",
                                 steps=int(total_steps))
        mask = self._fr.record_mask() if self._fr is not None else None
        if self.sim.compression is not None:
            res = np.stack([nd._comp_res for nd in self.nodes])
            self.comp_res_norms.append(float(np.mean(
                np.linalg.norm(res.reshape(n, -1), axis=1))))
        _record_stacks(self.sim, trace, now, total_steps, n, xhat, z,
                       comm_total, mask=mask)

    def materialize_nodes(self) -> list:
        return self.nodes

    # -- fault-injection adapter (driven by repro.faults.FaultRuntime) -------
    # Both engines expose this same surface; the runtime keeps all fault
    # bookkeeping in shared code so the engines stay bit-identical under
    # every plan. `self.active` (live unfinished nodes) is the shared
    # termination counter the runtime reads to stop rescheduling its
    # recurring events.

    def fault_state(self) -> dict:
        """Stacked copies of the mutable per-node state (the checkpoint /
        warm-start snapshot)."""
        return {"x": np.stack([nd.x for nd in self.nodes]),
                "xhat": np.stack([nd.xhat for nd in self.nodes]),
                "z": np.stack([nd.z for nd in self.nodes]),
                "t": np.array([nd.t for nd in self.nodes], dtype=np.int64),
                "comm_iters": np.array([nd.comm_iters for nd in self.nodes],
                                       dtype=np.int64)}

    def fault_apply_node(self, j: int, row: dict) -> None:
        nd = self.nodes[j]
        nd.x = np.array(row["x"], dtype=np.float64)
        nd.xhat = np.array(row["xhat"], dtype=np.float64)
        nd.z = np.array(row["z"], dtype=np.float64)
        nd.t = int(row["t"])
        nd.comm_iters = int(row["comm_iters"])
        nd.next_comm = int(row["next_comm"])

    def fault_clear_inbox(self, j: int) -> None:
        """Forget j's gossip everywhere: receivers fold the missing weight
        back into their self-loop (degraded_matrix semantics) and j itself
        restarts with an empty inbox."""
        self.nodes[j].inbox.clear()
        for nd in self.nodes:
            nd.inbox.pop(j, None)

    def fault_deactivate(self, j: int) -> None:
        if self.nodes[j].t < self._T:
            self.active -= 1

    def fault_activate(self, j: int) -> None:
        if self.nodes[j].t < self._T:
            self.active += 1
            self.q.schedule_in(self._step_busy(j), "step", node=j,
                               gen=int(self._fr.step_gen[j]))

    def fault_next_comm(self, t: int) -> int:
        return int(self.sim.schedule.next_comm_step(int(t)))

    def fault_splice_graph(self, g) -> None:
        from repro.core.graphs import GraphSequence
        self.net.seq = GraphSequence((g,))
        self.net.epoch = 0
        self.net._out_cache.clear()

    def fault_notify_membership(self, sub_graph, members) -> None:
        ctrl = self.sim.controller
        if ctrl is not None:
            ctrl.on_membership(sub_graph, members)

    def fault_notify_heal(self, now: float) -> None:
        ctrl = self.sim.controller
        if ctrl is not None:
            ctrl.on_partition_heal(now)


# ---------------------------------------------------------------------------
# vectorized engine
# ---------------------------------------------------------------------------


class _EdgeStore:
    """Growable per-directed-edge row store: `eid[a, b]` maps an (a, b) pair
    to a row in the value pools, allocated (zero-initialized) on first
    touch. This is how (n, n, d)-shaped per-link state (inbox values,
    push-sum sigma/rho mass) stays O(edges seen), not O(n^2 d)."""

    __slots__ = ("eid", "y", "w", "size", "_tail", "_scalar")

    def __init__(self, n: int, tail: tuple[int, ...], scalar: bool = False):
        self.eid = np.full((n, n), -1, dtype=np.int64)
        self._tail = tail
        self._scalar = scalar
        self.size = 0
        self.y = np.zeros((0,) + tail, dtype=np.float64)
        self.w = np.zeros(0, dtype=np.float64) if scalar else None

    def _ensure(self, need: int) -> None:
        cap = len(self.y)
        if need <= cap:
            return
        cap = max(16, cap)
        while cap < need:
            cap *= 2
        y = np.zeros((cap,) + self._tail, dtype=np.float64)
        y[:self.size] = self.y[:self.size]
        self.y = y
        if self._scalar:
            w = np.zeros(cap, dtype=np.float64)
            w[:self.size] = self.w[:self.size]
            self.w = w

    def rows(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Row indices for (a, b) pairs, allocating missing ones. Pairs must
        be unique within the call (callers guarantee this; duplicate-pair
        batches go through the scalar fallback paths)."""
        r = self.eid[a, b]
        miss = r < 0
        if miss.any():
            m = int(miss.sum())
            self._ensure(self.size + m)
            self.eid[a[miss], b[miss]] = np.arange(self.size, self.size + m)
            self.size += m
            r = self.eid[a, b]
        return r

    def row1(self, a: int, b: int) -> int:
        r = int(self.eid[a, b])
        if r < 0:
            self._ensure(self.size + 1)
            r = self.size
            self.eid[a, b] = r
            self.size += 1
        return r


class VectorizedEngine:
    """Struct-of-arrays engine: batched event processing over stacked node
    state. See the module docstring for the equivalence contract."""

    name = "vectorized"

    def __init__(self, sim: "NetSimulator"):
        self.sim = sim
        self.net = sim.net
        self.algorithm = sim.algorithm
        self.drops = 0
        self.sent = 0
        self.rewires = 0
        self.retransmits = 0
        # mean per-node error-feedback residual norm at each trace point
        # (empty when sim.compression is None)
        self.comp_res_norms: list[float] = []
        self._fr = None  # FaultRuntime when sim.faults is set
        self._retry_on = False
        self._flight_chunks: list[np.ndarray] = []
        self._compute_chunks: list[np.ndarray] = []
        self._a_cache: dict[float, float] = {}
        self._epoch_cache: dict[int, tuple] = {}
        self._proj = (_RowBatch(sim.projection)
                      if sim.projection is not None else None)
        self._ctrl = None  # bound per-run in run()
        self._mw_cache: tuple | None = None  # (W, S_in, Wslot, Wdiag)
        # same detail-tracing contract as ObjectEngine: one branch per
        # event BATCH here (the engine's own batching amortizes it)
        tracer = getattr(sim, "tracer", None)
        self._tr = tracer if (tracer is not None and tracer.detail) else None

    # -- observability (same contract as ObjectEngine's lists) --------------

    @property
    def msg_flights(self) -> list[float]:
        if not self._flight_chunks:
            return []
        return np.concatenate(self._flight_chunks).tolist()

    @property
    def compute_times(self) -> list[float]:
        if not self._compute_chunks:
            return []
        return np.concatenate(self._compute_chunks).tolist()

    # -- topology / timing caches -------------------------------------------

    def _rebuild_topology(self) -> None:
        net = self.net
        idx = net.epoch % len(net.seq)
        cached = self._epoch_cache.get(idx)
        if cached is None:
            g = net.seq.at(idx)
            n, k = g.n, g.degree
            S_in = np.empty((n, k), dtype=np.int64)
            S_out = np.empty((n, k), dtype=np.int64)
            ar = np.arange(n)
            for slot, perm in enumerate(g.perms):
                p = np.asarray(perm, dtype=np.int64)
                S_in[:, slot] = p          # receiver i hears from perm[i]
                S_out[p, slot] = ar        # sender perm[i] ships to i
            # NIC occupancy per full gossip round, accumulated link-by-link
            # in the object engine's out-neighbor order so the float result
            # matches its Python `sum()` bitwise.
            send_busy = np.zeros(n, dtype=np.float64)
            if net.link_overrides:
                for i in range(n):
                    busy = 0.0
                    for slot in range(k):
                        busy += net.serialize_time(i, int(S_out[i, slot]))
                    send_busy[i] = busy
            else:
                busy, s = 0.0, net.link.serialize(net.wire_bytes)
                for _ in range(k):
                    busy += s
                send_busy[:] = busy
            cached = (g, S_in, S_out, send_busy)
            self._epoch_cache[idx] = cached
        self.graph, self.S_in, self.S_out, self.send_busy = cached
        self.k = self.graph.degree

    # -- flight sampling (RNG consumed in the object engine's order) ---------

    def _sample_flights(self, srcs: np.ndarray, dsts: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(keep, flight, extra) per message, node-major slot-minor order."""
        m = len(srcs)
        net, rng = self.net, self.rng
        link = net.link
        if not net.link_overrides and link.jitter == 0.0:
            if link.loss > 0.0:
                keep = rng.random(m) >= link.loss
            else:
                keep = np.ones(m, dtype=bool)
            s = link.serialize(net.wire_bytes)
            flight = s + link.latency
            extra = max(flight - s, 0.0)
            return (keep, np.full(m, flight), np.full(m, extra))
        # jitter or per-edge overrides: exact per-message sampling
        keep = np.zeros(m, dtype=bool)
        flights = np.zeros(m, dtype=np.float64)
        extras = np.zeros(m, dtype=np.float64)
        for j in range(m):
            src, dst = int(srcs[j]), int(dsts[j])
            f = net.sample_flight(src, dst, rng)
            if f is None:
                continue
            keep[j] = True
            flights[j] = f
            extras[j] = max(f - net.serialize_time(src, dst), 0.0)
        return keep, flights, extras

    def _ship(self, srcs, dsts, payload: dict[str, Any]) -> None:
        """Sample flights for a flat message batch and schedule arrival
        groups (one queue entry per distinct arrival time)."""
        fr = self._fr
        if fr is not None:
            # partitioned/flapped links refuse at send time BEFORE any
            # loss/jitter draw (matching the object engine's per-message
            # skip), keeping the optimization RNG stream untouched
            ok = ~fr.blocked[srcs, dsts]
            if not ok.all():
                fr.blocked_sends += int((~ok).sum())
                if not ok.any():
                    return
                srcs, dsts = srcs[ok], dsts[ok]
                payload = {key: (val if key == "buf" else val[ok])
                           for key, val in payload.items()}
        m = len(srcs)
        self.sent += m
        keep, flights, extras = self._sample_flights(srcs, dsts)
        n_drop = int(m - keep.sum())
        self.drops += n_drop
        if self._tr is not None and n_drop:
            self._tr.add_instant("drop", self.q.now, track="net",
                                 count=n_drop)
        if n_drop and self._retry_on:
            # queue a retry per dropped message, in message (index) order --
            # the same order the object engine's per-message loop uses
            for j in np.nonzero(~keep)[0]:
                src, dst = int(srcs[j]), int(dsts[j])
                link = self.net.link_for(src, dst)
                if link.retries <= 0:
                    continue
                pl = {key: val[j:j + 1].copy()
                      for key, val in payload.items() if key != "buf"}
                pl["buf"] = payload["buf"][int(payload["rows"][j])][None].copy()
                pl["rows"] = np.zeros(1, dtype=np.int64)
                self.q.schedule_in(link.retry_timeout, "retry", src=src,
                                   dst=dst, payload=pl, attempt=1)
        if not keep.any():
            return
        ks = np.nonzero(keep)[0]
        self._flight_chunks.append(flights[ks])
        if self._tr is not None:
            self._tr.add_spans("flight", np.full(len(ks), self.q.now),
                               flights[ks], track="net")
        if self._ctrl is not None:
            self._ctrl.on_messages(flights[ks])
        arrivals = self.q.now + extras[ks]
        times, inv = np.unique(arrivals, return_inverse=True)
        for u, tm in enumerate(times):
            sel = ks[inv == u]
            data = {key: val[sel] for key, val in payload.items()
                    if key != "buf"}
            if "buf" in payload:
                data["buf"] = payload["buf"]
            self.q.schedule(float(tm), "msgs", srcs=srcs[sel],
                            dsts=dsts[sel], **data)

    # -- stepsize (scalar calls, scattered to the batch) ---------------------

    def _a_batch(self, t_new: np.ndarray) -> np.ndarray:
        uniq, inv = np.unique(t_new, return_inverse=True)
        vals = np.empty(len(uniq), dtype=np.float64)
        for j, u in enumerate(uniq):
            u = float(u)
            a = self._a_cache.get(u)
            if a is None:
                a = float(self.sim.a_fn(u))
                self._a_cache[u] = a
            vals[j] = a
        return vals[inv]

    def _col(self, v: np.ndarray) -> np.ndarray:
        return v.reshape(v.shape[0], *([1] * len(self.tail)))

    # -- lifecycle ------------------------------------------------------------

    def _init_state(self, x0_stack: np.ndarray) -> None:
        sim, n = self.sim, self.net.n
        self.n = n
        self.tail = x0_stack.shape[1:]
        self.x = x0_stack.copy()
        self.xhat = x0_stack.copy()
        self.t = np.zeros(n, dtype=np.int64)
        self.next_comm = np.full(n, sim.schedule.next_comm_step(0),
                                 dtype=np.int64)
        self.comm_iters = np.zeros(n, dtype=np.int64)
        self.local_step = np.array(
            [spec.scale / n for spec in self.net.node_specs],
            dtype=np.float64)
        if self.algorithm == "pushsum":
            self.y = (np.zeros_like(self.x) if sim.pushsum_y0 is None
                      else np.array(sim.pushsum_y0, dtype=np.float64))
            self.w = np.ones(n, dtype=np.float64)
            self.w_floor = sim.pushsum_w_floor
            self.sigma = _EdgeStore(n, self.tail, scalar=True)
            self.rho = _EdgeStore(n, self.tail, scalar=True)
        else:
            self.z = np.zeros_like(self.x)
            self.stamp = np.zeros((n, n), dtype=np.int64)
            self.val = _EdgeStore(n, self.tail)
            # sender-side error-feedback residuals (compressed gossip)
            self.comp_res = (np.zeros_like(self.x)
                             if sim.compression is not None else None)

    def _z_est_all(self) -> np.ndarray:
        if self.algorithm == "pushsum":
            return self.y / self._col(np.maximum(self.w, self.w_floor))
        return self.z

    def _schedule_steps(self, nodes: np.ndarray, fire: np.ndarray) -> None:
        """One 'steps' entry per distinct fire time (node order within).
        Under fault injection every entry snapshots each node's step
        generation so a crash/leave between scheduling and firing renders
        the entry stale (the object engine's per-event gen check)."""
        times, inv = np.unique(fire, return_inverse=True)
        fr = self._fr
        for u, tm in enumerate(times):
            sel = nodes[inv == u]
            if fr is None:
                self.q.schedule(float(tm), "steps", nodes=sel)
            else:
                self.q.schedule(float(tm), "steps", nodes=sel,
                                gens=fr.step_gen[sel].copy())

    # -- main loop ------------------------------------------------------------

    def run(self, x0_stack: np.ndarray, T: int, eval_every: int,
            time_limit: float) -> SimTrace:
        sim = self.sim
        n = self.net.n
        ctrl = self._ctrl = sim.controller
        if ctrl is not None:
            ctrl.bind(self.net)  # resets the schedule's splice history, so
            # it must run BEFORE _init_state caches next_comm answers
        self._init_state(x0_stack)
        self._rebuild_topology()
        self.rng = np.random.default_rng(sim.seed)
        self.q = q = EventQueue(backend="calendar")
        trace = SimTrace([], [], [], [], [])
        self._T = T
        net = self.net
        self._retry_on = (net.link.retries > 0
                          or any(l.retries > 0
                                 for l in net.link_overrides.values()))
        flt = None
        if sim.faults is not None:
            from repro.faults.runtime import FaultRuntime
            flt = FaultRuntime(sim.faults, n, tracer=sim.tracer)
        self._fr = flt

        nodes0 = np.arange(n, dtype=np.int64)
        busy0 = self.local_step + np.where(
            self.t + 1 == self.next_comm, self.send_busy, 0.0)
        self._schedule_steps(nodes0, busy0)
        if sim.scenario.rewire_every is not None:
            q.schedule(sim.scenario.rewire_every, "rewire")
        if flt is not None:
            flt.bind(self)
            flt.schedule_initial(q)

        self.total_steps = 0
        self.next_eval = eval_every * n
        self.active = n

        while not q.empty():
            ev = q.pop()
            if ev.time > time_limit:
                break
            if ev.kind == "steps":
                nodes = ev.data["nodes"]
                if flt is None:
                    # coalesce same-time step entries (consecutive by seq)
                    while (not q.empty() and q.peek().kind == "steps"
                           and q.peek().time == ev.time):
                        nodes = np.concatenate(
                            [nodes, q.pop().data["nodes"]])
                else:
                    # safe to coalesce under faults too: a same-time
                    # "fault" event (prio 1) pops BEFORE any "steps"
                    # (prio 3), so no fault can interleave mid-batch
                    gens = ev.data["gens"]
                    while (not q.empty() and q.peek().kind == "steps"
                           and q.peek().time == ev.time):
                        nxt = q.pop().data
                        nodes = np.concatenate([nodes, nxt["nodes"]])
                        gens = np.concatenate([gens, nxt["gens"]])
                    live = flt.alive[nodes] & (gens == flt.step_gen[nodes])
                    if not live.all():
                        nodes = nodes[live]
                        if len(nodes) == 0:
                            continue  # all stale: object engine skips too
                self._on_steps(nodes, T, trace, eval_every * n)
                if ctrl is not None and ctrl.retune_due(q.now):
                    alive = self.t < T  # frontier over still-active nodes
                    if flt is not None:
                        alive &= flt.alive & flt.member
                    cut = (ctrl.maybe_retune(
                        q.now, int(self.t[alive].max()) + 1)
                        if alive.any() else None)
                    if cut is not None:
                        stale = self.next_comm > cut
                        if stale.any():
                            self.next_comm[stale] = \
                                sim.schedule.next_comm_step_batch(
                                    self.t[stale])
            elif ev.kind == "msgs":
                data = ev.data
                if flt is not None:
                    keep = flt.alive[data["srcs"]] & flt.alive[data["dsts"]]
                    if not keep.all():
                        if not keep.any():
                            continue  # whole batch landed during downtime
                        data = {key: (val if key == "buf" else val[keep])
                                for key, val in data.items()}
                self._on_msgs(data)
            elif ev.kind == "retry":
                src, dst = ev.data["src"], ev.data["dst"]
                if flt is not None and (not flt.alive[src]
                                        or flt.blocked[src, dst]):
                    continue  # no RNG draw: state-identical on both engines
                self.sent += 1
                self.retransmits += 1
                flight = net.sample_flight(src, dst, self.rng)
                if flight is None:
                    self.drops += 1
                    attempt = ev.data["attempt"]
                    link = net.link_for(src, dst)
                    if attempt < link.retries:
                        q.schedule_in(
                            link.retry_timeout
                            * link.retry_backoff ** attempt,
                            "retry", src=src, dst=dst,
                            payload=ev.data["payload"], attempt=attempt + 1)
                else:
                    self._flight_chunks.append(np.array([flight]))
                    if self._tr is not None:
                        self._tr.add_span("flight", ev.time, flight,
                                          track="net", src=src, dst=dst,
                                          retry=True)
                    if ctrl is not None:
                        ctrl.on_messages(np.array([flight]))
                    # full flight in the air: no busy charge on retransmit
                    q.schedule_in(flight, "msgs",
                                  srcs=np.array([src], dtype=np.int64),
                                  dsts=np.array([dst], dtype=np.int64),
                                  **ev.data["payload"])
            elif ev.kind == "fault":
                flt.handle(q, ev.data)
            elif ev.kind == "rewire":
                self.net.rewire()
                self._rebuild_topology()
                self.rewires += 1
                if self._tr is not None:
                    self._tr.add_instant("rewire", ev.time, track="net")
                if ctrl is not None:
                    ctrl.on_rewire(self.net.graph)
                if self.active > 0:
                    q.schedule_in(sim.scenario.rewire_every, "rewire")

        if not trace.iters or trace.iters[-1] * n < self.total_steps:
            self._record(trace, q.now, self.total_steps)
        return trace

    def _record(self, trace: SimTrace, now: float, total_steps: int) -> None:
        if self._tr is not None:
            self._tr.add_instant("eval", now, track="net",
                                 steps=int(total_steps))
        mask = self._fr.record_mask() if self._fr is not None else None
        if self.sim.compression is not None:
            self.comp_res_norms.append(float(np.mean(np.linalg.norm(
                self.comp_res.reshape(self.n, -1), axis=1))))
        _record_stacks(self.sim, trace, now, total_steps, self.n, self.xhat,
                       self._z_est_all(), int(self.comm_iters.sum()),
                       mask=mask)

    # -- step processing ------------------------------------------------------

    def _on_steps(self, nodes: np.ndarray, T: int, trace: SimTrace,
                  eval_every_steps: int) -> None:
        """Drain a same-time batch of node steps, splitting at trace-record
        boundaries so a mid-batch `total_steps >= next_eval` crossing
        records exactly the state the object engine would have."""
        start, b = 0, len(nodes)
        while start < b:
            room = self.next_eval - self.total_steps
            chunk = nodes[start:start + min(room, b - start)]
            self._process_chunk(chunk, T)
            self.total_steps += len(chunk)
            start += len(chunk)
            if self.total_steps >= self.next_eval:
                self._record(trace, self.q.now, self.total_steps)
                self.next_eval += eval_every_steps

    def _process_chunk(self, due: np.ndarray, T: int) -> None:
        sim, now = self.sim, self.q.now
        i = due
        self._compute_chunks.append(self.local_step[i])
        if self._tr is not None:
            durs = self.local_step[i]
            self._tr.add_spans("step", now - durs, durs,
                               tracks=[f"node{j}" for j in i])
        if self._ctrl is not None:
            self._ctrl.on_steps(i, self.local_step[i])
        t_old = self.t[i]
        t_new = t_old + 1
        grads = sim._grad_batch.batch_or_loop(i, self.x[i], t_old)
        comm = t_new == self.next_comm[i]
        any_comm = bool(comm.any())
        if any_comm:
            ci = i[comm]
            if self.algorithm == "pushsum":
                self._comm_pushsum(ci)
            else:
                self._comm_dda(ci, t_new[comm], grads[comm])
            self.next_comm[ci] = sim.schedule.next_comm_step_batch(
                t_new[comm])
            self.comm_iters[ci] += 1
        if self.algorithm == "pushsum":
            if sim.pushsum_inject == "scaled":
                # w-scaled injection: a node holding little mass injects
                # proportionally little gradient (see PushSumDDANode)
                self.y[i] = self.y[i] + self._col(self.w[i]) * grads
            else:
                self.y[i] = self.y[i] + grads
            z_rows = self.y[i] / self._col(np.maximum(self.w[i],
                                                      self.w_floor))
        else:
            if (~comm).any():
                ni = i[~comm]
                self.z[ni] = self.z[ni] + grads[~comm]
            z_rows = self.z[i]
        a_t = self._a_batch(t_new)
        x_new = -self._col(a_t) * z_rows
        if self._proj is not None:
            x_new = self._proj(x_new)
        self.xhat[i] = (self._col(t_old) * self.xhat[i] + x_new) \
            / self._col(t_new)
        self.x[i] = x_new
        self.t[i] = t_new
        # reschedule survivors, grouped by their next fire time
        alive = t_new < T
        self.active -= int((~alive).sum())
        if alive.any():
            ai = i[alive]
            comm_next = (t_new[alive] + 1) == self.next_comm[ai]
            busy = self.local_step[ai] + np.where(comm_next,
                                                  self.send_busy[ai], 0.0)
            self._schedule_steps(ai, now + busy)

    def _mix_weight_slots(self) -> tuple[np.ndarray, np.ndarray] | None:
        """Per-slot stale-mix weights from `Network.mix_weights`, or None
        when no reweighted P is installed (the uniform fast path).

        Returns ((n, k) slot weights, (n,) self weights), folded through
        the shared `core.graphs.mix_weight_slots` convention (W[i, src] /
        multiplicity per slot) -- the same fold `AsyncDDANode._stale_mix`
        and the dense simulator's sparse gossip apply, keeping the engines
        and execution modes equivalent. Cached on the (W, S_in) object
        pair: a retune installs a new W, a rewire a new S_in; both
        invalidate.
        """
        W = self.net.mix_weights
        if W is None:
            return None
        hit = self._mw_cache
        if hit is None or hit[0] is not W or hit[1] is not self.S_in:
            from repro.core.graphs import mix_weight_slots
            w_slot, w_self = mix_weight_slots(W, self.S_in)
            self._mw_cache = hit = (W, self.S_in, w_slot, w_self)
        return hit[2], hit[3]

    def _comm_dda(self, ci: np.ndarray, stamps: np.ndarray,
                  grads: np.ndarray) -> None:
        """Communication iteration for a batch of stale-gossip DDA nodes:
        snapshot pre-mix z, ship it, then mix-with-latest + gradient."""
        k = self.k
        comp = self.sim.compression
        if comp is None:
            buf = self.z[ci].copy()  # one shared snapshot for all k messages
        else:
            # sender-side error feedback. `compress_np` is a pure function
            # of (row, node, stamp) -- per-message RNG is seeded from the
            # (compressor seed, node, stamp) triple, never drawn from the
            # engine stream -- so this row-at-a-time loop produces exactly
            # the payloads the object engine's per-node path does,
            # regardless of event interleaving (bit-identity contract).
            corrected = self.z[ci] + self.comp_res[ci]
            buf = np.stack([
                comp.compress_np(corrected[j], int(ci[j]), int(stamps[j]))
                for j in range(len(ci))])
            if comp.error_feedback:
                self.comp_res[ci] = corrected - buf
        # batched stale mix: accumulate in-neighbor slots in slot order,
        # folding never-delivered neighbors back into the self weight
        g = self.graph
        mw = self._mix_weight_slots()
        if mw is None:
            acc = np.zeros_like(buf)
            missing = np.zeros(len(ci), dtype=np.int64)
            for slot in range(k):
                srcs = self.S_in[ci, slot]
                st = self.stamp[ci, srcs]
                has = st > 0
                if has.any():
                    rows = self.val.eid[ci, srcs]
                    vals = self.val.y[np.where(has, rows, 0)]
                    acc += np.where(self._col(has), vals, 0.0)
                missing += ~has
            sw = g.self_weight + missing * g.edge_weight
            mixed = stale_combine_batch(self.z[ci], g.edge_weight * acc, sw)
        else:
            Wslot, Wdiag = mw
            acc = np.zeros_like(buf)
            sw = Wdiag[ci].copy()
            for slot in range(k):
                srcs = self.S_in[ci, slot]
                st = self.stamp[ci, srcs]
                has = st > 0
                w = Wslot[ci, slot]
                if has.any():
                    rows = self.val.eid[ci, srcs]
                    vals = self.val.y[np.where(has, rows, 0)]
                    acc += np.where(self._col(has),
                                    self._col(w) * vals, 0.0)
                sw += np.where(has, 0.0, w)
            mixed = stale_combine_batch(self.z[ci], acc, sw)
        self.z[ci] = mixed + grads
        srcs = np.repeat(ci, k)
        dsts = self.S_out[ci].ravel()
        self._ship(srcs, dsts, {
            "buf": buf,
            "rows": np.repeat(np.arange(len(ci), dtype=np.int64), k),
            "stamps": np.repeat(stamps, k)})

    def _comm_pushsum(self, ci: np.ndarray) -> None:
        """Communication iteration for a batch of push-sum nodes: split mass
        equally over self + out-links, bump each link's cumulative sigma,
        and ship the post-bump cumulative totals."""
        k = self.k
        share = 1.0 / (k + 1)
        y_sh = self.y[ci] * share
        w_sh = self.w[ci] * share
        b = len(ci)
        snap_y = np.empty((b, k) + self.tail, dtype=np.float64)
        snap_w = np.empty((b, k), dtype=np.float64)
        for slot in range(k):
            d_s = self.S_out[ci, slot]
            rows = self.sigma.rows(ci, d_s)
            self.sigma.y[rows] += y_sh
            self.sigma.w[rows] += w_sh
            snap_y[:, slot] = self.sigma.y[rows]
            snap_w[:, slot] = self.sigma.w[rows]
        self.y[ci] = y_sh
        self.w[ci] = w_sh
        srcs = np.repeat(ci, k)
        dsts = self.S_out[ci].ravel()
        self._ship(srcs, dsts, {
            "buf": snap_y.reshape((b * k,) + self.tail),
            "rows": np.arange(b * k, dtype=np.int64),
            "w": snap_w.ravel()})

    # -- message arrival ------------------------------------------------------

    def _on_msgs(self, data: dict[str, Any]) -> None:
        srcs, dsts = data["srcs"], data["dsts"]
        m = len(srcs)
        pairs = dsts.astype(np.int64) * self.n + srcs
        unique = len(np.unique(pairs)) == m
        if self.algorithm == "pushsum":
            self._recv_pushsum(srcs, dsts, data["buf"], data["rows"],
                               data["w"], unique)
        else:
            self._recv_dda(srcs, dsts, data["buf"], data["rows"],
                           data["stamps"], unique)

    def _recv_dda(self, srcs, dsts, buf, rows, stamps, unique: bool) -> None:
        if not unique:  # same link twice in one arrival batch: exact order
            for j in range(len(srcs)):
                s, d, st = int(srcs[j]), int(dsts[j]), int(stamps[j])
                if st > self.stamp[d, s]:
                    r = self.val.row1(d, s)
                    self.val.y[r] = buf[rows[j]]
                    self.stamp[d, s] = st
            return
        cur = self.stamp[dsts, srcs]
        upd = stamps > cur
        if not upd.any():
            return
        ds, ss = dsts[upd], srcs[upd]
        r = self.val.rows(ds, ss)
        self.val.y[r] = buf[rows[upd]]
        self.stamp[ds, ss] = stamps[upd]

    def _recv_pushsum(self, srcs, dsts, buf, rows, w, unique: bool) -> None:
        if not unique:
            for j in range(len(srcs)):
                s, d = int(srcs[j]), int(dsts[j])
                r = self.rho.row1(s, d)
                S_y, S_w = buf[rows[j]], float(w[j])
                if S_w >= self.rho.w[r]:
                    self.y[d] = self.y[d] + (S_y - self.rho.y[r])
                    self.w[d] += S_w - self.rho.w[r]
                    self.rho.y[r] = S_y
                    self.rho.w[r] = S_w
            return
        r = self.rho.rows(srcs, dsts)
        ok = w >= self.rho.w[r]  # ignore out-of-order older messages
        if not ok.any():
            return
        rr = r[ok]
        S_y = buf[rows[ok]]
        S_w = w[ok]
        d_ok = dsts[ok]
        np.add.at(self.y, d_ok, S_y - self.rho.y[rr])
        np.add.at(self.w, d_ok, S_w - self.rho.w[rr])
        self.rho.y[rr] = S_y
        self.rho.w[rr] = S_w

    # -- fault-injection adapter (driven by repro.faults.FaultRuntime) -------
    # Mirrors ObjectEngine's surface; every method performs the exact same
    # float ops on the SoA rows the object engine performs on its node
    # objects, so fault handling preserves the bit-identity contract.

    def fault_state(self) -> dict:
        return {"x": self.x.copy(), "xhat": self.xhat.copy(),
                "z": self.z.copy(), "t": self.t.copy(),
                "comm_iters": self.comm_iters.copy()}

    def fault_apply_node(self, j: int, row: dict) -> None:
        self.x[j] = row["x"]
        self.xhat[j] = row["xhat"]
        self.z[j] = row["z"]
        self.t[j] = int(row["t"])
        self.comm_iters[j] = int(row["comm_iters"])
        self.next_comm[j] = int(row["next_comm"])

    def fault_clear_inbox(self, j: int) -> None:
        # stamp == 0 reads as "never delivered": receivers fold j's weight
        # into their self-loop and j restarts with an empty inbox (the
        # pooled values go stale-unreachable until a fresh stamp lands)
        self.stamp[j, :] = 0
        self.stamp[:, j] = 0

    def fault_deactivate(self, j: int) -> None:
        if self.t[j] < self._T:
            self.active -= 1

    def fault_activate(self, j: int) -> None:
        if self.t[j] < self._T:
            self.active += 1
            busy = self.local_step[j] + (
                self.send_busy[j]
                if self.t[j] + 1 == self.next_comm[j] else 0.0)
            self.q.schedule_in(
                float(busy), "steps",
                nodes=np.array([j], dtype=np.int64),
                gens=np.array([self._fr.step_gen[j]], dtype=np.int64))

    def fault_next_comm(self, t: int) -> int:
        return int(self.sim.schedule.next_comm_step(int(t)))

    def fault_splice_graph(self, g) -> None:
        from repro.core.graphs import GraphSequence
        self.net.seq = GraphSequence((g,))
        self.net.epoch = 0
        self.net._out_cache.clear()
        self._epoch_cache.clear()
        self._mw_cache = None
        self._rebuild_topology()

    def fault_notify_membership(self, sub_graph, members) -> None:
        if self._ctrl is not None:
            self._ctrl.on_membership(sub_graph, members)

    def fault_notify_heal(self, now: float) -> None:
        if self._ctrl is not None:
            self._ctrl.on_partition_heal(now)

    # -- interop with the object world ---------------------------------------

    def materialize_nodes(self) -> list:
        """Build per-node objects mirroring the SoA state, so diagnostics
        written against the object engine (`pushsum_mass_audit`, direct
        `.z_est` reads) keep working after a vectorized run."""
        sim, n = self.sim, self.n
        nodes: list[AsyncDDANode | PushSumDDANode] = []
        for i in range(n):
            if self.algorithm == "pushsum":
                node = PushSumDDANode(i, self.x[i], sim.grad_fn, sim.a_fn,
                                      sim.schedule, sim.projection,
                                      w_floor=self.w_floor,
                                      inject=sim.pushsum_inject)
                node.y = self.y[i].copy()
                node.w = float(self.w[i])
                for dst in np.nonzero(self.sigma.eid[i] >= 0)[0]:
                    r = self.sigma.eid[i, dst]
                    node.sigma_y[int(dst)] = self.sigma.y[r].copy()
                    node.sigma_w[int(dst)] = float(self.sigma.w[r])
                for src in np.nonzero(self.rho.eid[:, i] >= 0)[0]:
                    r = self.rho.eid[src, i]
                    node.rho_y[int(src)] = self.rho.y[r].copy()
                    node.rho_w[int(src)] = float(self.rho.w[r])
            else:
                node = AsyncDDANode(i, self.x[i], sim.grad_fn, sim.a_fn,
                                    sim.schedule, sim.projection,
                                    compression=sim.compression)
                node.z = self.z[i].copy()
                if sim.compression is not None:
                    node._comp_res = self.comp_res[i].copy()
                for src in np.nonzero(self.stamp[i] > 0)[0]:
                    r = self.val.eid[i, src]
                    node.inbox[int(src)] = (int(self.stamp[i, src]),
                                            self.val.y[r].copy())
            node.x = self.x[i].copy()
            node.xhat = self.xhat[i].copy()
            node.t = int(self.t[i])
            node.next_comm = int(self.next_comm[i])
            node.comm_iters = int(self.comm_iters[i])
            nodes.append(node)
        return nodes
