"""Scenario presets for the event-driven cluster simulator.

A `Scenario` bundles everything the driver needs about the *cluster*
(topology, link models, node speeds, message size) -- the *problem*
(gradients, objective, stepsize) stays with `NetSimulator`. All presets are
parameterized by the paper's r: the per-message transmit time in full-grad
units, realized as link bandwidth = message_bytes / r so that a lossless
homogeneous run reproduces eq. (9)'s 1/n + k*r per-iteration cost exactly.
Every preset accepts `graph=` to override its default topology with a
prebuilt CommGraph/GraphSequence -- the repro.experiments runner resolves
topologies through its registry and hands the built graph in.

Presets:
  * homogeneous            -- identical nodes, perfect links (the paper's
                              idealized cluster; calibration baseline).
  * straggler              -- `n_slow` nodes compute `slow_factor`x slower
                              (section I's "unrelated tasks" motivation).
  * lossy                  -- i.i.d. packet loss on every link.
  * time_varying_expander  -- the expander is rewired every `rewire_every`
                              time units (PAPERS.md: Yarmoshik-Klimenko
                              time-varying-network regime).
  * adversarial            -- everything at once: packet loss on every link,
                              `n_slow` stragglers, and periodic rewiring.
                              The worst cluster the model can express; used
                              as the engine-equivalence stress scenario
                              (tests/test_netsim_engine.py).
"""

from __future__ import annotations

import dataclasses

from repro.core.graphs import (CommGraph, GraphSequence, expander_sequence,
                               kregular_expander)
from repro.netsim.network import LinkModel, Network, NodeSpec

__all__ = [
    "Scenario",
    "homogeneous",
    "straggler",
    "lossy",
    "time_varying_expander",
    "adversarial",
]

DEFAULT_MESSAGE_BYTES = 800.0  # a 100-double dual vector


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    topology: CommGraph | GraphSequence
    link: LinkModel
    node_specs: tuple[NodeSpec, ...]
    message_bytes: float = DEFAULT_MESSAGE_BYTES
    rewire_every: float | None = None   # sim-time between topology epochs

    @property
    def n(self) -> int:
        return self.topology.n

    def build_network(self) -> Network:
        return Network(self.topology, self.link, list(self.node_specs),
                       self.message_bytes)


def _link_for_r(r: float, message_bytes: float, *, latency: float = 0.0,
                jitter: float = 0.0, loss: float = 0.0, retries: int = 0,
                retry_timeout: float = 0.0) -> LinkModel:
    """Bandwidth such that one message serializes in exactly r time units."""
    if r < 0:
        raise ValueError("r must be >= 0")
    bw = message_bytes / r if r > 0 else float("inf")
    return LinkModel(latency=latency, bandwidth=bw, jitter=jitter, loss=loss,
                     retries=retries, retry_timeout=retry_timeout)


def _graph(n: int, k: int, seed: int) -> CommGraph:
    return kregular_expander(n, k=k, seed=seed)


def homogeneous(n: int, r: float, k: int = 4, seed: int = 0,
                message_bytes: float = DEFAULT_MESSAGE_BYTES,
                graph: CommGraph | None = None) -> Scenario:
    return Scenario(
        name="homogeneous",
        topology=graph if graph is not None else _graph(n, k, seed),
        link=_link_for_r(r, message_bytes),
        node_specs=tuple(NodeSpec() for _ in range(n)),
        message_bytes=message_bytes)


def straggler(n: int, r: float, slow_factor: float = 4.0, n_slow: int = 1,
              k: int = 4, seed: int = 0,
              message_bytes: float = DEFAULT_MESSAGE_BYTES,
              graph: CommGraph | GraphSequence | None = None) -> Scenario:
    if not 0 <= n_slow <= n:
        raise ValueError(f"n_slow must be in [0, {n}]")
    specs = tuple(NodeSpec.slowed(slow_factor) if i < n_slow else NodeSpec()
                  for i in range(n))
    return Scenario(
        name=f"straggler{slow_factor:g}x{n_slow}",
        topology=graph if graph is not None else _graph(n, k, seed),
        link=_link_for_r(r, message_bytes),
        node_specs=specs,
        message_bytes=message_bytes)


def lossy(n: int, r: float, loss: float = 0.2, k: int = 4, seed: int = 0,
          jitter: float = 0.0,
          message_bytes: float = DEFAULT_MESSAGE_BYTES,
          retries: int = 0, retry_timeout: float = 0.0,
          graph: CommGraph | GraphSequence | None = None) -> Scenario:
    return Scenario(
        name=f"lossy{loss:g}",
        topology=graph if graph is not None else _graph(n, k, seed),
        link=_link_for_r(r, message_bytes, jitter=jitter, loss=loss,
                         retries=retries, retry_timeout=retry_timeout),
        node_specs=tuple(NodeSpec() for _ in range(n)),
        message_bytes=message_bytes)


def adversarial(n: int, r: float, loss: float = 0.2,
                slow_factor: float = 4.0, n_slow: int = 1,
                rewire_every: float | None = None,
                k: int = 4, length: int = 4, seed: int = 0,
                message_bytes: float = DEFAULT_MESSAGE_BYTES,
                retries: int = 0, retry_timeout: float = 0.0,
                graph: CommGraph | GraphSequence | None = None) -> Scenario:
    """Loss + stragglers + (optionally) a time-varying topology, together."""
    if not 0 <= n_slow <= n:
        raise ValueError(f"n_slow must be in [0, {n}]")
    specs = tuple(NodeSpec.slowed(slow_factor) if i < n_slow else NodeSpec()
                  for i in range(n))
    topology: CommGraph | GraphSequence
    if graph is not None:
        topology = graph
    elif rewire_every is not None:
        topology = expander_sequence(n, k=k, length=length, seed=seed)
    else:
        topology = _graph(n, k, seed)
    return Scenario(
        name=f"adversarial_l{loss:g}_s{slow_factor:g}x{n_slow}",
        topology=topology,
        link=_link_for_r(r, message_bytes, loss=loss,
                         retries=retries, retry_timeout=retry_timeout),
        node_specs=specs,
        message_bytes=message_bytes,
        rewire_every=rewire_every)


def time_varying_expander(n: int, r: float, rewire_every: float,
                          k: int = 4, length: int = 4, seed: int = 0,
                          loss: float = 0.0,
                          message_bytes: float = DEFAULT_MESSAGE_BYTES,
                          graph: CommGraph | GraphSequence | None = None
                          ) -> Scenario:
    return Scenario(
        name=f"timevarying_T{rewire_every:g}",
        topology=(graph if graph is not None
                  else expander_sequence(n, k=k, length=length, seed=seed)),
        link=_link_for_r(r, message_bytes, loss=loss),
        node_specs=tuple(NodeSpec() for _ in range(n)),
        message_bytes=message_bytes,
        rewire_every=rewire_every)
