"""Cluster model: heterogeneous nodes + lossy/jittery point-to-point links.

Everything is expressed in the paper's normalized time units (one full-data
gradient on the REFERENCE node = 1.0), so a link configured with
`serialize == r` reproduces eq. (9)'s `k * r` per-communication cost exactly
and the event timeline stays directly comparable to `core.tradeoff`.

  * `LinkModel`   -- per-link latency / bandwidth / jitter / packet loss.
  * `NodeSpec`    -- per-node compute speed, derived from a
                     `core.tradeoff.HardwareSpec` relative to a reference
                     spec (compute-bound assumption), or overridden
                     directly with `compute_scale` (straggler factor).
  * `Network`     -- the topology (a `CommGraph` or a time-varying
                     `GraphSequence`), link models with per-edge overrides,
                     and message transmission sampling.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.graphs import CommGraph, GraphSequence
from repro.core.tradeoff import TPU_V5E, HardwareSpec

__all__ = ["LinkModel", "NodeSpec", "Network"]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One directed link. All times in normalized units.

    latency:   propagation delay added to every message.
    bandwidth: bytes per time unit; serialization time = bytes / bandwidth.
               `math.inf` means serialization is free.
    jitter:    mean of an exponential extra delay (0 disables).
    loss:      i.i.d. packet drop probability in [0, 1).

    Bounded retransmission (ack + timeout, the operational form of
    "deadline gossip"): with `retries > 0`, a dropped message is re-sent up
    to `retries` times, attempt k firing `retry_timeout * retry_backoff**
    (k-1)` after the previous drop (exponential backoff). Retransmits do
    NOT occupy the sender's NIC busy time -- the engines model them as
    background re-sends whose full flight time is in the air -- and are
    counted separately (`NetSimulator.retransmits`).

    retries:       max retransmit attempts per message (0 disables).
    retry_timeout: delay before the first retransmit (> 0 when retries > 0).
    retry_backoff: multiplicative backoff per attempt (>= 1).
    """

    latency: float = 0.0
    bandwidth: float = math.inf
    jitter: float = 0.0
    loss: float = 0.0
    retries: int = 0
    retry_timeout: float = 0.0
    retry_backoff: float = 2.0

    def __post_init__(self):
        if not 0.0 <= self.loss < 1.0:
            raise ValueError(f"loss must be in [0, 1), got {self.loss}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.retries > 0 and not self.retry_timeout > 0.0:
            raise ValueError("retries > 0 needs retry_timeout > 0")
        if self.retry_backoff < 1.0:
            raise ValueError(
                f"retry_backoff must be >= 1, got {self.retry_backoff}")

    def serialize(self, nbytes: float) -> float:
        """Sender NIC occupancy per message (the paper's per-message r when
        latency == jitter == 0)."""
        return nbytes / self.bandwidth if math.isfinite(self.bandwidth) else 0.0

    def sample_flight(self, nbytes: float,
                      rng: np.random.Generator) -> float | None:
        """Send-to-arrival delay for one message, or None if dropped."""
        if self.loss > 0.0 and rng.random() < self.loss:
            return None
        flight = self.serialize(nbytes) + self.latency
        if self.jitter > 0.0:
            flight += rng.exponential(self.jitter)
        return flight


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Per-node compute speed.

    `compute_scale` multiplies the node's local-step time (1.0 = reference
    speed, 4.0 = a 4x straggler). When None it is derived from the node's
    `HardwareSpec` peak FLOPs relative to `ref` (compute-bound local steps;
    memory-bound workloads should set `compute_scale` explicitly from their
    roofline, see tradeoff.derive_r_from_roofline).
    """

    hw: HardwareSpec = TPU_V5E
    compute_scale: float | None = None
    ref: HardwareSpec = TPU_V5E

    @property
    def scale(self) -> float:
        if self.compute_scale is not None:
            return self.compute_scale
        return self.ref.peak_flops / self.hw.peak_flops

    @staticmethod
    def slowed(factor: float) -> "NodeSpec":
        """A straggler: same chip family, `factor`x less effective compute
        (e.g. co-scheduled unrelated work, the paper's section I motivation)."""
        return NodeSpec(hw=dataclasses.replace(
            TPU_V5E, peak_flops=TPU_V5E.peak_flops / factor))


class Network:
    """Topology + links + node speeds; the netsim's world model."""

    def __init__(self, topology: CommGraph | GraphSequence,
                 link: LinkModel = LinkModel(),
                 node_specs: list[NodeSpec] | None = None,
                 message_bytes: float = 8.0,
                 link_overrides: dict[tuple[int, int], LinkModel] | None = None):
        if isinstance(topology, CommGraph):
            topology = GraphSequence((topology,))
        self.seq = topology
        self.epoch = 0
        self.link = link
        self.message_bytes = float(message_bytes)
        # Bytes that actually cross the wire per message. Equal to
        # `message_bytes` uncompressed; `NetSimulator` scales it by the
        # attached compressor's `wire_ratio` so bandwidth-limited links
        # (LinkModel.serialize) genuinely feel the compression ratio,
        # while `message_bytes` stays the calibration constant scenarios
        # derive link bandwidth from (bw = message_bytes / r).
        self.wire_bytes = self.message_bytes
        self.link_overrides = dict(link_overrides or {})
        n = topology.n
        self.node_specs = list(node_specs or [NodeSpec()] * n)
        if len(self.node_specs) != n:
            raise ValueError(
                f"need {n} node specs, got {len(self.node_specs)}")
        self._out_cache: dict[int, list[list[int]]] = {}
        # Optional (n, n) override of the stale-gossip mixing weights: when
        # set (by an AdaptiveController with reweight_gossip=True), row i of
        # this matrix replaces the graph's uniform self/edge weights in the
        # nodes' stale mix -- the straggler-aware effective P acts on the
        # ACTUAL gossip, not just on the lambda2 estimate. None (the
        # default) keeps the configured uniform weights and the engines'
        # bit-identity contract untouched. Must be row-stochastic with the
        # current graph's support; weight of undelivered neighbors still
        # folds into the self weight, so rows stay convex combinations.
        self.mix_weights: np.ndarray | None = None

    @property
    def n(self) -> int:
        return self.seq.n

    @property
    def graph(self) -> CommGraph:
        return self.seq.at(self.epoch)

    def rewire(self) -> CommGraph:
        """Advance to the next graph in the time-varying sequence."""
        self.epoch += 1
        return self.graph

    # -- topology queries ---------------------------------------------------

    def in_neighbors(self, i: int) -> list[int]:
        """Sources node i receives from, one entry per permutation slot
        (the mixing weight is edge_weight per slot)."""
        g = self.graph
        return [perm[i] for perm in g.perms]

    def out_neighbors(self, i: int) -> list[int]:
        """Destinations node i sends to (one message per slot per round)."""
        idx = self.epoch % len(self.seq)
        if idx not in self._out_cache:
            g = self.seq.at(idx)
            out: list[list[int]] = [[] for _ in range(g.n)]
            for perm in g.perms:
                for dst in range(g.n):
                    out[perm[dst]].append(dst)
            self._out_cache[idx] = out
        return self._out_cache[idx][i]

    # -- timing -------------------------------------------------------------

    def link_for(self, src: int, dst: int) -> LinkModel:
        return self.link_overrides.get((src, dst), self.link)

    def serialize_time(self, src: int, dst: int) -> float:
        return self.link_for(src, dst).serialize(self.wire_bytes)

    def send_busy_time(self, i: int) -> float:
        """NIC occupancy for one full gossip round from node i (the k*r
        term of eq. 9): messages leave serially over the node's uplink."""
        return sum(self.serialize_time(i, d) for d in self.out_neighbors(i))

    def sample_flight(self, src: int, dst: int,
                      rng: np.random.Generator) -> float | None:
        return self.link_for(src, dst).sample_flight(self.wire_bytes, rng)

    def local_step_time(self, i: int) -> float:
        """One local (sub)gradient step on node i's 1/n data shard."""
        return self.node_specs[i].scale / self.n
